"""Serving with LMB-backed KV capacity: more in-flight KV than "HBM".

Submits a burst of requests whose combined KV exceeds the onboard page
budget; cold sequences spill to the LMB pool, requests still finish, and
two requests share a common prompt prefix zero-copy (fork).

Run:  PYTHONPATH=src python examples/serve_paged.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core import DeviceSpec, HostSpec, LMBSystem, SystemSpec
from repro.models import build_model
from repro.models.flags import Flags
from repro.serve import EngineConfig, ServeEngine, SubmitSpec

cfg = get_config("h2o-danube-3-4b").reduced()
model = build_model(cfg, Flags(remat=False))
params = model.init(jax.random.key(0))

system = LMBSystem(SystemSpec(
    expanders=1, pool_gib=4,
    hosts=(HostSpec("server", page_bytes=4096),),
    devices=(DeviceSpec("tpu0"),)))

eng = ServeEngine(model, params, system, EngineConfig(
    decode_slots=3, max_seq_len=96, page_tokens=8,
    onboard_pages=6,          # deliberately tiny HBM-tier budget
    prefill_bucket=16))

rng = np.random.default_rng(0)
rids = [eng.submit(SubmitSpec(
            prompt=rng.integers(0, cfg.vocab_size, int(n)),
            max_new_tokens=8))
        for n in rng.integers(8, 40, 8)]
eng.run()

st = eng.stats()
print("all done:", all(eng.requests[r].state == "done" for r in rids))
print("kv stats:", st["kv"])
c = eng.kv.buf.metrics.tier(eng.kv.buf.name, "onboard")
print(f"onboard hit ratio {c.hit_ratio:.2f}  "
      f"(misses={c.misses} -> paged via LMB pool)")

# zero-copy prefix fork (Table-2 share applied to KV pages)
sid = eng.kv.new_seq()
import jax.numpy as jnp
L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim_
eng.kv.append_tokens(sid, jnp.ones((L, 2, 16, KV, hd),
                                   jnp.dtype(cfg.dtype)))
fork = eng.kv.fork(sid)
print(f"forked seq {sid} -> {fork} with zero new LMB bytes "
      f"(owned={system.host().owned_bytes('tpu0')})")
