"""Reproduce the paper's Figure 6 (LMB vs Ideal vs DFTL on Gen4/Gen5 SSDs).

Run:  PYTHONPATH=src python examples/ssd_sim.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.sim import make_ssd_model, make_workload, simulate
from repro.sim.ssd import make_schemes
from repro.sim.workload import ALL_PAPER_WORKLOADS

for gen in (4, 5):
    spec = make_ssd_model(gen)
    schemes = make_schemes(spec)
    print(f"\n=== PCIe Gen{gen} SSD (Fig 6{'a' if gen == 4 else 'b'}) ===")
    print(f"{'workload':<10}" + "".join(f"{s:>16}" for s in schemes))
    for wl_name in ALL_PAPER_WORKLOADS:
        wl = make_workload(wl_name, n_ios=100_000)
        ideal = simulate(spec, schemes["ideal"], wl).iops
        cells = []
        for sname, scheme in schemes.items():
            r = simulate(spec, scheme, wl)
            cells.append(f"{r.iops/1e3:7.0f}K {r.iops/ideal*100:4.0f}%")
        print(f"{wl_name:<10}" + "".join(f"{c:>16}" for c in cells))

print("""
Paper anchors: Gen4 writes LMB==Ideal, DFTL ~7-8x worse; Gen4 reads
LMB-PCIe -13..17%; Gen5 randread LMB-CXL -56%, LMB-PCIe -70%;
all LMB schemes >10x DFTL.""")
