"""Quickstart: the LMB client API in 60 lines.

Declares the whole stack in one SystemSpec (expanders, host, a PCIe SSD
and a CXL accelerator), opens an LMBSystem session, exercises typed
MemoryHandle capabilities (alloc / share / free — device-class-agnostic,
no raw mmids), then backs an SSD's L2P index with a LinkedBuffer and
shows tier traffic.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import (DeviceClass, DeviceSpec, ExpanderSpec, LMBSystem,
                        StaleHandle, SystemSpec)

# --- the whole fabric, declaratively: one 8 GiB expander, one host, two
# --- devices; the session owns FM/host/arbiter wiring and frees every
# --- live grant when the with-block ends
spec = SystemSpec(
    expanders=(ExpanderSpec(gib=8),),
    hosts=("host0",),
    devices=(DeviceSpec("ssd0"),                            # PCIe (default)
             DeviceSpec("accel0", DeviceClass.CXL, spid=0x11)))

with LMBSystem(spec) as system:
    # --- capability API: alloc/share/free dispatch on DeviceClass -------
    with system.alloc("ssd0", 64 << 20) as h:   # SSD takes 64 MiB
        print(f"alloc  -> {h}")
        print(f"          pcie bus_addr={h.bus_addr:#x} != hpa={h.hpa:#x}"
              "  (IOVA window)")

        peer = h.share("accel0")                # zero-copy share
        print(f"share  -> accel0 sees hpa={peer.hpa:#x} "
              f"bus_addr={peer.bus_addr:#x} dpid={peer.dpid} (same region)")
    # leaving the with-block freed the grant (and revoked accel0's map)
    print(f"free   -> fm holds {system.fm.held_bytes('host0')} bytes "
          "(block returned)")
    try:
        peer.expander()
    except StaleHandle as e:
        print(f"stale  -> {e}")

    # --- LinkedBuffer: an L2P table bigger than onboard DRAM ------------
    # 64 logical pages of mapping entries; only 8 fit "onboard".
    l2p = system.buffer(name="l2p", device_id="ssd0",
                        page_shape=(1024,), dtype=jnp.uint32,
                        onboard_pages=8, policy="clock", prefetch_depth=2)
    pages = l2p.append_pages(64)
    for p in pages:                                # populate the index
        l2p.write(p, np.full((1024,), p, np.uint32))

    rng = np.random.default_rng(0)
    for lba in rng.zipf(1.5, 2000):                # hot/cold lookups
        page = int(lba) % 64
        entry = l2p.read(page)                     # faults cold pages in
        assert int(entry[0]) == page

    print("l2p stats:", l2p.stats())
    print("fm snapshot:", system.snapshot())
