"""Quickstart: the LMB core in 60 lines.

Builds a fabric (expander + FM), registers a PCIe SSD and a CXL
accelerator, exercises the Table-2 API (alloc / share / free), then backs
an SSD's L2P index with a LinkedBuffer and shows tier traffic.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import (DeviceClass, DeviceInfo, LMBHost, LinkedBuffer,
                        make_default_fabric)

# --- fabric: one 8 GiB expander behind a switch, managed by the FM ------
fm, expander = make_default_fabric(pool_gib=8)
fm.bind_host("host0")
fm.register_device(DeviceInfo("ssd0", DeviceClass.PCIE))
fm.register_device(DeviceInfo("accel0", DeviceClass.CXL, spid=0x11))
lmb = LMBHost(fm, "host0")

# --- Table-2 API ---------------------------------------------------------
a = lmb.lmb_pcie_alloc("ssd0", 64 << 20)          # SSD takes 64 MiB
print(f"alloc  -> mmid={a.mmid} hpa={a.hpa:#x} bytes={a.nbytes}")

s = lmb.lmb_pcie_share("ssd0", a.mmid, "accel0")  # zero-copy share
print(f"share  -> accel0 sees hpa={s.hpa:#x} dpid={s.dpid} (same region)")

lmb.lmb_cxl_free("accel0", a.mmid)                # sharer drops mapping
lmb.lmb_pcie_free("ssd0", a.mmid)                 # owner frees; block
print(f"free   -> fm holds {fm.held_bytes('host0')} bytes (block returned)")

# --- LinkedBuffer: an L2P table bigger than onboard DRAM -----------------
# 64 logical pages of mapping entries; only 8 fit "onboard".
l2p = LinkedBuffer(name="l2p", device_id="ssd0", host=lmb,
                   page_shape=(1024,), dtype=jnp.uint32,
                   onboard_pages=8, policy="clock", prefetch_depth=2)
pages = l2p.append_pages(64)
for p in pages:                                    # populate the index
    l2p.write(p, np.full((1024,), p, np.uint32))

hits = misses = 0
rng = np.random.default_rng(0)
for lba in rng.zipf(1.5, 2000):                    # hot/cold lookups
    page = int(lba) % 64
    entry = l2p.read(page)                         # faults cold pages in
    assert int(entry[0]) == page

print("l2p stats:", l2p.stats())
print("fm snapshot:", fm.snapshot())
