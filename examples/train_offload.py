"""End-to-end training driver with LMB extras.

Trains a ~100M-parameter qwen2-family model for a few hundred steps on
the synthetic corpus with:
  * checkpoint/restart (kill it mid-run and re-run: it resumes),
  * optimizer state parked in the LMB tier between steps,
  * int8 error-feedback gradient compression.

Run:  PYTHONPATH=src python examples/train_offload.py [--steps 300]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.configs.base import get_config, register
from repro.launch.train import run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    # ~100M-class config in the qwen2 family
    base = get_config("qwen2-1.5b")
    cfg = dataclasses.replace(
        base, name="qwen2-100m", num_layers=8, d_model=512, num_heads=8,
        num_kv_heads=2, d_ff=2048, vocab_size=8192, head_dim=64,
        dtype="float32", remat=False)
    register(cfg)
    print(f"params ~= {cfg.param_count()/1e6:.0f}M")

    out = run("qwen2-100m", steps=args.steps, global_batch=8, seq_len=256,
              ckpt_dir=args.ckpt, ckpt_every=50, reduced=False,
              offload_opt=True, compress_grads=True, lr=3e-4)
    print(f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
          f"({out['steps']} steps, {out['wall_s']:.0f}s)")


if __name__ == "__main__":
    main()
