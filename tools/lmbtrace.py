"""Inspect LMB trace artifacts (Chrome-trace JSON or span JSONL).

Usage:
    python tools/lmbtrace.py summary TRACE.json
    python tools/lmbtrace.py diff OLD.json NEW.json

``summary`` prints the figures the paper's evaluation turns on, straight
from the span stream:

  * span counts per name (fault / evict.batch / prefetch.burst / ...),
  * per-op-class byte totals over ``link.xfer`` spans — these reconcile
    exactly with ``FabricManager.op_bytes()`` because both accrue at the
    same arbiter call,
  * per-failure-domain link bytes (spans tagged with a rack topology
    ``domain``) — the blast-radius view: how much traffic rides links
    that one switch/power-domain failure would take out together,
  * the hidden fraction: prefetch link seconds over total link seconds
    (durations of ``link.xfer`` spans are MODELED virtual delay, so the
    figure is machine-independent),
  * per-tenant link-wait p50/p99 over ``link.xfer`` spans carrying a
    tenant tag.

``diff`` prints the same summary for two traces side by side with
deltas — the before/after view for an optimization PR.

Exit code 1 if the trace is empty or unreadable (CI smoke gate).
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import numpy as np  # noqa: E402

from repro.obs.export import load_trace  # noqa: E402
from repro.obs.trace import Span  # noqa: E402


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024
    return f"{n:.1f} GiB"


def summarize(spans: List[Span]) -> dict:
    """The summary dict ``summary``/``diff`` render (and tests assert)."""
    names: Dict[str, int] = {}
    op_bytes: Dict[str, int] = {}
    op_secs: Dict[str, float] = {}
    domain_bytes: Dict[str, int] = {}
    tenant_waits: Dict[str, List[float]] = {}
    for s in spans:
        names[s.name] = names.get(s.name, 0) + 1
        if s.name != "link.xfer":
            continue
        op = s.op or "unknown"
        op_bytes[op] = op_bytes.get(op, 0) + s.nbytes
        op_secs[op] = op_secs.get(op, 0.0) + s.dur
        dom = s.args.get("domain")
        if dom is not None:
            domain_bytes[dom] = domain_bytes.get(dom, 0) + s.nbytes
        if s.tenant is not None:
            tenant_waits.setdefault(s.tenant, []).append(s.dur)
    total_s = sum(op_secs.values())
    hidden = (op_secs.get("prefetch", 0.0) / total_s) if total_s else None
    tenants = {}
    for tenant, waits in sorted(tenant_waits.items()):
        arr = np.asarray(waits)
        tenants[tenant] = {
            "n": len(waits),
            "p50_s": float(np.percentile(arr, 50)),
            "p99_s": float(np.percentile(arr, 99)),
        }
    return {
        "spans": len(spans),
        "names": dict(sorted(names.items())),
        "op_bytes": dict(sorted(op_bytes.items())),
        "op_secs": dict(sorted(op_secs.items())),
        "domain_bytes": dict(sorted(domain_bytes.items())),
        "hidden_fraction": hidden,
        "tenants": tenants,
    }


def print_summary(summary: dict, label: Optional[str] = None) -> None:
    if label:
        print(f"== {label} ==")
    print(f"spans: {summary['spans']}")
    for name, n in summary["names"].items():
        print(f"  {name:<20s} {n}")
    if summary["op_bytes"]:
        print("link bytes by op class (== FabricManager.op_bytes()):")
        for op, nb in summary["op_bytes"].items():
            secs = summary["op_secs"][op]
            print(f"  {op:<10s} {_fmt_bytes(nb):>12s}  "
                  f"{secs * 1e3:8.3f} ms modeled")
    if summary.get("domain_bytes"):
        print("link bytes by failure domain (rack topology):")
        for dom, nb in summary["domain_bytes"].items():
            print(f"  {dom:<10s} {_fmt_bytes(nb):>12s}")
    if summary["hidden_fraction"] is not None:
        print(f"hidden fraction (prefetch link-s / total link-s): "
              f"{summary['hidden_fraction']:.3f}")
    if summary["tenants"]:
        print("per-tenant link wait:")
        for tenant, t in summary["tenants"].items():
            print(f"  {tenant:<12s} n={t['n']:<6d} "
                  f"p50={t['p50_s'] * 1e6:9.2f} us  "
                  f"p99={t['p99_s'] * 1e6:9.2f} us")


def _delta(old: Optional[float], new: Optional[float]) -> str:
    if old is None or new is None:
        return "n/a"
    if old == 0:
        return "n/a" if new == 0 else "+inf"
    return f"{(new - old) / old * 100:+.1f}%"


def print_diff(old: dict, new: dict) -> None:
    print(f"{'metric':<32s} {'old':>14s} {'new':>14s} {'delta':>8s}")
    print(f"{'spans':<32s} {old['spans']:>14d} {new['spans']:>14d} "
          f"{_delta(old['spans'], new['spans']):>8s}")
    for op in sorted(set(old["op_bytes"]) | set(new["op_bytes"])):
        o, n = old["op_bytes"].get(op, 0), new["op_bytes"].get(op, 0)
        print(f"{'bytes.' + op:<32s} {_fmt_bytes(o):>14s} "
              f"{_fmt_bytes(n):>14s} {_delta(o, n):>8s}")
    for dom in sorted(set(old.get("domain_bytes", {}))
                      | set(new.get("domain_bytes", {}))):
        o = old.get("domain_bytes", {}).get(dom, 0)
        n = new.get("domain_bytes", {}).get(dom, 0)
        print(f"{'bytes.domain.' + dom:<32s} {_fmt_bytes(o):>14s} "
              f"{_fmt_bytes(n):>14s} {_delta(o, n):>8s}")
    for op in sorted(set(old["op_secs"]) | set(new["op_secs"])):
        o = old["op_secs"].get(op, 0.0)
        n = new["op_secs"].get(op, 0.0)
        print(f"{'link_s.' + op:<32s} {o:>14.6f} {n:>14.6f} "
              f"{_delta(o, n):>8s}")
    o, n = old["hidden_fraction"], new["hidden_fraction"]
    print(f"{'hidden_fraction':<32s} "
          f"{('%.3f' % o) if o is not None else 'n/a':>14s} "
          f"{('%.3f' % n) if n is not None else 'n/a':>14s} "
          f"{_delta(o, n):>8s}")
    for tenant in sorted(set(old["tenants"]) | set(new["tenants"])):
        for q in ("p50_s", "p99_s"):
            o = old["tenants"].get(tenant, {}).get(q)
            n = new["tenants"].get(tenant, {}).get(q)
            print(f"{'wait.' + tenant + '.' + q:<32s} "
                  f"{(o if o is not None else float('nan')):>14.6g} "
                  f"{(n if n is not None else float('nan')):>14.6g} "
                  f"{_delta(o, n):>8s}")


def _load(path: str) -> List[Span]:
    try:
        spans = load_trace(path)
    except (OSError, ValueError) as e:
        raise SystemExit(f"cannot read trace {path!r}: {e}")
    if not spans:
        raise SystemExit(f"trace {path!r} contains no spans")
    return spans


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    p_sum = sub.add_parser("summary", help="summarize one trace")
    p_sum.add_argument("trace")
    p_diff = sub.add_parser("diff", help="compare two traces")
    p_diff.add_argument("old")
    p_diff.add_argument("new")
    args = ap.parse_args(argv)
    if args.cmd == "summary":
        print_summary(summarize(_load(args.trace)), label=args.trace)
    else:
        old, new = summarize(_load(args.old)), summarize(_load(args.new))
        print_diff(old, new)
    return 0


if __name__ == "__main__":
    sys.exit(main())
