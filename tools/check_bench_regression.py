"""Gate the batched-data-path benchmark against a committed baseline.

Usage:
    python tools/check_bench_regression.py BENCH_ci.json \
        --baseline BENCH_baseline.json [--rtol 0.25] [--min-ratio 5] \
        [--min-hidden 0.5]

Three checks — two from ``gather_sweep`` rows, one from the
``prefetch_sweep`` gate row:

  * **latency** — per-page gather latency of every ``batched`` row with
    batch >= 32, NORMALIZED to the same run's ``scalar`` row (the
    batched/scalar ratio cancels machine speed, so a baseline committed
    from one box gates CI runners fairly), must not regress more than
    ``rtol`` (default +25%) against the baseline's ratio.  Small batches
    are excluded: their per-page numbers are dominated by fixed dispatch
    overhead and jitter, not by the coalesced path this gate protects.
    Rows report min-of-iterations latency, the noise-robust statistic.
  * **metering** — the ``gather_sweep.meter_reduction.b064`` row's
    scalar/batched arbiter-call ratio must stay >= ``--min-ratio``
    (default 5, the acceptance floor; the batched engine ships at >100x).
    This is machine-independent: call counts are deterministic.
  * **overlap** — the ``prefetch_sweep.gate.hidden`` row (compute-rich
    sequential scan with the burst-aware prefetcher) must show prefetch
    hiding at least ``--min-hidden`` (default 0.5) of the LMB read
    latency, beating demand-only per-page effective latency by at least
    1.5x, with random access at parity (ratio <= 1.25 — prefetch must
    not hurt where it cannot help).  All three figures are modeled
    virtual-time quantities, so they are machine-independent and need
    no committed baseline.

Exit code 1 on any violation (CI fails the bench-smoke job).
"""

from __future__ import annotations

import argparse
import json
import re
import sys

# only the LMB-resident cells are gated: they exercise the coalesced
# link path this gate protects, and their ratios are stable; the
# onboard cells (tens of us of pure in-memory gather) are informational
GATED = re.compile(r"^gather_sweep\.(lmb)\.b(\d+)\.batched$")
MIN_GATED_BATCH = 32


def load_rows(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: r for r in payload["rows"]}


def normalized(row: dict, scalar_row: dict | None) -> float:
    """Per-page latency as a fraction of the same run's scalar path."""
    if scalar_row is None or scalar_row["us_per_call"] <= 0:
        raise SystemExit(f"no scalar companion row for {row['name']!r}")
    return row["us_per_call"] / scalar_row["us_per_call"]


def derived_field(row: dict, key: str) -> float:
    m = re.search(rf"{key}=([0-9.]+)", row.get("derived", ""))
    if m is None:
        raise SystemExit(f"row {row['name']!r} has no {key}= in derived")
    return float(m.group(1))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh BENCH json (benchmarks.run --json)")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--rtol", type=float, default=0.25,
                    help="allowed per-page latency regression (0.25 = +25%%)")
    ap.add_argument("--min-ratio", type=float, default=5.0,
                    help="required scalar/batched meter-call ratio @ b064")
    ap.add_argument("--min-hidden", type=float, default=0.5,
                    help="required prefetch hidden-fraction in the "
                         "compute-rich sequential configuration")
    args = ap.parse_args()

    base = load_rows(args.baseline)
    cur = load_rows(args.current)
    failures = []

    for name, row in sorted(cur.items()):
        m = GATED.match(name)
        if not m or int(m.group(2)) < MIN_GATED_BATCH:
            continue
        ref = base.get(name)
        if ref is None:
            print(f"  [new ] {name}: no baseline row, skipping")
            continue
        scalar_name = name[:-len("batched")] + "scalar"
        got = normalized(row, cur.get(scalar_name))
        want = normalized(ref, base.get(scalar_name))
        limit = want * (1.0 + args.rtol)
        verdict = "FAIL" if got > limit else "ok"
        print(f"  [{verdict:4s}] {name}: batched/scalar {got:.3f} "
              f"({row['us_per_call']:.1f}us/page; baseline ratio "
              f"{want:.3f}, limit {limit:.3f})")
        if got > limit:
            failures.append(f"{name}: ratio {got:.3f} > {limit:.3f}")

    red = cur.get("gather_sweep.meter_reduction.b064")
    if red is None:
        failures.append("missing gather_sweep.meter_reduction.b064 row")
    else:
        ratio = derived_field(red, "ratio")
        verdict = "FAIL" if ratio < args.min_ratio else "ok"
        print(f"  [{verdict:4s}] meter_reduction.b064: {ratio:.1f}x "
              f"(floor {args.min_ratio:.0f}x)")
        if ratio < args.min_ratio:
            failures.append(
                f"meter-call reduction {ratio:.1f}x < {args.min_ratio}x")

    pf = cur.get("prefetch_sweep.gate.hidden")
    if pf is None:
        failures.append("missing prefetch_sweep.gate.hidden row")
    else:
        hidden = derived_field(pf, "hidden")
        speedup = derived_field(pf, "speedup")
        rand_ratio = derived_field(pf, "rand_ratio")
        ok = (hidden >= args.min_hidden and speedup >= 1.5
              and rand_ratio <= 1.25)
        verdict = "ok" if ok else "FAIL"
        print(f"  [{verdict:4s}] prefetch gate: hidden {hidden:.3f} "
              f"(floor {args.min_hidden:.2f}), speedup {speedup:.1f}x "
              f"(floor 1.5x), rand parity {rand_ratio:.3f} (cap 1.25)")
        if hidden < args.min_hidden:
            failures.append(
                f"prefetch hides {hidden:.3f} < {args.min_hidden} of LMB "
                "read latency in the compute-rich configuration")
        if speedup < 1.5:
            failures.append(
                f"prefetch speedup {speedup:.1f}x < 1.5x vs demand-only")
        if rand_ratio > 1.25:
            failures.append(
                f"random-access parity broken: {rand_ratio:.3f} > 1.25")

    if failures:
        print("\nBENCH REGRESSION:", *failures, sep="\n  - ")
        return 1
    print("\nbench gate: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
