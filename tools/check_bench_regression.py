"""Gate benchmark output: declarative scenario gates + latency baseline.

Usage:
    python tools/check_bench_regression.py BENCH_ci.json \
        --baseline BENCH_baseline.json [--rtol 0.25]

Two kinds of checks:

  * **Declarative gates** — every scenario that ran declares its own
    :class:`benchmarks.run.Gate` rows (``@scenario(..., gate=...)``) and
    ``--json`` embeds them in the payload under ``"gates"``.  Each gate
    names a row, a ``key=value`` field in its ``derived`` column, and a
    ``[min, max]`` bound; a missing row or an out-of-bounds value fails
    CI.  Gate bounds are machine-independent (modeled / virtual-time /
    count figures), so they need no committed baseline — and adding a
    gated sweep never means hand-wiring a new key into this checker.
  * **Gather latency vs baseline** — per-page gather latency of every
    ``gather_sweep`` ``batched`` row with batch >= 32, NORMALIZED to the
    same run's ``scalar`` row (the batched/scalar ratio cancels machine
    speed, so a baseline committed from one box gates CI runners
    fairly), must not regress more than ``rtol`` (default +25%) against
    the baseline's ratio.  Small batches are excluded: their per-page
    numbers are dominated by fixed dispatch overhead and jitter, not by
    the coalesced path this gate protects.  This check stays here (not
    in a Gate row) because it is baseline-RELATIVE, not an absolute
    bound.

For payloads written before the gates list existed, the legacy
hand-wired checks (meter-reduction floor, prefetch-overlap gate) run as
a fallback.

Exit code 1 on any violation (CI fails the bench-smoke job).
"""

from __future__ import annotations

import argparse
import json
import re
import sys

# only the LMB-resident cells are gated: they exercise the coalesced
# link path this gate protects, and their ratios are stable; the
# onboard cells (tens of us of pure in-memory gather) are informational
GATED = re.compile(r"^gather_sweep\.(lmb)\.b(\d+)\.batched$")
MIN_GATED_BATCH = 32


def load_payload(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def row_index(payload: dict) -> dict:
    return {r["name"]: r for r in payload["rows"]}


def normalized(row: dict, scalar_row: dict | None) -> float:
    """Per-page latency as a fraction of the same run's scalar path."""
    if scalar_row is None or scalar_row["us_per_call"] <= 0:
        raise SystemExit(f"no scalar companion row for {row['name']!r}")
    return row["us_per_call"] / scalar_row["us_per_call"]


def derived_field(row: dict, key: str) -> float:
    m = re.search(rf"{key}=([0-9.]+)", row.get("derived", ""))
    if m is None:
        raise SystemExit(f"row {row['name']!r} has no {key}= in derived")
    return float(m.group(1))


def check_declared_gates(payload: dict, rows: dict, failures: list) -> None:
    """Enforce the scenario-declared gates embedded in the payload."""
    for gate in payload.get("gates", []):
        name, field = gate["row"], gate["field"]
        lo, hi = gate.get("min"), gate.get("max")
        row = rows.get(name)
        if row is None:
            print(f"  [FAIL] {name}: gated row missing from output")
            failures.append(f"gated row {name!r} missing")
            continue
        val = derived_field(row, field)
        ok = ((lo is None or val >= lo) and (hi is None or val <= hi))
        bound = "".join([f" >= {lo}" if lo is not None else "",
                         f" <= {hi}" if hi is not None else ""])
        verdict = "ok" if ok else "FAIL"
        print(f"  [{verdict:4s}] {name}: {field} = {val}"
              f" (required{bound})")
        if not ok:
            note = gate.get("note", "")
            failures.append(f"{name}: {field} = {val} violates{bound}"
                            + (f" — {note}" if note else ""))


def check_gather_latency(args, base_rows: dict, cur_rows: dict,
                         failures: list) -> None:
    """Baseline-relative batched/scalar gather-latency regression."""
    for name, row in sorted(cur_rows.items()):
        m = GATED.match(name)
        if not m or int(m.group(2)) < MIN_GATED_BATCH:
            continue
        ref = base_rows.get(name)
        if ref is None:
            print(f"  [new ] {name}: no baseline row, skipping")
            continue
        scalar_name = name[:-len("batched")] + "scalar"
        got = normalized(row, cur_rows.get(scalar_name))
        want = normalized(ref, base_rows.get(scalar_name))
        limit = want * (1.0 + args.rtol)
        verdict = "FAIL" if got > limit else "ok"
        print(f"  [{verdict:4s}] {name}: batched/scalar {got:.3f} "
              f"({row['us_per_call']:.1f}us/page; baseline ratio "
              f"{want:.3f}, limit {limit:.3f})")
        if got > limit:
            failures.append(f"{name}: ratio {got:.3f} > {limit:.3f}")


def check_legacy_gates(args, cur_rows: dict, failures: list) -> None:
    """Hand-wired checks for payloads predating the gates list."""
    red = cur_rows.get("gather_sweep.meter_reduction.b064")
    if red is None:
        failures.append("missing gather_sweep.meter_reduction.b064 row")
    else:
        ratio = derived_field(red, "ratio")
        verdict = "FAIL" if ratio < args.min_ratio else "ok"
        print(f"  [{verdict:4s}] meter_reduction.b064: {ratio:.1f}x "
              f"(floor {args.min_ratio:.0f}x)")
        if ratio < args.min_ratio:
            failures.append(
                f"meter-call reduction {ratio:.1f}x < {args.min_ratio}x")

    pf = cur_rows.get("prefetch_sweep.gate.hidden")
    if pf is None:
        failures.append("missing prefetch_sweep.gate.hidden row")
    else:
        hidden = derived_field(pf, "hidden")
        speedup = derived_field(pf, "speedup")
        rand_ratio = derived_field(pf, "rand_ratio")
        ok = (hidden >= args.min_hidden and speedup >= 1.5
              and rand_ratio <= 1.25)
        verdict = "ok" if ok else "FAIL"
        print(f"  [{verdict:4s}] prefetch gate: hidden {hidden:.3f} "
              f"(floor {args.min_hidden:.2f}), speedup {speedup:.1f}x "
              f"(floor 1.5x), rand parity {rand_ratio:.3f} (cap 1.25)")
        if hidden < args.min_hidden:
            failures.append(
                f"prefetch hides {hidden:.3f} < {args.min_hidden} of LMB "
                "read latency in the compute-rich configuration")
        if speedup < 1.5:
            failures.append(
                f"prefetch speedup {speedup:.1f}x < 1.5x vs demand-only")
        if rand_ratio > 1.25:
            failures.append(
                f"random-access parity broken: {rand_ratio:.3f} > 1.25")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh BENCH json (benchmarks.run --json)")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--rtol", type=float, default=0.25,
                    help="allowed per-page latency regression (0.25 = +25%%)")
    ap.add_argument("--min-ratio", type=float, default=5.0,
                    help="legacy fallback: required scalar/batched "
                         "meter-call ratio @ b064")
    ap.add_argument("--min-hidden", type=float, default=0.5,
                    help="legacy fallback: required prefetch "
                         "hidden-fraction, compute-rich sequential")
    args = ap.parse_args()

    base = load_payload(args.baseline)
    cur = load_payload(args.current)
    base_rows, cur_rows = row_index(base), row_index(cur)
    failures: list = []

    check_gather_latency(args, base_rows, cur_rows, failures)
    if "gates" in cur:
        check_declared_gates(cur, cur_rows, failures)
    else:
        check_legacy_gates(args, cur_rows, failures)

    if failures:
        print("\nBENCH REGRESSION:", *failures, sep="\n  - ")
        return 1
    print("\nbench gate: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
