"""Mechanical formatting normalization (the ROADMAP "ruff format" item).

Applies the whitespace-level subset of ruff-format's behavior that can be
done — and *verified* — without the formatter binary (which the dev
container does not ship): strip trailing whitespace, expand tabs in
indentation, and end every file with exactly one newline.  Every rewrite
is gated on ``ast.dump`` equality before/after, so the pass provably
cannot change program behavior; files whose AST would change are left
untouched and reported.

Run:  python tools/normalize_format.py [--check] [paths...]

``--check`` exits non-zero if any file would change (CI-friendly); the
default applies changes in place.  With no paths, walks the repo's
Python surface (src/ tests/ examples/ benchmarks/ tools/).
"""

from __future__ import annotations

import argparse
import ast
import pathlib
import sys

DEFAULT_ROOTS = ("src", "tests", "examples", "benchmarks", "tools")


def normalize(text: str) -> str:
    lines = text.split("\n")
    out = []
    for line in lines:
        stripped = line.rstrip()
        # expandtabs only in leading whitespace (string bodies are
        # protected by the AST check anyway, but don't even try)
        indent = stripped[: len(stripped) - len(stripped.lstrip())]
        if "\t" in indent:
            stripped = indent.expandtabs(8) + stripped.lstrip()
        out.append(stripped)
    result = "\n".join(out)
    return result.rstrip("\n") + "\n" if result.strip() else ""


def process(path: pathlib.Path, check: bool) -> str:
    """Returns '' (unchanged), 'changed', or 'skipped' (AST mismatch)."""
    text = path.read_text()
    new = normalize(text)
    if new == text:
        return ""
    try:
        if ast.dump(ast.parse(text)) != ast.dump(ast.parse(new)):
            return "skipped"
    except SyntaxError:
        return "skipped"
    if not check:
        path.write_text(new)
    return "changed"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="*", default=None)
    ap.add_argument("--check", action="store_true",
                    help="report files that would change; exit 1 if any")
    args = ap.parse_args()
    roots = [pathlib.Path(p) for p in (args.paths or DEFAULT_ROOTS)]
    files: list[pathlib.Path] = []
    for root in roots:
        if root.is_file():
            files.append(root)
        elif root.is_dir():
            files.extend(sorted(root.rglob("*.py")))
    changed = 0
    for f in files:
        status = process(f, args.check)
        if status:
            changed += status == "changed"
            print(f"{status}: {f}")
    verb = "would change" if args.check else "normalized"
    print(f"{verb}: {changed} of {len(files)} files")
    return 1 if (args.check and changed) else 0


if __name__ == "__main__":
    sys.exit(main())
