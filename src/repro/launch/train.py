"""Training launcher: end-to-end driver with checkpointing, fault
tolerance, straggler detection, and LMB optimizer-state offload.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --steps 200 --d-model 256 --layers 8 ...    # ~100M-class run

On CPU this runs a reduced config end-to-end (the integration test path);
on a pod the same script runs the full config over the production mesh.
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import DeviceSpec, HostSpec, LMBSystem, SystemSpec
from repro.core.offload import (PINNED_HOST, backend_memory_kinds,
                                supports_in_jit_offload, tree_put_tier,
                                nbytes_of, DEVICE)
from repro.data.pipeline import DataConfig, make_dataset
from repro.models.flags import Flags
from repro.models.zoo import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.fault import FailureInjector, StragglerDetector
from repro.train.loop import make_train_step, opt_state_init


def run(arch: str, steps: int = 50, global_batch: int = 8,
        seq_len: int = 128, ckpt_dir: Optional[str] = None,
        ckpt_every: int = 25, grad_accum: int = 1,
        compress_grads: bool = False, offload_opt: bool = False,
        reduced: bool = True, fail_at: Optional[set] = None,
        lr: float = 1e-3, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    flags = Flags(remat=False, attn_chunk=seq_len)
    model = build_model(cfg, flags)

    # --- LMB pool for optimizer-state offload (host tier) ----------------
    # one declarative spec replaces the fabric/host/device hand-wiring;
    # allocations below are MemoryHandle capabilities, freed via close()
    system = LMBSystem(SystemSpec(
        expanders=1, pool_gib=4,
        hosts=(HostSpec("trainer"),),
        devices=(DeviceSpec("tpu0"),)))
    offload_handles = []

    rng = jax.random.key(0)
    params = model.init(rng)
    opt_state = opt_state_init(params, compress_grads)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1),
                          total_steps=steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg, grad_accum,
                                      compress_grads))

    data = make_dataset(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len,
        global_batch=global_batch))

    start = 0
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        trees, start = restore_checkpoint(
            ckpt_dir, {"params": params, "opt_state": opt_state})
        params, opt_state = trees["params"], trees["opt_state"]
        if verbose:
            print(f"[train] resumed from step {start}")

    if offload_opt:
        # park m/v/master in the LMB tier between steps (host-stage mode);
        # in-jit mode (TPU) annotates shardings instead.  Pool accounting:
        # regions live inside single 256 MB blocks, so allocate per block.
        from repro.core.pool import BLOCK_BYTES
        remaining = max(nbytes_of(opt_state), 1)
        while remaining > 0:
            take = min(remaining, BLOCK_BYTES)
            offload_handles.append(system.alloc("tpu0", take))
            remaining -= take
        if not supports_in_jit_offload():
            opt_state = tree_put_tier(opt_state, PINNED_HOST
                                      if PINNED_HOST in
                                      backend_memory_kinds() else DEVICE)

    injector = FailureInjector(fail_at)
    straggler = StragglerDetector()
    losses = []
    t_train0 = time.monotonic()
    for step in range(start, steps):
        injector.maybe_fail(step)
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        if cfg.encoder_decoder:
            batch["src_emb"] = jnp.zeros(
                (batch["tokens"].shape[0], seq_len, cfg.d_model),
                jnp.dtype(cfg.dtype))
        t0 = time.monotonic()
        if offload_opt and not supports_in_jit_offload():
            opt_state = tree_put_tier(opt_state, DEVICE)     # page in
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if offload_opt and not supports_in_jit_offload():
            opt_state = tree_put_tier(opt_state, PINNED_HOST
                                      if PINNED_HOST in
                                      backend_memory_kinds() else DEVICE)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.monotonic() - t0
        if straggler.observe(dt) and verbose:
            print(f"[train] step {step}: straggler ({dt:.2f}s)")
        if verbose and (step % 10 == 0 or step == steps - 1):
            print(f"[train] step {step} loss={loss:.4f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step + 1,
                            {"params": params, "opt_state": opt_state})
    system.close()                 # frees every live offload handle
    return {
        "final_loss": losses[-1] if losses else None,
        "first_loss": losses[0] if losses else None,
        "losses": losses,
        "steps": len(losses),
        "wall_s": time.monotonic() - t_train0,
        "params": params, "opt_state": opt_state,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--offload-opt", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config — pod hardware")
    args = ap.parse_args()
    out = run(args.arch, steps=args.steps, global_batch=args.global_batch,
              seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
              grad_accum=args.grad_accum,
              compress_grads=args.compress_grads,
              offload_opt=args.offload_opt, reduced=not args.full)
    print(f"[train] done: loss {out['first_loss']:.3f} -> "
          f"{out['final_loss']:.3f} in {out['steps']} steps "
          f"({out['wall_s']:.1f}s)")


if __name__ == "__main__":
    main()
