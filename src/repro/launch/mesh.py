"""Production mesh construction.

A FUNCTION (not module-level state) so importing never touches jax device
state.  Single pod: 16x16 = 256 chips (v5e pod).  Multi-pod: 2 pods = 512
chips with a dedicated "pod" axis (data-parallel across the pod boundary —
the only traffic crossing DCN is the gradient all-reduce).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
