"""Serving launcher: continuous batching with LMB-backed KV capacity.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --requests 16 --decode-slots 4
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core import DeviceSpec, HostSpec, LMBSystem, SystemSpec
from repro.models import build_model
from repro.models.flags import Flags
from repro.serve import EngineConfig, ServeEngine, SubmitSpec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--decode-slots", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--onboard-pages", type=int, default=16)
    ap.add_argument("--pool-gib", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg, Flags(remat=False))
    params = model.init(jax.random.key(0))

    spec = SystemSpec(expanders=1, pool_gib=args.pool_gib,
                      hosts=(HostSpec("server", page_bytes=4096),),
                      devices=(DeviceSpec("tpu0"),))
    with LMBSystem(spec) as system:
        eng = ServeEngine(model, params, system, EngineConfig(
            decode_slots=args.decode_slots, max_seq_len=128, page_tokens=16,
            onboard_pages=args.onboard_pages))
        rng = np.random.default_rng(0)
        t0 = time.monotonic()
        for _ in range(args.requests):
            eng.submit(SubmitSpec(
                prompt=rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(4, 48))),
                max_new_tokens=args.max_new_tokens))
        eng.run()
        wall = time.monotonic() - t0
        st = eng.stats()
        st["wall_s"] = wall
        st["tok_per_s"] = args.requests * args.max_new_tokens / wall
        print(json.dumps(st, indent=1, default=str))


if __name__ == "__main__":
    main()
