import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the right step function (train_4k -> train_step;
prefill_32k -> prefill; decode_32k / long_500k -> serve_step = one-token
decode), jits it with full production shardings, ``.lower().compile()``s
against ShapeDtypeStruct inputs (no allocation), and records:

  * ``memory_analysis()``  — proves the cell fits per-device HBM,
  * ``cost_analysis()``    — FLOPs / bytes for §Roofline,
  * parsed collective bytes, and the three roofline terms.

Results append to a JSON table (``--out``); already-done cells are skipped
so the sweep is resumable.  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
        --shape train_4k --mesh single          # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, get_config, \
    list_configs
from repro.launch.mesh import make_production_mesh
from repro.models.flags import Flags
from repro.models.zoo import build_model
from repro.optim.adamw import AdamWConfig
from repro.roofline.analysis import model_flops, roofline_terms
from repro.sharding.constraints import activation_mesh
from repro.sharding.partition import (batch_spec, cache_shardings,
                                      param_shardings)
from repro.train.loop import abstract_train_state, make_train_step


def _cost_dict(compiled) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across JAX versions: older
    releases return ``[{...}]`` (one dict per device program), newer ones
    the dict itself, and some backends ``None``."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def opt_state_shardings(opt_shapes, mesh, cfg, fsdp=False):
    """m/v/master shard like params; scalars replicated."""
    out = {}
    for key, sub in opt_shapes.items():
        if key in ("m", "v", "master", "ef_err"):
            out[key] = param_shardings(sub, mesh, cfg, fsdp=fsdp)
        else:
            out[key] = jax.tree_util.tree_map(
                lambda _: NamedSharding(mesh, P()), sub)
    return out


def _want_fsdp(cfg, shape) -> bool:
    """ZeRO/FSDP when the per-device state wouldn't fit HBM otherwise.

    train: params/grads/opt = ~16 B/param, sharded 16-way TP -> FSDP when
    that exceeds half of HBM.  serve: bf16 params only."""
    n = cfg.param_count()
    per_dev = (16.0 if shape.kind == "train" else 2.0) * n / 16
    return per_dev > 8e9


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, flags: Flags):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    model = build_model(cfg, flags)
    fsdp = _want_fsdp(cfg, shape)
    params_shapes = model.abstract_params()
    p_shard = param_shardings(params_shapes, mesh, cfg, fsdp=fsdp)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        params_shapes, opt_shapes = abstract_train_state(model)
        o_shard = opt_state_shardings(opt_shapes, mesh, cfg, fsdp=fsdp)
        step = make_train_step(model, AdamWConfig())
        specs = model.input_specs(shape)
        b_shard = {
            k: NamedSharding(mesh, batch_spec(mesh, B, len(v.shape) - 1))
            for k, v in specs.items()}
        fn = jax.jit(step,
                     in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, None),
                     donate_argnums=(0, 1))
        return fn, (params_shapes, opt_shapes, specs)

    if shape.kind == "prefill":
        specs = model.input_specs(shape)
        cache_shapes = jax.eval_shape(lambda: model.init_cache(B, S))
        c_shard = cache_shardings(cache_shapes, mesh, cfg, B)
        b_shard = {
            k: NamedSharding(mesh, batch_spec(mesh, B, len(v.shape) - 1))
            for k, v in specs.items()}
        fn = jax.jit(model.prefill,
                     in_shardings=(p_shard, b_shard, c_shard),
                     out_shardings=(NamedSharding(
                         mesh, batch_spec(mesh, B, 1)), c_shard),
                     donate_argnums=(2,))
        return fn, (params_shapes, specs, cache_shapes)

    # serve_step: one new token against a seq_len KV cache
    cache_shapes = jax.eval_shape(lambda: model.init_cache(B, S))
    c_shard = cache_shardings(cache_shapes, mesh, cfg, B)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    t_shard = NamedSharding(mesh, batch_spec(mesh, B, 1))
    fn = jax.jit(model.decode_step,
                 in_shardings=(p_shard, c_shard, t_shard),
                 out_shardings=(NamedSharding(mesh, batch_spec(mesh, B, 1)),
                                c_shard),
                 donate_argnums=(1,))
    return fn, (params_shapes, cache_shapes, tok)


def _measure(cfg, shape, mesh, flags) -> Dict[str, float]:
    """lower+compile one step fn; returns {flops, bytes, coll} (per-device)
    plus memory analysis + compile timings."""
    from repro.roofline.analysis import collective_bytes_per_device
    t0 = time.monotonic()
    fn, args = build_cell(cfg, shape, mesh, flags)
    with activation_mesh(mesh if flags.act_constraints else None):
        lowered = fn.lower(*args)
    t_lower = time.monotonic() - t0
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0 - t_lower
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": collective_bytes_per_device(hlo)["total"],
        "lower_s": t_lower, "compile_s": t_compile,
    }
    try:
        mem = compiled.memory_analysis()
        out["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # pragma: no cover
        out["memory"] = {"error": str(e)}
    return out


def _inner_chunk_cost(cfg, shape, mesh, flags) -> Dict[str, float]:
    """Per-chunk {flops, bytes, coll} of the wkv/ssd inner scan, measured
    as cost(2 chunks, unrolled) - cost(1 chunk).  Needed because the inner
    lax.scan body is also counted once by cost_analysis."""
    from repro.configs.base import HYBRID, RWKV6
    from repro.roofline.analysis import collective_bytes_per_device
    from jax.sharding import NamedSharding
    B = shape.global_batch
    T = flags.scan_chunk
    bspec = batch_spec(mesh, B, 3)
    results = []
    for n_chunks in (1, 2):
        S = T * n_chunks
        if cfg.block_type == RWKV6:
            from repro.models.rwkv6 import wkv_chunked
            H = cfg.d_model // cfg.rwkv_head_dim
            N = cfg.rwkv_head_dim
            seq = jax.ShapeDtypeStruct((B, S, H, N), jnp.float32)
            u = jax.ShapeDtypeStruct((H, N), jnp.float32)
            st = jax.ShapeDtypeStruct((B, H, N, N), jnp.float32)
            ms = mesh.shape["model"]
            h_ax = "model" if H % ms == 0 else None
            sh_seq = NamedSharding(mesh, jax.sharding.PartitionSpec(
                bspec[0], None, h_ax, None))
            sh_u = NamedSharding(mesh, jax.sharding.PartitionSpec(h_ax, None))
            sh_st = NamedSharding(mesh, jax.sharding.PartitionSpec(
                bspec[0], h_ax, None, None))
            fn = jax.jit(lambda r, k, v, w, u, s: wkv_chunked(
                r, k, v, w, u, s, chunk=T, unroll=True),
                in_shardings=(sh_seq,) * 4 + (sh_u, sh_st))
            args = (seq, seq, seq, seq, u, st)
        elif cfg.block_type == HYBRID:
            from repro.models.ssm import ssd_chunked
            d_in = cfg.ssm_expand * cfg.d_model
            H = cfg.ssm_heads or max(1, d_in // 64)
            P_ = d_in // H
            N = cfg.ssm_state
            xh = jax.ShapeDtypeStruct((B, S, H, P_), jnp.float32)
            dt = jax.ShapeDtypeStruct((B, S, H), jnp.float32)
            A = jax.ShapeDtypeStruct((H,), jnp.float32)
            Bm = jax.ShapeDtypeStruct((B, S, N), jnp.float32)
            st = jax.ShapeDtypeStruct((B, H, P_, N), jnp.float32)
            sh4 = NamedSharding(mesh, jax.sharding.PartitionSpec(
                bspec[0], None, None, None))
            sh3 = NamedSharding(mesh, jax.sharding.PartitionSpec(
                bspec[0], None, None))
            shA = NamedSharding(mesh, jax.sharding.PartitionSpec(None))
            fn = jax.jit(lambda x, d, a, bm, cm, s: ssd_chunked(
                x, d, a, bm, cm, s, chunk=T, unroll=True),
                in_shardings=(sh4, sh3, shA, sh3, sh3, sh4))
            args = (xh, dt, A, Bm, Bm, st)   # Cm shares Bm's spec
        else:
            return {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
        compiled = fn.lower(*args).compile()
        cost = _cost_dict(compiled)
        results.append({
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": collective_bytes_per_device(
                compiled.as_text())["total"]})
    return {k: max(results[1][k] - results[0][k], 0.0) for k in results[0]}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             flags: Flags = Flags(), verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "flags": dataclasses.asdict(flags), "status": "skipped",
    }
    if shape_name not in cfg.shape_cells():
        rec["reason"] = "long-context N/A for pure full-attention arch"
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.size
    try:
        # ---- artifact: full depth, scanned (memory + compile proof) ----
        art = _measure(cfg, shape, mesh, flags)
        rec["memory"] = art.pop("memory")
        # ---- per-layer body: unroll@2 - scan@2 (cost_analysis counts a
        # while body once; scan@L has identical body HLO for any L) ----
        L = cfg.num_layers
        cfg2 = dataclasses.replace(
            cfg, num_layers=2,
            num_encoder_layers=2 if cfg.encoder_decoder else 0)
        scan2 = _measure(cfg2, shape, mesh, flags)
        unroll2 = _measure(cfg2, shape, mesh,
                           dataclasses.replace(flags, unroll_layers=True))
        body = {k: max(unroll2[k] - scan2[k], 0.0)
                for k in ("flops", "bytes", "coll")}
        # ---- inner chunk scans (rwkv/ssd) also count once ----
        corr = {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
        if shape.kind != "decode" and cfg.block_type in ("rwkv6", "hybrid"):
            nc = shape.seq_len // flags.scan_chunk
            chunk_cost = _inner_chunk_cost(cfg, shape, mesh, flags)
            mult = (3.0 if shape.kind == "train" else 1.0)  # fwd+bwd+remat
            corr = {k: L * max(nc - 1, 0) * chunk_cost[k] * mult
                    for k in chunk_cost}
            rec["inner_chunk_cost"] = chunk_cost
        totals = {k: art[k] + (L - 1) * body[k] + corr[k]
                  for k in ("flops", "bytes", "coll")}
        cost = {"flops": totals["flops"], "bytes accessed": totals["bytes"]}
        mf = model_flops(cfg, shape)
        terms = roofline_terms(cost, "", chips, mf)
        terms.collective_s = totals["coll"] / 50e9
        terms.coll_bytes_per_dev = totals["coll"]
        rec.update(status="ok", lower_s=round(art["lower_s"], 2),
                   compile_s=round(art["compile_s"], 2),
                   raw_artifact={k: art[k] for k in ("flops", "bytes", "coll")},
                   body_per_layer=body,
                   roofline=terms.row())
        if verbose:
            r = terms
            print(f"[{arch} × {shape_name} × {mesh_kind}] OK "
                  f"lower={art['lower_s']:.1f}s compile={art['compile_s']:.1f}s "
                  f"compute={r.compute_s*1e3:.2f}ms "
                  f"memory={r.memory_s*1e3:.2f}ms "
                  f"coll={r.collective_s*1e3:.2f}ms "
                  f"dom={r.dominant} "
                  f"MFU@roof={r.roofline_fraction*100:.1f}% "
                  f"useful={r.useful_flops_ratio*100:.0f}%")
            if "memory" in rec and "temp_size_in_bytes" in rec.get("memory", {}):
                m = rec["memory"]
                print(f"    mem/device: args={m['argument_size_in_bytes']/2**30:.2f}GiB "
                      f"temp={m['temp_size_in_bytes']/2**30:.2f}GiB "
                      f"out={m['output_size_in_bytes']/2**30:.2f}GiB")
    except Exception as e:
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_kind}] FAIL {type(e).__name__}: {e}")
    return rec


def load_table(path: str) -> Dict[str, Any]:
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {}


def cell_key(arch, shape, mesh, tag="base") -> str:
    return f"{arch}|{shape}|{mesh}|{tag}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--tag", default="base")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--causal-skip", action="store_true")
    ap.add_argument("--attn-chunk", type=int, default=512)
    ap.add_argument("--no-remat", action="store_true")
    args = ap.parse_args()

    flags = Flags(causal_skip=args.causal_skip, attn_chunk=args.attn_chunk,
                  remat=not args.no_remat)
    archs = list_configs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    table = load_table(args.out)
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                key = cell_key(arch, shape, mesh_kind, args.tag)
                if key in table and table[key]["status"] == "ok" \
                        and not args.force:
                    print(f"[{key}] cached")
                    continue
                rec = run_cell(arch, shape, mesh_kind, flags)
                table[key] = rec
                with open(args.out, "w") as f:
                    json.dump(table, f, indent=1)
    ok = sum(1 for r in table.values() if r["status"] == "ok")
    fail = sum(1 for r in table.values() if r["status"] == "fail")
    skip = sum(1 for r in table.values() if r["status"] == "skipped")
    print(f"== dry-run table: {ok} ok / {fail} fail / {skip} skipped(N/A) ==")


if __name__ == "__main__":
    main()
