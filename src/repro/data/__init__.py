from repro.data.pipeline import DataConfig, SyntheticLM, TokenFileDataset

__all__ = ["DataConfig", "SyntheticLM", "TokenFileDataset"]
