"""Deterministic data pipeline: synthetic LM stream + token-file backend.

Determinism contract (fault tolerance): batch at step ``s`` depends only on
(seed, s, host shard) — a restarted/elastic job regenerates the exact
stream from the checkpointed step, on any host layout.

SyntheticLM produces a *learnable* distribution (bigram chain with noise),
so integration tests can assert loss decreases.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    #: this host's shard (process index, process count)
    shard: tuple = (0, 1)

    @property
    def host_batch(self) -> int:
        idx, n = self.shard
        assert self.global_batch % n == 0
        return self.global_batch // n


class SyntheticLM:
    """Markov-chain synthetic corpus; next-token structure is learnable."""

    def __init__(self, cfg: DataConfig, order_seed: int = 1234):
        self.cfg = cfg
        rng = np.random.default_rng(order_seed)
        # deterministic "grammar": each token maps to a preferred successor
        self._succ = rng.permutation(cfg.vocab_size)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        idx, n = cfg.shard
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 97 + idx)
        B, S = cfg.host_batch, cfg.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, B)
        noise = rng.random((B, S)) < 0.1
        rand_next = rng.integers(0, cfg.vocab_size, (B, S))
        for t in range(S):
            nxt = self._succ[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand_next[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class TokenFileDataset:
    """Binary token file (np.int32 memmap) chopped into sequences.

    The production path: a pre-tokenized corpus on shared storage, read
    with zero-copy memmap; epoch shuffling is a seeded permutation of
    sequence indices so every host computes the same order independently.
    """

    def __init__(self, cfg: DataConfig, path: str):
        self.cfg = cfg
        self._data = np.memmap(path, dtype=np.int32, mode="r")
        self.n_seqs = (len(self._data) - 1) // cfg.seq_len
        if self.n_seqs <= 0:
            raise ValueError(f"{path} too small for seq_len={cfg.seq_len}")

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        idx, n = cfg.shard
        epoch_len = self.n_seqs // cfg.global_batch
        epoch, within = divmod(step, max(epoch_len, 1))
        order = np.random.default_rng(cfg.seed + epoch).permutation(
            self.n_seqs)
        base = (within * cfg.global_batch + idx * cfg.host_batch) \
            % self.n_seqs
        rows = []
        for i in range(cfg.host_batch):
            s = order[(base + i) % self.n_seqs] * cfg.seq_len
            rows.append(self._data[s:s + cfg.seq_len + 1])
        toks = np.stack([r if len(r) == cfg.seq_len + 1
                         else np.pad(r, (0, cfg.seq_len + 1 - len(r)))
                         for r in rows]).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_dataset(cfg: DataConfig, path: Optional[str] = None):
    if path and os.path.exists(path):
        return TokenFileDataset(cfg, path)
    return SyntheticLM(cfg)
