"""Modality frontend STUBS (assignment: backbone only).

The audio (seamless) and vision (chameleon VQ) frontends are not part of
the assigned backbone; these helpers produce the tensors the backbone
expects so the examples/tests have an end-to-end path:

  * audio  — a deterministic "feature extractor" mapping a raw waveform
             stand-in to frame embeddings [B, S, D];
  * vision — a stub VQ tokenizer mapping an image grid to code ids in the
             (shared, early-fusion) vocabulary.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def audio_frames(rng, batch: int, seq: int, d_model: int,
                 dtype=jnp.bfloat16) -> jax.Array:
    """Precomputed frame embeddings (stand-in for w2v-BERT features)."""
    return (jax.random.normal(rng, (batch, seq, d_model), jnp.float32)
            * 0.1).astype(dtype)


def vq_tokenize(rng, batch: int, grid: int, vocab: int,
                image_vocab_offset: int = 4096) -> jax.Array:
    """Stub VQ-VAE: an image becomes grid*grid code ids (early fusion)."""
    n = grid * grid
    codes = jax.random.randint(rng, (batch, n), 0,
                               vocab - image_vocab_offset)
    return (codes + image_vocab_offset).astype(jnp.int32)
