"""Run-time (non-architecture) flags: performance knobs for hillclimbing.

Baseline = defaults.  Each knob is an EXPERIMENTS.md §Perf lever; flipping
them must never change results beyond numerics.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Flags:
    #: q-chunk size for the chunked-attention XLA path
    attn_chunk: int = 512
    #: statically skip fully-masked K blocks (causal/SWA) in the unrolled
    #: q-chunk loop — FLOP reduction visible in cost_analysis
    causal_skip: bool = False
    #: sequence-axis chunk for the cross-entropy readout
    loss_chunk: int = 512
    #: use Pallas TPU kernels for attention/rwkv/ssm hot spots (TPU only;
    #: the CPU dry-run lowers the XLA path)
    use_kernels: bool = False
    #: activation rematerialization for the scanned layer stack
    remat: bool = True
    #: remat policy: "nothing" (recompute everything; min memory) or
    #: "dots" (save matmul outputs; less recompute, more memory)
    remat_policy: str = "nothing"
    #: apply Megatron-SP activation sharding constraints (needs an active
    #: activation_mesh context; no-op otherwise)
    act_constraints: bool = True
    #: offload optimizer state to the LMB tier inside the step (TPU only)
    offload_opt_state: bool = False
    #: chunk length for rwkv/ssm chunked scans
    scan_chunk: int = 64
    #: unroll the layer stack as a python loop (analysis + perf experiments;
    #: cost_analysis counts while-loop bodies once, so the dry-run measures
    #: body cost via unroll@L=2 minus scan@L=2)
    unroll_layers: bool = False
    #: unroll inner sequence-chunk scans (wkv/ssd) the same way
    unroll_scans: bool = False
    #: unroll the chunked-loss readout loop (few copies; keeps the readout
    #: matmul visible to cost_analysis at its true trip count)
    unroll_loss: bool = True
    #: fold the rwkv token-shift mix into fused projection weights:
    #: (mu*x + (1-mu)*xs) @ W == x @ (diag(mu)W) + xs @ (diag(1-mu)W) —
    #: 5 projections share ONE gathered x and ONE gathered xs (collective
    #: term lever; numerically identical modulo float association)
    fuse_rwkv_proj: bool = False
    #: tokens per MoE dispatch group (bounds [g,E,C] one-hot tensors)
    moe_group: int = 1024


DEFAULT_FLAGS = Flags()
