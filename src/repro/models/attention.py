"""GQA attention: train/prefill (chunked, flash-style) and decode paths.

Layouts:
  x        [B, S, D]
  q        [B, S, H, hd]        (H = num_heads)
  k, v     [B, S, KV, hd]       (KV = num_kv_heads; GQA groups G = H/KV)
  caches   [B, S_max, KV, hd]   (linear) or [B, W, KV, hd] (SWA ring)

The chunked path bounds score memory to O(B * H * chunk * S) and — with
``flags.causal_skip`` — statically truncates each q-chunk's K range to the
causal/SWA-reachable prefix, which removes the masked FLOPs from the HLO
(visible in cost_analysis; this is hillclimb lever #1).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.flags import Flags, DEFAULT_FLAGS
from repro.models.layers import (Params, apply_rope, dense, dense_init,
                                 dtype_of, head_rms_norm, rope_angles)


def attention_init(rng, cfg, cross: bool = False) -> Params:
    dt = dtype_of(cfg)
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    ks = jax.random.split(rng, 4)
    return {
        "wq": dense_init(ks[0], D, H * hd, dt, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], D, KV * hd, dt, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], D, KV * hd, dt, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], H * hd, D, dt),
    }


def _qkv(p: Params, cfg, x: jax.Array,
         positions: Optional[jax.Array],
         rope: bool = True) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    q = dense(p["wq"], x).reshape(B, S, H, hd)
    k = dense(p["wk"], x).reshape(B, S, KV, hd)
    v = dense(p["wv"], x).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q, k = head_rms_norm(q), head_rms_norm(k)
    if rope and positions is not None:
        sin, cos = rope_angles(positions, hd, cfg.rope_theta)
        q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
    return q, k, v


def _scores_softmax_out(q, k, v, mask, scale) -> jax.Array:
    """q [B,c,KV,G,hd]; k/v [B,Sk,KV,hd]; mask [B,c,Sk] -> [B,c,KV,G,hd]."""
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, k_pos: jax.Array,
                      *, causal: bool,
                      window: Optional[int] = None,
                      flags: Flags = DEFAULT_FLAGS) -> jax.Array:
    """q [B,Sq,H,hd]; k,v [B,Sk,KV,hd]; positions [B,S*] -> [B,Sq,H,hd]."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    cq = min(flags.attn_chunk, Sq)
    n = -(-Sq // cq)
    qg = q.reshape(B, Sq, KV, G, hd)

    outs = []
    for i in range(n):
        lo, hi = i * cq, min((i + 1) * cq, Sq)
        qc = qg[:, lo:hi]
        qp = q_pos[:, lo:hi]
        k_lo, k_hi = 0, Sk
        if flags.causal_skip and causal and Sq == Sk:
            # static causal truncation: this q-chunk can only see k <= hi-1
            k_hi = hi
            if window is not None:
                k_lo = max(0, lo - window)
        kc, vc = k[:, k_lo:k_hi], v[:, k_lo:k_hi]
        kp = k_pos[:, k_lo:k_hi]
        mask = jnp.ones((B, hi - lo, k_hi - k_lo), bool)
        if causal:
            mask &= kp[:, None, :] <= qp[:, :, None]
        if window is not None:
            mask &= kp[:, None, :] > (qp[:, :, None] - window)
        outs.append(_scores_softmax_out(qc, kc, vc, mask, scale))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out.reshape(B, Sq, H, hd)


# --------------------------------------------------------------- public ops
def attn_forward(p: Params, cfg, x: jax.Array, positions: jax.Array,
                 *, causal: bool = True, flags: Flags = DEFAULT_FLAGS,
                 return_kv: bool = False):
    """Train/prefill attention.  Returns (out, (k, v) if return_kv)."""
    q, k, v = _qkv(p, cfg, x, positions)
    if flags.use_kernels and causal:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, window=cfg.sliding_window)
    else:
        out = chunked_attention(q, k, v, positions, positions,
                                causal=causal,
                                window=cfg.sliding_window if causal else None,
                                flags=flags)
    B, S = x.shape[:2]
    y = dense(p["wo"], out.reshape(B, S, -1))
    if return_kv:
        return y, (k, v)
    return y


def attn_decode(p: Params, cfg, x: jax.Array,
                cache_k: jax.Array, cache_v: jax.Array,
                cache_pos: jax.Array, step: jax.Array,
                flags: Flags = DEFAULT_FLAGS):
    """One-token decode against a (linear or ring) KV cache.

    x          [B, 1, D]
    cache_k/v  [B, C, KV, hd]  (C = S_max, or window size for SWA ring)
    cache_pos  [B, C] int32    absolute position stored in each slot (-1 empty)
    step       []    int32     absolute position of the new token

    Returns (y, cache_k, cache_v, cache_pos).
    """
    B, _, _ = x.shape
    C = cache_k.shape[1]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    positions = jnp.broadcast_to(step[None, None], (B, 1))
    q, k, v = _qkv(p, cfg, x, positions)

    slot = jnp.mod(step, C)  # ring index (== step for linear caches)
    cache_k = _write_slot(cache_k, k[:, 0], slot)
    cache_v = _write_slot(cache_v, v[:, 0], slot)
    cache_pos = _write_slot_scalar(cache_pos, positions[:, 0], slot)

    scale = 1.0 / math.sqrt(hd)
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    valid = cache_pos >= 0
    mask = valid & (cache_pos <= step)
    if cfg.sliding_window is not None:
        mask &= cache_pos > (step - cfg.sliding_window)
    out = _scores_softmax_out(qg, cache_k, cache_v, mask[:, None, :], scale)
    y = dense(p["wo"], out.reshape(B, 1, H * hd))
    return y, cache_k, cache_v, cache_pos


def attn_decode_paged(p: Params, cfg, x: jax.Array,
                      k_pages: jax.Array, v_pages: jax.Array,
                      page_table: jax.Array, lengths: jax.Array,
                      flags: Flags = DEFAULT_FLAGS):
    """One-token batched decode straight against the paged KV pool.

    x           [B, 1, D]
    k/v_pages   [P, T, KV, hd]   the pool (one layer's slice)
    page_table  [B, MP] int32    pool page indices (-1 = unmapped pad)
    lengths     [B] int32        tokens already stored per sequence

    The new token's K/V is scattered into each sequence's tail page
    (``page_table[b, lengths[b] // T]`` must be mapped — the serve layer
    guarantees a tail page exists before the step) and attention runs
    over ``lengths + 1`` tokens through the page table.  Numerics match
    :func:`attn_decode` bitwise (same einsum/softmax ordering via the
    paged kernel's decode dispatcher), which is what lets the serve
    engine retire its dense slot cache without perturbing one token.

    Active sequences must not share a tail page (the engine never forks
    a mid-flight sequence), otherwise the scatters would collide.

    Returns (y, k_pages, v_pages).
    """
    from repro.kernels import ops as kops
    B = x.shape[0]
    T = k_pages.shape[1]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    positions = lengths[:, None].astype(jnp.int32)  # == dense path's step
    q, k, v = _qkv(p, cfg, x, positions)

    tail = jnp.take_along_axis(
        page_table, (lengths[:, None] // T).astype(jnp.int32), axis=1)[:, 0]
    tail = jnp.maximum(tail, 0)                     # contract: mapped
    off = lengths % T
    k_pages = k_pages.at[tail, off].set(k[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[tail, off].set(v[:, 0].astype(v_pages.dtype))

    out = kops.paged_attention_decode(q[:, 0], k_pages, v_pages,
                                      page_table, lengths + 1)
    y = dense(p["wo"], out.reshape(B, 1, H * hd))
    return y, k_pages, v_pages


def _write_slot(cache: jax.Array, val: jax.Array, slot: jax.Array) -> jax.Array:
    """cache [B, C, ...], val [B, ...] -> write at ring slot (traced)."""
    return jax.lax.dynamic_update_slice_in_dim(
        cache, val[:, None], slot, axis=1)


def _write_slot_scalar(cache: jax.Array, val: jax.Array,
                       slot: jax.Array) -> jax.Array:
    return jax.lax.dynamic_update_slice_in_dim(cache, val[:, None], slot,
                                               axis=1)


def cross_attn_init(rng, cfg) -> Params:
    return attention_init(rng, cfg, cross=True)


def cross_attn(p: Params, cfg, x: jax.Array, enc_k: jax.Array,
               enc_v: jax.Array, enc_mask: Optional[jax.Array] = None,
               flags: Flags = DEFAULT_FLAGS) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V (no RoPE)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    q = dense(p["wq"], x).reshape(B, S, H, hd)
    Sk = enc_k.shape[1]
    qpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kpos = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk))
    out = chunked_attention(q, enc_k, enc_v, qpos, kpos, causal=False,
                            flags=flags)
    return dense(p["wo"], out.reshape(B, S, -1))


def cross_kv(p: Params, cfg, enc_out: jax.Array):
    """Precompute cross-attention K/V from encoder output."""
    B, Sk, _ = enc_out.shape
    KV, hd = cfg.num_kv_heads, cfg.head_dim_
    k = dense(p["wk"], enc_out).reshape(B, Sk, KV, hd)
    v = dense(p["wv"], enc_out).reshape(B, Sk, KV, hd)
    return k, v
