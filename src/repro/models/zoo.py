"""Model facade: init / loss / prefill / decode_step / input_specs.

``build_model(cfg, flags)`` returns a ``Model`` whose methods are pure
functions of (params, batch) — ready for ``jax.jit`` with shardings.
``input_specs(shape_name)`` returns ShapeDtypeStruct stand-ins for every
input of the step that shape cell lowers (the dry-run contract).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ArchConfig, DENSE, MOE, ShapeConfig,
                                SHAPES)
from repro.models import encdec as encdec_mod
from repro.models.flags import Flags, DEFAULT_FLAGS
from repro.models.layers import (chunked_softmax_xent, dtype_of, embed_init,
                                 embed_logits, embed_lookup, rms_norm,
                                 rms_norm_init)
from repro.models.transformer import (init_cache, stacked_layers_init,
                                      trunk_decode, trunk_decode_paged,
                                      trunk_prefill, trunk_train)

AUX_LOSS_WEIGHT = 0.01


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    flags: Flags = DEFAULT_FLAGS

    # ------------------------------------------------------------------ init
    def init(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        k_emb, k_layers, k_out = jax.random.split(rng, 3)
        params: Dict[str, Any] = {
            "embed": embed_init(k_emb, cfg.padded_vocab, cfg.d_model,
                                dtype_of(cfg)),
            "final_norm": rms_norm_init(cfg.d_model),
        }
        if cfg.encoder_decoder:
            params["trunk"] = encdec_mod.encdec_init(k_layers, cfg)
        else:
            params["trunk"] = stacked_layers_init(k_layers, cfg,
                                                  cfg.num_layers)
        return params

    def abstract_params(self) -> Dict[str, Any]:
        """Parameter ShapeDtypeStructs without allocating (dry-run)."""
        return jax.eval_shape(
            lambda seed: self.init(jax.random.key(seed)),
            jax.ShapeDtypeStruct((), jnp.int32))

    # ------------------------------------------------------------------ loss
    def _readout(self, params, x: jax.Array) -> jax.Array:
        xn = rms_norm(params["final_norm"], x, self.cfg.norm_eps)
        return embed_logits(params["embed"], xn)

    def loss(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg, flags = self.cfg, self.flags
        labels = batch["labels"]
        if cfg.encoder_decoder:
            enc_out = encdec_mod.encode(params["trunk"], cfg,
                                        batch["src_emb"], flags)
            tgt = embed_lookup(params["embed"], batch["tokens"])
            x = encdec_mod.decode_train(params["trunk"], cfg, tgt, enc_out,
                                        flags)
            aux = jnp.float32(0.0)
        else:
            x = embed_lookup(params["embed"], batch["tokens"])
            B, S = batch["tokens"].shape
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            x, aux = trunk_train(params["trunk"], cfg, x, positions, flags)
        xn = rms_norm(params["final_norm"], x, cfg.norm_eps)
        xent = chunked_softmax_xent(
            lambda xc: embed_logits(params["embed"], xc), xn, labels,
            chunk=min(self.flags.loss_chunk, labels.shape[1]),
            unroll=self.flags.unroll_loss)
        return xent + AUX_LOSS_WEIGHT * aux

    # --------------------------------------------------------------- prefill
    def init_cache(self, batch: int, seq_len: int,
                   src_len: Optional[int] = None) -> Dict[str, Any]:
        if self.cfg.encoder_decoder:
            return encdec_mod.init_encdec_cache(self.cfg, batch, seq_len,
                                                src_len or seq_len)
        return init_cache(self.cfg, batch, seq_len)

    def prefill(self, params, batch: Dict[str, jax.Array],
                cache: Dict[str, Any]):
        """Prompt pass; returns (last-token logits [B, V], filled cache)."""
        cfg, flags = self.cfg, self.flags
        if cfg.encoder_decoder:
            enc_out = encdec_mod.encode(params["trunk"], cfg,
                                        batch["src_emb"], flags)
            tgt = embed_lookup(params["embed"], batch["tokens"])
            x, cache = encdec_mod.prefill(params["trunk"], cfg, tgt, enc_out,
                                          cache, flags)
        else:
            tokens = batch["tokens"]
            B, S = tokens.shape
            x = embed_lookup(params["embed"], tokens)
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            x, cache = trunk_prefill(params["trunk"], cfg, x, positions,
                                     flags, cache)
        logits = self._readout(params, x[:, -1:])[:, 0]
        return logits, cache

    # ---------------------------------------------------------------- decode
    def decode_step(self, params, cache: Dict[str, Any],
                    token: jax.Array):
        """token [B, 1] int32 -> (logits [B, V], updated cache)."""
        cfg, flags = self.cfg, self.flags
        x = embed_lookup(params["embed"], token)
        if cfg.encoder_decoder:
            x, cache = encdec_mod.decode_step(params["trunk"], cfg, x, cache,
                                              flags)
        else:
            x, cache = trunk_decode(params["trunk"], cfg, x, cache, flags)
        logits = self._readout(params, x)[:, 0]
        return logits, cache

    def supports_paged_decode(self) -> bool:
        """Whether :meth:`decode_step_paged` covers this architecture.

        The paged pool keeps absolute positions (no ring wrap), so SWA
        ring caches, recurrent state (RWKV/HYBRID), and encoder-decoder
        caches stay on the dense slot path."""
        cfg = self.cfg
        return (not cfg.encoder_decoder and cfg.sliding_window is None
                and cfg.block_type in (DENSE, MOE))

    def decode_step_paged(self, params, pool: jax.Array,
                          page_table: jax.Array, lengths: jax.Array,
                          token: jax.Array):
        """One batched decode step straight against the paged KV pool.

        pool       [P, L, 2, T, KV, hd]  page-major (PagedKVStore layout)
        page_table [B, MP] int32         pool page indices (-1 pad)
        lengths    [B] int32             tokens stored per sequence
        token      [B, 1] int32

        Returns (logits [B, V], updated pool) — the new token's K/V is
        written into each sequence's tail page across all layers.
        """
        cfg, flags = self.cfg, self.flags
        x = embed_lookup(params["embed"], token)
        k_pools = jnp.moveaxis(pool[:, :, 0], 0, 1)   # [L, P, T, KV, hd]
        v_pools = jnp.moveaxis(pool[:, :, 1], 0, 1)
        x, k_pools, v_pools = trunk_decode_paged(
            params["trunk"], cfg, x, k_pools, v_pools, page_table,
            lengths, flags)
        logits = self._readout(params, x)[:, 0]
        pool = jnp.stack([jnp.moveaxis(k_pools, 0, 1),
                          jnp.moveaxis(v_pools, 0, 1)], axis=2)
        return logits, pool

    # ------------------------------------------------------------- dry specs
    def input_specs(self, shape: ShapeConfig | str) -> Dict[str, Any]:
        """ShapeDtypeStructs for the step this shape cell lowers."""
        if isinstance(shape, str):
            shape = SHAPES[shape]
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        dt = dtype_of(cfg)
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if shape.kind == "train":
            specs = {"tokens": tok, "labels": tok}
            if cfg.encoder_decoder:
                specs["src_emb"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                        dt)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": tok}
            if cfg.encoder_decoder:
                specs["src_emb"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                        dt)
            return specs
        if shape.kind == "decode":
            return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        raise ValueError(shape.kind)

    def cache_specs(self, shape: ShapeConfig | str) -> Dict[str, Any]:
        if isinstance(shape, str):
            shape = SHAPES[shape]
        return jax.eval_shape(
            lambda: self.init_cache(shape.global_batch, shape.seq_len))


def build_model(cfg: ArchConfig, flags: Flags = DEFAULT_FLAGS) -> Model:
    return Model(cfg, flags)
