"""Decoder-only LM trunk: blocks, scan-over-layers, KV caches.

Production choices:
  * **scan-over-layers** — layer params are stacked on a leading [L] axis
    and the trunk is one ``jax.lax.scan``: HLO size (and compile time) is
    O(1) in depth — mandatory for the 88-layer/104B dry-runs.
  * **remat** — the block body is ``jax.checkpoint``-wrapped under
    ``flags.remat`` (dots_with_no_batch_dims_saveable policy).
  * **ring KV caches** — SWA archs keep a window-sized ring buffer
    (absolute positions tracked per slot), so `long_500k` decode state is
    O(window), not O(S).
  * **chunked loss** — the vocab readout is computed in sequence chunks
    (never materializes [B, S, V]).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import HYBRID, MOE, RWKV6, ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.flags import Flags
from repro.models.layers import (Params, dtype_of, mlp_apply, mlp_init,
                                 rms_norm, rms_norm_init)
from repro.models.scan_utils import scan_layers
from repro.sharding.constraints import constrain


# ---------------------------------------------------------------- layer init
def layer_init(rng, cfg: ArchConfig, cross: bool = False) -> Params:
    ks = jax.random.split(rng, 6)
    p: Params = {"norm1": rms_norm_init(cfg.d_model),
                 "norm2": rms_norm_init(cfg.d_model)}
    if cfg.block_type == RWKV6:
        p["rwkv"] = rwkv_mod.rwkv_init(ks[0], cfg)
        return p
    p["attn"] = attn.attention_init(ks[0], cfg)
    if cfg.block_type == MOE:
        p["moe"] = moe_mod.moe_init(ks[1], cfg)
    else:
        p["mlp"] = mlp_init(ks[1], cfg)
    if cfg.block_type == HYBRID:
        p["ssm"] = ssm_mod.ssm_init(ks[2], cfg)
        p["fuse_norm_a"] = rms_norm_init(cfg.d_model)
        p["fuse_norm_s"] = rms_norm_init(cfg.d_model)
    if cross:
        p["cross"] = attn.cross_attn_init(ks[3], cfg)
        p["norm3"] = rms_norm_init(cfg.d_model)
    return p


def stacked_layers_init(rng, cfg: ArchConfig, n: int,
                        cross: bool = False) -> Params:
    """[L]-stacked layer params (vmapped init = identical structure)."""
    rngs = jax.random.split(rng, n)
    return jax.vmap(lambda r: layer_init(r, cfg, cross))(rngs)


def _remat(body, flags: Flags):
    if not flags.remat:
        return body
    if flags.remat_policy == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body)  # "nothing": recompute everything


# -------------------------------------------------------------- block bodies
def _ffn(p: Params, cfg, x, flags):
    if cfg.block_type == MOE:
        y, aux = moe_mod.moe_apply(p["moe"], cfg, x, flags)
        return y, aux
    return mlp_apply(p["mlp"], x, cfg.act), jnp.float32(0.0)


def block_train(p: Params, cfg: ArchConfig, x: jax.Array,
                positions: jax.Array, flags: Flags,
                causal: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence block (training / encoder).  Returns (x, aux_loss)."""
    if cfg.block_type == RWKV6:
        B = x.shape[0]
        x = constrain(x, "residual")
        prev = jnp.zeros((B, 1, cfg.d_model), x.dtype)
        st = jnp.zeros((B, cfg.d_model // cfg.rwkv_head_dim,
                        cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32)
        h, _, _ = rwkv_mod.time_mix(p["rwkv"], cfg, rms_norm(
            p["norm1"], x, cfg.norm_eps), prev, st, flags)
        x = x + h
        h, _ = rwkv_mod.channel_mix(p["rwkv"], cfg, rms_norm(
            p["norm2"], x, cfg.norm_eps), prev)
        return constrain(x + h, "residual"), jnp.float32(0.0)
    x = constrain(x, "residual")
    xn = rms_norm(p["norm1"], x, cfg.norm_eps)
    a = attn.attn_forward(p["attn"], cfg, xn, positions, causal=causal,
                          flags=flags)
    if cfg.block_type == HYBRID:
        B = x.shape[0]
        cs, ss = ssm_mod.ssm_state_init(cfg, B, x.dtype)
        s, _, _ = ssm_mod.ssm_apply(p["ssm"], cfg, xn, cs, ss, flags)
        a = 0.5 * (rms_norm(p["fuse_norm_a"], a, cfg.norm_eps)
                   + rms_norm(p["fuse_norm_s"], s, cfg.norm_eps))
    x = x + a
    y, aux = _ffn(p, cfg, rms_norm(p["norm2"], x, cfg.norm_eps), flags)
    return constrain(x + y, "residual"), aux


def trunk_train(layers: Params, cfg: ArchConfig, x: jax.Array,
                positions: jax.Array, flags: Flags,
                causal: bool = True) -> Tuple[jax.Array, jax.Array]:
    """scan-over-layers trunk for full sequences."""

    def body(carry, lp):
        x, aux = carry
        x, a = block_train(lp, cfg, x, positions, flags, causal)
        return (x, aux + a), None

    body_fn = _remat(body, flags)
    (x, aux), _ = scan_layers(body_fn, (x, jnp.float32(0.0)), layers,
                              unroll=flags.unroll_layers)
    return x, aux


# ----------------------------------------------------------------- caches
def cache_len(cfg: ArchConfig, seq_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg: ArchConfig, batch: int, seq_len: int,
               n_layers: Optional[int] = None) -> Dict[str, Any]:
    """Zeroed decode cache (stacked [L] leaves).  pos slots start at -1."""
    L = n_layers or cfg.num_layers
    dt = dtype_of(cfg)
    cache: Dict[str, Any] = {"step": jnp.zeros((), jnp.int32)}
    if cfg.block_type == RWKV6:
        H = cfg.d_model // cfg.rwkv_head_dim
        N = cfg.rwkv_head_dim
        cache.update(
            tmix_prev=jnp.zeros((L, batch, 1, cfg.d_model), dt),
            wkv=jnp.zeros((L, batch, H, N, N), jnp.float32),
            cmix_prev=jnp.zeros((L, batch, 1, cfg.d_model), dt))
        return cache
    C = cache_len(cfg, seq_len)
    KV, hd = cfg.num_kv_heads, cfg.head_dim_
    cache.update(
        k=jnp.zeros((L, batch, C, KV, hd), dt),
        v=jnp.zeros((L, batch, C, KV, hd), dt),
        pos=jnp.full((batch, C), -1, jnp.int32))
    if cfg.block_type == HYBRID:
        d_in = cfg.ssm_expand * cfg.d_model
        H = cfg.ssm_heads or max(1, d_in // 64)
        P = d_in // H
        cache.update(
            conv=jnp.zeros((L, batch, ssm_mod.CONV_K - 1, d_in), dt),
            ssm=jnp.zeros((L, batch, H, P, cfg.ssm_state), jnp.float32))
    return cache


def _ring_fill(cache_arr: jax.Array, vals: jax.Array, C: int) -> jax.Array:
    """Write the last C of S computed entries into a ring cache.

    cache_arr [B, C, ...]; vals [B, S, ...] -> ring-ordered cache."""
    S = vals.shape[1]
    if C >= S:
        return vals if C == S else cache_arr.at[:, :S].set(vals)
    tail = vals[:, S - C:]
    idx = (jnp.arange(S - C, S) % C)
    return cache_arr.at[:, idx].set(tail)


# ------------------------------------------------------------ prefill/decode
def block_prefill(p: Params, cfg: ArchConfig, x: jax.Array,
                  positions: jax.Array, flags: Flags):
    """Block over the prompt; returns (x, per-layer cache entries)."""
    if cfg.block_type == RWKV6:
        B = x.shape[0]
        prev = jnp.zeros((B, 1, cfg.d_model), x.dtype)
        st = jnp.zeros((B, cfg.d_model // cfg.rwkv_head_dim,
                        cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32)
        xn = rms_norm(p["norm1"], x, cfg.norm_eps)
        h, tprev, st = rwkv_mod.time_mix(p["rwkv"], cfg, xn, prev, st, flags)
        x = x + h
        xn2 = rms_norm(p["norm2"], x, cfg.norm_eps)
        h, cprev = rwkv_mod.channel_mix(p["rwkv"], cfg, xn2, prev)
        return x + h, {"tmix_prev": tprev, "wkv": st, "cmix_prev": cprev}
    xn = rms_norm(p["norm1"], x, cfg.norm_eps)
    a, (k, v) = attn.attn_forward(p["attn"], cfg, xn, positions,
                                  causal=True, flags=flags, return_kv=True)
    entries: Dict[str, Any] = {"k": k, "v": v}
    if cfg.block_type == HYBRID:
        B = x.shape[0]
        cs, ss = ssm_mod.ssm_state_init(cfg, B, x.dtype)
        s, cs, ss = ssm_mod.ssm_apply(p["ssm"], cfg, xn, cs, ss, flags)
        a = 0.5 * (rms_norm(p["fuse_norm_a"], a, cfg.norm_eps)
                   + rms_norm(p["fuse_norm_s"], s, cfg.norm_eps))
        entries.update(conv=cs, ssm=ss)
    x = x + a
    y, _ = _ffn(p, cfg, rms_norm(p["norm2"], x, cfg.norm_eps), flags)
    return x + y, entries


def trunk_prefill(layers: Params, cfg: ArchConfig, x: jax.Array,
                  positions: jax.Array, flags: Flags, cache: Dict[str, Any]):
    """Prefill trunk: scan over layers, stacking cache entries [L, ...]."""
    S = x.shape[1]
    C = cache["k"].shape[2] if "k" in cache else None

    def body(carry, lp):
        x, aux = carry
        x, entries = block_prefill(lp, cfg, x, positions, flags)
        if "k" in entries and C is not None:
            entries["k"] = _ring_fill(jnp.zeros_like(cache["k"][0]),
                                      entries["k"], C)
            entries["v"] = _ring_fill(jnp.zeros_like(cache["v"][0]),
                                      entries["v"], C)
        return (x, aux), entries

    body_fn = _remat(body, flags)
    (x, _), stacked = scan_layers(body_fn, (x, jnp.float32(0.0)), layers,
                                  unroll=flags.unroll_layers)
    new_cache = dict(cache)
    new_cache.update(stacked)
    new_cache["step"] = jnp.asarray(S, jnp.int32)
    if "pos" in cache:
        pos = jnp.broadcast_to(positions[:, :], positions.shape)
        new_cache["pos"] = _ring_fill(cache["pos"], pos,
                                      cache["pos"].shape[1])
    return x, new_cache


def block_decode(p: Params, cfg: ArchConfig, x: jax.Array,
                 layer_cache: Dict[str, Any], pos_slots: jax.Array,
                 step: jax.Array, flags: Flags):
    """One-token decode for one layer.  Returns (x, updated layer cache)."""
    if cfg.block_type == RWKV6:
        xn = rms_norm(p["norm1"], x, cfg.norm_eps)
        h, tprev, wkv = rwkv_mod.time_mix(
            p["rwkv"], cfg, xn, layer_cache["tmix_prev"],
            layer_cache["wkv"], flags, decode=True)
        x = x + h
        xn2 = rms_norm(p["norm2"], x, cfg.norm_eps)
        h, cprev = rwkv_mod.channel_mix(p["rwkv"], cfg, xn2,
                                        layer_cache["cmix_prev"])
        return x + h, {"tmix_prev": tprev, "wkv": wkv, "cmix_prev": cprev}
    xn = rms_norm(p["norm1"], x, cfg.norm_eps)
    a, ck, cv, cpos = attn.attn_decode(
        p["attn"], cfg, xn, layer_cache["k"], layer_cache["v"],
        pos_slots, step, flags)
    out_cache: Dict[str, Any] = {"k": ck, "v": cv}
    if cfg.block_type == HYBRID:
        s, cs, ss = ssm_mod.ssm_apply(p["ssm"], cfg, xn, layer_cache["conv"],
                                      layer_cache["ssm"], flags, decode=True)
        a = 0.5 * (rms_norm(p["fuse_norm_a"], a, cfg.norm_eps)
                   + rms_norm(p["fuse_norm_s"], s, cfg.norm_eps))
        out_cache.update(conv=cs, ssm=ss)
    x = x + a
    y, _ = _ffn(p, cfg, rms_norm(p["norm2"], x, cfg.norm_eps), flags)
    return x + y, out_cache


def trunk_decode(layers: Params, cfg: ArchConfig, x: jax.Array,
                 cache: Dict[str, Any], flags: Flags):
    """Scan over layers threading per-layer caches; returns (x, cache)."""
    step = cache["step"]
    pos_slots = cache.get("pos")
    layer_keys = [k for k in cache if k not in ("step", "pos")]
    layer_caches = {k: cache[k] for k in layer_keys}

    def body(carry, inp):
        x = carry
        lp, lc = inp
        x, new_lc = block_decode(lp, cfg, x, lc, pos_slots, step, flags)
        return x, new_lc

    x, new_layer_caches = scan_layers(body, x, (layers, layer_caches),
                                      unroll=flags.unroll_layers)
    new_cache = dict(cache)
    new_cache.update(new_layer_caches)
    new_cache["step"] = step + 1
    if pos_slots is not None:
        C = pos_slots.shape[1]
        slot = jnp.mod(step, C)
        B = pos_slots.shape[0]
        new_cache["pos"] = jax.lax.dynamic_update_slice_in_dim(
            pos_slots, jnp.broadcast_to(step, (B, 1)).astype(jnp.int32),
            slot, axis=1)
    return x, new_cache


def trunk_decode_paged(layers: Params, cfg: ArchConfig, x: jax.Array,
                       k_pools: jax.Array, v_pools: jax.Array,
                       page_table: jax.Array, lengths: jax.Array,
                       flags: Flags):
    """Scan over layers decoding one token per sequence straight from the
    paged KV pool (no dense per-slot cache).

    x          [B, 1, D]
    k/v_pools  [L, P, T, KV, hd]  per-layer page pools
    page_table [B, MP] int32      pool page indices (-1 pad)
    lengths    [B] int32          tokens stored per sequence (pre-step)

    The block body mirrors :func:`block_decode`'s DENSE/MOE branch
    exactly (norm1 -> attention -> residual -> norm2 -> ffn) with
    :func:`attn.attn_decode_paged` standing in for the slot-cache
    attention.  Returns (x, k_pools, v_pools) with the new token's K/V
    scattered into each sequence's tail page in every layer.
    """

    def body(carry, inp):
        x = carry
        lp, kp, vp = inp
        xn = rms_norm(lp["norm1"], x, cfg.norm_eps)
        a, kp, vp = attn.attn_decode_paged(lp["attn"], cfg, xn, kp, vp,
                                           page_table, lengths, flags)
        x = x + a
        y, _ = _ffn(lp, cfg, rms_norm(lp["norm2"], x, cfg.norm_eps), flags)
        return x + y, (kp, vp)

    x, (k_pools, v_pools) = scan_layers(body, x, (layers, k_pools, v_pools),
                                        unroll=flags.unroll_layers)
    return x, k_pools, v_pools
