"""Shared layers: norms, embeddings, RoPE, MLPs, chunked cross-entropy.

Conventions:
  * parameters are plain nested dicts of jax.Arrays;
  * activations flow in ``cfg.dtype`` (bf16 in production), softmax/norm
    statistics in float32;
  * every init function takes an ``rng`` and returns the param subtree —
    dry-run gets shapes via ``jax.eval_shape`` over the same functions.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------- norms
def rms_norm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(dt)


def layer_norm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


def head_rms_norm(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Per-head qk-norm (chameleon), no learned scale."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt)


# ------------------------------------------------------------- linear init
def dense_init(rng, d_in: int, d_out: int, dtype,
               bias: bool = False, scale: Optional[float] = None) -> Params:
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(rng, (d_in, d_out), jnp.float32)
               * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# --------------------------------------------------------------- embedding
def embed_init(rng, vocab: int, d: int, dtype) -> Params:
    return {"table": (jax.random.normal(rng, (vocab, d), jnp.float32)
                      * 0.02).astype(dtype)}


def embed_lookup(p: Params, ids: jax.Array) -> jax.Array:
    return jnp.take(p["table"], ids, axis=0)


def embed_logits(p: Params, x: jax.Array) -> jax.Array:
    """Tied readout: x @ table.T"""
    return x @ p["table"].T


# -------------------------------------------------------------------- RoPE
def rope_angles(positions: jax.Array, head_dim: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    """positions [*, S] -> (sin, cos) each [*, S, head_dim/2], float32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x [..., S, H, hd]; sin/cos [..., S, hd/2] broadcast over heads."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    s, c = sin[..., None, :], cos[..., None, :]  # add head axis
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dt)


# --------------------------------------------------------------------- MLPs
def mlp_init(rng, cfg) -> Params:
    dt = dtype_of(cfg)
    ks = jax.random.split(rng, 3)
    if cfg.act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], cfg.d_model, cfg.d_ff, dt),
            "w_up": dense_init(ks[1], cfg.d_model, cfg.d_ff, dt),
            "w_down": dense_init(ks[2], cfg.d_ff, cfg.d_model, dt),
        }
    return {
        "w_up": dense_init(ks[0], cfg.d_model, cfg.d_ff, dt),
        "w_down": dense_init(ks[1], cfg.d_ff, cfg.d_model, dt),
    }


def mlp_apply(p: Params, x: jax.Array, act: str) -> jax.Array:
    from repro.sharding.constraints import constrain
    if act == "swiglu":
        g = jax.nn.silu(dense(p["w_gate"], x))
        h = constrain(g * dense(p["w_up"], x), "ffn_hidden")
        return dense(p["w_down"], h)
    h = constrain(jax.nn.gelu(dense(p["w_up"], x)), "ffn_hidden")
    return dense(p["w_down"], h)


# --------------------------------------------------- chunked cross-entropy
def chunked_softmax_xent(logits_fn, x: jax.Array, labels: jax.Array,
                         chunk: int = 512, unroll: bool = True) -> jax.Array:
    """Mean token cross-entropy without materializing [B, S, V] at once.

    ``logits_fn(x_chunk) -> [B, c, V]``; the sequence axis is processed in
    chunks so peak memory is O(B * chunk * V).  Vocab may be sharded —
    the max/sum reductions lower to small collectives.
    """
    B, S, _ = x.shape
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    xs = x.reshape(B, n, chunk, -1).swapaxes(0, 1)          # [n, B, c, D]
    ys = labels.reshape(B, n, chunk).swapaxes(0, 1)         # [n, B, c]

    def body(carry, inp):
        xc, yc = inp
        logits = logits_fn(xc).astype(jnp.float32)          # [B, c, V]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    if unroll:
        total = jnp.float32(0.0)
        for i in range(n):
            total, _ = body(total, (xs[i], ys[i]))
    else:
        total, _ = jax.lax.scan(body, jnp.float32(0.0), (xs, ys))
    return total / (B * S)


def causal_mask(q_pos: jax.Array, k_pos: jax.Array,
                window: Optional[int] = None) -> jax.Array:
    """Boolean [.., Q, K] mask: k attends-able from q (causal, opt. SWA)."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m
