"""Scan helpers: lax.scan with an optional python-loop unroll.

Unrolled mode exists for two reasons:
  * **analysis** — XLA's cost_analysis counts a while-loop body once, so
    the dry-run measures true per-layer cost from unroll@L=2 − scan@L=2;
  * **perf** — scan-vs-unroll is a real TPU compile-time/ICI-overlap
    trade-off (§Perf lever).
Semantics are identical; tests assert bit-equality.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


def scan_layers(body: Callable, carry: Any, xs: Any,
                unroll: bool = False) -> Tuple[Any, Any]:
    """Like jax.lax.scan(body, carry, xs) with optional python unroll."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    leaves = jax.tree_util.tree_leaves(xs)
    L = leaves[0].shape[0]
    ys = []
    for i in range(L):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree_util.tree_map(
            lambda *zs: jnp.stack(zs), *ys)
    else:
        stacked = None
    return carry, stacked
