"""Mixture-of-Experts FFN: token-choice top-k routing with capacity dispatch.

GShard/Switch-style einsum formulation — dispatch/combine are one-hot
matmuls, which (a) compiles cleanly under SPMD (the E axis sharded over the
model mesh axis emits all-to-alls), and (b) gives deterministic capacity-
bounded compute, the production norm on TPUs.

dbrx-132b: 16 experts / top-4  → experts shard 1:1 on the 16-way model axis
mixtral-8x22b: 8 experts / top-2 → E < mesh; the per-expert FFN hidden dim
  is TP-sharded instead (see sharding rules — divisibility fallback).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dtype_of


def moe_init(rng, cfg) -> Params:
    dt = dtype_of(cfg)
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(rng, 4)
    std = 1.0 / jnp.sqrt(D)
    return {
        "router": (jax.random.normal(ks[0], (D, E), jnp.float32) * 0.02),
        "w_gate": (jax.random.normal(ks[1], (E, D, F), jnp.float32)
                   * std).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, D, F), jnp.float32)
                 * std).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, F, D), jnp.float32)
                   / jnp.sqrt(F)).astype(dt),
    }


def _top_k_gating(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """logits [T, E] -> (weights [T, k], expert ids [T, k]); softmax over
    the selected k (dbrx/mixtral convention)."""
    vals, idx = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(vals.astype(jnp.float32), axis=-1)
    return w, idx


#: tokens per dispatch group — bounds the [g, E, C] one-hot tensors and the
#: dispatch-einsum FLOPs (GShard groups); capacity is enforced per group.
GROUP_TOKENS = 1024


def moe_apply(p: Params, cfg, x: jax.Array,
              flags=None) -> Tuple[jax.Array, jax.Array]:
    """x [B, S, D] -> (y [B, S, D], aux_loss []).

    GShard-style GROUPED capacity dispatch: tokens are split into groups of
    ``GROUP_TOKENS``; each group routes independently with capacity
    C = ceil(top_k * g * cf / E).  Groups ride the batch sharding, experts
    ride the model axis (dbrx) — the dispatch einsum then lowers to the
    canonical all-to-all.  Overflow tokens pass through on the residual.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    g = min(getattr(flags, "moe_group", None) or GROUP_TOKENS, T)
    while T % g:            # shapes are static; find a clean divisor
        g //= 2
    G = T // g
    C = int(-(-K * g * cfg.capacity_factor // E))
    xt = x.reshape(G, g, D)

    logits = (xt.astype(jnp.float32) @ p["router"])            # [G, g, E]
    weights, ids = _top_k_gating(logits, K)                    # [G, g, K]

    # position of each (token, choice) within its expert's per-group capacity
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.int32)           # [G, g, K, E]
    flat = onehot.reshape(G, g * K, E)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(G, g, K, E)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)             # [G, g, K]
    keep = pos < C
    w = weights * keep

    # dispatch [G, g, E, C]
    slot_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                             dtype=x.dtype)[..., :C]           # [G, g, K, C]
    disp = jnp.einsum("gtke,gtkc->gtec", onehot.astype(x.dtype), slot_oh)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", onehot.astype(jnp.float32),
                      slot_oh.astype(jnp.float32), w).astype(x.dtype)

    # expert compute: [E, G, C, D] (the G<->E transpose is the all-to-all)
    xin = jnp.einsum("gtec,gtd->egcd", disp, xt)
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xin, p["w_gate"]))
        h = h * jnp.einsum("egcd,edf->egcf", xin, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("egcd,edf->egcf", xin, p["w_up"]))
    xout = jnp.einsum("egcf,efd->egcd", h, p["w_down"])        # [E, G, C, D]

    y = jnp.einsum("gtec,egcd->gtd", comb, xout).reshape(B, S, D)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=(0, 1))
    ce = jnp.mean(onehot[:, :, 0].astype(jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return y, aux
