"""RWKV-6 "Finch": attention-free time-mix with data-dependent decay.

Recurrence (per head, head dim N = rwkv_head_dim):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          S in R^{N x N}
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)      (bonus u for current token)

with w_t in (0,1)^N *data-dependent* (the Finch contribution: w_t from a
token-shifted low-rank MLP).  We implement the CHUNKED parallel form — the
TPU-native adaptation (MXU-friendly matmuls instead of a length-S scalar
loop; same trick the paper's GPU kernel plays with warp tiles):

  within a chunk of length T: cumulative decay products A_t = prod_{<=t} w,
  intra-chunk contributions via a decay-ratio-masked score matrix, inter-
  chunk via the carried state.  Chunk math is exercised against the naive
  recurrence in tests and the Pallas kernel mirrors it block-for-block.

Decode is O(1): one recurrence step on state [B, H, N, N].
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.flags import Flags, DEFAULT_FLAGS
from repro.models.layers import (Params, dense, dense_init, dtype_of,
                                 rms_norm, rms_norm_init)


def rwkv_init(rng, cfg) -> Params:
    dt = dtype_of(cfg)
    D = cfg.d_model
    N = cfg.rwkv_head_dim
    H = D // N
    ks = jax.random.split(rng, 10)
    lora = max(32, D // 64)
    return {
        # time-mix projections
        "wr": dense_init(ks[0], D, D, dt),
        "wk": dense_init(ks[1], D, D, dt),
        "wv": dense_init(ks[2], D, D, dt),
        "wg": dense_init(ks[3], D, D, dt),
        "wo": dense_init(ks[4], D, D, dt),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x A) B))
        "decay_w0": jnp.full((D,), -6.0, jnp.float32),
        "decay_A": dense_init(ks[5], D, lora, dt),
        "decay_B": dense_init(ks[6], lora, D, dt),
        "bonus_u": jnp.zeros((H, N), jnp.float32),
        # token-shift mixing coefficients
        "mu_r": jnp.full((D,), 0.5, jnp.float32),
        "mu_k": jnp.full((D,), 0.5, jnp.float32),
        "mu_v": jnp.full((D,), 0.5, jnp.float32),
        "mu_g": jnp.full((D,), 0.5, jnp.float32),
        "mu_w": jnp.full((D,), 0.5, jnp.float32),
        "ln_x": rms_norm_init(D),
        # channel-mix
        "cm_k": dense_init(ks[7], D, cfg.d_ff, dt),
        "cm_v": dense_init(ks[8], cfg.d_ff, D, dt),
        "cm_r": dense_init(ks[9], D, D, dt),
        "mu_ck": jnp.full((D,), 0.5, jnp.float32),
        "mu_cr": jnp.full((D,), 0.5, jnp.float32),
    }


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """shifted(x)_t = x_{t-1}; prev [B, 1, D] supplies x_{-1}."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x * mu + xs * (1.0 - mu)


def _rkvwg(p: Params, cfg, x: jax.Array, prev: jax.Array,
           fuse: bool = False):
    B, S, D = x.shape
    N = cfg.rwkv_head_dim
    H = D // N
    xs = _token_shift(x, prev)
    if fuse:
        # fold mu into the weights: one matmul against x, one against xs
        # (x/xs are each (all-)gathered ONCE instead of 5x under TP)
        names = ("wr", "wk", "wv", "wg")
        mus = (p["mu_r"], p["mu_k"], p["mu_v"], p["mu_g"])
        dt = x.dtype
        wx = jnp.concatenate(
            [(mu[:, None] * p[n]["w"].astype(jnp.float32)).astype(dt)
             for n, mu in zip(names, mus)]
            + [(p["mu_w"][:, None]
                * p["decay_A"]["w"].astype(jnp.float32)).astype(dt)],
            axis=1)
        ws = jnp.concatenate(
            [((1.0 - mu)[:, None] * p[n]["w"].astype(jnp.float32)).astype(dt)
             for n, mu in zip(names, mus)]
            + [((1.0 - p["mu_w"])[:, None]
                * p["decay_A"]["w"].astype(jnp.float32)).astype(dt)],
            axis=1)
        fused = x @ wx + xs.astype(x.dtype) @ ws       # [B,S,4D+lora]
        r, k, v, g, aw = jnp.split(
            fused, [D, 2 * D, 3 * D, 4 * D], axis=-1)
    else:
        r = dense(p["wr"], _mix(x, xs, p["mu_r"]).astype(x.dtype))
        k = dense(p["wk"], _mix(x, xs, p["mu_k"]).astype(x.dtype))
        v = dense(p["wv"], _mix(x, xs, p["mu_v"]).astype(x.dtype))
        g = dense(p["wg"], _mix(x, xs, p["mu_g"]).astype(x.dtype))
        xw = _mix(x, xs, p["mu_w"]).astype(x.dtype)
        aw = dense(p["decay_A"], xw)
    dec = p["decay_w0"] + jnp.tanh(aw.astype(jnp.float32)) \
        @ p["decay_B"]["w"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec))                                  # (0,1)^D
    shape = (B, S, H, N)
    return (r.reshape(shape), k.reshape(shape), v.reshape(shape),
            jax.nn.silu(g), w.reshape(shape))


def wkv_chunked(r, k, v, w, u, state, chunk: int = 64,
                unroll: bool = False):
    """Chunked WKV6.  r,k,v,w: [B,S,H,N]; u: [H,N]; state [B,H,N,N].

    Returns (out [B,S,H,N], final state).  All math float32.
    """
    B, S, H, N = r.shape
    T = min(chunk, S)
    assert S % T == 0, (S, T)
    nc = S // T
    f32 = jnp.float32
    r, k, v, w = (a.astype(f32) for a in (r, k, v, w))
    logw = jnp.log(jnp.maximum(w, 1e-38))                       # [B,S,H,N]
    rc = r.reshape(B, nc, T, H, N).swapaxes(0, 1)
    kc = k.reshape(B, nc, T, H, N).swapaxes(0, 1)
    vc = v.reshape(B, nc, T, H, N).swapaxes(0, 1)
    lw = logw.reshape(B, nc, T, H, N).swapaxes(0, 1)

    def body(state, inp):
        rt, kt, vt, lwt = inp                                   # [B,T,H,N]
        # cumulative log-decay within the chunk, EXCLUSIVE of position t
        cum = jnp.cumsum(lwt, axis=1)                           # incl.
        cum_excl = cum - lwt
        A = jnp.exp(cum_excl)                                   # prod_{<t}
        # inter-chunk: o_t += (r_t * A_t) @ state
        r_dec = rt * A
        inter = jnp.einsum("bthn,bhnm->bthm", r_dec, state)
        # intra-chunk: pairs s < t with decay prod_{s<j<t} w_j
        #   = exp(cum_excl_t - cum_s).  Computed via the PAIRWISE exponent
        # difference so every exponent is <= 0 (factored forms like
        # k*exp(-cum) overflow for strong decay).
        diff = cum_excl[:, :, None] - cum[:, None, :]           # [B,T,T,H,N]
        tri = jnp.tril(jnp.ones((T, T), bool), k=-1)
        decay_ts = jnp.exp(jnp.where(tri[None, :, :, None, None], diff,
                                     -jnp.inf))
        scores = jnp.einsum("bthn,bshn,btshn->bhts", rt, kt, decay_ts)
        intra = jnp.einsum("bhts,bshm->bthm", scores, vt)
        # current-token bonus u
        bonus = jnp.einsum("bthn,bthn,bthm->bthm",
                           rt, u[None, None] * kt, vt)
        out = inter + intra + bonus
        # state update: S' = diag(prod chunk) S + sum_s (prod_{>s} w) k_s v_s
        total = cum[:, -1]                                      # [B,H,N]
        k_carry = kt * jnp.exp(total[:, None] - cum)            # prod_{>s}
        state = state * jnp.exp(total)[..., None] + \
            jnp.einsum("bshn,bshm->bhnm", k_carry, vt)
        return state, out

    if unroll:
        st = state.astype(f32)
        outs_l = []
        for i in range(nc):
            st, o = body(st, (rc[i], kc[i], vc[i], lw[i]))
            outs_l.append(o)
        state, outs = st, jnp.stack(outs_l)
    else:
        state, outs = jax.lax.scan(body, state.astype(f32),
                                   (rc, kc, vc, lw))
    out = outs.swapaxes(0, 1).reshape(B, S, H, N)
    return out, state


def wkv_step(r, k, v, w, u, state):
    """One decode step.  r,k,v,w [B,H,N]; state [B,H,N,N] -> (o, state')."""
    f32 = jnp.float32
    r, k, v, w = (a.astype(f32) for a in (r, k, v, w))
    kv = jnp.einsum("bhn,bhm->bhnm", k, v)
    o = jnp.einsum("bhn,bhnm->bhm", r, state + u[None, ..., None] * kv)
    state = state * w[..., None] + kv
    return o, state


def time_mix(p: Params, cfg, x: jax.Array, prev_x: jax.Array,
             state: jax.Array, flags: Flags = DEFAULT_FLAGS,
             decode: bool = False):
    """x [B,S,D]; prev_x [B,1,D]; state [B,H,N,N].

    Returns (out [B,S,D], new_prev_x, new_state).
    """
    B, S, D = x.shape
    N = cfg.rwkv_head_dim
    r, k, v, g, w = _rkvwg(p, cfg, x, prev_x,
                           fuse=getattr(flags, "fuse_rwkv_proj", False))
    u = p["bonus_u"]
    if decode:
        o, state = wkv_step(r[:, 0], k[:, 0], v[:, 0], w[:, 0], u, state)
        o = o[:, None]
    else:
        if flags.use_kernels:
            from repro.kernels import ops as kops
            o, state = kops.rwkv6_scan(r, k, v, w, u, state)
        else:
            o, state = wkv_chunked(r, k, v, w, u, state,
                                   chunk=flags.scan_chunk,
                                   unroll=flags.unroll_scans)
    o = o.reshape(B, S, D).astype(x.dtype)
    o = rms_norm(p["ln_x"], o, cfg.norm_eps) * g
    out = dense(p["wo"], o)
    return out, x[:, -1:], state


def channel_mix(p: Params, cfg, x: jax.Array, prev_x: jax.Array):
    """RWKV channel-mix (squared-relu FFN with receptance gate)."""
    from repro.sharding.constraints import constrain
    xs = _token_shift(x, prev_x)
    xk = _mix(x, xs, p["mu_ck"]).astype(x.dtype)
    xr = _mix(x, xs, p["mu_cr"]).astype(x.dtype)
    h = constrain(jnp.square(jax.nn.relu(dense(p["cm_k"], xk))),
                  "ffn_hidden")
    kv = dense(p["cm_v"], h)
    return jax.nn.sigmoid(dense(p["cm_r"], xr)) * kv, x[:, -1:]


def rwkv_state_init(cfg, batch: int, dtype=jnp.float32) -> Tuple:
    N = cfg.rwkv_head_dim
    H = cfg.d_model // N
    return (jnp.zeros((batch, 1, cfg.d_model), dtype),   # time-mix shift
            jnp.zeros((batch, H, N, N), jnp.float32),    # wkv state
            jnp.zeros((batch, 1, cfg.d_model), dtype))   # channel-mix shift
