"""Selective SSM (Mamba-2/SSD style) — the SSM branch of hymba blocks.

Per head h (P = head channel dim, N = ssm_state):

    h_t = exp(dt_t * A_h) h_{t-1} + dt_t * (x_t ⊗ B_t)     h in R^{P x N}
    y_t = h_t C_t + D_h x_t

with dt_t data-dependent (softplus), A_h < 0 learned scalars per head, and
B_t, C_t ∈ R^N input-dependent (the "selective" part).  Decay is scalar per
(head, t) — the Mamba-2 simplification — which keeps the chunked parallel
form's decay mask at [T, T, H] (TPU adaptation: block matmuls on the MXU,
not a length-S scalar scan; see DESIGN.md).

All pairwise decay exponents are differences of cumulative sums and ≤ 0 —
numerically safe.  Decode is an O(1) state update.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.flags import Flags, DEFAULT_FLAGS
from repro.models.layers import Params, dense, dense_init, dtype_of

CONV_K = 4  # depthwise causal conv kernel width


def ssm_init(rng, cfg) -> Params:
    dt_ = dtype_of(cfg)
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    H = cfg.ssm_heads or max(1, d_in // 64)
    N = cfg.ssm_state
    ks = jax.random.split(rng, 6)
    return {
        "in_proj": dense_init(ks[0], D, 2 * d_in, dt_),   # [x, gate z]
        "conv_w": (jax.random.normal(ks[1], (CONV_K, d_in), jnp.float32)
                   * 0.2).astype(dt_),
        "conv_b": jnp.zeros((d_in,), dt_),
        "bc_proj": dense_init(ks[2], d_in, 2 * N, dt_),   # B_t, C_t
        "dt_proj": dense_init(ks[3], d_in, H, dt_, bias=True),
        "A_log": jnp.zeros((H,), jnp.float32),            # A = -exp(A_log)
        "D_skip": jnp.ones((H,), jnp.float32),
        "out_proj": dense_init(ks[4], d_in, D, dt_),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 init_state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv.  x [B,S,C]; w [K,C]; init_state [B,K-1,C].

    Returns (y [B,S,C], new_state [B,K-1,C])."""
    K = w.shape[0]
    xp = jnp.concatenate([init_state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else init_state
    return jax.nn.silu(y + b), new_state


def ssd_chunked(xh, dt, A, Bm, Cm, state, chunk: int = 64,
                unroll: bool = False):
    """Chunked SSD scan.

    xh [B,S,H,P] head inputs; dt [B,S,H]; A [H]; Bm/Cm [B,S,N];
    state [B,H,P,N].  Returns (y [B,S,H,P], final state).  float32 math.
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    T = min(chunk, S)
    assert S % T == 0
    nc = S // T
    f32 = jnp.float32
    xh, dt, Bm, Cm = (a.astype(f32) for a in (xh, dt, Bm, Cm))
    loga = dt * A[None, None, :]                           # [B,S,H]  (<= 0)

    def resh(a, trailing):
        return a.reshape((B, nc, T) + trailing).swapaxes(0, 1)

    xs = resh(xh, (H, P))
    dts = resh(dt, (H,))
    las = resh(loga, (H,))
    Bs = resh(Bm, (N,))
    Cs = resh(Cm, (N,))

    def body(state, inp):
        xc, dtc, lac, Bc, Cc = inp
        cum = jnp.cumsum(lac, axis=1)                      # [B,T,H] inclusive
        # inter-chunk: y_t += exp(cum_t) * (state · C_t)
        inter = jnp.einsum("bhpn,btn->bthp", state, Cc) * \
            jnp.exp(cum)[..., None]
        # wait: contribution of carried state to y_t decays by prod_{j<=t} a_j
        # (state enters before token 1) — exp(cum_t) inclusive is correct.
        # intra-chunk: s <= t, decay exp(cum_t - cum_s), weight dt_s
        diff = cum[:, :, None] - cum[:, None, :]           # [B,T,T,H]
        tri = jnp.tril(jnp.ones((T, T), bool))
        L = jnp.exp(jnp.where(tri[None, ..., None], diff, -jnp.inf))
        scores = jnp.einsum("btn,bsn,btsh,bsh->bhts", Cc, Bc, L, dtc)
        intra = jnp.einsum("bhts,bshp->bthp", scores, xc)
        y = inter + intra
        # state carry: h' = exp(total) h + sum_s exp(total - cum_s) dt_s x_s B_s
        total = cum[:, -1]                                 # [B,H]
        w_carry = jnp.exp(total[:, None] - cum) * dtc      # [B,T,H]
        state = state * jnp.exp(total)[..., None, None] + \
            jnp.einsum("bth,bthp,btn->bhpn", w_carry, xc, Bc)
        return state, y

    if unroll:
        st = state.astype(f32)
        ys_l = []
        for i in range(nc):
            st, y_i = body(st, (xs[i], dts[i], las[i], Bs[i], Cs[i]))
            ys_l.append(y_i)
        state, ys = st, jnp.stack(ys_l)
    else:
        state, ys = jax.lax.scan(body, state.astype(f32),
                                 (xs, dts, las, Bs, Cs))
    y = ys.swapaxes(0, 1).reshape(B, S, H, P)
    return y, state


def ssd_step(xh, dt, A, Bm, Cm, state):
    """One decode step.  xh [B,H,P]; dt [B,H]; Bm/Cm [B,N]; state [B,H,P,N]."""
    f32 = jnp.float32
    xh, dt, Bm, Cm = (a.astype(f32) for a in (xh, dt, Bm, Cm))
    a = jnp.exp(dt * A[None, :])                           # [B,H]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bm)
    state = state * a[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cm)
    return y, state


def ssm_apply(p: Params, cfg, x: jax.Array, conv_state: jax.Array,
              ssm_state: jax.Array, flags: Flags = DEFAULT_FLAGS,
              decode: bool = False):
    """x [B,S,D]; conv_state [B,K-1,d_in]; ssm_state [B,H,P,N].

    Returns (y [B,S,D], conv_state', ssm_state')."""
    B, S, D = x.shape
    d_in = cfg.ssm_expand * D
    H = cfg.ssm_heads or max(1, d_in // 64)
    P = d_in // H

    xz = dense(p["in_proj"], x)
    xc, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xc, p["conv_w"], p["conv_b"], conv_state)
    bc = dense(p["bc_proj"], xc)
    Bm, Cm = jnp.split(bc, 2, axis=-1)                     # [B,S,N] each
    dt = jax.nn.softplus(dense(p["dt_proj"], xc).astype(jnp.float32))
    A = -jnp.exp(p["A_log"])
    xh = xc.reshape(B, S, H, P)

    if decode:
        y, ssm_state = ssd_step(xh[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0],
                                ssm_state)
        y = y[:, None]
    else:
        if flags.use_kernels:
            from repro.kernels import ops as kops
            y, ssm_state = kops.ssd_scan(xh, dt, A, Bm, Cm, ssm_state)
        else:
            y, ssm_state = ssd_chunked(xh, dt, A, Bm, Cm, ssm_state,
                                       chunk=flags.scan_chunk,
                                       unroll=flags.unroll_scans)
    y = y + xh.astype(jnp.float32) * p["D_skip"][None, None, :, None]
    y = y.reshape(B, S, d_in).astype(x.dtype) * jax.nn.silu(z)
    return dense(p["out_proj"], y), conv_state, ssm_state


def ssm_state_init(cfg, batch: int, dtype=jnp.float32):
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    H = cfg.ssm_heads or max(1, d_in // 64)
    P = d_in // H
    return (jnp.zeros((batch, CONV_K - 1, d_in), dtype),
            jnp.zeros((batch, H, P, cfg.ssm_state), jnp.float32))
