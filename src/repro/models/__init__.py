"""repro.models — the architecture zoo (10 assigned archs)."""

from repro.models.zoo import Model, build_model

__all__ = ["Model", "build_model"]
