"""Encoder–decoder trunk (seamless-m4t): encoder + cross-attending decoder.

The audio frontend is a stub: the encoder consumes precomputed frame
embeddings [B, S_src, D] (``input_specs`` supplies them).  Decoder layers
carry self-attention (cached at decode) and cross-attention over encoder
output (K/V precomputed once at prefill and stored [L, B, S_src, KV, hd]).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.flags import Flags
from repro.models.layers import Params, rms_norm
from repro.models.scan_utils import scan_layers
from repro.models.transformer import (_ffn, init_cache,
                                      stacked_layers_init, trunk_train)


def encdec_init(rng, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(rng)
    return {
        "enc": stacked_layers_init(k1, cfg, cfg.num_encoder_layers),
        "dec": stacked_layers_init(k2, cfg, cfg.num_layers, cross=True),
    }


def encode(layers: Params, cfg: ArchConfig, src_emb: jax.Array,
           flags: Flags) -> jax.Array:
    B, S, _ = src_emb.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x, _ = trunk_train(layers["enc"], cfg, src_emb, positions, flags,
                       causal=False)
    return x


def _dec_block_train(p: Params, cfg, x, positions, enc_out, flags):
    xn = rms_norm(p["norm1"], x, cfg.norm_eps)
    x = x + attn.attn_forward(p["attn"], cfg, xn, positions, causal=True,
                              flags=flags)
    ek, ev = attn.cross_kv(p["cross"], cfg, enc_out)
    xn = rms_norm(p["norm3"], x, cfg.norm_eps)
    x = x + attn.cross_attn(p["cross"], cfg, xn, ek, ev, flags=flags)
    y, _ = _ffn(p, cfg, rms_norm(p["norm2"], x, cfg.norm_eps), flags)
    return x + y


def decode_train(layers: Params, cfg: ArchConfig, tgt_emb: jax.Array,
                 enc_out: jax.Array, flags: Flags) -> jax.Array:
    """Teacher-forced decoder pass."""
    B, S, _ = tgt_emb.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, lp):
        return _dec_block_train(lp, cfg, x, positions, enc_out, flags), None

    from repro.models.transformer import _remat
    body_fn = _remat(body, flags)
    x, _ = scan_layers(body_fn, tgt_emb, layers["dec"],
                       unroll=flags.unroll_layers)
    return x


def init_encdec_cache(cfg: ArchConfig, batch: int, seq_len: int,
                      src_len: int) -> Dict[str, Any]:
    cache = init_cache(cfg, batch, seq_len, n_layers=cfg.num_layers)
    KV, hd = cfg.num_kv_heads, cfg.head_dim_
    dt = jnp.dtype(cfg.dtype)
    cache["cross_k"] = jnp.zeros((cfg.num_layers, batch, src_len, KV, hd), dt)
    cache["cross_v"] = jnp.zeros((cfg.num_layers, batch, src_len, KV, hd), dt)
    return cache


def prefill(layers: Params, cfg: ArchConfig, tgt_emb: jax.Array,
            enc_out: jax.Array, cache: Dict[str, Any], flags: Flags):
    """Encoder output + target prefix -> hidden states + filled caches."""
    B, S, _ = tgt_emb.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    C = cache["k"].shape[2]

    def body(carry, lp):
        x = carry
        xn = rms_norm(lp["norm1"], x, cfg.norm_eps)
        a, (k, v) = attn.attn_forward(lp["attn"], cfg, xn, positions,
                                      causal=True, flags=flags,
                                      return_kv=True)
        x = x + a
        ek, ev = attn.cross_kv(lp["cross"], cfg, enc_out)
        xn = rms_norm(lp["norm3"], x, cfg.norm_eps)
        x = x + attn.cross_attn(lp["cross"], cfg, xn, ek, ev, flags=flags)
        y, _ = _ffn(lp, cfg, rms_norm(lp["norm2"], x, cfg.norm_eps), flags)
        if S < C:   # prompt shorter than cache: pad into the fixed slots
            k = jnp.zeros((B, C) + k.shape[2:], k.dtype).at[:, :S].set(k)
            v = jnp.zeros((B, C) + v.shape[2:], v.dtype).at[:, :S].set(v)
        entries = {"k": k[:, :C], "v": v[:, :C], "cross_k": ek, "cross_v": ev}
        return x + y, entries

    x, stacked = scan_layers(body, tgt_emb, layers["dec"],
                             unroll=flags.unroll_layers)
    new_cache = dict(cache)
    new_cache.update(stacked)
    new_cache["step"] = jnp.asarray(S, jnp.int32)
    slots = jnp.arange(C)
    pos_row = jnp.where(slots < S, slots, -1).astype(jnp.int32)
    new_cache["pos"] = jnp.broadcast_to(pos_row[None], (B, C))
    return x, new_cache


def decode_step(layers: Params, cfg: ArchConfig, x: jax.Array,
                cache: Dict[str, Any], flags: Flags):
    step = cache["step"]
    pos_slots = cache["pos"]

    def body(carry, inp):
        x = carry
        lp, lc = inp
        xn = rms_norm(lp["norm1"], x, cfg.norm_eps)
        a, ck, cv, _ = attn.attn_decode(lp["attn"], cfg, xn, lc["k"],
                                        lc["v"], pos_slots, step, flags)
        x = x + a
        xn = rms_norm(lp["norm3"], x, cfg.norm_eps)
        x = x + attn.cross_attn(lp["cross"], cfg, xn, lc["cross_k"],
                                lc["cross_v"], flags=flags)
        y, _ = _ffn(lp, cfg, rms_norm(lp["norm2"], x, cfg.norm_eps), flags)
        return x + y, {"k": ck, "v": cv,
                       "cross_k": lc["cross_k"], "cross_v": lc["cross_v"]}

    layer_keys = ("k", "v", "cross_k", "cross_v")
    lcs = {k: cache[k] for k in layer_keys}
    x, new_lcs = scan_layers(body, x, (layers["dec"], lcs),
                             unroll=flags.unroll_layers)
    new_cache = dict(cache)
    new_cache.update(new_lcs)
    new_cache["step"] = step + 1
    C = pos_slots.shape[1]
    slot = jnp.mod(step, C)
    B = pos_slots.shape[0]
    new_cache["pos"] = jax.lax.dynamic_update_slice_in_dim(
        pos_slots, jnp.broadcast_to(step, (B, 1)).astype(jnp.int32),
        slot, axis=1)
    return x, new_cache
