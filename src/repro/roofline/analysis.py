"""Three-term roofline from compiled dry-run artifacts.

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

``cost_analysis()`` supplies FLOPs / bytes for the whole (global) program.
Collective bytes are NOT in cost_analysis: we parse the post-SPMD HLO and
sum result-shape bytes of every collective op, weighted per op kind by the
ring-traffic factor (all-reduce moves ~2x its tensor size per device;
gather/scatter/permute/all-to-all ~1x).  The post-SPMD module is
per-device, so Σ(weighted bytes) is per-device link traffic; multiplying
by chips gives the global ``collective_bytes`` of the formula (the two
chip factors cancel — the term equals per-device-bytes / link_bw).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.core.tiers import TPU_HBM_BW_Bps, TPU_ICI_BW_Bps, TPU_PEAK_FLOPS

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

#: per-device ring traffic multiplier by collective kind
_TRAFFIC_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(([^()]*)\)|([a-z0-9_\[\],{}:#\s]*?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> List[Tuple[str, int]]:
    """[(kind, result_bytes)] for every collective in the HLO module."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        if "-start" in line and f"{kind}-done" in hlo_text:
            pass  # async pair: count only the -start
        if f"{kind}-done(" in line:
            continue
        type_str = m.group(1) or m.group(2) or ""
        nbytes = _shape_bytes(type_str)
        if nbytes:
            out.append((kind, nbytes))
    return out


def collective_bytes_per_device(hlo_text: str) -> Dict[str, float]:
    per_kind: Dict[str, float] = {}
    for kind, nbytes in parse_collectives(hlo_text):
        per_kind[kind] = per_kind.get(kind, 0.0) + \
            nbytes * _TRAFFIC_FACTOR[kind]
    per_kind["total"] = sum(v for k, v in per_kind.items() if k != "total")
    return per_kind


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    coll_bytes_per_dev: float
    chips: int
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline (no-overlap upper bound ≈ max; report max term)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU at the roofline bound."""
        ideal = self.model_flops / (self.chips * TPU_PEAK_FLOPS)
        return ideal / self.step_time_s if self.step_time_s else 0.0

    def row(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "chips": self.chips,
        }


def roofline_terms(cost: dict, hlo_text: str, chips: int,
                   model_flops: float = 0.0) -> RooflineTerms:
    """``cost`` comes from the post-SPMD (per-device) module — verified
    empirically: an N-way-sharded matmul reports total/N flops.  So
    HLO_FLOPs(global) = per_device * chips, and the chips factor in each
    term's denominator cancels against it."""
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes_per_device(hlo_text)["total"]
    return RooflineTerms(
        compute_s=flops_dev / TPU_PEAK_FLOPS,
        memory_s=bytes_dev / TPU_HBM_BW_Bps,
        collective_s=coll / TPU_ICI_BW_Bps,
        hlo_flops=flops_dev * chips, hlo_bytes=bytes_dev * chips,
        coll_bytes_per_dev=coll,
        chips=chips, model_flops=model_flops)


def model_flops(cfg, shape, kind: Optional[str] = None) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference fwd), N = active."""
    n_active = cfg.active_param_count()
    kind = kind or shape.kind
    tokens = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens
