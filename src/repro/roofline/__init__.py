from repro.roofline.analysis import (collective_bytes_per_device,
                                     roofline_terms, model_flops)

__all__ = ["collective_bytes_per_device", "roofline_terms", "model_flops"]
