from repro.serve.engine import (EngineConfig, Request, ServeEngine,
                                SubmitSpec)
from repro.serve.kv_cache import PagedKVStore
from repro.serve.loadgen import (SweepReport, TenantLoad, VirtualClock,
                                 build_trace, run_sweep)

__all__ = ["EngineConfig", "Request", "ServeEngine", "SubmitSpec",
           "PagedKVStore", "TenantLoad", "VirtualClock", "build_trace",
           "run_sweep", "SweepReport"]
