from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.kv_cache import PagedKVStore

__all__ = ["EngineConfig", "Request", "ServeEngine", "PagedKVStore"]
