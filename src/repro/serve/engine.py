"""Continuous-batching serving engine with LMB-backed KV capacity.

The scheduler runs fixed decode slots (the jitted decode step's batch);
waiting/preempted requests' KV parks in the LMB pool via PagedKVStore.
The admission limit is pool capacity — onboard (HBM) only bounds the
number of *simultaneously decoding* requests, which is the paper's thesis
applied to serving.

Flow per request: admit -> prefill (bucketed padding) -> decode in a slot
-> [optional preempt: KV pages out to LMB; resume: pages back] -> finish.
Swap decisions consult the tier cost model; all movement is metered by
repro.core.metrics.

Multi-tenant QoS (repro.qos): requests carry a tenant id; when the engine
is built with an AdmissionController, every seating decision routes
through it — ADMIT seats the request, THROTTLE leaves it queued for a
later round, SHED rejects it outright (state "shed").  Completed request
latencies feed the tenant's SLO tracker, closing the loop.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import LMBHost
from repro.core.client import LMBSystem
from repro.core.pool import OutOfMemory
from repro.models.zoo import Model
from repro.obs.trace import DEFAULT_RING_CAPACITY, SpanTracer
from repro.qos.slo import AdmissionController, Decision
from repro.serve.kv_cache import PagedKVStore


@dataclasses.dataclass(frozen=True)
class SubmitSpec:
    """Typed submission: everything one request brings to the engine.

    Replaces the growing ``submit(prompt, max_new_tokens=..., ...)``
    positional/kwarg surface — load generators build these up front
    (``arrival_time_s`` stamps when the request entered the system, in
    the engine clock's timebase, so queueing delay counts toward TTFT),
    and policy code reads ``slo_deadline_s`` instead of re-deriving
    per-tenant targets."""

    prompt: np.ndarray                 # [S] int32 token ids
    max_new_tokens: int = 16
    tenant: str = "default"
    #: arrival timestamp in the engine clock's timebase; ``None`` means
    #: "now" (the clock value at submit time).  A trace replay sets it
    #: so admission/queueing delay is charged to TTFT.
    arrival_time_s: Optional[float] = None
    #: per-request SLO deadline (seconds from arrival to completion);
    #: recorded on the request for policy layers, not enforced here
    slo_deadline_s: Optional[float] = None
    #: hard deadline (seconds from arrival): a request not finished by
    #: ``arrival + deadline_s`` is CANCELLED — removed from the queue or
    #: pulled out of its decode slot mid-flight, its KV pages freed, and
    #: counted per-tenant (``cancelled_count`` in the SLO snapshot).
    #: ``None`` means no enforcement (the pre-deadline behavior).
    deadline_s: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "prompt",
                           np.asarray(self.prompt, np.int32))
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.deadline_s is not None and self.deadline_s < 0:
            raise ValueError("deadline_s must be >= 0")


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray                 # [S] int32
    max_new_tokens: int = 16
    tenant: str = "default"
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    seq_id: Optional[int] = None
    state: str = "waiting"     # waiting|active|preempted|done|shed|cancelled
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    last_token_at: Optional[float] = None
    done_at: Optional[float] = None
    slo_deadline_s: Optional[float] = None
    #: absolute engine-clock instant after which the request is cancelled
    deadline_s: Optional[float] = None
    #: why a cancelled request was cancelled ("deadline" | "capacity")
    cancel_reason: Optional[str] = None


@dataclasses.dataclass
class EngineConfig:
    decode_slots: int = 4
    max_seq_len: int = 256
    page_tokens: int = 32
    onboard_pages: int = 32            # HBM-tier KV budget
    prefill_bucket: int = 64
    #: feed each active sequence's next-decode page list to the KV
    #: store's prefetcher every batch round (exact future knowledge,
    #: moved as coalesced bursts).  Pure performance knob: tokens are
    #: identical with it off.
    kv_prefetch: bool = True
    #: pages of prefetch lookahead per round (0 disables the prefetcher
    #: outright, not just the engine-fed schedule)
    kv_prefetch_depth: int = 2
    #: initial compute-window estimate for the overlap scheduler; the
    #: engine refines it with measured decode-round times
    kv_compute_window_s: float = 1e-3
    #: pipeline the step: admission and next-round KV prefetch run at
    #: the END of each decode round, while the round's compute window
    #: is still draining the expander links (FabricManager.advance_links
    #: models the drain).  Tokens are byte-identical to the phased
    #: (admit -> prefetch -> decode) order; only the modeled exposed
    #: link wait changes (strictly down — bursts issue into a drained
    #: link under an open overlap window).
    pipeline: bool = True
    #: virtual decode-round duration: when set, the engine drains links
    #: and sizes the overlap window with this fixed figure instead of
    #: measured wall time, so a sweep driven by a virtual clock is
    #: machine-independent and seed-reproducible
    round_time_s: Optional[float] = None
    #: decode straight from the paged KV pool: every round runs ONE
    #: batched paged-attention step over all active slots against a
    #: DecodeView of the pool (union of the actives' pages, one
    #: coalesced read burst) instead of a per-request dense slot cache
    #: filled by host-side gather_seq swap-in.  Token streams are
    #: byte-identical to the dense path (the decode dispatcher's
    #: numerics mirror attn_decode bitwise).  Automatically falls back
    #: to the dense path for architectures the paged kernel does not
    #: cover (SWA rings, RWKV/HYBRID state, encoder-decoder).
    paged_decode: bool = True
    #: record spans (serve rounds, TTFT/token events, the KV data path)
    #: into a private tracer attached to the engine's fabric — unless
    #: the fabric already carries an enabled tracer (LMBSystem with
    #: ObsSpec.trace, or benchmarks' global tracer), which is reused
    trace: bool = False
    #: ring capacity of the engine-minted tracer
    trace_capacity: int = DEFAULT_RING_CAPACITY


class ServeEngine:
    """``lmb`` is the LMB stack the KV store pages against: an
    :class:`~repro.core.client.LMBSystem` session (the client API) or a
    bare :class:`~repro.core.api.LMBHost` for low-level wiring."""

    def __init__(self, model: Model, params,
                 lmb: Union[LMBSystem, LMBHost],
                 ecfg: EngineConfig, device_id: str = "tpu0",
                 qos: Optional[AdmissionController] = None,
                 clock: Optional[Callable[[], float]] = None):
        host = lmb.host() if isinstance(lmb, LMBSystem) else lmb
        self.model = model
        self.params = params
        self.ecfg = ecfg
        self.cfg = model.cfg
        self.qos = qos
        #: timestamp source for request latency accounting (TTFT/ITL);
        #: defaults to wall time — a load harness injects a
        #: VirtualClock so latency figures are machine-independent
        self.clock: Callable[[], float] = clock or time.monotonic
        self.shed: List[int] = []
        self.cancelled: List[int] = []
        self._tenant_live: Dict[str, int] = {}   # in-flight reqs per tenant
        self.metrics = host.metrics
        self._fm = host.fm              # link drain + placement queries
        # tracing: reuse an already-enabled fabric tracer (session/global)
        # or, when the config asks, mint one and attach it to the fabric
        # BEFORE the KV store builds its LinkedBuffer, so the whole KV
        # data path records into the same ring as the serve rounds
        self.trace: SpanTracer = host.fm.tracer
        if ecfg.trace and not self.trace.enabled:
            self.trace = SpanTracer(capacity=ecfg.trace_capacity)
            host.fm.tracer = self.trace
        overlap = None
        if ecfg.kv_prefetch and ecfg.kv_prefetch_depth:
            # admission gate for prefetch bursts: sized to the decode
            # round's compute window (EWMA-learned from measured rounds)
            from repro.core.overlap import OverlapScheduler
            from repro.core.tiers import TierKind, tpu_tiers
            overlap = OverlapScheduler(
                tpu_tiers()[TierKind.HOST_DRAM],
                compute_window_s=ecfg.kv_compute_window_s,
                trace=self.trace)
        self.kv = PagedKVStore(
            cfg=model.cfg, host=host, device_id=device_id,
            page_tokens=ecfg.page_tokens, onboard_pages=ecfg.onboard_pages,
            prefetch_depth=(ecfg.kv_prefetch_depth if ecfg.kv_prefetch
                            else 0),
            overlap=overlap)
        self.waiting: deque[Request] = deque()
        self.active: Dict[int, Request] = {}      # slot -> request
        self.requests: Dict[int, Request] = {}
        self._next_req = 0
        self._decode_cache = None                 # dense cache for slots
        self._slot_free = list(range(ecfg.decode_slots))[::-1]
        self._prefill_fn = jax.jit(model.prefill)
        self._decode_fn = jax.jit(model.decode_step)
        #: paged decode: the batched pool-direct step (retires the dense
        #: slot cache for decode); dense stays for uncovered archs
        self._use_paged = (ecfg.paged_decode
                           and model.supports_paged_decode())
        self._paged_fn = (jax.jit(model.decode_step_paged)
                          if self._use_paged else None)
        self._max_pages = -(-ecfg.max_seq_len // ecfg.page_tokens)
        self.paged_rounds = 0

    # -------------------------------------------------------------- intake
    def submit(self, spec: Union[SubmitSpec, np.ndarray],
               max_new_tokens: int = 16, tenant: str = "default") -> int:
        """Enqueue one request described by a :class:`SubmitSpec`.

        The pre-redesign ``submit(prompt, max_new_tokens=..., tenant=...)``
        signature still works as a deprecated shim (the positional
        prompt is wrapped into a spec) so out-of-tree callers keep
        running; in-repo callers all pass specs."""
        if not isinstance(spec, SubmitSpec):
            warnings.warn(
                "ServeEngine.submit(prompt, ...) is deprecated; pass a "
                "SubmitSpec (typed submission surface)",
                DeprecationWarning, stacklevel=2)
            spec = SubmitSpec(prompt=spec, max_new_tokens=max_new_tokens,
                              tenant=tenant)
        rid = self._next_req
        self._next_req += 1
        arrived = (self.clock() if spec.arrival_time_s is None
                   else spec.arrival_time_s)
        req = Request(rid, spec.prompt, spec.max_new_tokens,
                      tenant=spec.tenant, submitted_at=arrived,
                      slo_deadline_s=spec.slo_deadline_s,
                      deadline_s=(None if spec.deadline_s is None
                                  else arrived + spec.deadline_s))
        self.requests[rid] = req
        self.waiting.append(req)
        self._tenant_live[spec.tenant] = (
            self._tenant_live.get(spec.tenant, 0) + 1)
        return rid

    # ----------------------------------------------------------- prefill
    def _bucket(self, n: int) -> int:
        b = self.ecfg.prefill_bucket
        return min(((n + b - 1) // b) * b, self.ecfg.max_seq_len)

    def _prefill(self, req: Request) -> None:
        S = self._bucket(len(req.prompt))
        toks = np.zeros((1, S), np.int32)
        toks[0, :len(req.prompt)] = req.prompt
        cache = self.model.init_cache(1, self.ecfg.max_seq_len)
        # prefill runs at prompt length; the dense cache covers max_seq_len
        logits, cache = self._prefill_fn(
            self.params, {"tokens": jnp.asarray(toks[:, :len(req.prompt)])},
            cache)
        req.seq_id = self.kv.new_seq()
        kv = self._cache_to_pages(cache, len(req.prompt))
        if kv is not None:
            self.kv.append_tokens(req.seq_id, kv)
        else:
            self.kv.seq(req.seq_id).length = len(req.prompt)
        # dense handoff only for the slot-cache path; paged decode reads
        # everything back from the pool, so holding the dense cache per
        # request would defeat the capacity story
        req._cache = None if self._use_paged else cache
        nxt = int(np.argmax(np.asarray(logits[0])))
        req.out_tokens.append(nxt)
        if req.first_token_at is None:
            req.first_token_at = self.clock()
            req.last_token_at = req.first_token_at
            ttft = req.first_token_at - req.submitted_at
            self.metrics.observe(f"serve.ttft.{req.tenant}", ttft)
            tr = self.trace
            if tr.enabled:
                tr.event("ttft", tenant=req.tenant, op="serve",
                         req=req.req_id, ttft_s=ttft)

    def _cache_to_pages(self, cache, length: int):
        if "k" not in cache:
            return None                           # rwkv: O(1) state
        k = jnp.asarray(cache["k"])[:, 0, :length]   # [L, len, KV, hd]
        v = jnp.asarray(cache["v"])[:, 0, :length]
        return jnp.stack([k, v], axis=1)          # [L, 2, len, KV, hd]

    # ------------------------------------------------------------- decode
    def _qos_gate(self, req: Request) -> Decision:
        """SLO admission for one fresh request; resumes bypass the gate
        (a preempted request was already admitted — re-seating it is a
        swap-in, not new load on the link)."""
        if self.qos is None or req.state == "preempted":
            return Decision.ADMIT
        return self.qos.decide(req.tenant)

    def _cancel(self, req: Request, reason: str) -> None:
        """Terminal bookkeeping for a deadline-expired or capacity-starved
        request: its KV sequence is freed mid-flight (LMB pages return to
        the pool), the tenant's SLO record counts the cancellation, and
        the tenant's link demand is released once nothing of theirs is
        left in flight.  Callers remove the request from whichever
        structure held it (waiting deque / active slot)."""
        req.state = "cancelled"
        req.cancel_reason = reason
        req.done_at = self.clock()
        if req.seq_id is not None:
            self.kv.free_seq(req.seq_id)
            req.seq_id = None
        self.cancelled.append(req.req_id)
        self._tenant_live[req.tenant] -= 1
        if self.qos is not None:
            self.qos.record_cancel(req.tenant)
            if self._tenant_live[req.tenant] <= 0:
                self.qos.release(req.tenant)
        tr = self.trace
        if tr.enabled:
            tr.event("cancel", tenant=req.tenant, op="serve",
                     req=req.req_id, reason=reason)

    def _expire_waiting(self) -> None:
        """Drop queued (waiting or preempted-and-requeued) requests whose
        deadline has passed, preserving arrival order for the rest.  A
        preempted request's parked KV is freed here too."""
        if not any(r.deadline_s is not None for r in self.waiting):
            return
        now = self.clock()
        keep: List[Request] = []
        for req in self.waiting:
            if req.deadline_s is not None and now >= req.deadline_s:
                self._cancel(req, "deadline")
            else:
                keep.append(req)
        if len(keep) != len(self.waiting):
            self.waiting = deque(keep)

    def _admit(self) -> None:
        self._expire_waiting()
        considered = 0
        limit = len(self.waiting)   # each waiter gets one decision per round
        deferred: List[Request] = []   # throttled this round
        while self.waiting and self._slot_free and considered < limit:
            considered += 1
            req = self.waiting.popleft()
            decision = self._qos_gate(req)
            if decision is Decision.SHED:
                req.state = "shed"
                self.shed.append(req.req_id)
                self._tenant_live[req.tenant] -= 1
                continue
            if decision is Decision.THROTTLE:
                # retry a later round — deferred requests return to the
                # FRONT of the queue in arrival order (they arrived before
                # everything still waiting), so a throttled tenant cannot
                # leapfrog, and a permanently-throttled one cannot starve
                # later arrivals: each waiter still gets exactly one
                # decision per round, and the deadline bounds its retries
                deferred.append(req)
                continue
            try:
                if req.state == "preempted":
                    self.kv.schedule_swap_in(req.seq_id)  # LMB -> onboard
                else:
                    self._prefill(req)
            except OutOfMemory:
                # pool too degraded to hold the KV (e.g. expander failed
                # with no spare): cancel instead of crashing the engine
                self._cancel(req, "capacity")
                continue
            # NOTE: nothing is pinned — cold pages may spill to the LMB
            # pool freely.  Paged decode faults each round's working set
            # back in one coalesced burst; the dense fallback decodes
            # from its per-request slot cache.
            slot = self._slot_free.pop()
            req.state = "active"
            self.active[slot] = req
        self.waiting.extendleft(reversed(deferred))

    def preempt(self, slot: int) -> None:
        """Evict a running request: its KV pages demote to the LMB tier
        on pressure (LinkedBuffer eviction does the actual move)."""
        req = self.active.pop(slot)
        req.state = "preempted"
        self.waiting.appendleft(req)
        self._slot_free.append(slot)

    def _schedule_round_prefetch(self) -> None:
        """Feed the prefetcher this round's exact future, batched into
        ONE schedule call so the pages group into per-(chunk, expander)
        bursts instead of per-sequence dribbles.  Dense path: every
        active sequence's next-decode (tail) page.  Paged path: every
        active sequence's FULL page list — the next round's DecodeView
        reads the whole working set, so all of it is exact future
        knowledge for the prefetcher."""
        pages: List[int] = []
        for req in self.active.values():
            if req.seq_id is None:
                continue
            if self._use_paged:
                pages.extend(self.kv.seq(req.seq_id).pages)
            else:
                pages.extend(self.kv.next_decode_pages(req.seq_id))
        if pages:
            self.kv.schedule_prefetch(pages)

    def step(self) -> int:
        """One engine iteration: admit + one decode step per active req.

        Decodes per-request (CPU-demo path); the TPU path batches slots
        into one decode_step with the paged-attention kernel.  With
        ``kv_prefetch`` on, each round's next-decode KV pages are
        scheduled ahead as bursts.  ``pipeline=True`` (default) runs
        admission and that prefetch scheduling at the END of the round,
        inside the just-measured compute window's link drain; the
        phased order (admit -> prefetch -> decode, never draining)
        remains as the reference mode.  Token streams are byte-identical
        between the two.  When tracing is on, the round runs under a
        ``serve.round`` span whose children carry per-sequence TTFT and
        inter-token events."""
        impl = (self._step_pipelined if self.ecfg.pipeline
                else self._step_phased)
        tr = self.trace
        if not tr.enabled:
            return impl()
        with tr.span("serve.round", op="serve", active=len(self.active),
                     waiting=len(self.waiting),
                     mode=("pipelined" if self.ecfg.pipeline
                           else "phased")):
            return impl()

    def _step_phased(self) -> int:
        """Strictly-phased reference order: admit, schedule this round's
        prefetch, then decode.  Bursts issue at the same modeled instant
        the decode they feed begins, and links never drain between
        rounds — the pre-pipeline behavior, kept for A/B runs."""
        self._admit()
        if self.ecfg.kv_prefetch:
            self._schedule_round_prefetch()
        finished, round_dt = self._decode_round()
        if self.ecfg.kv_prefetch and self.active:
            self.kv.note_compute_window(
                round_dt, observed=self.ecfg.round_time_s is None)
        return finished

    def _step_pipelined(self) -> int:
        """Pipelined order: decode first, then run the intake work for
        the NEXT round — link drain, admission, prefetch scheduling —
        inside the round's compute window.  Arrivals that landed since
        the previous round's tail are caught up before decoding so no
        request waits an extra round versus the phased order."""
        self._admit()                      # catch-up: post-tail arrivals
        finished, round_dt = self._decode_round()
        self._round_tail(round_dt)
        return finished

    def _round_tail(self, round_dt: float) -> None:
        """The pipelined step's intake half, run while the decode
        round's compute window drains the expander links: let modeled
        time pass on every link (advance_links), open the next overlap
        window at the measured round time, admit arrivals, and schedule
        their (plus the surviving actives') next-decode pages as
        prefetch bursts — which now ride a drained link under a freshly
        opened window instead of queueing behind the round's demand
        traffic."""
        if round_dt > 0.0:
            self._fm.advance_links(round_dt)
        if not self.ecfg.kv_prefetch:
            self._admit()
            return
        self.kv.note_compute_window(
            round_dt, observed=self.ecfg.round_time_s is None)
        self._admit()
        self._schedule_round_prefetch()

    def _decode_round(self) -> tuple:
        """One decode pass over the active slots; returns ``(finished,
        round_dt)`` where ``round_dt`` is the round's compute-window
        duration — ``EngineConfig.round_time_s`` when pinned (virtual
        sweeps), measured wall time otherwise.  Dispatches to the paged
        pool-direct round when :attr:`EngineConfig.paged_decode` covers
        the model; the per-request dense-slot loop below is the
        fallback."""
        if self._use_paged:
            return self._decode_round_paged()
        round_t0 = time.monotonic()
        finished = 0
        for slot, req in list(self.active.items()):
            if (req.deadline_s is not None
                    and self.clock() >= req.deadline_s):
                # mid-flight cancellation: pull the request out of its
                # decode slot and free its KV sequence immediately
                self._cancel(req, "deadline")
                del self.active[slot]
                self._slot_free.append(slot)
                continue
            tok = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
            logits, req._cache = self._decode_fn(self.params, req._cache,
                                                 tok)
            nxt = int(np.argmax(np.asarray(logits[0])))
            req.out_tokens.append(nxt)
            now = self.clock()
            if req.last_token_at is not None:
                gap = now - req.last_token_at
                self.metrics.observe(f"serve.itl.{req.tenant}", gap)
                tr = self.trace
                if tr.enabled:
                    tr.event("token", tenant=req.tenant, op="serve",
                             req=req.req_id, gap_s=gap)
            req.last_token_at = now
            kv_new = self._decode_kv_tail(req._cache)
            try:
                if kv_new is not None:
                    self.kv.append_tokens(req.seq_id, kv_new)
                else:
                    self.kv.seq(req.seq_id).length += 1
            except OutOfMemory:
                # the pool shrank under us (failover mid-decode): free
                # what the sequence still holds and release the slot
                self._cancel(req, "capacity")
                del self.active[slot]
                self._slot_free.append(slot)
                continue
            if len(req.out_tokens) >= req.max_new_tokens:
                self._finish_active(slot, req)
                finished += 1
        if self.ecfg.round_time_s is not None:
            return finished, (self.ecfg.round_time_s if self.active
                              or finished else 0.0)
        return finished, time.monotonic() - round_t0

    def _finish_active(self, slot: int, req: Request) -> None:
        """Terminal bookkeeping for a request completing in its slot."""
        req.state = "done"
        req.done_at = self.clock()
        self.kv.free_seq(req.seq_id)
        del self.active[slot]
        self._slot_free.append(slot)
        self._qos_finish(req)

    def _decode_round_paged(self) -> tuple:
        """The pool-direct decode round: ONE batched paged-attention
        step over every active slot, straight against the paged KV pool.

        The round builds a :class:`~repro.serve.kv_cache.DecodeView`
        (tail pages guaranteed, the actives' page union faulted onboard
        with one coalesced burst — the round's touched-page list riding
        the same meter/prefetch accounting as every other access), runs
        the compiled ``decode_step_paged`` once for the whole batch, and
        commits only the tail pages back.  Token streams are
        byte-identical to the dense per-request loop; what changed is
        the data path — no per-request dense cache, no host-side
        gather_seq swap-in.
        """
        round_t0 = time.monotonic()
        finished = 0
        live: List[tuple] = []
        for slot, req in list(self.active.items()):
            if (req.deadline_s is not None
                    and self.clock() >= req.deadline_s):
                # mid-flight cancellation: pull the request out of its
                # decode slot and free its KV sequence immediately
                self._cancel(req, "deadline")
                del self.active[slot]
                self._slot_free.append(slot)
                continue
            if self.kv.seq(req.seq_id).length >= self.ecfg.max_seq_len:
                # context window exhausted: the dense slot cache would
                # silently ring-wrap here; the paged path finishes the
                # request instead of outgrowing its page table
                self._finish_active(slot, req)
                finished += 1
                continue
            live.append((slot, req))
        if live:
            try:
                view = self.kv.decode_view([r.seq_id for _, r in live],
                                           self._max_pages)
                toks = jnp.asarray([[r.out_tokens[-1]] for _, r in live],
                                   jnp.int32)
                logits, pool = self._paged_fn(
                    self.params, view.pool, jnp.asarray(view.tables),
                    jnp.asarray(view.lengths), toks)
                logits = np.asarray(logits)
                self.kv.commit_decode(view, pool)
            except OutOfMemory:
                # the pool shrank under us (failover mid-decode): the
                # round's working set can no longer be materialized —
                # cancel the batch instead of crashing the engine
                for slot, req in live:
                    self._cancel(req, "capacity")
                    del self.active[slot]
                    self._slot_free.append(slot)
                live = []
            else:
                self.paged_rounds += 1
                tr = self.trace
                if tr.enabled:
                    tr.event("decode.paged", op="serve",
                             batch=len(live), pages=len(view.pages),
                             pool=int(view.pool.shape[0]))
        for i, (slot, req) in enumerate(live):
            nxt = int(np.argmax(logits[i]))
            req.out_tokens.append(nxt)
            now = self.clock()
            if req.last_token_at is not None:
                gap = now - req.last_token_at
                self.metrics.observe(f"serve.itl.{req.tenant}", gap)
                tr = self.trace
                if tr.enabled:
                    tr.event("token", tenant=req.tenant, op="serve",
                             req=req.req_id, gap_s=gap)
            req.last_token_at = now
            if len(req.out_tokens) >= req.max_new_tokens:
                self._finish_active(slot, req)
                finished += 1
        if self.ecfg.round_time_s is not None:
            return finished, (self.ecfg.round_time_s if self.active
                              or finished else 0.0)
        return finished, time.monotonic() - round_t0

    def _qos_finish(self, req: Request) -> None:
        """Feed the completed request's latency to its tenant's SLO
        tracker; drop the tenant's demand off the link once it drains."""
        self._tenant_live[req.tenant] -= 1
        if self.qos is None:
            return
        self.qos.observe(req.tenant, req.done_at - req.submitted_at)
        if self._tenant_live[req.tenant] <= 0:
            self.qos.release(req.tenant)

    def _decode_kv_tail(self, cache):
        if "k" not in cache:
            return None
        step = int(cache["step"]) - 1
        C = cache["k"].shape[2]
        slot = step % C
        k = jnp.asarray(cache["k"])[:, 0, slot:slot + 1]
        v = jnp.asarray(cache["v"])[:, 0, slot:slot + 1]
        return jnp.stack([k, v], axis=1)

    def run(self, max_iters: int = 1000) -> None:
        it = 0
        while (self.waiting or self.active) and it < max_iters:
            self.step()
            it += 1

    # ------------------------------------------------------------- metrics
    def stats(self) -> dict:
        done = [r for r in self.requests.values() if r.state == "done"]
        ttft = [r.first_token_at - r.submitted_at for r in done
                if r.first_token_at]
        fm = self.kv.buf.host.fm
        # per-tenant latency distributions from the unified registry:
        # serve.ttft.<tenant> / serve.itl.<tenant> histograms with
        # p50/p90/p99 — the numbers the serve-sweep reports against
        hists = self.metrics.snapshot()["histograms"]
        latency = {name: snap for name, snap in sorted(hists.items())
                   if name.startswith("serve.")}
        self.metrics.gauge("fm.journal_len",
                           fm.journal_stats()["len"])
        return {
            "done": len(done),
            "waiting": len(self.waiting),
            "active": len(self.active),
            "decode_path": "paged" if self._use_paged else "dense",
            "paged_rounds": self.paged_rounds,
            "shed": len(self.shed),
            "cancelled": len(self.cancelled),
            "mean_ttft_s": float(np.mean(ttft)) if ttft else None,
            "latency": latency,
            "trace": self.trace.snapshot(),
            "kv": self.kv.stats(),
            "qos": self.qos.snapshot() if self.qos else None,
            # pooled-fabric placement: which expander backs the engine's KV
            # blocks/pages and how loaded each expander's link runs — the
            # signals the MigrationEngine acts on
            "fabric": {
                "block_placement": fm.placement(),
                "kv_page_placement": self.kv.buf.lmb_placement(),
                "link_utilization": fm.link_utilizations(),
                # arbitration round-trips: grows with coalesced bursts,
                # not pages — the batched-data-path health signal
                "meter_calls": fm.meter_calls(),
            },
        }
