"""Continuous-batching serving engine with LMB-backed KV capacity.

The scheduler runs fixed decode slots (the jitted decode step's batch);
waiting/preempted requests' KV parks in the LMB pool via PagedKVStore.
The admission limit is pool capacity — onboard (HBM) only bounds the
number of *simultaneously decoding* requests, which is the paper's thesis
applied to serving.

Flow per request: admit -> prefill (bucketed padding) -> decode in a slot
-> [optional preempt: KV pages out to LMB; resume: pages back] -> finish.
Swap decisions consult the tier cost model; all movement is metered by
repro.core.metrics.

Multi-tenant QoS (repro.qos): requests carry a tenant id; when the engine
is built with an AdmissionController, every seating decision routes
through it — ADMIT seats the request, THROTTLE leaves it queued for a
later round, SHED rejects it outright (state "shed").  Completed request
latencies feed the tenant's SLO tracker, closing the loop.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import LMBHost
from repro.core.client import LMBSystem
from repro.models.zoo import Model
from repro.obs.trace import DEFAULT_RING_CAPACITY, SpanTracer
from repro.qos.slo import AdmissionController, Decision
from repro.serve.kv_cache import PagedKVStore


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray                 # [S] int32
    max_new_tokens: int = 16
    tenant: str = "default"
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    seq_id: Optional[int] = None
    state: str = "waiting"             # waiting|active|preempted|done|shed
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    last_token_at: Optional[float] = None
    done_at: Optional[float] = None


@dataclasses.dataclass
class EngineConfig:
    decode_slots: int = 4
    max_seq_len: int = 256
    page_tokens: int = 32
    onboard_pages: int = 32            # HBM-tier KV budget
    prefill_bucket: int = 64
    #: feed each active sequence's next-decode page list to the KV
    #: store's prefetcher every batch round (exact future knowledge,
    #: moved as coalesced bursts).  Pure performance knob: tokens are
    #: identical with it off.
    kv_prefetch: bool = True
    #: pages of prefetch lookahead per round (0 disables the prefetcher
    #: outright, not just the engine-fed schedule)
    kv_prefetch_depth: int = 2
    #: initial compute-window estimate for the overlap scheduler; the
    #: engine refines it with measured decode-round times
    kv_compute_window_s: float = 1e-3
    #: record spans (serve rounds, TTFT/token events, the KV data path)
    #: into a private tracer attached to the engine's fabric — unless
    #: the fabric already carries an enabled tracer (LMBSystem with
    #: ObsSpec.trace, or benchmarks' global tracer), which is reused
    trace: bool = False
    #: ring capacity of the engine-minted tracer
    trace_capacity: int = DEFAULT_RING_CAPACITY


class ServeEngine:
    """``lmb`` is the LMB stack the KV store pages against: an
    :class:`~repro.core.client.LMBSystem` session (the client API) or a
    bare :class:`~repro.core.api.LMBHost` for low-level wiring."""

    def __init__(self, model: Model, params,
                 lmb: Union[LMBSystem, LMBHost],
                 ecfg: EngineConfig, device_id: str = "tpu0",
                 qos: Optional[AdmissionController] = None):
        host = lmb.host() if isinstance(lmb, LMBSystem) else lmb
        self.model = model
        self.params = params
        self.ecfg = ecfg
        self.cfg = model.cfg
        self.qos = qos
        self.shed: List[int] = []
        self._tenant_live: Dict[str, int] = {}   # in-flight reqs per tenant
        self.metrics = host.metrics
        # tracing: reuse an already-enabled fabric tracer (session/global)
        # or, when the config asks, mint one and attach it to the fabric
        # BEFORE the KV store builds its LinkedBuffer, so the whole KV
        # data path records into the same ring as the serve rounds
        self.trace: SpanTracer = host.fm.tracer
        if ecfg.trace and not self.trace.enabled:
            self.trace = SpanTracer(capacity=ecfg.trace_capacity)
            host.fm.tracer = self.trace
        overlap = None
        if ecfg.kv_prefetch and ecfg.kv_prefetch_depth:
            # admission gate for prefetch bursts: sized to the decode
            # round's compute window (EWMA-learned from measured rounds)
            from repro.core.overlap import OverlapScheduler
            from repro.core.tiers import TierKind, tpu_tiers
            overlap = OverlapScheduler(
                tpu_tiers()[TierKind.HOST_DRAM],
                compute_window_s=ecfg.kv_compute_window_s,
                trace=self.trace)
        self.kv = PagedKVStore(
            cfg=model.cfg, host=host, device_id=device_id,
            page_tokens=ecfg.page_tokens, onboard_pages=ecfg.onboard_pages,
            prefetch_depth=(ecfg.kv_prefetch_depth if ecfg.kv_prefetch
                            else 0),
            overlap=overlap)
        self.waiting: deque[Request] = deque()
        self.active: Dict[int, Request] = {}      # slot -> request
        self.requests: Dict[int, Request] = {}
        self._next_req = 0
        self._decode_cache = None                 # dense cache for slots
        self._slot_free = list(range(ecfg.decode_slots))[::-1]
        self._prefill_fn = jax.jit(model.prefill)
        self._decode_fn = jax.jit(model.decode_step)

    # -------------------------------------------------------------- intake
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               tenant: str = "default") -> int:
        rid = self._next_req
        self._next_req += 1
        req = Request(rid, np.asarray(prompt, np.int32), max_new_tokens,
                      tenant=tenant, submitted_at=time.monotonic())
        self.requests[rid] = req
        self.waiting.append(req)
        self._tenant_live[tenant] = self._tenant_live.get(tenant, 0) + 1
        return rid

    # ----------------------------------------------------------- prefill
    def _bucket(self, n: int) -> int:
        b = self.ecfg.prefill_bucket
        return min(((n + b - 1) // b) * b, self.ecfg.max_seq_len)

    def _prefill(self, req: Request) -> None:
        S = self._bucket(len(req.prompt))
        toks = np.zeros((1, S), np.int32)
        toks[0, :len(req.prompt)] = req.prompt
        cache = self.model.init_cache(1, self.ecfg.max_seq_len)
        # prefill runs at prompt length; the dense cache covers max_seq_len
        logits, cache = self._prefill_fn(
            self.params, {"tokens": jnp.asarray(toks[:, :len(req.prompt)])},
            cache)
        req.seq_id = self.kv.new_seq()
        kv = self._cache_to_pages(cache, len(req.prompt))
        if kv is not None:
            self.kv.append_tokens(req.seq_id, kv)
        else:
            self.kv.seq(req.seq_id).length = len(req.prompt)
        req._cache = cache                        # dense handoff
        nxt = int(np.argmax(np.asarray(logits[0])))
        req.out_tokens.append(nxt)
        if req.first_token_at is None:
            req.first_token_at = time.monotonic()
            req.last_token_at = req.first_token_at
            ttft = req.first_token_at - req.submitted_at
            self.metrics.observe(f"serve.ttft.{req.tenant}", ttft)
            tr = self.trace
            if tr.enabled:
                tr.event("ttft", tenant=req.tenant, op="serve",
                         req=req.req_id, ttft_s=ttft)

    def _cache_to_pages(self, cache, length: int):
        if "k" not in cache:
            return None                           # rwkv: O(1) state
        k = jnp.asarray(cache["k"])[:, 0, :length]   # [L, len, KV, hd]
        v = jnp.asarray(cache["v"])[:, 0, :length]
        return jnp.stack([k, v], axis=1)          # [L, 2, len, KV, hd]

    # ------------------------------------------------------------- decode
    def _qos_gate(self, req: Request) -> Decision:
        """SLO admission for one fresh request; resumes bypass the gate
        (a preempted request was already admitted — re-seating it is a
        swap-in, not new load on the link)."""
        if self.qos is None or req.state == "preempted":
            return Decision.ADMIT
        return self.qos.decide(req.tenant)

    def _admit(self) -> None:
        considered = 0
        limit = len(self.waiting)   # each waiter gets one decision per round
        while self.waiting and self._slot_free and considered < limit:
            considered += 1
            req = self.waiting.popleft()
            decision = self._qos_gate(req)
            if decision is Decision.SHED:
                req.state = "shed"
                self.shed.append(req.req_id)
                self._tenant_live[req.tenant] -= 1
                continue
            if decision is Decision.THROTTLE:
                self.waiting.append(req)       # retry a later round
                continue
            if req.state == "preempted":
                self.kv.schedule_swap_in(req.seq_id)   # LMB -> onboard
            else:
                self._prefill(req)
            # NOTE: active requests decode from their dense slot cache; the
            # paged store is the park/share tier, so nothing is pinned and
            # cold pages may spill to the LMB pool freely.
            slot = self._slot_free.pop()
            req.state = "active"
            self.active[slot] = req

    def preempt(self, slot: int) -> None:
        """Evict a running request: its KV pages demote to the LMB tier
        on pressure (LinkedBuffer eviction does the actual move)."""
        req = self.active.pop(slot)
        req.state = "preempted"
        self.waiting.appendleft(req)
        self._slot_free.append(slot)

    def _schedule_round_prefetch(self) -> None:
        """Feed the prefetcher this round's exact future: every active
        sequence's next-decode page list, batched into ONE schedule call
        so the pages group into per-(chunk, expander) bursts instead of
        per-sequence dribbles."""
        pages: List[int] = []
        for req in self.active.values():
            if req.seq_id is not None:
                pages.extend(self.kv.next_decode_pages(req.seq_id))
        if pages:
            self.kv.schedule_prefetch(pages)

    def step(self) -> int:
        """One engine iteration: admit + one decode step per active req.

        Decodes per-request (CPU-demo path); the TPU path batches slots
        into one decode_step with the paged-attention kernel.  With
        ``kv_prefetch`` on, the round's next-decode KV pages are
        scheduled ahead as bursts, and the measured decode time feeds
        the overlap scheduler's compute-window estimate.  When tracing
        is on, the round runs under a ``serve.round`` span whose
        children carry per-sequence TTFT and inter-token events."""
        tr = self.trace
        if not tr.enabled:
            return self._step_impl()
        with tr.span("serve.round", op="serve", active=len(self.active),
                     waiting=len(self.waiting)):
            return self._step_impl()

    def _step_impl(self) -> int:
        self._admit()
        if self.ecfg.kv_prefetch:
            self._schedule_round_prefetch()
        round_t0 = time.monotonic()
        finished = 0
        for slot, req in list(self.active.items()):
            tok = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
            logits, req._cache = self._decode_fn(self.params, req._cache,
                                                 tok)
            nxt = int(np.argmax(np.asarray(logits[0])))
            req.out_tokens.append(nxt)
            now = time.monotonic()
            if req.last_token_at is not None:
                gap = now - req.last_token_at
                self.metrics.observe(f"serve.itl.{req.tenant}", gap)
                tr = self.trace
                if tr.enabled:
                    tr.event("token", tenant=req.tenant, op="serve",
                             req=req.req_id, gap_s=gap)
            req.last_token_at = now
            kv_new = self._decode_kv_tail(req._cache)
            if kv_new is not None:
                self.kv.append_tokens(req.seq_id, kv_new)
            else:
                self.kv.seq(req.seq_id).length += 1
            if len(req.out_tokens) >= req.max_new_tokens:
                req.state = "done"
                req.done_at = time.monotonic()
                self.kv.free_seq(req.seq_id)
                del self.active[slot]
                self._slot_free.append(slot)
                finished += 1
                self._qos_finish(req)
        if self.ecfg.kv_prefetch and self.active:
            self.kv.note_compute_window(time.monotonic() - round_t0)
        return finished

    def _qos_finish(self, req: Request) -> None:
        """Feed the completed request's latency to its tenant's SLO
        tracker; drop the tenant's demand off the link once it drains."""
        self._tenant_live[req.tenant] -= 1
        if self.qos is None:
            return
        self.qos.observe(req.tenant, req.done_at - req.submitted_at)
        if self._tenant_live[req.tenant] <= 0:
            self.qos.release(req.tenant)

    def _decode_kv_tail(self, cache):
        if "k" not in cache:
            return None
        step = int(cache["step"]) - 1
        C = cache["k"].shape[2]
        slot = step % C
        k = jnp.asarray(cache["k"])[:, 0, slot:slot + 1]
        v = jnp.asarray(cache["v"])[:, 0, slot:slot + 1]
        return jnp.stack([k, v], axis=1)

    def run(self, max_iters: int = 1000) -> None:
        it = 0
        while (self.waiting or self.active) and it < max_iters:
            self.step()
            it += 1

    # ------------------------------------------------------------- metrics
    def stats(self) -> dict:
        done = [r for r in self.requests.values() if r.state == "done"]
        ttft = [r.first_token_at - r.submitted_at for r in done
                if r.first_token_at]
        fm = self.kv.buf.host.fm
        # per-tenant latency distributions from the unified registry:
        # serve.ttft.<tenant> / serve.itl.<tenant> histograms with
        # p50/p90/p99 — the numbers the serve-sweep reports against
        hists = self.metrics.snapshot()["histograms"]
        latency = {name: snap for name, snap in sorted(hists.items())
                   if name.startswith("serve.")}
        self.metrics.gauge("fm.journal_len",
                           fm.journal_stats()["len"])
        return {
            "done": len(done),
            "waiting": len(self.waiting),
            "active": len(self.active),
            "shed": len(self.shed),
            "mean_ttft_s": float(np.mean(ttft)) if ttft else None,
            "latency": latency,
            "trace": self.trace.snapshot(),
            "kv": self.kv.stats(),
            "qos": self.qos.snapshot() if self.qos else None,
            # pooled-fabric placement: which expander backs the engine's KV
            # blocks/pages and how loaded each expander's link runs — the
            # signals the MigrationEngine acts on
            "fabric": {
                "block_placement": fm.placement(),
                "kv_page_placement": self.kv.buf.lmb_placement(),
                "link_utilization": fm.link_utilizations(),
                # arbitration round-trips: grows with coalesced bursts,
                # not pages — the batched-data-path health signal
                "meter_calls": fm.meter_calls(),
            },
        }
