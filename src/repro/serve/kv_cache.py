"""Paged KV storage on a LinkedBuffer — the LMB applied to serving.

A request's KV state is chopped into **KV pages** (``page_tokens`` tokens
of all layers' K+V at once) and stored as LinkedBuffer logical pages:

  * the working set of ACTIVE requests stays in the onboard (HBM) tier;
  * preempted / waiting requests' KV parks in the LMB pool (the paper's
    "exchange time for space"): admission capacity is the POOL size, not
    HBM;
  * prefix sharing = LinkedBuffer.share (zero-copy, copy-on-write) — the
    paper's shared-buffer SSD→accelerator scenario;
  * swap-in cost is predicted with the tier model so the scheduler can
    decide hide-or-stall (repro.core.tiers.hideable_page_bytes).

Layout per logical page: [L, 2, page_tokens, KV, hd] (K and V stacked) —
one DMA per page move, layer-major so a layer-by-layer decode can stream.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import LMBHost
from repro.core.buffer import LinkedBuffer
from repro.core.client import LMBSystem
from repro.core.offload import TierExecutor
from repro.core.overlap import OverlapScheduler


@dataclasses.dataclass
class SeqPages:
    """Page bookkeeping for one sequence."""

    seq_id: int
    pages: List[int] = dataclasses.field(default_factory=list)
    length: int = 0


@dataclasses.dataclass
class DecodeView:
    """One decode round's batched view over the paged pool.

    ``pool`` is the union of the active sequences' pages materialized
    onboard with ONE coalesced ``read_many`` burst (padded with zero
    pages to a power of two so the compiled step sees few distinct pool
    shapes); ``tables`` indexes INTO THE POOL (not logical page ids), so
    a compiled paged-attention step can consume it directly.  ``pages``
    is the round's touched-page list — exactly what rides the
    schedule_prefetch / meter accounting so modeled link traffic
    reconciles with ``fm.op_bytes()``.
    """

    sids: List[int]
    pool: jax.Array          # [P_pad, L, 2, T, KV, hd]
    tables: np.ndarray       # [B, MP] int32 pool indices (-1 pad)
    lengths: np.ndarray      # [B] int32 tokens stored (pre-step)
    pages: List[int]         # union logical pages backing pool[:n]
    tail_pages: List[int]    # per-sequence logical tail page
    tail_index: List[int]    # per-sequence pool index of the tail page


class PagedKVStore:
    """KV pages over a LinkedBuffer.  Construct with ``system=`` (an
    :class:`~repro.core.client.LMBSystem` session — the client API) or,
    for low-level wiring, a bare ``host=`` LMBHost."""

    def __init__(self, *, cfg, host: Optional[LMBHost] = None,
                 system: Optional[LMBSystem] = None,
                 host_id: Optional[str] = None,
                 device_id: str,
                 page_tokens: int = 64, onboard_pages: int = 64,
                 n_layers: Optional[int] = None,
                 compress_cold: bool = False,
                 prefetch_depth: int = 2,
                 overlap: Optional[OverlapScheduler] = None,
                 executor: Optional[TierExecutor] = None):
        if host is None:
            if system is None:
                raise ValueError("PagedKVStore needs system= or host=")
            host = system.host(host_id)
        self.cfg = cfg
        L = n_layers or cfg.num_layers
        KV, hd = cfg.num_kv_heads, cfg.head_dim_
        self.page_tokens = page_tokens
        self.page_shape = (L, 2, page_tokens, KV, hd)
        self.buf = LinkedBuffer(
            name=f"kv:{device_id}", device_id=device_id, host=host,
            executor=executor, page_shape=self.page_shape,
            dtype=jnp.dtype(cfg.dtype), onboard_pages=onboard_pages,
            policy="cost", prefetch_depth=prefetch_depth,
            overlap=overlap, compress_lmb=compress_cold)
        self._seqs: Dict[int, SeqPages] = {}
        self._next_id = 0

    # ------------------------------------------------------------ lifecycle
    def new_seq(self) -> int:
        sid = self._next_id
        self._next_id += 1
        self._seqs[sid] = SeqPages(sid)
        return sid

    def seq(self, sid: int) -> SeqPages:
        return self._seqs[sid]

    def free_seq(self, sid: int) -> None:
        for p in self._seqs[sid].pages:
            self.buf.release(p)
        del self._seqs[sid]

    def fork(self, sid: int) -> int:
        """Zero-copy prefix share: new sequence maps the same pages (COW
        on write) — the Table-2 ``share`` scenario.  One batched
        ``share_many`` call for the whole prefix."""
        new = self.new_seq()
        src = self._seqs[sid]
        dst = self._seqs[new]
        dst.pages = self.buf.share_many(src.pages)
        dst.length = src.length
        return new

    # ------------------------------------------------------------ data path
    def append_tokens(self, sid: int, kv: jax.Array) -> None:
        """kv [L, 2, T, KV, hd] for T new tokens (T <= page_tokens from
        decode; prefill calls in page-sized slabs).  Batched data path:
        the touched pages are planned up front, faulted in with ONE
        ``read_many`` burst, updated, and written back with ONE
        ``write_many`` burst — a multi-page prefill slab costs one
        coalesced transfer per LMB chunk instead of a read/write pair
        per page."""
        seq = self._seqs[sid]
        T = kv.shape[2]
        if T == 0:
            return                        # empty slab: scalar loop no-op
        # plan the page segments this slab touches
        segs = []                         # (page, token offset, take, src)
        done, length = 0, seq.length
        while done < T:
            off = length % self.page_tokens
            if off == 0:
                seq.pages.extend(self.buf.append_pages(1))
            page = seq.pages[length // self.page_tokens]
            take = min(self.page_tokens - off, T - done)
            segs.append((page, off, take, done))
            length += take
            done += take
        if len(segs) == 1:
            # decode path: one page per token — plain scalar read/write,
            # no stack/batch machinery on the hottest per-token path
            page, off, take, _ = segs[0]
            cur = self.buf.read(page)
            self.buf.write(page, jax.lax.dynamic_update_slice_in_dim(
                cur, kv, off, axis=2))
            seq.length = length
            return
        pages = [s[0] for s in segs]
        cur = self.buf.read_many(pages)        # one coalesced fault burst
        updated = [
            jax.lax.dynamic_update_slice_in_dim(
                cur[i], kv[:, :, done:done + take], off, axis=2)
            for i, (page, off, take, done) in enumerate(segs)]
        self.buf.write_many(pages, jnp.stack(updated))
        seq.length = length

    def gather_seq(self, sid: int) -> jax.Array:
        """Materialize a sequence's KV [L, 2, seq.length, KV, hd] onboard
        (used for swap-in to a dense decode slot).  The token axis is
        trimmed to the sequence's true length — the tail page's unwritten
        slots are allocator garbage and must never reach attention (the
        silent padded return was the PR-10 bug class).  ``gather`` rides
        the batched path: one coalesced transfer per LMB chunk and one
        arbiter charge per expander link for the whole sequence."""
        seq = self._seqs[sid]
        if not seq.pages:
            return jnp.zeros(self.page_shape, self.buf.dtype)[:, :, :0]
        stacked = self.buf.gather(seq.pages)       # [n, L, 2, T, KV, hd]
        n = stacked.shape[0]
        L, _, T, KV, hd = self.page_shape
        full = jnp.moveaxis(stacked, 0, 2).reshape(L, 2, n * T, KV, hd)
        return full[:, :, :seq.length]

    def pin_seq(self, sid: int) -> None:
        """Pin a sequence's pages onboard with ONE batched fault burst
        (a compiled step is about to DMA them)."""
        self.buf.pin_many(self._seqs[sid].pages)

    def unpin_seq(self, sid: int) -> None:
        self.buf.unpin_many(self._seqs[sid].pages)

    def next_decode_pages(self, sid: int) -> List[int]:
        """The KV pages the NEXT decode step of this sequence will touch
        — exact future knowledge for the prefetcher.  A token landing at
        a page boundary opens a fresh page (nothing to fetch); otherwise
        the partially-filled tail page is read-modified-written."""
        seq = self._seqs[sid]
        if seq.length == 0 or seq.length % self.page_tokens == 0:
            return []
        return [seq.pages[seq.length // self.page_tokens]]

    def schedule_prefetch(self, pages: List[int]) -> None:
        """Feed a batch round's worth of scheduled page accesses to the
        buffer's prefetcher: pages move as coalesced per-(chunk,
        expander) bursts, bounded by free slots and the overlap window
        (remainder deferred, not dropped)."""
        self.buf.schedule_prefetch(pages)

    def note_compute_window(self, seconds: float,
                            observed: bool = True) -> None:
        """Report one decode round's compute time so the overlap
        scheduler can size the next prefetch window.  ``observed=False``
        pins the window exactly instead of folding the sample into the
        EWMA estimate (virtual-time sweeps with a declared round
        duration)."""
        self.buf.note_compute_window(seconds, observed=observed)

    def schedule_swap_in(self, sid: int) -> None:
        self.schedule_prefetch(self._seqs[sid].pages)

    # ----------------------------------------------------------- accounting
    def lmb_resident_pages(self) -> int:
        """KV pages currently parked in the LMB pool tier (not onboard)
        — the "concurrent sequences backed by LMB-resident KV" figure a
        load sweep reports alongside its latency table."""
        return self.buf.stats()["resident"].get("lmb", 0)

    def parked_sequences(self) -> int:
        """Sequences whose KV is entirely LMB/unmaterialized-resident —
        admitted work the onboard tier is NOT holding pages for."""
        return sum(1 for s in self._seqs.values()
                   if s.pages and not any(self.buf.tier_of(p) == "onboard"
                                          for p in s.pages))

    def stats(self) -> dict:
        st = self.buf.stats()
        st["sequences"] = len(self._seqs)
        st["page_tokens"] = self.page_tokens
        return st

    def page_table(self, sid: int, max_pages: int) -> np.ndarray:
        """int32 [max_pages] logical page ids (-1 pad) — feeds the Pallas
        paged-attention kernel on TPU.  Raises ``ValueError`` when the
        sequence has outgrown the table: the old behavior silently
        dropped the tail pages (numpy slice clamping), which would make
        attention read garbage for every token past the table edge."""
        seq = self._seqs[sid]
        if len(seq.pages) > max_pages:
            raise ValueError(
                f"seq {sid}: {len(seq.pages)} pages exceed the "
                f"{max_pages}-entry page table (length {seq.length}, "
                f"page_tokens {self.page_tokens}) — the tail KV would be "
                f"silently dropped")
        out = np.full((max_pages,), -1, np.int32)
        out[:len(seq.pages)] = seq.pages
        return out

    def page_tables(self, sids: List[int],
                    max_pages: int) -> tuple:
        """Batched decode view: (tables int32 [B, max_pages] logical page
        ids with -1 pad, lengths int32 [B]) for one engine round's active
        sequences — the host-side half of the kernel's L2P lookup.
        Raises like :meth:`page_table` instead of truncating."""
        tables = np.full((len(sids), max_pages), -1, np.int32)
        lengths = np.zeros((len(sids),), np.int32)
        for i, sid in enumerate(sids):
            tables[i] = self.page_table(sid, max_pages)
            lengths[i] = self._seqs[sid].length
        return tables, lengths

    # ------------------------------------------------------- paged decode
    def ensure_tail_page(self, sid: int) -> int:
        """Guarantee the page the sequence's NEXT token lands in exists
        (a token at a page boundary opens a fresh page); returns its
        logical id.  Allocation is logical-only — the page materializes
        on first touch."""
        seq = self._seqs[sid]
        idx = seq.length // self.page_tokens
        if len(seq.pages) == idx:
            seq.pages.extend(self.buf.append_pages(1))
        return seq.pages[idx]

    def decode_view(self, sids: List[int], max_pages: int) -> DecodeView:
        """Build one round's batched decode view: tail pages guaranteed,
        the union of the active sequences' pages faulted onboard with ONE
        coalesced ``read_many`` burst (metered exactly like any other
        batched access — hits for onboard-resident pages, link charges
        only for LMB misses, waves when the union exceeds onboard
        capacity), and page tables rewritten into pool-index space for
        the compiled step.  Active sequences must not share a tail page
        (the engine never forks a mid-flight sequence)."""
        for sid in sids:
            self.ensure_tail_page(sid)
        tables, lengths = self.page_tables(sids, max_pages)
        union: List[int] = []
        index: Dict[int, int] = {}
        for sid in sids:
            for p in self._seqs[sid].pages:
                if p not in index:
                    index[p] = len(union)
                    union.append(p)
        pool = self.buf.read_many(union)       # [n, L, 2, T, KV, hd]
        n = len(union)
        # pad with zero pages to a power of two: the compiled decode step
        # sees O(log) distinct pool shapes instead of one per round
        cap = max(8, 1 << (n - 1).bit_length())
        if cap > n:
            pool = jnp.concatenate(
                [pool, jnp.zeros((cap - n,) + self.page_shape,
                                 pool.dtype)])
        pool_tables = np.full_like(tables, -1)
        mapped = tables >= 0
        pool_tables[mapped] = [index[p] for p in tables[mapped].tolist()]
        tail_pages = [
            self._seqs[sid].pages[self._seqs[sid].length //
                                  self.page_tokens]
            for sid in sids]
        tail_index = [index[p] for p in tail_pages]
        return DecodeView(sids=list(sids), pool=pool,
                          tables=pool_tables,
                          lengths=lengths, pages=union,
                          tail_pages=tail_pages, tail_index=tail_index)

    def commit_decode(self, view: DecodeView, pool: jax.Array) -> None:
        """Write one decode round's results back: only the tail pages
        changed (the step scatters the new token's K/V there), so ONE
        ``write_many`` burst covers the whole batch, and each sequence
        advances by the token it just stored."""
        rows = pool[np.asarray(view.tail_index, np.int64)]
        self.buf.write_many(view.tail_pages, rows)
        for sid in view.sids:
            self._seqs[sid].length += 1
