"""Trace-driven continuous-batching load harness for the serve engine.

The evaluation bed for every "LMB keeps DRAM-starved serving minimally
impacted" claim: seeded multi-tenant arrival processes (Poisson and
bursty, from :func:`repro.sim.workload.arrival_times`) produce a TRACE —
a time-ordered list of :class:`~repro.serve.engine.SubmitSpec` — which
:func:`run_sweep` replays against a :class:`~repro.serve.engine.
ServeEngine` on a virtual clock.  Mixed prefill+decode pressure,
admission, KV prefetch overlap, and preemption all run together under
sustained load, which is exactly where CXL load-latency curves bend.

Two rules keep results honest and reproducible:

  * **No harness-local timing.**  Per-tenant TTFT and inter-token
    latency come straight out of ``ServeEngine.stats()["latency"]``
    (the ``serve.ttft.*`` / ``serve.itl.*`` histograms PR 6 landed);
    the harness only builds the report table from that snapshot.
  * **Virtual time.**  The engine is driven with a :class:`VirtualClock`
    and a pinned ``EngineConfig.round_time_s``, so every latency figure
    is a modeled quantity — identical on any machine, for a given
    trace seed.

Typical use (the ``serve_sweep`` benchmark scenario)::

    trace = build_trace([TenantLoad("gold", rate_rps=200, n_requests=32),
                         TenantLoad("burst", rate_rps=200, n_requests=32,
                                    process="bursty")],
                        vocab_size=cfg.vocab_size, seed=0)
    clock = VirtualClock()
    eng = ServeEngine(model, params, system,
                      EngineConfig(round_time_s=2e-3, ...), clock=clock)
    report = run_sweep(eng, trace, clock)
    print(report.table())
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.engine import ServeEngine, SubmitSpec
from repro.sim.workload import arrival_times


class VirtualClock:
    """A monotonic virtual timebase the harness advances explicitly.

    Injected as ``ServeEngine(..., clock=clock)`` so every request
    timestamp (arrival, TTFT, inter-token, completion) is a modeled
    virtual-time quantity: machine-independent and exactly reproducible
    for a fixed trace.
    """

    def __init__(self, t0: float = 0.0):
        self._now = float(t0)

    def __call__(self) -> float:
        return self._now

    @property
    def now(self) -> float:
        return self._now

    def advance(self, dt_s: float) -> None:
        if dt_s < 0:
            raise ValueError("virtual time cannot run backwards")
        self._now += dt_s

    def advance_to(self, t_s: float) -> None:
        """Jump forward to ``t_s`` (no-op if already past it)."""
        self._now = max(self._now, t_s)


@dataclasses.dataclass(frozen=True)
class TenantLoad:
    """One tenant's offered load: arrival process + request shape.

    All draws are seeded per tenant (trace seed + tenant name), so
    adding a tenant to a sweep never perturbs another tenant's stream.
    """

    name: str
    #: mean request arrival rate (requests/second of virtual time)
    rate_rps: float
    n_requests: int
    #: "poisson" (i.i.d. exponential gaps) or "bursty" (on/off bursts
    #: at burst_factor x the mean rate; same long-run offered load)
    process: str = "poisson"
    burst_size: int = 8
    burst_factor: float = 10.0
    #: uniform [lo, hi] prompt length in tokens
    prompt_tokens: tuple = (8, 24)
    #: uniform [lo, hi] decode length in tokens
    max_new_tokens: tuple = (4, 8)
    #: optional per-request SLO deadline stamped on every SubmitSpec
    slo_deadline_s: Optional[float] = None
    #: optional hard deadline (seconds from arrival) stamped on every
    #: SubmitSpec — expired requests are cancelled by the engine
    deadline_s: Optional[float] = None


def build_trace(tenants: Sequence[TenantLoad], *, vocab_size: int,
                seed: int = 0, t0: float = 0.0) -> List[SubmitSpec]:
    """Merge every tenant's seeded arrival stream into one time-ordered
    trace of typed submissions.

    Deterministic: same ``(tenants, vocab_size, seed)`` -> byte-identical
    trace (prompt token ids included).  Ties on arrival time break by
    tenant name then per-tenant index, so the merge order is stable too.
    """
    events = []
    for tl in tenants:
        # independent per-tenant stream: seed derived from (seed, name)
        tseed = np.random.SeedSequence(
            [seed, *[ord(c) for c in tl.name]])
        seeds = tseed.generate_state(2)
        times = arrival_times(
            tl.n_requests, tl.rate_rps, process=tl.process,
            burst_size=tl.burst_size, burst_factor=tl.burst_factor,
            seed=int(seeds[0]), t0=t0)
        rng = np.random.default_rng(int(seeds[1]))
        p_lo, p_hi = tl.prompt_tokens
        m_lo, m_hi = tl.max_new_tokens
        for i, t in enumerate(times):
            plen = int(rng.integers(p_lo, p_hi + 1))
            events.append((float(t), tl.name, i, SubmitSpec(
                prompt=rng.integers(0, vocab_size, plen),
                max_new_tokens=int(rng.integers(m_lo, m_hi + 1)),
                tenant=tl.name,
                arrival_time_s=float(t),
                slo_deadline_s=tl.slo_deadline_s,
                deadline_s=tl.deadline_s)))
    events.sort(key=lambda e: (e[0], e[1], e[2]))
    return [spec for *_key, spec in events]


@dataclasses.dataclass
class SweepReport:
    """What one sweep run measured — all latency figures sourced from
    ``ServeEngine.stats()["latency"]``, never harness-local timing."""

    #: tenant -> {ttft_p50_s, ttft_p99_s, itl_p50_s, itl_p99_s, done, ...}
    per_tenant: Dict[str, dict]
    #: engine + fabric roll-up for the whole run
    totals: dict
    #: the full engine stats snapshot the report was built from
    engine_stats: dict = dataclasses.field(repr=False, default_factory=dict)

    def table(self) -> str:
        """Human-readable per-tenant latency table (ms)."""
        hdr = (f"{'tenant':<12}{'done':>6}{'shed':>6}{'ttft_p50':>10}"
               f"{'ttft_p99':>10}{'itl_p50':>9}{'itl_p99':>9}")
        lines = [hdr]
        for name, row in sorted(self.per_tenant.items()):
            lines.append(
                f"{name:<12}{row['done']:>6}{row['shed']:>6}"
                f"{row['ttft_p50_s'] * 1e3:>9.2f}m"
                f"{row['ttft_p99_s'] * 1e3:>9.2f}m"
                f"{row['itl_p50_s'] * 1e3:>8.2f}m"
                f"{row['itl_p99_s'] * 1e3:>8.2f}m")
        return "\n".join(lines)


def _tenant_rows(engine: ServeEngine) -> Dict[str, dict]:
    """Per-tenant latency rows from the engine's unified registry
    histograms (``serve.ttft.<tenant>`` / ``serve.itl.<tenant>``)."""
    lat = engine.stats()["latency"]
    tenants = sorted({name.split(".", 2)[2] for name in lat})
    shed_by_tenant: Dict[str, int] = {}
    done_by_tenant: Dict[str, int] = {}
    cancelled_by_tenant: Dict[str, int] = {}
    for req in engine.requests.values():
        if req.state == "shed":
            shed_by_tenant[req.tenant] = shed_by_tenant.get(req.tenant,
                                                            0) + 1
        elif req.state == "done":
            done_by_tenant[req.tenant] = done_by_tenant.get(req.tenant,
                                                            0) + 1
        elif req.state == "cancelled":
            cancelled_by_tenant[req.tenant] = cancelled_by_tenant.get(
                req.tenant, 0) + 1
    rows = {}
    for t in tenants:
        ttft = lat.get(f"serve.ttft.{t}")
        itl = lat.get(f"serve.itl.{t}")
        rows[t] = {
            "done": done_by_tenant.get(t, 0),
            "shed": shed_by_tenant.get(t, 0),
            "cancelled": cancelled_by_tenant.get(t, 0),
            "ttft_count": ttft["count"] if ttft else 0,
            "ttft_p50_s": ttft["p50"] if ttft else 0.0,
            "ttft_p99_s": ttft["p99"] if ttft else 0.0,
            "itl_count": itl["count"] if itl else 0,
            "itl_p50_s": itl["p50"] if itl else 0.0,
            "itl_p99_s": itl["p99"] if itl else 0.0,
        }
    return rows


def run_sweep(engine: ServeEngine, trace: Sequence[SubmitSpec],
              clock: VirtualClock, *, round_s: Optional[float] = None,
              max_rounds: int = 100_000,
              drain_idle_gaps: bool = False) -> SweepReport:
    """Replay a trace against the engine on a virtual clock.

    Open-loop: each round releases every arrival whose timestamp is due,
    runs one engine step, then advances virtual time by the engine's
    pinned round duration (``EngineConfig.round_time_s``, overridable
    with ``round_s``).  When the engine drains before the trace does,
    the clock jumps to the next arrival instead of spinning empty
    rounds.  Runs until the trace is exhausted and the engine is idle
    (or ``max_rounds``, a runaway guard).

    ``drain_idle_gaps``: also advance the fabric's link clock across
    those idle jumps.  Off by default — it would let links drain (and is
    therefore visible in exposed-wait figures) — but chaos runs need it
    so a :class:`~repro.core.faults.FaultInjector`'s event clock tracks
    virtual time through quiet stretches of the trace.
    """
    if round_s is None:
        round_s = engine.ecfg.round_time_s
    if round_s is None or round_s <= 0:
        raise ValueError(
            "run_sweep needs a positive virtual round duration: set "
            "EngineConfig.round_time_s or pass round_s=")
    trace = list(trace)
    for spec in trace:
        if spec.arrival_time_s is None:
            raise ValueError("trace entries need arrival_time_s "
                             "(build_trace stamps them)")
    i, rounds = 0, 0
    peak_concurrent = 0
    peak_lmb_pages = 0
    while i < len(trace) or engine.waiting or engine.active:
        if rounds >= max_rounds:
            raise RuntimeError(
                f"sweep did not drain in {max_rounds} rounds "
                f"({len(engine.waiting)} waiting, {len(engine.active)} "
                "active) — raise max_rounds or lower the offered load")
        while i < len(trace) and trace[i].arrival_time_s <= clock.now:
            engine.submit(trace[i])
            i += 1
        if not (engine.waiting or engine.active):
            gap = trace[i].arrival_time_s - clock.now
            clock.advance_to(trace[i].arrival_time_s)
            if drain_idle_gaps and gap > 0.0:
                engine._fm.advance_links(gap)
            continue
        engine.step()
        clock.advance(round_s)
        rounds += 1
        peak_concurrent = max(peak_concurrent,
                              len(engine.active) + len(engine.waiting))
        peak_lmb_pages = max(peak_lmb_pages,
                             engine.kv.lmb_resident_pages())
    st = engine.stats()
    kv = st["kv"]
    totals = {
        "rounds": rounds,
        "virtual_s": clock.now,
        "requests": len(trace),
        "done": st["done"],
        "shed": st["shed"],
        "cancelled": st["cancelled"],
        "peak_concurrent": peak_concurrent,
        "peak_lmb_resident_pages": peak_lmb_pages,
        "exposed_link_wait_s": kv["link_wait_s"],
        "hidden_link_wait_s": kv["prefetch"]["hidden_wait_s"],
        "kv_hit_ratio": kv["hit_ratio"],
        "meter_calls": st["fabric"]["meter_calls"],
    }
    return SweepReport(per_tenant=_tenant_rows(engine), totals=totals,
                       engine_stats=st)
