"""Congestion-aware tier costs: effective latency as f(link utilization).

The seed's :class:`~repro.core.tiers.TierSpec` carries a *fixed*
``added_latency_s`` — correct for one device on an idle link (the paper's
Fig-6 setup) and wrong for the regime the paper actually argues for (many
devices per expander).  This module replaces the fixed constant on hot
paths with an effective latency derived from observed or predicted link
utilization, using the queueing shape in
:func:`repro.core.tiers.congested_latency`.

``LinkState`` is the glue: consumers feed it metered transfer bytes (from
the :class:`~repro.qos.arbiter.LinkArbiter`) or a predicted demand total,
and cost-model callers read a utilization scalar from it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.tiers import TierKind, TierSpec, congested_latency


@dataclasses.dataclass
class LinkState:
    """Tracks one shared link's load as a utilization scalar in [0, 1].

    Two feeding modes, composable:
      * ``observe_bytes`` — EWMA over metered transfer windows (runtime);
      * ``set_demand``    — offered-load prediction (planning/simulation).
    """

    link_bandwidth_Bps: float
    ewma_alpha: float = 0.3
    _util: float = 0.0

    def observe_bytes(self, nbytes: int, window_s: float) -> None:
        if window_s <= 0:
            return
        inst = min(nbytes / (self.link_bandwidth_Bps * window_s), 1.0)
        self._util += self.ewma_alpha * (inst - self._util)

    def set_demand(self, demand_Bps: float) -> None:
        self._util = min(max(demand_Bps, 0.0) / self.link_bandwidth_Bps, 1.0)

    @property
    def utilization(self) -> float:
        return self._util


@dataclasses.dataclass(frozen=True)
class ContendedTierSpec:
    """A TierSpec whose access cost reads live congestion off a LinkState.

    Drop-in for :class:`TierSpec` on hot paths: same ``kind`` /
    ``bandwidth_Bps`` / ``capacity_bytes`` attributes, but ``access_time``
    and ``added_latency_s`` reflect the current link load instead of the
    uncontended constant.
    """

    base: TierSpec
    link: LinkState

    @property
    def kind(self) -> TierKind:
        return self.base.kind

    @property
    def bandwidth_Bps(self) -> float:
        return self.base.bandwidth_Bps

    @property
    def capacity_bytes(self) -> Optional[int]:
        return self.base.capacity_bytes

    @property
    def added_latency_s(self) -> float:
        """Effective (congested) added latency at the current link load."""
        return congested_latency(self.base.added_latency_s,
                                 self.link.utilization)

    def access_time(self, nbytes: int,
                    utilization: Optional[float] = None) -> float:
        rho = self.link.utilization if utilization is None else utilization
        return self.base.access_time(nbytes, utilization=rho)


def contended_tiers(tiers: Dict[TierKind, TierSpec],
                    link: LinkState,
                    shared_kinds: Optional[set] = None,
                    ) -> Dict[TierKind, TierSpec | ContendedTierSpec]:
    """Wrap the tiers that sit behind the shared expander link.

    Onboard memory and flash are device-local and keep their fixed costs;
    every LMB path (CXL P2P or host-forwarded) and host DRAM contend.
    """
    if shared_kinds is None:
        shared_kinds = {TierKind.LMB_CXL, TierKind.LMB_PCIE_GEN4,
                        TierKind.LMB_PCIE_GEN5, TierKind.HOST_DRAM}
    return {k: (ContendedTierSpec(spec, link) if k in shared_kinds else spec)
            for k, spec in tiers.items()}
