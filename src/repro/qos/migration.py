"""Hot-page migration between pooled expanders.

The paper assumes one expander behind the Fabric Manager; at pool scale
("My CXL Pool Obviates Your PCIe Switch", arXiv 2503.23611) the realistic
shape is several expanders, each with its own link, and the interesting
failure mode is *asymmetric saturation*: one expander's link runs hot while
a sibling idles.  Page-granular tiering/migration is the standard answer in
the CXL literature (survey, arXiv 2412.20249).

This module closes that loop on top of two existing hooks:

  * the per-expander :class:`~repro.qos.arbiter.LinkArbiter` utilization
    EWMA (the saturation signal), and
  * :meth:`LinkedBuffer.migrate_pages` (the mechanism: re-granting
    SAT/IOMMU entries through the Table-2 alloc/free path, exactly like
    the failover re-grant machinery).

:class:`MigrationEngine` is the runtime policy driver: registered
LinkedBuffers expose per-page access heat; when the hottest link crosses
``saturation_threshold`` and a cooler expander exists, the engine moves
the hottest pages across and journals the event on the FM (like a DCD
capacity event).

:func:`plan_rebalance` is the pure planning analogue used by the
discrete-event simulator (``repro.sim.engine.simulate_multi_expander``):
given per-device sustained demands and a device→expander placement, it
greedily rebalances until no link exceeds the threshold or no move helps.

No ``repro.core`` imports at runtime (the FM and buffers arrive duck-typed
via ``register``): ``core.fabric`` imports ``repro.qos.arbiter``, so a
module-level import back into core would cycle.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids the import cycle
    from repro.core.buffer import LinkedBuffer
    from repro.core.fabric import FabricManager


@dataclasses.dataclass(frozen=True)
class MigrationPolicy:
    """Knobs for when and how aggressively pages migrate."""

    #: source-link EWMA utilization that counts as saturated
    saturation_threshold: float = 0.7
    #: require dst utilization < src utilization - min_gap (hysteresis:
    #: stops ping-pong between two warm links)
    min_gap: float = 0.15
    #: per run_once() cap on moved pages (migration is link traffic too)
    max_pages_per_round: int = 64
    #: ignore pages cooler than this (decayed touch count).  The default
    #: keeps pages untouched for ~45 link crossings (0.95^45 ≈ 0.1) out
    #: of the batch: copying near-idle pages costs both links without
    #: reducing the hot one's load
    min_heat: float = 0.1


@dataclasses.dataclass
class MigrationReport:
    """Outcome of one MigrationEngine round."""

    triggered: bool
    src_expander: Optional[int] = None
    dst_expander: Optional[int] = None
    pages_moved: int = 0
    bytes_moved: int = 0
    #: per-expander link utilization sampled at decision time
    utilization: Dict[int, float] = dataclasses.field(default_factory=dict)
    reason: str = ""


class MigrationEngine:
    """Watches per-expander link utilization; moves hot LMB pages from the
    most-saturated expander to the least-loaded one.

    ``fm`` may be a FabricManager or an ``LMBSystem`` client session
    (anything carrying its FM as ``.fm`` — duck-typed to preserve this
    module's no-core-imports rule)."""

    def __init__(self, fm: "FabricManager",
                 policy: Optional[MigrationPolicy] = None):
        self.fm = getattr(fm, "fm", fm)
        self.policy = policy or MigrationPolicy()
        self._buffers: List["LinkedBuffer"] = []
        self.rounds = 0
        self.total_pages_moved = 0
        self.total_bytes_moved = 0

    def register(self, buf: "LinkedBuffer") -> None:
        """Track a LinkedBuffer's pages as migration candidates."""
        if buf.host.fm is not self.fm:
            raise ValueError(
                f"buffer {buf.name} belongs to a different FabricManager: "
                "its expander ids and utilization signals would not match "
                "this engine's")
        if buf not in self._buffers:
            self._buffers.append(buf)

    def run_once(self) -> MigrationReport:
        """One control-loop iteration: sample links, maybe migrate."""
        self.rounds += 1
        utils = self.fm.link_utilizations()
        report = MigrationReport(triggered=False, utilization=dict(utils))
        if len(utils) < 2:
            report.reason = "single healthy expander"
            return report
        src = max(utils, key=lambda eid: (utils[eid], -eid))
        if utils[src] < self.policy.saturation_threshold:
            report.reason = (f"hottest link {utils[src]:.2f} below "
                             f"threshold {self.policy.saturation_threshold}")
            return report
        dst = self.fm.least_loaded_expander(exclude=[src])
        if dst is None:
            report.reason = "no migration target with free capacity"
            return report
        if utils[dst] > utils[src] - self.policy.min_gap:
            report.reason = (f"gap {utils[src] - utils[dst]:.2f} below "
                             f"min_gap {self.policy.min_gap}")
            return report
        report.src_expander, report.dst_expander = src, dst
        budget = self.policy.max_pages_per_round
        for buf in self._buffers:
            if budget <= 0:
                break
            cands = buf.hottest_pages(budget, expander_id=src,
                                      min_heat=self.policy.min_heat)
            if not cands:
                continue
            # migrate_pages stops early (partial count) if the target
            # refuses growth; remaining pages stay intact on the source
            moved = buf.migrate_pages(cands, dst)
            nbytes = moved * buf.lmb_page_bytes
            budget -= moved
            report.pages_moved += moved
            report.bytes_moved += nbytes
            if moved:
                self.fm.record_migration(buf.device_id, src, dst,
                                         moved, nbytes)
            if moved < len(cands):
                report.reason = "target capacity exhausted mid-round"
                break
        report.triggered = report.pages_moved > 0
        if not report.reason:
            report.reason = ("migrated" if report.triggered
                             else "no candidate pages on the hot expander")
        self.total_pages_moved += report.pages_moved
        self.total_bytes_moved += report.bytes_moved
        # span tracer rides on the FM (duck-typed: no core import needed;
        # repro.obs is a dependency leaf either way)
        tr = getattr(self.fm, "tracer", None)
        if tr is not None and tr.enabled and report.triggered:
            tr.event("migration.round", op="migrate",
                     nbytes=report.bytes_moved, expander=dst,
                     pages=report.pages_moved, src=src,
                     src_util=utils[src], dst_util=utils[dst])
        return report

    def stats(self) -> dict:
        return {
            "rounds": self.rounds,
            "pages_moved": self.total_pages_moved,
            "bytes_moved": self.total_bytes_moved,
            "buffers": len(self._buffers),
            "policy": dataclasses.asdict(self.policy),
        }


def plan_rebalance(demands_Bps: Sequence[float],
                   placement: Sequence[int],
                   n_expanders: int,
                   link_bandwidth_Bps: float,
                   saturation_threshold: float = 0.7,
                   alive: Optional[Sequence[int]] = None) -> List[int]:
    """Greedy device→expander rebalance (the simulator's migration model).

    Repeatedly moves the heaviest device off the most-loaded expander onto
    the least-loaded one, while the hottest link's offered load exceeds
    ``saturation_threshold`` and the move strictly lowers it.  Deterministic
    and conservative: never increases the maximum link load.

    ``alive`` restricts targets to the surviving expanders after a
    (correlated) failure: every device homed on a dead expander is FORCED
    off it first — least-loaded survivor, heaviest device first, no
    improvement test, because staying is not an option — and the greedy
    rebalance then runs over the survivors only.  Default: all alive.
    """
    if len(demands_Bps) != len(placement):
        raise ValueError("demands and placement length mismatch")
    live = sorted(set(range(n_expanders) if alive is None
                      else (int(e) for e in alive)))
    if not live:
        raise ValueError("no surviving expander to rebalance onto")
    if any(not 0 <= e < n_expanders for e in live):
        raise ValueError(f"alive references unknown expander: {live}")
    place = list(placement)
    loads = [0.0] * n_expanders
    for dev, eid in enumerate(place):
        loads[eid] += demands_Bps[dev]

    def rho(eid: int) -> float:
        return loads[eid] / link_bandwidth_Bps

    live_set = set(live)
    evacuees = sorted((dev for dev, eid in enumerate(place)
                       if eid not in live_set),
                      key=lambda dev: demands_Bps[dev], reverse=True)
    for dev in evacuees:
        dst = min(live, key=rho)
        loads[place[dev]] -= demands_Bps[dev]
        place[dev] = dst
        loads[dst] += demands_Bps[dev]

    while True:
        src = max(live, key=rho)
        if rho(src) <= saturation_threshold:
            break
        dst = min(live, key=rho)
        movers = sorted((dev for dev, eid in enumerate(place)
                         if eid == src),
                        key=lambda dev: demands_Bps[dev], reverse=True)
        moved = False
        for dev in movers:
            d = demands_Bps[dev]
            # only if it strictly lowers the hottest of the two links
            if max(loads[src] - d, loads[dst] + d) < loads[src]:
                place[dev] = dst
                loads[src] -= d
                loads[dst] += d
                moved = True
                break
        if not moved:
            break
    return place
