"""Per-tenant SLO tracking and congestion-aware admission control.

Closes the QoS loop: the arbiter (repro.qos.arbiter) decides *who gets the
link*, the contention model (repro.qos.contention) decides *what the link
costs*, and this module decides *who gets in at all*.  An
:class:`AdmissionController` holds per-tenant latency targets, predicts
each tenant's p99 under the load the admitted set puts on the shared link,
and answers admit / throttle / shed — the serving engine consults it
before seating a request in a decode slot.

Modeled p99 is intentionally pessimistic-monotone: admitting demand can
only raise everyone's predicted tail (utilization is a sum of admitted
demands and ``congested_latency`` is monotone in it), so incumbents are
never promised an improvement by adding a neighbor.  Tests pin this
property (SLO-admission monotonicity).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional

import numpy as np

from repro.core.tiers import congested_latency
from repro.qos.arbiter import LinkArbiter


class Decision(enum.Enum):
    ADMIT = "admit"          # predicted p99 within target
    THROTTLE = "throttle"    # over target but under the shed line: defer
    SHED = "shed"            # would blow the target even if deferred: reject


@dataclasses.dataclass(frozen=True)
class SLOTarget:
    """What a tenant was promised."""

    p99_latency_s: float
    #: predicted p99 above ``shed_factor * p99_latency_s`` rejects outright
    shed_factor: float = 2.0


@dataclasses.dataclass
class TenantSLO:
    """Controller-side record for one tenant."""

    tenant_id: str
    target: SLOTarget
    #: sustained link demand this tenant adds when admitted (B/s)
    demand_Bps: float
    #: uncontended per-request latency floor (tier access + service time)
    base_latency_s: float
    admitted: bool = False
    window: int = 256
    _lat: list = dataclasses.field(default_factory=list)
    admitted_count: int = 0
    throttled_count: int = 0
    shed_count: int = 0
    #: requests cancelled after admission (deadline expiry / capacity loss)
    cancelled_count: int = 0

    def observe(self, latency_s: float) -> None:
        self._lat.append(latency_s)
        if len(self._lat) > self.window:
            del self._lat[: len(self._lat) - self.window]

    def observed_p99(self) -> Optional[float]:
        if not self._lat:
            return None
        return float(np.percentile(np.asarray(self._lat), 99))


class AdmissionController:
    """Admit / throttle / shed tenants against a shared-link budget."""

    def __init__(self, link_bandwidth_Bps: float,
                 default_target: SLOTarget = SLOTarget(p99_latency_s=1.0),
                 arbiter: Optional[LinkArbiter] = None):
        self.link_bandwidth_Bps = float(link_bandwidth_Bps)
        self.default_target = default_target
        self.arbiter = arbiter
        self._tenants: Dict[str, TenantSLO] = {}

    # -- registration --------------------------------------------------------
    def register(self, tenant_id: str, *,
                 target: Optional[SLOTarget] = None,
                 demand_Bps: float = 0.0,
                 base_latency_s: float = 1e-3) -> TenantSLO:
        t = TenantSLO(tenant_id, target or self.default_target,
                      demand_Bps=demand_Bps, base_latency_s=base_latency_s)
        self._tenants[tenant_id] = t
        return t

    def tenant(self, tenant_id: str) -> TenantSLO:
        t = self._tenants.get(tenant_id)
        if t is None:
            t = self.register(tenant_id)
        return t

    # -- load model ----------------------------------------------------------
    def admitted_demand_Bps(self) -> float:
        return sum(t.demand_Bps for t in self._tenants.values() if t.admitted)

    def utilization(self, extra_demand_Bps: float = 0.0) -> float:
        """Predicted link utilization with the admitted set (+ extra)."""
        rho = ((self.admitted_demand_Bps() + extra_demand_Bps)
               / self.link_bandwidth_Bps)
        if self.arbiter is not None:
            # never predict below what the link is already observed doing
            rho = max(rho, self.arbiter.utilization())
        return min(rho, 1.0)

    def modeled_p99(self, tenant_id: str,
                    extra_demand_Bps: float = 0.0) -> float:
        """Tenant's predicted p99 under current admissions (+ extra load).

        Floor is the worse of the tenant's uncontended base latency and its
        *observed* p99; congestion then inflates it.  Monotone in the
        admitted demand by construction.
        """
        t = self.tenant(tenant_id)
        floor = t.base_latency_s
        obs = t.observed_p99()
        if obs is not None:
            floor = max(floor, obs)
        return congested_latency(floor, self.utilization(extra_demand_Bps))

    # -- the decision --------------------------------------------------------
    def decide(self, tenant_id: str) -> Decision:
        """Admit / throttle / shed one unit of ``tenant_id``'s work.

        Admission is evaluated *with* the tenant's demand on the link (an
        un-admitted tenant's demand counts as the extra; an admitted one is
        already in the sum).
        """
        t = self.tenant(tenant_id)
        extra = 0.0 if t.admitted else t.demand_Bps
        p99 = self.modeled_p99(tenant_id, extra_demand_Bps=extra)
        target = t.target.p99_latency_s
        if p99 <= target:
            t.admitted = True
            t.admitted_count += 1
            return Decision.ADMIT
        if p99 <= target * t.target.shed_factor:
            t.throttled_count += 1
            return Decision.THROTTLE
        t.shed_count += 1
        return Decision.SHED

    def release(self, tenant_id: str) -> None:
        """Tenant's work drained; stop counting its demand against the link."""
        self.tenant(tenant_id).admitted = False

    def observe(self, tenant_id: str, latency_s: float) -> None:
        self.tenant(tenant_id).observe(latency_s)

    def record_cancel(self, tenant_id: str) -> None:
        """A previously admitted request was cancelled (deadline expiry or
        mid-flight capacity loss) — counted against the tenant's SLO."""
        self.tenant(tenant_id).cancelled_count += 1

    # -- introspection -------------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "link_bandwidth_Bps": self.link_bandwidth_Bps,
            "utilization": self.utilization(),
            "tenants": {
                tid: {
                    "admitted": t.admitted,
                    "demand_Bps": t.demand_Bps,
                    "target_p99_s": t.target.p99_latency_s,
                    "observed_p99_s": t.observed_p99(),
                    "modeled_p99_s": self.modeled_p99(tid),
                    "admitted_count": t.admitted_count,
                    "throttled_count": t.throttled_count,
                    "shed_count": t.shed_count,
                    "cancelled_count": t.cancelled_count,
                }
                for tid, t in self._tenants.items()
            },
        }
