"""repro.qos — shared-fabric contention and multi-tenant QoS.

The paper's scalability story (one expander behind many PCIe devices)
makes the CXL link a contended, arbitrated resource.  Three layers:

  arbiter    — weighted-fair / token-bucket scheduling of per-tenant
               transfer demand onto per-expander link bandwidth
  contention — effective tier latency as a function of link utilization
               (replaces the fixed added_latency_s on hot paths)
  slo        — per-tenant SLO tracking + admit/throttle/shed control
  migration  — hot-page migration between pooled expanders (saturation-
               triggered, heat-ranked, journaled like DCD events)

Wired through: FabricManager owns a LinkArbiter next to its capacity
quotas, LinkedBuffer meters paging traffic through it, the Fig-6
simulator grows a multi-device shared-fabric mode, and the serving
engine routes admission through the SLO controller.
"""

# arbiter must come first: it is core-free, and importing contention/slo
# below pulls in repro.core, whose fabric module imports repro.qos.arbiter
from repro.qos.arbiter import (LinkArbiter, TenantState, TransferGrant,
                               UnknownTenant, jain_fairness,
                               weighted_max_min)
from repro.qos.contention import (ContendedTierSpec, LinkState,
                                  contended_tiers)
from repro.qos.migration import (MigrationEngine, MigrationPolicy,
                                 MigrationReport, plan_rebalance)
from repro.qos.slo import (AdmissionController, Decision, SLOTarget,
                           TenantSLO)

__all__ = [
    "LinkArbiter", "TenantState", "TransferGrant", "UnknownTenant",
    "jain_fairness", "weighted_max_min", "ContendedTierSpec", "LinkState",
    "contended_tiers", "AdmissionController", "Decision", "SLOTarget",
    "TenantSLO", "MigrationEngine", "MigrationPolicy", "MigrationReport",
    "plan_rebalance",
]
