"""Weighted-fair link arbiter: per-expander bandwidth as a scheduled resource.

The seed modeled every consumer as alone on the CXL link; the paper's
scalability claim (one expander supplementing *many* PCIe devices, §3,
Table 1) makes the link a contended resource.  This module arbitrates it
two ways, matching how the Fabric Manager uses it:

  * **planning** — :meth:`LinkArbiter.allocate` answers "given these
    per-tenant demands (B/s), who gets how much of the link?" by weighted
    max-min fairness (progressive water-filling).  Used by the multi-device
    simulator and the SLO admission controller to predict steady state.
  * **metering** — :meth:`LinkArbiter.meter` charges an individual transfer
    against the tenant's token bucket and the shared wire, returning the
    modeled delay.  Used on LinkedBuffer's demote/fault paths so paging
    traffic shows up as link occupancy.  ``nbytes`` is arbitrary, so a
    coalesced multi-page burst is ONE meter call with the burst's total
    bytes — fairness accounting is byte-denominated (token bucket +
    weighted refill), so a burst charge is exactly equivalent to N
    back-to-back page charges, minus N-1 arbiter round-trips.
    :attr:`LinkArbiter.meter_calls` counts the round-trips, which is how
    the ``gather_sweep`` benchmark proves the batched data path amortizes
    arbitration (doorbells, in hardware terms) over bursts.

Time here is *virtual* (deterministic, driven by metered transfers), so
tests and the simulator get exact, reproducible schedules — no wall clock.

This module stays free of ``repro.core`` imports: ``core.fabric`` imports
it (the FM owns a LinkArbiter), so depending on core here would be a
cycle.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional


class UnknownTenant(KeyError):
    pass


@dataclasses.dataclass
class TenantState:
    """Arbiter-side accounting for one tenant (a device or a host)."""

    tenant_id: str
    weight: float = 1.0
    #: token-bucket burst allowance; 0 disables the bucket (pure FIFO wire)
    burst_bytes: int = 0
    tokens: float = 0.0
    last_refill_s: float = 0.0
    bytes_total: int = 0
    busy_s: float = 0.0
    wait_s: float = 0.0

    def goodput_Bps(self, elapsed_s: float) -> float:
        return self.bytes_total / elapsed_s if elapsed_s > 0 else 0.0


@dataclasses.dataclass(frozen=True)
class TransferGrant:
    """Outcome of metering one transfer through the link."""

    tenant_id: str
    nbytes: int
    start_s: float          # when the wire picked the transfer up
    completion_s: float     # when the last byte arrived
    delay_s: float          # completion - submission (queue + wire)


def weighted_max_min(demands_Bps: Dict[str, float],
                     weights: Dict[str, float],
                     capacity_Bps: float) -> Dict[str, float]:
    """Weighted max-min fair allocation (progressive water-filling).

    Tenants demanding less than their weighted fair share are fully
    satisfied; the surplus is re-divided among the rest by weight.
    Guarantees ``sum(grants) <= capacity_Bps`` and ``grant <= demand``.
    """
    grants = {t: 0.0 for t in demands_Bps}
    active = {t: d for t, d in demands_Bps.items() if d > 0}
    remaining = capacity_Bps
    while active and remaining > 1e-9:
        total_w = sum(weights.get(t, 1.0) for t in active)
        share = {t: remaining * weights.get(t, 1.0) / total_w for t in active}
        satisfied = [t for t in active if active[t] <= share[t] + 1e-12]
        if not satisfied:
            for t in active:
                grants[t] = share[t]
            return grants
        for t in satisfied:
            grants[t] = active[t]
            remaining -= active[t]
            del active[t]
    return grants


class LinkArbiter:
    """Schedules per-tenant transfer demand onto one expander's link."""

    def __init__(self, link_bandwidth_Bps: float, *,
                 ewma_alpha: float = 0.2):
        if link_bandwidth_Bps <= 0:
            raise ValueError("link bandwidth must be positive")
        self.link_bandwidth_Bps = float(link_bandwidth_Bps)
        self._tenants: Dict[str, TenantState] = {}
        self._ewma_alpha = ewma_alpha
        self._now_s = 0.0           # virtual clock
        self._busy_until_s = 0.0    # wire free time
        self._busy_accum_s = 0.0
        self._prev_completion_s = 0.0
        self._util_ewma = 0.0
        #: arbitration round-trips (one per meter() call, whatever the
        #: burst size) — the per-transfer overhead the batched data path
        #: amortizes; NOT bytes (those are in TenantState.bytes_total)
        self.meter_calls = 0

    # -- tenant management ---------------------------------------------------
    def register(self, tenant_id: str, weight: float = 1.0,
                 burst_bytes: int = 0) -> None:
        if weight <= 0:
            raise ValueError("tenant weight must be positive")
        st = self._tenants.get(tenant_id)
        if st is None:
            self._tenants[tenant_id] = TenantState(
                tenant_id, weight=weight, burst_bytes=burst_bytes,
                tokens=float(burst_bytes), last_refill_s=self._now_s)
        else:
            st.weight, st.burst_bytes = weight, burst_bytes

    def unregister(self, tenant_id: str) -> None:
        self._tenants.pop(tenant_id, None)

    def set_weight(self, tenant_id: str, weight: float) -> None:
        self._tenant(tenant_id).weight = float(weight)

    def _tenant(self, tenant_id: str) -> TenantState:
        st = self._tenants.get(tenant_id)
        if st is None:
            raise UnknownTenant(f"tenant {tenant_id} not registered")
        return st

    def fair_rate_Bps(self, tenant_id: str) -> float:
        """This tenant's weighted share of the raw link (its refill rate)."""
        st = self._tenant(tenant_id)
        total_w = sum(t.weight for t in self._tenants.values())
        return self.link_bandwidth_Bps * st.weight / total_w

    # -- planning: steady-state shares ---------------------------------------
    def allocate(self, demands_Bps: Dict[str, float]) -> Dict[str, float]:
        """Weighted max-min grants for a set of sustained demands."""
        weights = {t: self._tenant(t).weight for t in demands_Bps}
        return weighted_max_min(demands_Bps, weights,
                                self.link_bandwidth_Bps)

    # -- metering: individual transfers --------------------------------------
    def meter(self, tenant_id: str, nbytes: int,
              now_s: Optional[float] = None) -> TransferGrant:
        """Charge one ``nbytes`` transfer; returns its modeled schedule.

        A transfer first draws burst credit from the tenant's token bucket
        (refilled at the tenant's weighted fair rate); a drained bucket
        waits for tokens.  It then serializes on the shared wire at the raw
        link bandwidth.
        """
        st = self._tenant(tenant_id)
        self.meter_calls += 1
        now = self._now_s if now_s is None else max(now_s, self._now_s)
        self._now_s = now
        token_ready = now
        if st.burst_bytes > 0:
            rate = self.fair_rate_Bps(tenant_id)
            st.tokens = min(float(st.burst_bytes),
                            st.tokens + rate * (now - st.last_refill_s))
            st.last_refill_s = now
            if st.tokens >= nbytes:
                st.tokens -= nbytes
            else:
                deficit = nbytes - st.tokens
                token_ready = now + deficit / rate
                st.tokens = 0.0
                st.last_refill_s = token_ready
        wire_s = nbytes / self.link_bandwidth_Bps
        start = max(token_ready, self._busy_until_s)
        completion = start + wire_s
        self._busy_until_s = completion
        self._busy_accum_s += wire_s
        st.bytes_total += nbytes
        st.busy_s += wire_s
        st.wait_s += start - now
        # instantaneous utilization = wire-busy fraction of the window
        # between consecutive completions: back-to-back (queued) transfers
        # give 1.0, sparse traffic gives wire/gap -> 0
        inst = wire_s / max(completion - self._prev_completion_s, wire_s)
        self._prev_completion_s = completion
        self._util_ewma += self._ewma_alpha * (inst - self._util_ewma)
        return TransferGrant(tenant_id, nbytes, start, completion,
                             completion - now)

    def advance(self, dt_s: float) -> None:
        """Let virtual time pass with the link idle (drains the queue)."""
        self._now_s += max(dt_s, 0.0)

    # -- introspection -------------------------------------------------------
    def utilization(self) -> float:
        """EWMA of instantaneous link utilization (1.0 = always queued)."""
        return self._util_ewma

    def cumulative_utilization(self) -> float:
        horizon = max(self._busy_until_s, self._now_s)
        return self._busy_accum_s / horizon if horizon > 0 else 0.0

    def goodput_Bps(self, tenant_id: str) -> float:
        horizon = max(self._busy_until_s, self._now_s)
        return self._tenant(tenant_id).goodput_Bps(horizon)

    def snapshot(self) -> dict:
        return {
            "link_bandwidth_Bps": self.link_bandwidth_Bps,
            "utilization_ewma": self._util_ewma,
            "utilization_cumulative": self.cumulative_utilization(),
            "meter_calls": self.meter_calls,
            "tenants": {
                t: {"weight": s.weight, "bytes_total": s.bytes_total,
                    "busy_s": s.busy_s, "wait_s": s.wait_s}
                for t, s in self._tenants.items()
            },
        }


def jain_fairness(values: Dict[str, float] | list) -> float:
    """Jain's index over per-tenant goodputs: 1.0 = perfectly fair."""
    xs = list(values.values()) if isinstance(values, dict) else list(values)
    if not xs or all(x == 0 for x in xs):
        return 1.0
    num = sum(xs) ** 2
    den = len(xs) * sum(x * x for x in xs)
    return num / den if den else 1.0
