"""Discrete-event engine: closed-loop QD simulation of the SSD pipeline.

A fixed queue-depth worker pool (fio/libaio semantics, QD=64) keeps ``qd``
IOs in flight.  Each IO serializes through up to two rate-limited stages:

  index stage — only for external (non-onboard) lookups; throughput-limited
      by the device's IndexEngine at the scheme's tier latency, and adds the
      tier latency to the IO's completion time;
  data stage  — throughput-limited by the device's baseline Table-3 numbers,
      and adds the baseline per-IO latency.

Event structure: because both stages are work-conserving single-queue rate
limiters, the DES reduces to tracking each stage's next-free time while still
processing every IO individually (so we get exact per-IO latencies and can
mix hit/miss populations from the locality model).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional

import numpy as np

from repro.sim.ssd import Scheme, SSDSpec
from repro.sim.workload import Workload


@dataclasses.dataclass
class SimResult:
    scheme: str
    workload: str
    device: str
    n_ios: int
    wall_s: float
    iops: float
    bandwidth_MBps: float
    mean_lat_us: float
    p99_lat_us: float
    index_hit_ratio: float

    def row(self) -> str:
        return (f"{self.device},{self.workload},{self.scheme},"
                f"{self.iops:.0f},{self.bandwidth_MBps:.1f},"
                f"{self.mean_lat_us:.2f},{self.p99_lat_us:.2f}")


def simulate(spec: SSDSpec, scheme: Scheme, workload: Workload,
             seed: Optional[int] = None) -> SimResult:
    rng = np.random.default_rng(workload.seed if seed is None else seed)
    n = workload.n_ios
    qd = workload.queue_depth
    pattern, op = workload.pattern, workload.op

    # ---- stage rates ------------------------------------------------------
    data_rate = spec.base_iops(pattern, op)
    # Table-3 latencies are QD1 figures; at QD=64 the device pipelines, so
    # the steady-state per-IO latency is qd/rate (Little) — whichever is
    # smaller binds.  Without this the Ideal scheme could never reach the
    # device's own spec-sheet IOPS at the paper's queue depth.
    data_lat = min(spec.base_latency_s(op), qd / data_rate)

    engine = spec.index_rand if pattern in ("rand", "zipf") else spec.index_seq
    needs_index = scheme.t_tier_s is not None and (
        op == "read" or scheme.write_through_index)
    if needs_index:
        if scheme.name == "dftl":
            # flash-resident index: single outstanding flash index op
            index_rate = spec.dftl_concurrency / scheme.t_tier_s
        else:
            index_rate = engine.rate(scheme.t_tier_s)
        index_lat = scheme.t_tier_s
    else:
        index_rate, index_lat = float("inf"), 0.0

    hit_ratio = scheme.onboard_hit_ratio
    hits = (rng.random(n) < hit_ratio) if needs_index and hit_ratio > 0 \
        else np.zeros(n, dtype=bool) if needs_index else np.ones(n, dtype=bool)

    # ---- closed-loop DES ---------------------------------------------------
    # worker completion heap holds the times the qd slots free up
    slots: List[float] = [0.0] * qd
    heapq.heapify(slots)
    index_free = 0.0
    data_free = 0.0
    lat = np.empty(n)
    t_end = 0.0
    inv_data = 1.0 / data_rate
    inv_index = (1.0 / index_rate) if index_rate != float("inf") else 0.0

    for i in range(n):
        start = heapq.heappop(slots)
        t = start
        if needs_index and not hits[i]:
            issue = max(t, index_free)
            index_free = issue + inv_index
            t = issue + index_lat
        issue = max(t, data_free)
        data_free = issue + inv_data
        t = issue + data_lat
        lat[i] = t - start
        t_end = max(t_end, t)
        heapq.heappush(slots, t)

    wall = t_end
    iops = n / wall
    return SimResult(
        scheme=scheme.name, workload=workload.name, device=spec.name,
        n_ios=n, wall_s=wall, iops=iops,
        bandwidth_MBps=iops * workload.io_bytes / 1e6,
        mean_lat_us=float(lat.mean() * 1e6),
        p99_lat_us=float(np.percentile(lat, 99) * 1e6),
        index_hit_ratio=float(hits.mean()) if needs_index else 1.0,
    )
