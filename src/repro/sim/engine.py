"""Discrete-event engine: closed-loop QD simulation of the SSD pipeline.

A fixed queue-depth worker pool (fio/libaio semantics, QD=64) keeps ``qd``
IOs in flight.  Each IO serializes through up to two rate-limited stages:

  index stage — only for external (non-onboard) lookups; throughput-limited
      by the device's IndexEngine at the scheme's tier latency, and adds the
      tier latency to the IO's completion time;
  data stage  — throughput-limited by the device's baseline Table-3 numbers,
      and adds the baseline per-IO latency.

Event structure: because both stages are work-conserving single-queue rate
limiters, the DES reduces to tracking each stage's next-free time while still
processing every IO individually (so we get exact per-IO latencies and can
mix hit/miss populations from the locality model).

As of the rack-scale PR the default execution engine is the VECTORIZED
struct-of-arrays core (``repro.rack.des.simulate_lanes``): the same
recurrence, evaluated as chunked max-plus prefix scans over numpy
arrays, with many devices advancing in lockstep lanes.  ``simulate``,
``simulate_shared_fabric`` and ``simulate_multi_expander`` are all
re-expressed on that core; the original per-IO Python loop survives as
``engine="scalar"`` — the reference implementation regression tests and
the rack_sweep speedup gate compare against.

Multi-device mode (``simulate_shared_fabric``): N devices hammer ONE
expander through a shared link — the scalability question the paper's Fig 6
never answers.  The link is arbitrated by weighted max-min fairness
(repro.qos.arbiter); each device's data stage is capped at its granted
share, and every device's external index accesses see the congested tier
latency (repro.qos / tiers.congested_latency) at the link's total load.

Multi-expander mode (``simulate_multi_expander``): devices spread over a
POOL of expanders, each with its own link.  A skewed placement (every
device on expander 0, siblings idle) saturates one link; hot-page
migration (repro.qos.migration.plan_rebalance) then rebalances placement
and the per-device p99 recovers toward the uncontended baseline — at the
cost of the migrated bytes crossing both links once.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.overlap import exposed_latency_s
from repro.core.tiers import congested_latency
from repro.obs.trace import GLOBAL_TRACER
from repro.qos.arbiter import jain_fairness, weighted_max_min
from repro.qos.migration import plan_rebalance
from repro.sim.ssd import Scheme, SSDSpec
from repro.sim.workload import Workload, locality_hits


def recovery_fraction(hot_before_us: float, hot_after_us: float,
                      baseline_us: float) -> float:
    """1.0 = the contended p99 fully recovered to the uncontended
    baseline; 0.0 = the intervention didn't help.  Guarded against the
    zero/negative-denominator case: when the contended and baseline p99
    coincide (nothing was lost) the answer is full recovery, not a
    divide-by-zero.  Shared by :class:`MultiExpanderResult` and the
    rack-scale failover metrics (repro.rack.scenarios)."""
    gap = hot_before_us - baseline_us
    if gap <= 0:
        return 1.0
    rec = (hot_before_us - hot_after_us) / gap
    return float(min(max(rec, 0.0), 1.0))


@dataclasses.dataclass
class SimResult:
    scheme: str
    workload: str
    device: str
    n_ios: int
    wall_s: float
    iops: float
    bandwidth_MBps: float
    mean_lat_us: float
    p99_lat_us: float
    index_hit_ratio: float

    def row(self) -> str:
        return (f"{self.device},{self.workload},{self.scheme},"
                f"{self.iops:.0f},{self.bandwidth_MBps:.1f},"
                f"{self.mean_lat_us:.2f},{self.p99_lat_us:.2f}")


def _lane_to_result(spec: SSDSpec, scheme: Scheme, workload: Workload,
                    lanes, i: int, device: Optional[str] = None) -> SimResult:
    """One lane of a ``repro.rack.des.LaneResult`` as a SimResult."""
    iops = float(lanes.iops[i])
    result = SimResult(
        scheme=scheme.name, workload=workload.name,
        device=device or spec.name,
        n_ios=lanes.n_ios, wall_s=float(lanes.wall_s[i]), iops=iops,
        bandwidth_MBps=iops * workload.io_bytes / 1e6,
        mean_lat_us=float(lanes.mean_lat_s[i] * 1e6),
        p99_lat_us=float(lanes.p99_lat_s[i] * 1e6),
        index_hit_ratio=float(lanes.index_hit_ratio[i]),
    )
    tr = GLOBAL_TRACER
    if tr.enabled:
        tr.add("sim.run", tr.now(), result.wall_s, op="sim",
               nbytes=result.n_ios * workload.io_bytes, scheme=scheme.name,
               workload=workload.name, device=result.device,
               iops=round(iops), p99_us=round(result.p99_lat_us, 2))
    return result


def simulate(spec: SSDSpec, scheme: Scheme, workload: Workload,
             seed: Optional[int] = None, *,
             data_rate_cap_iops: Optional[float] = None,
             link_utilization: float = 0.0,
             prefetch_depth: int = 0,
             extra_index_latency_s: float = 0.0,
             engine: str = "vector") -> SimResult:
    """Closed-loop DES of one device.

    ``data_rate_cap_iops`` throttles the data stage below the device's
    Table-3 rate — the granted share of a shared expander link in
    multi-device mode.  ``link_utilization`` inflates the external index
    latency by the queueing model (0.0 = seed behaviour: alone on the
    link).  ``prefetch_depth`` models a sequential lookahead of that
    many IOs: the external L2P access for IO *i* issues while the
    preceding ``depth`` IOs occupy the data stage, hiding up to that
    compute window of its latency (repro.core.overlap) — bandwidth is
    hideable behind compute, but the index engine's service rate is
    not, and random/zipf patterns (no predictable next index) get no
    hiding at all: the demand-only parity case.
    ``extra_index_latency_s`` adds a fabric path cost (a
    :class:`repro.rack.topology.PathCost` latency) to every external
    index access — 0.0 is the direct-attach degenerate case.

    ``engine`` selects the execution core: ``"vector"`` (default) runs
    the lockstep struct-of-arrays core (``repro.rack.des``); ``"scalar"``
    is the original per-IO Python loop, kept as the reference the
    regression tests and the rack_sweep speedup gate compare against.
    Both produce the same seeded results to floating-point tolerance.
    """
    if engine not in ("vector", "scalar"):
        raise ValueError(f"unknown engine {engine!r} "
                         "(choose 'vector' or 'scalar')")
    lane_seed = workload.seed if seed is None else seed
    if engine == "vector":
        from repro.rack.des import simulate_lanes
        lanes = simulate_lanes(
            spec, scheme, workload, seeds=[lane_seed],
            data_rate_cap_iops=data_rate_cap_iops,
            link_utilization=link_utilization,
            extra_index_latency_s=extra_index_latency_s,
            prefetch_depth=prefetch_depth)
        return _lane_to_result(spec, scheme, workload, lanes, 0)
    n = workload.n_ios
    qd = workload.queue_depth
    pattern, op = workload.pattern, workload.op

    # ---- stage rates ------------------------------------------------------
    data_rate = spec.base_iops(pattern, op)
    if data_rate_cap_iops is not None:
        data_rate = min(data_rate, max(data_rate_cap_iops, 1.0))
    # Table-3 latencies are QD1 figures; at QD=64 the device pipelines, so
    # the steady-state per-IO latency is qd/rate (Little) — whichever is
    # smaller binds.  Without this the Ideal scheme could never reach the
    # device's own spec-sheet IOPS at the paper's queue depth.
    data_lat = min(spec.base_latency_s(op), qd / data_rate)

    engine = spec.index_rand if pattern in ("rand", "zipf") else spec.index_seq
    needs_index = scheme.t_tier_s is not None and (
        op == "read" or scheme.write_through_index)
    if needs_index:
        if scheme.name == "dftl":
            # flash-resident index: single outstanding flash index op
            # (flash is device-local — link congestion does not apply)
            index_rate = spec.dftl_concurrency / scheme.t_tier_s
            index_lat = scheme.t_tier_s
        else:
            # Congestion adds *waiting* to each external access; the
            # throughput cost of sharing is already the arbiter's grant cap
            # (data_rate_cap_iops), so inflating the engine's sustained
            # rate as well would double-count the link.
            t_eff = scheme.t_tier_s + extra_index_latency_s
            index_rate = engine.rate(t_eff)
            index_lat = congested_latency(t_eff, link_utilization)
            if prefetch_depth > 0 and pattern == "seq":
                # lookahead window = the data-stage service time of the
                # depth preceding IOs; only the latency the window can't
                # cover stays exposed (congestion inflation included —
                # outstanding transfers hide queueing too)
                index_lat = exposed_latency_s(
                    index_lat, prefetch_depth / data_rate)
    else:
        index_rate, index_lat = float("inf"), 0.0

    hit_ratio = scheme.onboard_hit_ratio
    hits = locality_hits(n, hit_ratio, lane_seed) if needs_index \
        else np.ones(n, dtype=bool)

    # ---- closed-loop DES ---------------------------------------------------
    # worker completion heap holds the times the qd slots free up
    slots: List[float] = [0.0] * qd
    heapq.heapify(slots)
    index_free = 0.0
    data_free = 0.0
    lat = np.empty(n)
    t_end = 0.0
    inv_data = 1.0 / data_rate
    inv_index = (1.0 / index_rate) if index_rate != float("inf") else 0.0

    for i in range(n):
        start = heapq.heappop(slots)
        t = start
        if needs_index and not hits[i]:
            issue = max(t, index_free)
            index_free = issue + inv_index
            t = issue + index_lat
        issue = max(t, data_free)
        data_free = issue + inv_data
        t = issue + data_lat
        lat[i] = t - start
        t_end = max(t_end, t)
        heapq.heappush(slots, t)

    wall = t_end
    iops = n / wall
    result = SimResult(
        scheme=scheme.name, workload=workload.name, device=spec.name,
        n_ios=n, wall_s=wall, iops=iops,
        bandwidth_MBps=iops * workload.io_bytes / 1e6,
        mean_lat_us=float(lat.mean() * 1e6),
        p99_lat_us=float(np.percentile(lat, 99) * 1e6),
        index_hit_ratio=float(hits.mean()) if needs_index else 1.0,
    )
    tr = GLOBAL_TRACER
    if tr.enabled:
        # one summary span per simulated run (dur = VIRTUAL wall time,
        # like the link.xfer convention) so benchmark traces show the
        # fig6/fabric sweeps alongside the live-system spans
        tr.add("sim.run", tr.now(), wall, op="sim",
               nbytes=n * workload.io_bytes, scheme=scheme.name,
               workload=workload.name, device=spec.name,
               iops=round(iops), p99_us=round(result.p99_lat_us, 2))
    return result


# ---------------------------------------------------------------------------
# Multi-device shared-fabric mode (repro.qos)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SharedFabricResult:
    """N devices sharing one expander link (the paper's scalability case)."""

    n_devices: int
    link_bandwidth_Bps: float
    weights: List[float]
    per_device: List[SimResult]
    #: one device's unconstrained link demand (B/s)
    demand_Bps: float
    #: weighted max-min grants actually scheduled onto the link (B/s)
    grants_Bps: List[float]
    #: sum of achieved per-device goodput through the link (B/s)
    aggregate_goodput_Bps: float
    #: offered load relative to link capacity (>= achieved utilization)
    offered_utilization: float
    fairness_jain: float
    mean_p99_us: float

    def row(self) -> str:
        return (f"{self.n_devices},{self.aggregate_goodput_Bps/1e9:.2f},"
                f"{self.offered_utilization:.2f},{self.fairness_jain:.3f},"
                f"{self.mean_p99_us:.1f}")


def simulate_shared_fabric(spec: SSDSpec, scheme: Scheme, workload: Workload,
                           n_devices: int,
                           link_bandwidth_Bps: float = 30e9,
                           weights: Optional[Sequence[float]] = None,
                           prefetch_depth: int = 0,
                           engine: str = "vector",
                           ) -> SharedFabricResult:
    """Fig-6 pipeline × N devices hammering ONE expander.

    Each device stages its IO payloads through the expander (the paper's
    shared-buffer scenario), so every IO moves ``workload.io_bytes`` over
    the link.  The link is divided by weighted max-min fairness
    (:func:`repro.qos.arbiter.weighted_max_min`); each device's data stage
    is capped at its grant and its external index accesses see the
    congested tier latency at the link's offered load.  ``prefetch_depth``
    gives every device the sequential-lookahead latency hiding modeled in
    :func:`simulate` — prefetch bandwidth rides behind the data stage, so
    it raises goodput without changing the arbiter's fairness math.
    """
    if weights is None:
        weights = [1.0] * n_devices
    if len(weights) != n_devices:
        raise ValueError(f"{len(weights)} weights for {n_devices} devices")

    # one device's unconstrained throughput = its sustained link demand
    base = simulate(spec, scheme, workload, prefetch_depth=prefetch_depth,
                    engine=engine)
    demand_Bps = base.iops * workload.io_bytes

    names = [f"dev{i}" for i in range(n_devices)]
    grants = weighted_max_min(
        {nm: demand_Bps for nm in names},
        {nm: w for nm, w in zip(names, weights)},
        link_bandwidth_Bps)
    offered = min(n_devices * demand_Bps / link_bandwidth_Bps, 1.0)

    per_device: List[SimResult] = []
    if engine == "vector":
        # all devices advance as lockstep lanes of one vectorized run
        from repro.rack.des import simulate_lanes
        lanes = simulate_lanes(
            spec, scheme, workload,
            seeds=[workload.seed + i for i in range(n_devices)],
            data_rate_cap_iops=[grants[nm] / workload.io_bytes
                                for nm in names],
            link_utilization=offered,
            prefetch_depth=prefetch_depth)
        for i in range(n_devices):
            per_device.append(_lane_to_result(
                spec, scheme, workload, lanes, i,
                device=f"{spec.name}#{i}"))
    else:
        for i, nm in enumerate(names):
            r = simulate(spec, scheme, workload, seed=workload.seed + i,
                         data_rate_cap_iops=grants[nm] / workload.io_bytes,
                         link_utilization=offered,
                         prefetch_depth=prefetch_depth, engine=engine)
            per_device.append(
                dataclasses.replace(r, device=f"{r.device}#{i}"))

    goodputs = [r.iops * workload.io_bytes for r in per_device]
    return SharedFabricResult(
        n_devices=n_devices,
        link_bandwidth_Bps=link_bandwidth_Bps,
        weights=list(weights),
        per_device=per_device,
        demand_Bps=demand_Bps,
        grants_Bps=[grants[nm] for nm in names],
        aggregate_goodput_Bps=float(sum(goodputs)),
        offered_utilization=offered,
        fairness_jain=jain_fairness(goodputs),
        mean_p99_us=float(np.mean([r.p99_lat_us for r in per_device])),
    )


# ---------------------------------------------------------------------------
# Multi-expander pool + hot-page migration (repro.qos.migration)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MultiExpanderResult:
    """Skewed placement on a pooled fabric, before/after migration."""

    n_devices: int
    n_expanders: int
    link_bandwidth_Bps: float
    #: one device's unconstrained link demand (B/s)
    demand_Bps: float
    placement_before: List[int]          # device index -> expander id
    placement_after: List[int]
    #: per-expander offered load (rho) in each phase
    utilization_before: List[float]
    utilization_after: List[float]
    per_device_before: List[SimResult]
    per_device_after: List[SimResult]
    #: one device alone on an idle link (the recovery target)
    baseline_p99_us: float
    #: mean p99 of the devices placed on the initially-hot expander
    hot_p99_before_us: float
    hot_p99_after_us: float
    migrated_devices: int
    #: LMB-resident bytes that crossed links to realize the new placement
    migrated_bytes: int
    #: serialized time the migration traffic occupies a link
    migration_wall_s: float

    @property
    def recovery_fraction(self) -> float:
        """1.0 = hot-expander p99 fully recovered to the uncontended
        baseline; 0.0 = migration didn't help."""
        return recovery_fraction(self.hot_p99_before_us,
                                 self.hot_p99_after_us,
                                 self.baseline_p99_us)

    def row(self) -> str:
        return (f"{self.n_devices},{self.n_expanders},"
                f"{self.hot_p99_before_us:.1f},{self.hot_p99_after_us:.1f},"
                f"{self.baseline_p99_us:.1f},{self.recovery_fraction:.2f},"
                f"{self.migrated_bytes/2**20:.0f}MiB")


def simulate_multi_expander(spec: SSDSpec, scheme: Scheme,
                            workload: Workload, n_devices: int,
                            n_expanders: int = 2,
                            link_bandwidth_Bps: float = 30e9,
                            placement: Optional[Sequence[int]] = None,
                            resident_bytes_per_device: int = 64 * 2**20,
                            saturation_threshold: float = 0.7,
                            engine: str = "vector",
                            ) -> MultiExpanderResult:
    """Pooled fabric: ``n_devices`` spread over ``n_expanders`` links.

    Default placement is the worst case the MigrationEngine exists for:
    every device homed on expander 0 (hot) while the siblings idle.  Phase
    one simulates that skew; :func:`repro.qos.migration.plan_rebalance`
    then migrates load (modeling the engine's hottest-pages-first policy at
    device granularity — a device's resident LMB bytes move with it) and
    phase two simulates the rebalanced pool.
    """
    if placement is None:
        placement = [0] * n_devices
    placement = list(placement)
    if len(placement) != n_devices:
        raise ValueError(f"{len(placement)} placements for {n_devices}")
    if any(not 0 <= p < n_expanders for p in placement):
        raise ValueError("placement references unknown expander")

    base = simulate(spec, scheme, workload, engine=engine)
    demand_Bps = base.iops * workload.io_bytes

    def phase(place: Sequence[int]) -> tuple:
        # per-expander arbitration first (pure bookkeeping), then ONE
        # vectorized run with per-lane caps/utilizations for the whole pool
        by_exp: Dict[int, List[int]] = {}
        for dev, eid in enumerate(place):
            by_exp.setdefault(eid, []).append(dev)
        rhos = [0.0] * n_expanders
        caps = np.empty(n_devices)
        utils = np.empty(n_devices)
        for eid in range(n_expanders):
            devs = by_exp.get(eid, [])
            if not devs:
                continue
            rho = min(len(devs) * demand_Bps / link_bandwidth_Bps, 1.0)
            rhos[eid] = rho
            grants = weighted_max_min(
                {f"dev{d}": demand_Bps for d in devs},
                {f"dev{d}": 1.0 for d in devs}, link_bandwidth_Bps)
            for d in devs:
                caps[d] = grants[f"dev{d}"] / workload.io_bytes
                utils[d] = rho
        results: List[Optional[SimResult]] = [None] * n_devices
        if engine == "vector":
            from repro.rack.des import simulate_lanes
            lanes = simulate_lanes(
                spec, scheme, workload,
                seeds=[workload.seed + d for d in range(n_devices)],
                data_rate_cap_iops=caps, link_utilization=utils)
            for d in range(n_devices):
                results[d] = _lane_to_result(
                    spec, scheme, workload, lanes, d,
                    device=f"{spec.name}#{d}@x{place[d]}")
        else:
            for d in range(n_devices):
                r = simulate(
                    spec, scheme, workload, seed=workload.seed + d,
                    data_rate_cap_iops=float(caps[d]),
                    link_utilization=float(utils[d]), engine=engine)
                results[d] = dataclasses.replace(
                    r, device=f"{r.device}#{d}@x{place[d]}")
        return results, rhos

    before, rhos_before = phase(placement)
    after_placement = plan_rebalance(
        [demand_Bps] * n_devices, placement, n_expanders,
        link_bandwidth_Bps, saturation_threshold)
    after, rhos_after = phase(after_placement)

    moved = [d for d in range(n_devices)
             if after_placement[d] != placement[d]]
    migrated_bytes = len(moved) * resident_bytes_per_device
    # the hot expander is wherever the initial load actually peaks (the
    # default all-on-0 placement makes that expander 0, but a caller
    # placement may skew any link)
    hot_eid = int(np.argmax(rhos_before))
    hot = [d for d in range(n_devices) if placement[d] == hot_eid]

    return MultiExpanderResult(
        n_devices=n_devices,
        n_expanders=n_expanders,
        link_bandwidth_Bps=link_bandwidth_Bps,
        demand_Bps=demand_Bps,
        placement_before=placement,
        placement_after=after_placement,
        utilization_before=rhos_before,
        utilization_after=rhos_after,
        per_device_before=before,
        per_device_after=after,
        baseline_p99_us=base.p99_lat_us,
        hot_p99_before_us=float(np.mean(
            [before[d].p99_lat_us for d in hot])) if hot else 0.0,
        hot_p99_after_us=float(np.mean(
            [after[d].p99_lat_us for d in hot])) if hot else 0.0,
        migrated_devices=len(moved),
        migrated_bytes=migrated_bytes,
        migration_wall_s=migrated_bytes / link_bandwidth_Bps,
    )
