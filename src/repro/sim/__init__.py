"""repro.sim — discrete-event reproduction of the paper's §4 evaluation."""

from repro.sim.engine import (MultiExpanderResult, SharedFabricResult,
                              SimResult, simulate, simulate_multi_expander,
                              simulate_shared_fabric)
from repro.sim.ssd import (GEN4_SSD, GEN5_SSD, Scheme, SSDSpec,
                           make_ssd_model)
from repro.sim.workload import Workload, arrival_times, make_workload

__all__ = ["MultiExpanderResult", "SharedFabricResult", "SimResult",
           "simulate", "simulate_multi_expander", "simulate_shared_fabric",
           "GEN4_SSD", "GEN5_SSD", "Scheme", "SSDSpec", "make_ssd_model",
           "Workload", "arrival_times", "make_workload"]
