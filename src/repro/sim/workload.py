"""fio-like workload generation (paper §4: libaio, QD=64, 4 KB IOs).

Generates deterministic, seeded IO streams over a device's LBA space.
Patterns: ``randread / randwrite / seqread / seqwrite`` (the paper's four),
plus ``zipfread`` for the §4.1.2 locality sweep (hot L2P entries hitting the
onboard cache).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Sequence

import numpy as np

IO_BYTES = 4096
QUEUE_DEPTH = 64


@dataclasses.dataclass(frozen=True)
class IO:
    op: str          # "read" | "write"
    lba: int         # in 4K pages
    nbytes: int = IO_BYTES


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    pattern: str     # "rand" | "seq" | "zipf"
    op: str          # "read" | "write"
    n_ios: int
    queue_depth: int = QUEUE_DEPTH
    io_bytes: int = IO_BYTES
    zipf_alpha: float = 1.2
    seed: int = 0

    def generate(self, lba_space: int) -> np.ndarray:
        """Return LBA array of length n_ios (deterministic)."""
        rng = np.random.default_rng(self.seed)
        if self.pattern == "seq":
            start = int(rng.integers(0, max(lba_space - self.n_ios, 1)))
            return (start + np.arange(self.n_ios)) % lba_space
        if self.pattern == "rand":
            return rng.integers(0, lba_space, self.n_ios)
        if self.pattern == "zipf":
            # bounded zipf over the LBA space
            ranks = rng.zipf(self.zipf_alpha, self.n_ios)
            return (ranks - 1) % lba_space
        raise ValueError(f"unknown pattern {self.pattern}")

    def ios(self, lba_space: int) -> Iterator[IO]:
        for lba in self.generate(lba_space):
            yield IO(self.op, int(lba), self.io_bytes)


def make_workload(name: str, n_ios: int = 200_000, seed: int = 0,
                  **kw) -> Workload:
    """The paper's four workloads by name (+ zipfread)."""
    table = {
        "seqwrite": ("seq", "write"),
        "randwrite": ("rand", "write"),
        "seqread": ("seq", "read"),
        "randread": ("rand", "read"),
        "zipfread": ("zipf", "read"),
    }
    pattern, op = table[name]
    return Workload(name=name, pattern=pattern, op=op, n_ios=n_ios,
                    seed=seed, **kw)


ALL_PAPER_WORKLOADS: List[str] = ["seqwrite", "randwrite", "seqread",
                                  "randread"]


# ---------------------------------------------------------------------------
# Locality (index hit/miss) streams
# ---------------------------------------------------------------------------

def locality_hits(n: int, hit_ratio: float, seed: int) -> np.ndarray:
    """The onboard-index hit stream the DES consumes: ``n`` seeded
    Bernoulli(``hit_ratio``) draws.  ``hit_ratio == 0`` returns the
    all-miss stream WITHOUT touching the RNG (the seed engine's exact
    behaviour, kept so seeded runs stay bit-identical).  Single source
    of truth for both the scalar per-IO engine and the vectorized
    batch path — determinism across the two is tested."""
    if hit_ratio > 0:
        return np.random.default_rng(seed).random(n) < hit_ratio
    return np.zeros(n, dtype=bool)


def batch_locality_hits(n: int, hit_ratio: float,
                        seeds: Sequence[int]) -> np.ndarray:
    """Vectorized batch generation: one ``(len(seeds), n)`` hit matrix,
    row ``i`` identical to ``locality_hits(n, hit_ratio, seeds[i])`` —
    each lane keeps its own seeded stream so a vectorized rack run
    reproduces the scalar per-device runs lane-for-lane."""
    if hit_ratio > 0:
        return np.stack([np.random.default_rng(s).random(n) < hit_ratio
                         for s in seeds])
    return np.zeros((len(seeds), n), dtype=bool)


# ---------------------------------------------------------------------------
# Arrival processes (serving load generation)
# ---------------------------------------------------------------------------
#: arrival processes ``arrival_times`` understands
ARRIVAL_PROCESSES = ("poisson", "bursty")


def arrival_times(n: int, rate_rps: float, *, process: str = "poisson",
                  burst_size: int = 8, burst_factor: float = 10.0,
                  seed: int = 0, t0: float = 0.0) -> np.ndarray:
    """``n`` seeded request arrival timestamps at mean rate ``rate_rps``.

    ``"poisson"`` draws i.i.d. exponential inter-arrival gaps (the
    open-loop serving default).  ``"bursty"`` is an on/off
    (Markov-modulated) process: geometric bursts of mean ``burst_size``
    arrivals whose within-burst rate is ``burst_factor`` times the mean
    rate, separated by compensating idle gaps so the LONG-RUN rate still
    averages ``rate_rps`` — the same offered load, concentrated into
    spikes that stress admission and link queues.  Deterministic for a
    given seed; timestamps are non-decreasing and start at or after
    ``t0``.
    """
    if n < 1:
        return np.empty(0, np.float64)
    if rate_rps <= 0:
        raise ValueError("rate_rps must be > 0")
    if process not in ARRIVAL_PROCESSES:
        raise ValueError(f"unknown arrival process {process!r} "
                         f"(choose from {ARRIVAL_PROCESSES})")
    rng = np.random.default_rng(seed)
    mean_gap = 1.0 / rate_rps
    if process == "poisson":
        gaps = rng.exponential(mean_gap, n)
        return t0 + np.cumsum(gaps)
    if burst_size < 1 or burst_factor <= 1.0:
        raise ValueError("bursty needs burst_size >= 1, burst_factor > 1")
    gaps = np.empty(n, np.float64)
    fast_gap = mean_gap / burst_factor
    done = 0
    while done < n:
        burst = min(int(rng.geometric(1.0 / burst_size)), n - done)
        gaps[done:done + burst] = rng.exponential(fast_gap, burst)
        done += burst
        if done < n:
            # idle long enough that the burst+idle cycle averages out to
            # the requested mean rate: burst arrivals "owe" the slow
            # process (mean_gap - fast_gap) each
            owed = burst * (mean_gap - fast_gap)
            gaps[done - 1] += rng.exponential(owed) if owed > 0 else 0.0
    return t0 + np.cumsum(gaps)


def batch_arrival_times(n: int, rate_rps: float, seeds: Sequence[int],
                        **kw) -> np.ndarray:
    """Batched arrival generation: ``(len(seeds), n)`` timestamp matrix,
    row ``i`` identical to ``arrival_times(n, rate_rps, seed=seeds[i],
    **kw)``.  Per-lane seeded streams, so the vectorized rack DES and
    any scalar replay of one lane see the same arrivals."""
    kw.pop("seed", None)
    return np.stack([arrival_times(n, rate_rps, seed=int(s), **kw)
                     for s in seeds])
