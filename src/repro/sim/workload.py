"""fio-like workload generation (paper §4: libaio, QD=64, 4 KB IOs).

Generates deterministic, seeded IO streams over a device's LBA space.
Patterns: ``randread / randwrite / seqread / seqwrite`` (the paper's four),
plus ``zipfread`` for the §4.1.2 locality sweep (hot L2P entries hitting the
onboard cache).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List

import numpy as np

IO_BYTES = 4096
QUEUE_DEPTH = 64


@dataclasses.dataclass(frozen=True)
class IO:
    op: str          # "read" | "write"
    lba: int         # in 4K pages
    nbytes: int = IO_BYTES


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    pattern: str     # "rand" | "seq" | "zipf"
    op: str          # "read" | "write"
    n_ios: int
    queue_depth: int = QUEUE_DEPTH
    io_bytes: int = IO_BYTES
    zipf_alpha: float = 1.2
    seed: int = 0

    def generate(self, lba_space: int) -> np.ndarray:
        """Return LBA array of length n_ios (deterministic)."""
        rng = np.random.default_rng(self.seed)
        if self.pattern == "seq":
            start = int(rng.integers(0, max(lba_space - self.n_ios, 1)))
            return (start + np.arange(self.n_ios)) % lba_space
        if self.pattern == "rand":
            return rng.integers(0, lba_space, self.n_ios)
        if self.pattern == "zipf":
            # bounded zipf over the LBA space
            ranks = rng.zipf(self.zipf_alpha, self.n_ios)
            return (ranks - 1) % lba_space
        raise ValueError(f"unknown pattern {self.pattern}")

    def ios(self, lba_space: int) -> Iterator[IO]:
        for lba in self.generate(lba_space):
            yield IO(self.op, int(lba), self.io_bytes)


def make_workload(name: str, n_ios: int = 200_000, seed: int = 0,
                  **kw) -> Workload:
    """The paper's four workloads by name (+ zipfread)."""
    table = {
        "seqwrite": ("seq", "write"),
        "randwrite": ("rand", "write"),
        "seqread": ("seq", "read"),
        "randread": ("rand", "read"),
        "zipfread": ("zipf", "read"),
    }
    pattern, op = table[name]
    return Workload(name=name, pattern=pattern, op=op, n_ios=n_ios,
                    seed=seed, **kw)


ALL_PAPER_WORKLOADS: List[str] = ["seqwrite", "randwrite", "seqread",
                                  "randread"]
