"""SSD device models (paper Table 3) and L2P-index placement schemes.

Pipeline model
--------------
Each IO passes two stages:

  1. **L2P lookup** — resolving LBA→PPA through the mapping table.  Where the
     table lives is the *scheme*:
       Ideal     — all of it in onboard DRAM: lookup rides the device's
                   hardware-assisted path and is already part of the baseline
                   numbers (no extra cost).
       LMB-CXL   — table in the CXL expander, device reaches it P2P
                   (+190 ns per access, paper §4).
       LMB-PCIe  — table in the expander, host-forwarded (+880 ns Gen4,
                   +1190 ns Gen5).
       DFTL      — table in flash; a miss costs a flash read (+25 µs).
     External lookups flow through the device's **index engine**, a
     firmware-managed unit with limited memory-level parallelism: effective
     concurrency ``K`` over a per-lookup busy time ``t_proc + t_tier``.
  2. **media/data stage** — rate-limited by the device's baseline throughput
     (Table 3), with per-IO base latency for the closed-loop QD behaviour.

Writes post their index *updates* asynchronously (write-back mapping cache),
so memory-tier schemes show no write degradation — matching Fig 6.  DFTL
writes must read-modify-write flash-resident index pages on the critical
path.

Calibration
-----------
``K`` and ``t_proc`` are per-device and per-pattern, fitted analytically to
Fig 6's reported deltas (the paper: "Baseline performance variations between
the two SSDs result in different simulation outputs under a same condition"):

  Gen4: K≈7.9, t_proc≈4.3 µs (slow but deeply pipelined firmware lookup)
  Gen5: rand K≈2.6, t_proc≈2.0 µs; seq K≈2.3, t_proc≈0.51 µs
        (fast, shallow lookup engine → more sensitive to added latency,
        exactly the §4.1.2 observation)

These reproduce: Gen4 reads — LMB-CXL ≈ Ideal, LMB-PCIe −13…−17 %;
Gen5 reads — LMB-CXL −8 % seq / −56 % rand, LMB-PCIe −62 % / −70 %;
writes — LMB ≈ Ideal, DFTL 7–20× worse.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.tiers import (DFTL_FLASH_READ_S, LMB_CXL_ADDED_S,
                              LMB_PCIE_GEN4_ADDED_S, LMB_PCIE_GEN5_ADDED_S)
from repro.sim.workload import IO_BYTES


@dataclasses.dataclass(frozen=True)
class IndexEngine:
    """Firmware lookup unit for EXTERNAL (non-onboard) index accesses."""

    concurrency: float        # effective memory-level parallelism
    t_proc_s: float           # firmware processing per lookup

    def rate(self, t_tier_s: float) -> float:
        """Sustained lookups/s when each access costs t_tier extra."""
        return self.concurrency / (self.t_proc_s + t_tier_s)


@dataclasses.dataclass(frozen=True)
class SSDSpec:
    """Table 3 device description."""

    name: str
    pcie_gen: int
    capacity_bytes: int
    rand_read_iops: float
    rand_write_iops: float
    seq_read_Bps: float
    seq_write_Bps: float
    rand_read_lat_s: float
    rand_write_lat_s: float
    index_rand: IndexEngine
    index_seq: IndexEngine
    #: DFTL flash-index path: effectively one outstanding flash index op
    dftl_concurrency: float = 1.0

    @property
    def lba_space(self) -> int:
        return self.capacity_bytes // IO_BYTES

    @property
    def l2p_bytes(self) -> int:
        # 4 B PPA per 4 KB page — the paper's 0.1 % rule
        return self.lba_space * 4

    def base_iops(self, pattern: str, op: str) -> float:
        if pattern in ("rand", "zipf"):
            return self.rand_read_iops if op == "read" else self.rand_write_iops
        bw = self.seq_read_Bps if op == "read" else self.seq_write_Bps
        return bw / IO_BYTES

    def base_latency_s(self, op: str) -> float:
        return self.rand_read_lat_s if op == "read" else self.rand_write_lat_s


GEN4_SSD = SSDSpec(
    name="pcie_gen4", pcie_gen=4, capacity_bytes=7_680_000_000_000,
    rand_read_iops=1_750_000.0, rand_write_iops=340_000.0,
    seq_read_Bps=7.2e9, seq_write_Bps=6.8e9,
    rand_read_lat_s=67e-6, rand_write_lat_s=9e-6,
    index_rand=IndexEngine(concurrency=7.86, t_proc_s=4.302e-6),
    index_seq=IndexEngine(concurrency=7.86, t_proc_s=4.360e-6),
)

GEN5_SSD = SSDSpec(
    name="pcie_gen5", pcie_gen=5, capacity_bytes=7_680_000_000_000,
    rand_read_iops=2_800_000.0, rand_write_iops=700_000.0,
    seq_read_Bps=14e9, seq_write_Bps=10e9,
    rand_read_lat_s=56e-6, rand_write_lat_s=8e-6,
    index_rand=IndexEngine(concurrency=2.64, t_proc_s=1.953e-6),
    index_seq=IndexEngine(concurrency=2.27, t_proc_s=0.514e-6),
)


@dataclasses.dataclass(frozen=True)
class Scheme:
    """An L2P-index placement scheme."""

    name: str
    #: added latency per external index access; None = onboard (no external)
    t_tier_s: Optional[float]
    #: whether index updates on writes hit the critical path
    write_through_index: bool = False
    #: fraction of lookups that hit the onboard mapping cache (§4.1.2);
    #: Fig 6 assumes 0.0 ("all indexing supported by CXL extended memory")
    onboard_hit_ratio: float = 0.0


def make_schemes(spec: SSDSpec) -> Dict[str, Scheme]:
    lmb_pcie_lat = (LMB_PCIE_GEN4_ADDED_S if spec.pcie_gen == 4
                    else LMB_PCIE_GEN5_ADDED_S)
    return {
        "ideal": Scheme("ideal", None),
        "lmb-cxl": Scheme("lmb-cxl", LMB_CXL_ADDED_S),
        "lmb-pcie": Scheme("lmb-pcie", lmb_pcie_lat),
        "dftl": Scheme("dftl", DFTL_FLASH_READ_S, write_through_index=True),
    }


def make_ssd_model(gen: int) -> SSDSpec:
    if gen == 4:
        return GEN4_SSD
    if gen == 5:
        return GEN5_SSD
    raise ValueError(f"no model for PCIe Gen{gen}")
