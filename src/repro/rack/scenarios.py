"""Rack-level experiments on the switched-topology + vectorized-DES stack.

Four scenario families, published through ``benchmarks/run.py --only
rack_sweep`` (each row carries declarative :class:`benchmarks.run.Gate`
bounds enforced by ``tools/check_bench_regression.py``):

  * :func:`hop_cost_sweep` — the same device behind deeper and deeper
    fabric paths (direct -> same-leaf -> cross-leaf -> cross-pod); p99
    index latency must grow monotonically with hop cost.
  * :func:`placement_face_off` — skewed placement (every device piled on
    one cross-leaf expander) vs the topology-aware ``pool-aware`` policy
    (near-first, capacity-balanced) and the topology-blind spread, all
    simulated from placements the REAL FabricManager produced.
  * :func:`failover_recovery` — correlated failure: a whole leaf's power
    domain dies, its devices pile onto one survivor, and
    :func:`repro.qos.migration.plan_rebalance` (``alive=`` survivors)
    replays the PR-2 migration planner as domain-wide failover; the hot
    survivor's p99 must recover >= 90% of the way to the balanced-
    survivor baseline.  Also exercises the FM's
    ``inject_domain_failure`` re-grant path end to end.
  * :func:`scale_sweep` — pool-utilization / scale: 256 devices x 1M+
    simulated IOs across a 16-expander rack in one vectorized call,
    plus the measured wall-clock speedup of the vectorized core over
    the scalar reference engine on the same scenario.

Everything here consumes public seams: :class:`RackTopology` path
costs feed ``simulate_lanes(extra_index_latency_s=...)`` (the
``repro.core.tiers.tier_over_path`` fold), per-expander offered load
feeds ``link_utilization``, and arbiter grants feed
``data_rate_cap_iops`` — the same wiring ``simulate_shared_fabric``
uses, at rack scale.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.trace import GLOBAL_TRACER
from repro.qos.arbiter import weighted_max_min
from repro.qos.migration import plan_rebalance
from repro.rack.des import LaneResult, simulate_lanes
from repro.rack.topology import RackTopology
from repro.sim.engine import recovery_fraction, simulate
from repro.sim.ssd import Scheme, SSDSpec, make_schemes
from repro.sim.workload import Workload, make_workload

#: per-port link bandwidth used by every scenario (the LMB_CXL default)
LINK_BW_Bps = 30e9


def _default_model() -> Tuple[SSDSpec, Scheme]:
    from repro.sim import make_ssd_model
    spec = make_ssd_model(5)
    return spec, make_schemes(spec)["lmb-cxl"]


def _pool_lanes(spec: SSDSpec, scheme: Scheme, wl: Workload,
                placement: Sequence[int], topo: RackTopology,
                host_id: str, demand_Bps: float,
                link_bandwidth_Bps: float = LINK_BW_Bps,
                ) -> Tuple[LaneResult, List[float]]:
    """One vectorized run of a whole placed pool: per-expander max-min
    grants cap each lane's data stage, per-expander offered load sets
    its congestion, and the host->expander path latency rides on every
    external index access.  Exactly ``simulate_shared_fabric``'s wiring,
    with the rack topology supplying the per-lane path costs."""
    n_dev = len(placement)
    by_exp: Dict[int, List[int]] = {}
    for dev, eid in enumerate(placement):
        by_exp.setdefault(int(eid), []).append(dev)
    caps = np.empty(n_dev)
    utils = np.empty(n_dev)
    extra = np.empty(n_dev)
    rhos = {eid: 0.0 for eid in by_exp}
    for eid, devs in by_exp.items():
        rho = min(len(devs) * demand_Bps / link_bandwidth_Bps, 1.0)
        rhos[eid] = rho
        grants = weighted_max_min(
            {f"d{d}": demand_Bps for d in devs},
            {f"d{d}": 1.0 for d in devs}, link_bandwidth_Bps)
        lat = topo.path(host_id, eid).latency_s
        for d in devs:
            caps[d] = grants[f"d{d}"] / wl.io_bytes
            utils[d] = rho
            extra[d] = lat
    lanes = simulate_lanes(
        spec, scheme, wl, seeds=[wl.seed + d for d in range(n_dev)],
        data_rate_cap_iops=caps, link_utilization=utils,
        extra_index_latency_s=extra)
    return lanes, [rhos[e] for e in sorted(rhos)]


# ---------------------------------------------------------------------------
# 1. hop-cost sweep
# ---------------------------------------------------------------------------

def _three_tier() -> RackTopology:
    """Two pods of two leaves under one spine — the cross-pod (5-hop)
    case the two-tier canned shape cannot express."""
    topo = RackTopology()
    topo.add_switch("spine")
    for pod in range(2):
        topo.add_switch(f"pod{pod}", uplink="spine")
        for leaf in range(2):
            name = f"leaf{pod}{leaf}"
            topo.add_switch(name, uplink=f"pod{pod}",
                            power_domain=f"pd{pod}{leaf}")
            topo.attach_expander(pod * 2 + leaf, name)
    topo.attach_host("h0", "leaf00")
    return topo


def hop_cost_sweep(spec: Optional[SSDSpec] = None,
                   scheme: Optional[Scheme] = None,
                   n_ios: int = 20_000) -> List[dict]:
    """One uncontended device behind ever-deeper fabric paths.  p99 and
    mean index latency must grow monotonically with path latency; the
    direct (1-hop, 0 ns) case must match the topology-free simulator."""
    if spec is None or scheme is None:
        spec, scheme = _default_model()
    wl = make_workload("randread", n_ios=n_ios)
    two = RackTopology.two_tier(2, 2, hosts_per_leaf=1)
    cases = [
        ("direct", RackTopology.direct((0,), ("h0",)).path("h0", 0)),
        ("same_leaf", two.path("h0", 0)),
        ("cross_leaf", two.path("h0", 2)),
        ("cross_pod", _three_tier().path("h0", 3)),
    ]
    rows = []
    for name, path in cases:
        lanes = simulate_lanes(spec, scheme, wl, seeds=[wl.seed],
                               extra_index_latency_s=path.latency_s)
        rows.append({
            "case": name, "hops": path.hops,
            "path_ns": path.latency_s * 1e9,
            "kiops": float(lanes.iops[0]) / 1e3,
            "p99_us": float(lanes.p99_lat_s[0]) * 1e6,
            "mean_us": float(lanes.mean_lat_s[0]) * 1e6,
        })
    return rows


# ---------------------------------------------------------------------------
# 2. skewed vs pool-aware placement
# ---------------------------------------------------------------------------

def placement_face_off(n_devices: int = 8, n_ios: int = 8192) -> dict:
    """Three placements of ``n_devices`` (all hosted on h0) over a
    2-leaf x 2-expander rack, each SIMULATED from a placement the real
    FabricManager produced or a declared worst case:

      * ``skewed``     — every device piled on one cross-leaf expander:
        one saturated far link (the rack-scale analogue of the
        migration_sweep hot/cold worst case),
      * ``spread``     — topology-blind balance over all four links
        (what least-loaded placement does without a topology): even
        load, but half the devices pay the cross-leaf hop cost,
      * ``pool-aware`` — the PoolAwarePolicy choosing through a real
        topology-wired FM: near-first, capacity-balanced over the two
        same-leaf expanders.
    """
    from repro.core.fabric import make_multi_fabric
    spec, scheme = _default_model()
    wl = make_workload("randread", n_ios=n_ios)
    topo = RackTopology.two_tier(2, 2, hosts_per_leaf=1)
    demand = simulate(spec, scheme, wl).iops * wl.io_bytes

    # the pool-aware placement comes from the REAL FM machinery
    fm, _ = make_multi_fabric(4, pool_gib=4, topology=topo,
                              placement="pool-aware")
    fm.bind_host("h0")
    pool_place = []
    for d in range(n_devices):
        g = fm.request_block("h0")
        pool_place.append(fm.expander_of(g.block_id))

    placements = {
        "skewed": [2] * n_devices,
        "spread": [d % 4 for d in range(n_devices)],
        "pool_aware": pool_place,
    }
    out: Dict[str, dict] = {}
    for name, place in placements.items():
        lanes, rhos = _pool_lanes(spec, scheme, wl, place, topo, "h0",
                                  demand)
        out[name] = {
            "placement": list(place),
            "p99_us": float(lanes.p99_lat_s.mean()) * 1e6,
            "kiops_total": float(lanes.iops.sum()) / 1e3,
            "rho_max": max(rhos),
        }
    out["p99_ratio_skew_over_pool"] = (
        out["skewed"]["p99_us"] / out["pool_aware"]["p99_us"])
    out["near_fraction_pool_aware"] = (
        sum(1 for e in pool_place if e in (0, 1)) / n_devices)
    return out


# ---------------------------------------------------------------------------
# 3. correlated-failure recovery
# ---------------------------------------------------------------------------

def failover_recovery(n_devices: int = 16, n_ios: int = 8192) -> dict:
    """A whole leaf's power domain dies; the migration planner recovers.

    Phase 1 (balanced): ``n_devices`` spread 4-per-expander over a
    2-leaf rack.  Phase 2 (pile-up): domain ``pd0`` (expanders 0+1)
    fails and the naive failover lands EVERY evacuated device on the
    first survivor — one link now carries 3/4 of the rack.  Phase 3
    (recovery): :func:`plan_rebalance` with ``alive=`` survivors forces
    the evacuees off the dead domain and balances the survivors; the
    hot survivor's p99 must recover >= 90% of the way from the pile-up
    to the balanced-survivor baseline.

    Also drives the CONTROL plane end to end: a topology-wired FM with
    granted blocks takes :meth:`inject_domain_failure`, and the
    re-granted blocks must all land outside the dead domain (the
    single-pass ``_fail_locked`` property), with per-domain ``link.xfer``
    spans emitted for the trace artifact when tracing is enabled.
    """
    from repro.core.fabric import DeviceClass, DeviceInfo, make_multi_fabric
    spec, scheme = _default_model()
    wl = make_workload("randread", n_ios=n_ios)
    topo = RackTopology.two_tier(2, 2, hosts_per_leaf=1)
    demand = simulate(spec, scheme, wl).iops * wl.io_bytes
    balanced = [d % 4 for d in range(n_devices)]
    survivors = [2, 3]

    # -- data plane: balanced -> pile-up -> rebalanced ----------------------
    lanes_bal, _ = _pool_lanes(spec, scheme, wl, balanced, topo, "h0",
                               demand)
    pileup = [2 if e in (0, 1) else e for e in balanced]
    lanes_pile, _ = _pool_lanes(spec, scheme, wl, pileup, topo, "h0",
                                demand)
    rebalanced = plan_rebalance([demand] * n_devices, balanced, 4,
                                LINK_BW_Bps, alive=survivors)
    lanes_reb, _ = _pool_lanes(spec, scheme, wl, rebalanced, topo, "h0",
                               demand)
    assert all(e in survivors for e in rebalanced)

    hot = [d for d in range(n_devices) if pileup[d] == 2]
    hot_pile_us = float(np.mean(
        [lanes_pile.p99_lat_s[d] for d in hot])) * 1e6
    hot_reb_us = float(np.mean(
        [lanes_reb.p99_lat_s[d] for d in hot])) * 1e6
    # the recovery target: what balanced survivors can do at all — the
    # same load the rebalanced phase carries, ideally spread
    even = [survivors[d % 2] for d in range(n_devices)]
    lanes_even, _ = _pool_lanes(spec, scheme, wl, even, topo, "h0", demand)
    target_us = float(np.mean(
        [lanes_even.p99_lat_s[d] for d in hot])) * 1e6
    recovery = recovery_fraction(hot_pile_us, hot_reb_us, target_us)

    # -- control plane: FM domain failure re-grants past the dead leaf ------
    fm, _ = make_multi_fabric(4, pool_gib=4, topology=topo)
    fm.bind_host("h0")
    for d in range(n_devices):
        fm.register_device(DeviceInfo(f"dev{d}", DeviceClass.CXL, spid=d))
    grants = [fm.request_block("h0", expander_id=balanced[d])
              for d in range(n_devices)]
    for d, g in enumerate(grants):       # per-domain link.xfer spans
        fm.meter_transfer(f"dev{d}", wl.io_bytes * 64, block_id=g.block_id)
    failed = fm.inject_domain_failure("pd0")
    stats = fm.journal_stats()["by_op"]
    homes = {fm.expander_of(g.block_id)
             for g in fm.held_grants("h0")}
    assert homes.isdisjoint(failed)
    tr = GLOBAL_TRACER
    if tr.enabled:
        for eid in sorted({*balanced}):
            tr.add("rack.recovery", tr.now(), 0.0, op="rack",
                   expander=eid, domain=topo.domain_of(eid),
                   nbytes=0, phase="failover")
    return {
        "baseline_p99_us": float(np.mean(
            [lanes_bal.p99_lat_s[d] for d in hot])) * 1e6,
        "pileup_p99_us": hot_pile_us,
        "rebalanced_p99_us": hot_reb_us,
        "target_p99_us": target_us,
        "recovery": recovery,
        "failed_expanders": list(failed),
        "regranted": stats.get("regrant", 0),
        "lost": stats.get("lost", 0),
        "moved_devices": sum(1 for a, b in zip(balanced, rebalanced)
                             if a != b),
    }


# ---------------------------------------------------------------------------
# 4. pool-utilization / scale sweep + vectorized-core speedup
# ---------------------------------------------------------------------------

def scale_sweep(n_expanders: int = 16, devices_per_expander: int = 16,
                n_ios: int = 4096) -> dict:
    """The rack-scale headline: ``n_expanders * devices_per_expander``
    devices x ``n_ios`` IOs each — 256 x 4096 = 1,048,576 simulated
    requests by default — in ONE vectorized call, with a utilization
    density sweep (4/8/16 devices per link) showing p99 climbing with
    offered load.  ``wall_s`` is measured host wall-clock; the CI gate
    bounds it (and the request count) so the vectorized core's
    rack-scale reach is a regression-checked property."""
    spec, scheme = _default_model()
    wl = make_workload("randread", n_ios=n_ios)
    leaves = max(n_expanders // 4, 1)
    topo = RackTopology.two_tier(leaves, n_expanders // leaves,
                                 hosts_per_leaf=1)
    demand = simulate(spec, scheme, wl).iops * wl.io_bytes
    density = {}
    for per in (4, 8, 16):
        if per > devices_per_expander:
            continue
        n_dev = n_expanders * per
        place = [d % n_expanders for d in range(n_dev)]
        t0 = time.perf_counter()
        lanes, rhos = _pool_lanes(spec, scheme, wl, place, topo, "h0",
                                  demand)
        wall = time.perf_counter() - t0
        density[per] = {
            "devices": n_dev,
            "requests": lanes.total_ios,
            "wall_s": wall,
            "rho_max": max(rhos),
            "p99_us": float(lanes.p99_lat_s.mean()) * 1e6,
            "agg_GBps": float(lanes.iops.sum()) * wl.io_bytes / 1e9,
        }
        tr = GLOBAL_TRACER
        if tr.enabled and per == devices_per_expander:
            for eid in range(n_expanders):
                n_on = sum(1 for e in place if e == eid)
                tr.add("rack.pool", tr.now(),
                       float(lanes.wall_s[place.index(eid)]),
                       op="rack", expander=eid,
                       domain=topo.domain_of(eid),
                       nbytes=n_on * n_ios * wl.io_bytes, devices=n_on)
    full = density[devices_per_expander]
    return {"density": density, **full}


def vector_speedup(n_lanes: int = 256, n_ios: int = 8192) -> dict:
    """Measured wall-clock of the scalar reference engine vs the
    vectorized core on the SAME scenario (``n_lanes`` independent
    seeded devices, identical results asserted) — the >= 20x speedup
    acceptance gate."""
    spec, scheme = _default_model()
    wl = make_workload("randread", n_ios=n_ios)
    seeds = [wl.seed + i for i in range(n_lanes)]
    t0 = time.perf_counter()
    scalar = [simulate(spec, scheme, wl, seed=s, engine="scalar")
              for s in seeds]
    t_scalar = time.perf_counter() - t0
    t_vector = float("inf")
    for _ in range(3):  # best-of-3: first call pays numpy buffer warmup
        t0 = time.perf_counter()
        lanes = simulate_lanes(spec, scheme, wl, seeds=seeds)
        t_vector = min(t_vector, time.perf_counter() - t0)
    agree = bool(np.allclose([r.p99_lat_us for r in scalar],
                             lanes.p99_lat_s * 1e6, rtol=1e-6))
    return {
        "lanes": n_lanes, "requests": lanes.total_ios,
        "scalar_s": t_scalar, "vector_s": t_vector,
        "speedup": t_scalar / max(t_vector, 1e-9),
        "results_agree": agree,
    }
