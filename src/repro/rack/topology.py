"""Switched rack topology: hosts x expanders x switch tiers.

"My CXL Pool Obviates Your PCIe Switch" (Zhong et al., arXiv 2503.23611)
argues that pool-level topology — many hosts and many expanders behind a
shared switched fabric — changes the placement, failover, and bandwidth
calculus entirely; the CXL interconnect introduction (Das Sharma et al.)
supplies the structure we model: ports with fixed crossing latency,
switches with per-tier hop latency, and links with per-port bandwidth.

The model is a forest of switches.  Hosts and expanders attach to
switches by edges; switches attach to parent switches (uplinks) by
edges.  Every edge carries a hop latency and a port bandwidth.  A
:meth:`RackTopology.path` walks host -> ... -> common ancestor ->
... -> expander and returns a :class:`PathCost`:

  * ``hops``          — number of switches traversed (1 = same leaf =
                        the direct-attach degenerate case),
  * ``latency_s``     — sum of per-edge hop latencies (what
                        :func:`repro.core.tiers.tier_over_path` folds
                        into a TierSpec's added latency),
  * ``bandwidth_Bps`` — bottleneck (min) edge bandwidth (what the
                        per-link arbiters consume).

Correlated failure domains: every expander belongs to a failure domain
(explicit, or inherited from its switch's power domain, or defaulting
to ``switch:<name>``) — a switch or power domain failing takes out
every expander behind it.  :meth:`expanders_in_domain` is what
``FabricManager.inject_domain_failure`` uses to fail them together.

Direct attach (today's single-expander model) falls out as the 1-switch
degenerate case built by :meth:`RackTopology.direct`: zero-latency
attach edges through one virtual switch, so a FabricManager given that
topology behaves exactly like one without a topology.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.tiers import CXL_PORT_LATENCY_S, CXL_SWITCH_HDM_LATENCY_S

#: default per-port bandwidth (matches the LMB_CXL tier / fabric default)
DEFAULT_PORT_BW_Bps = 30e9
#: default host/expander attach-edge latency (one CXL port crossing)
ATTACH_LATENCY_S = CXL_PORT_LATENCY_S
#: default switch-to-switch uplink latency (switch + HDM decode hop)
UPLINK_LATENCY_S = CXL_SWITCH_HDM_LATENCY_S


@dataclasses.dataclass(frozen=True)
class PathCost:
    """Cost of one host->expander path through the fabric."""

    #: switches traversed; 1 = same leaf (direct-attach degenerate case)
    hops: int
    #: sum of per-edge hop latencies along the path
    latency_s: float
    #: bottleneck (min) per-port bandwidth along the path
    bandwidth_Bps: float


@dataclasses.dataclass(frozen=True)
class _Edge:
    """One attachment (node -> switch) or uplink (switch -> switch)."""

    to_switch: str
    latency_s: float
    bandwidth_Bps: float


class TopologyError(ValueError):
    pass


class RackTopology:
    """A rack of hosts and expanders behind a switched CXL fabric."""

    def __init__(self) -> None:
        # switch name -> uplink edge (None = root of its tree)
        self._switches: Dict[str, Optional[_Edge]] = {}
        self._switch_power: Dict[str, Optional[str]] = {}
        self._hosts: Dict[str, _Edge] = {}
        self._expanders: Dict[int, _Edge] = {}
        self._expander_domain: Dict[int, str] = {}
        self._expander_capacity: Dict[int, Optional[int]] = {}
        self._path_cache: Dict[Tuple[str, int], PathCost] = {}

    # -- construction --------------------------------------------------------
    def add_switch(self, name: str, *, uplink: Optional[str] = None,
                   latency_s: float = UPLINK_LATENCY_S,
                   bandwidth_Bps: float = DEFAULT_PORT_BW_Bps,
                   power_domain: Optional[str] = None) -> "RackTopology":
        """Add a switch tier node; ``uplink`` chains it under a parent
        (leaf -> spine).  ``power_domain`` is the correlated-failure
        domain every expander behind this switch inherits by default."""
        if name in self._switches:
            raise TopologyError(f"duplicate switch {name!r}")
        if uplink is not None and uplink not in self._switches:
            raise TopologyError(f"uplink switch {uplink!r} unknown")
        self._switches[name] = (
            _Edge(uplink, latency_s, bandwidth_Bps)
            if uplink is not None else None)
        self._switch_power[name] = power_domain
        return self

    def attach_host(self, host_id: str, switch: str, *,
                    latency_s: float = ATTACH_LATENCY_S,
                    bandwidth_Bps: float = DEFAULT_PORT_BW_Bps,
                    ) -> "RackTopology":
        if switch not in self._switches:
            raise TopologyError(f"switch {switch!r} unknown")
        self._hosts[host_id] = _Edge(switch, latency_s, bandwidth_Bps)
        self._path_cache.clear()
        return self

    def attach_expander(self, expander_id: int, switch: str, *,
                        latency_s: float = ATTACH_LATENCY_S,
                        bandwidth_Bps: float = DEFAULT_PORT_BW_Bps,
                        domain: Optional[str] = None,
                        capacity_bytes: Optional[int] = None,
                        ) -> "RackTopology":
        """Attach an expander.  Failure domain precedence: explicit
        ``domain`` > the switch's ``power_domain`` > ``switch:<name>``
        (a switch failing takes out everything behind it either way)."""
        if switch not in self._switches:
            raise TopologyError(f"switch {switch!r} unknown")
        eid = int(expander_id)
        self._expanders[eid] = _Edge(switch, latency_s, bandwidth_Bps)
        self._expander_domain[eid] = (
            domain or self._switch_power[switch] or f"switch:{switch}")
        self._expander_capacity[eid] = capacity_bytes
        self._path_cache.clear()
        return self

    # -- introspection -------------------------------------------------------
    @property
    def host_ids(self) -> List[str]:
        return list(self._hosts)

    @property
    def expander_ids(self) -> List[int]:
        return list(self._expanders)

    @property
    def switch_names(self) -> List[str]:
        return list(self._switches)

    def domain_of(self, expander_id: int) -> str:
        dom = self._expander_domain.get(int(expander_id))
        if dom is None:
            raise TopologyError(f"expander {expander_id} not in topology")
        return dom

    def domains(self) -> Dict[str, List[int]]:
        """failure domain -> expander ids (sorted), covering the rack."""
        out: Dict[str, List[int]] = {}
        for eid, dom in self._expander_domain.items():
            out.setdefault(dom, []).append(eid)
        return {dom: sorted(eids) for dom, eids in sorted(out.items())}

    def expanders_in_domain(self, domain: str) -> List[int]:
        """Correlated failure set: every expander the domain takes out."""
        eids = self.domains().get(domain)
        if eids is None:
            raise TopologyError(f"unknown failure domain {domain!r}")
        return eids

    def port_bandwidth_Bps(self, expander_id: int) -> float:
        edge = self._expanders.get(int(expander_id))
        if edge is None:
            raise TopologyError(f"expander {expander_id} not in topology")
        return edge.bandwidth_Bps

    def pool_capacity_bytes(self, domain: Optional[str] = None) -> int:
        """Declared capacity of the pool (or one failure domain's slice);
        expanders attached without a capacity count as zero."""
        eids = (self.expanders_in_domain(domain) if domain is not None
                else self.expander_ids)
        return sum(self._expander_capacity.get(e) or 0 for e in eids)

    # -- path cost -----------------------------------------------------------
    def _ancestry(self, switch: str) -> List[Tuple[str, Optional[_Edge]]]:
        """(switch, uplink-edge) chain from ``switch`` to its root."""
        chain = []
        cur: Optional[str] = switch
        seen = set()
        while cur is not None:
            if cur in seen:
                raise TopologyError(f"uplink cycle through {cur!r}")
            seen.add(cur)
            edge = self._switches[cur]
            chain.append((cur, edge))
            cur = edge.to_switch if edge is not None else None
        return chain

    def path(self, host_id: str, expander_id: int) -> PathCost:
        """Cost of the host->expander path (cached).

        Walks host attach edge, uplinks to the lowest common ancestor
        switch, then down to the expander's attach edge.  Raises
        :class:`TopologyError` when the two sit in disjoint trees."""
        eid = int(expander_id)
        key = (host_id, eid)
        hit = self._path_cache.get(key)
        if hit is not None:
            return hit
        h_edge = self._hosts.get(host_id)
        if h_edge is None:
            raise TopologyError(f"host {host_id!r} not in topology")
        x_edge = self._expanders.get(eid)
        if x_edge is None:
            raise TopologyError(f"expander {eid} not in topology")
        up = self._ancestry(h_edge.to_switch)
        down = self._ancestry(x_edge.to_switch)
        down_names = {name: i for i, (name, _) in enumerate(down)}
        lca_i = next((i for i, (name, _) in enumerate(up)
                      if name in down_names), None)
        if lca_i is None:
            raise TopologyError(
                f"no fabric path {host_id!r} -> expander {eid}")
        lat = h_edge.latency_s + x_edge.latency_s
        bw = min(h_edge.bandwidth_Bps, x_edge.bandwidth_Bps)
        # uplink edges host-side below the LCA, then expander-side below
        hops = 1                                  # the LCA switch itself
        for _, edge in up[:lca_i]:
            lat += edge.latency_s
            bw = min(bw, edge.bandwidth_Bps)
            hops += 1
        for _, edge in down[:down_names[up[lca_i][0]]]:
            lat += edge.latency_s
            bw = min(bw, edge.bandwidth_Bps)
            hops += 1
        cost = PathCost(hops=hops, latency_s=lat, bandwidth_Bps=bw)
        self._path_cache[key] = cost
        return cost

    # -- canned shapes -------------------------------------------------------
    @classmethod
    def direct(cls, expander_ids: Sequence[int] = (0,),
               hosts: Sequence[str] = ("h0",),
               bandwidth_Bps: float = DEFAULT_PORT_BW_Bps,
               ) -> "RackTopology":
        """Degenerate 1-switch rack: every host and expander on one
        zero-latency virtual switch — path cost (hops=1, 0 s, link bw),
        i.e. exactly today's direct-attach model."""
        topo = cls()
        topo.add_switch("root", bandwidth_Bps=bandwidth_Bps)
        for h in hosts:
            topo.attach_host(h, "root", latency_s=0.0,
                             bandwidth_Bps=bandwidth_Bps)
        for eid in expander_ids:
            topo.attach_expander(int(eid), "root", latency_s=0.0,
                                 bandwidth_Bps=bandwidth_Bps)
        return topo

    @classmethod
    def two_tier(cls, n_leaves: int, expanders_per_leaf: int,
                 hosts_per_leaf: int = 1, *,
                 port_bandwidth_Bps: float = DEFAULT_PORT_BW_Bps,
                 spine_bandwidth_Bps: Optional[float] = None,
                 attach_latency_s: float = ATTACH_LATENCY_S,
                 uplink_latency_s: float = UPLINK_LATENCY_S,
                 capacity_bytes: Optional[int] = None,
                 ) -> "RackTopology":
        """Spine/leaf rack: one spine switch, ``n_leaves`` leaf switches,
        ``expanders_per_leaf`` expanders and ``hosts_per_leaf`` hosts per
        leaf.  Expander ids are dense (leaf-major); hosts are named
        ``h<k>`` leaf-major.  Each leaf is its own power/failure domain
        (``pd<leaf>``): a leaf switch dying takes out every expander
        behind it.  Same-leaf paths cost 1 hop; cross-leaf paths cost 3
        (leaf -> spine -> leaf)."""
        if n_leaves < 1 or expanders_per_leaf < 1 or hosts_per_leaf < 0:
            raise TopologyError("two_tier needs >=1 leaf and expander")
        topo = cls()
        spine_bw = (spine_bandwidth_Bps if spine_bandwidth_Bps is not None
                    else port_bandwidth_Bps)
        topo.add_switch("spine", bandwidth_Bps=spine_bw)
        for leaf in range(n_leaves):
            topo.add_switch(f"leaf{leaf}", uplink="spine",
                            latency_s=uplink_latency_s,
                            bandwidth_Bps=spine_bw,
                            power_domain=f"pd{leaf}")
            for i in range(hosts_per_leaf):
                topo.attach_host(f"h{leaf * hosts_per_leaf + i}",
                                 f"leaf{leaf}",
                                 latency_s=attach_latency_s,
                                 bandwidth_Bps=port_bandwidth_Bps)
            for i in range(expanders_per_leaf):
                topo.attach_expander(leaf * expanders_per_leaf + i,
                                     f"leaf{leaf}",
                                     latency_s=attach_latency_s,
                                     bandwidth_Bps=port_bandwidth_Bps,
                                     capacity_bytes=capacity_bytes)
        return topo

    def snapshot(self) -> dict:
        return {
            "switches": sorted(self._switches),
            "hosts": sorted(self._hosts),
            "expanders": sorted(self._expanders),
            "domains": self.domains(),
        }
