"""Vectorized discrete-event core: many device lanes in lockstep.

The scalar engine (``repro.sim.engine.simulate``) advances one IO at a
time through a closed-loop queue-depth pipeline — exact, but a Python
loop per IO caps it at a handful of devices.  This module re-expresses
the same recurrence as a numpy struct-of-arrays computation over many
independent *lanes* (one lane = one simulated device), which is what
makes rack-scale scenarios (hundreds of devices x millions of IOs)
reachable.

The scalar recurrence, per IO ``i`` (miss = external index access):

    start_i = pop(min slot)                      # closed loop, QD slots
    v_i  = max(start_i, index_free);  index_free = v_i + 1/index_rate
    w_i  = v_i + index_lat                       # (miss only; else start_i)
    s_i  = max(w_i, data_free);       data_free  = s_i + 1/data_rate
    t_i  = s_i + data_lat;            lat_i = t_i - start_i

Two structural facts make it vectorizable without changing the math:

  1. **Completions are strictly increasing** (``s_{i+1} >= s_i +
     1/data_rate``), so the slot heap degenerates to a FIFO ring:
     ``start_i = t_{i-qd}`` (0 for the first ``qd`` IOs).  The feedback
     loop therefore has lag ``qd`` — IOs can be processed in chunks of
     ``qd`` with all starts known up front.
  2. **Each stage is a max-plus prefix scan.**  With ``g = 1/rate`` and
     ordinal ``j`` inside a chunk, ``s_j = max(w_j, s_{j-1} + g)``
     rewrites to ``s_j - g*j = max(w_j - g*j, s_{j-1} - g*(j-1))`` — a
     running maximum (``np.maximum.accumulate``) in the transformed
     coordinate, seeded with the stage's carry-in next-free time.

Every lane shares the chunk loop, so the Python-level iteration count
is ``n_ios / qd`` **independent of the number of lanes**; all per-IO
work is numpy over ``(lanes, qd)`` blocks.  Results match the scalar
engine to floating-point association order (regression tests pin
p50/p99 agreement within tolerance).

Per-lane parameters (bandwidth grant caps, link utilization, extra
path latency from :class:`repro.rack.topology.RackTopology` hop costs,
RNG seeds) are arrays, which is how ``simulate_shared_fabric`` /
``simulate_multi_expander`` and the rack scenarios express whole racks
as a single call.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.overlap import exposed_latency_s
from repro.core.tiers import congested_latency
from repro.sim.ssd import Scheme, SSDSpec
from repro.sim.workload import Workload, batch_locality_hits

_ArrayLike = Union[float, Sequence[float], np.ndarray]


@dataclasses.dataclass
class LaneResult:
    """Per-lane (per-device) outcome arrays of one vectorized run."""

    n_lanes: int
    n_ios: int
    wall_s: np.ndarray           # (L,) completion time of the last IO
    iops: np.ndarray             # (L,)
    mean_lat_s: np.ndarray       # (L,)
    p50_lat_s: np.ndarray        # (L,)
    p99_lat_s: np.ndarray        # (L,)
    index_hit_ratio: np.ndarray  # (L,)

    @property
    def total_ios(self) -> int:
        return self.n_lanes * self.n_ios


def _per_lane(value: _ArrayLike, n_lanes: int, name: str) -> np.ndarray:
    arr = np.broadcast_to(np.asarray(value, np.float64), (n_lanes,))
    if arr.shape != (n_lanes,):
        raise ValueError(f"{name}: expected scalar or ({n_lanes},) array")
    return np.ascontiguousarray(arr)


def simulate_lanes(spec: SSDSpec, scheme: Scheme, workload: Workload,
                   *, seeds: Sequence[int],
                   data_rate_cap_iops: Optional[_ArrayLike] = None,
                   link_utilization: _ArrayLike = 0.0,
                   extra_index_latency_s: _ArrayLike = 0.0,
                   prefetch_depth: int = 0) -> LaneResult:
    """Closed-loop DES of ``len(seeds)`` independent device lanes.

    Mirrors :func:`repro.sim.engine.simulate` parameter-for-parameter,
    vectorized: ``data_rate_cap_iops`` and ``link_utilization`` may be
    per-lane arrays (the arbiter grant / offered load of each device's
    link), and ``extra_index_latency_s`` adds a per-lane fabric path
    latency (:class:`~repro.rack.topology.PathCost.latency_s` of the
    device's host->expander route) to every external index access —
    direct attach is the 0.0 degenerate case.  Locality draws come from
    :func:`repro.sim.workload.batch_locality_hits`, seeded per lane
    exactly like the scalar engine, so hit/miss populations (and
    therefore results) line up lane-for-lane with scalar runs.
    """
    L = len(seeds)
    if L < 1:
        raise ValueError("at least one lane required")
    n = workload.n_ios
    qd = workload.queue_depth
    pattern, op = workload.pattern, workload.op

    caps = (None if data_rate_cap_iops is None
            else _per_lane(data_rate_cap_iops, L, "data_rate_cap_iops"))
    utils = _per_lane(link_utilization, L, "link_utilization")
    extra = _per_lane(extra_index_latency_s, L, "extra_index_latency_s")

    # ---- per-lane stage rates (same derivation as the scalar engine) ------
    data_rate = np.full(L, spec.base_iops(pattern, op))
    if caps is not None:
        data_rate = np.minimum(data_rate, np.maximum(caps, 1.0))
    data_lat = np.minimum(spec.base_latency_s(op), qd / data_rate)

    engine = spec.index_rand if pattern in ("rand", "zipf") else spec.index_seq
    needs_index = scheme.t_tier_s is not None and (
        op == "read" or scheme.write_through_index)
    if needs_index:
        if scheme.name == "dftl":
            # flash-resident index is device-local: neither link
            # congestion nor fabric hop latency applies
            index_rate = np.full(L, spec.dftl_concurrency / scheme.t_tier_s)
            index_lat = np.full(L, scheme.t_tier_s)
        else:
            t_eff = scheme.t_tier_s + extra      # tier + fabric path cost
            index_rate = engine.concurrency / (engine.t_proc_s + t_eff)
            index_lat = np.array(
                [congested_latency(t, u) for t, u in zip(t_eff, utils)])
            if prefetch_depth > 0 and pattern == "seq":
                index_lat = np.array(
                    [exposed_latency_s(il, prefetch_depth / dr)
                     for il, dr in zip(index_lat, data_rate)])
        inv_index = 1.0 / index_rate
        hit_ratio = scheme.onboard_hit_ratio
        hits = batch_locality_hits(n, hit_ratio, seeds)
        miss = ~hits
    else:
        index_lat = inv_index = None
        miss = None

    # ---- lockstep chunked max-plus scan -----------------------------------
    # Everything feedback-independent is hoisted out of the chunk loop and
    # computed for ALL chunks in one vectorized pass: per-chunk miss
    # ordinals (cumsum over a reshaped (L, n_chunks, qd) view), the
    # g*j transform products, and the data-stage ramp.  The loop body is
    # then just the two max-plus scans on preallocated buffers — the
    # Python-level work per chunk is a handful of in-place ufunc calls.
    inv_data = 1.0 / data_rate
    n_pad = -(-n // qd) * qd             # ceil to whole chunks
    data_lat_c = data_lat[:, None]
    ramp = inv_data[:, None] * np.arange(qd)       # (L, qd) data transform
    # Fast path: most schemes run at hit_ratio 0 — EVERY IO misses, so the
    # per-chunk miss ordinal is just 0..c-1 in every lane and chunk, and
    # the where/copyto hit-masking machinery drops out entirely.
    uniform = needs_index and bool(miss.all())
    if uniform:
        ramp_i = inv_index[:, None] * np.arange(qd)
        # index->data handoff folded into one constant: w - ramp =
        # (cm + ramp_i + index_lat) - ramp
        delta = ramp_i + index_lat[:, None] - ramp
        ramp_lat = ramp + data_lat_c                   # issue -> completion
    elif needs_index:
        mp = np.zeros((L, n_pad), dtype=bool)
        mp[:, :n] = miss
        j3 = np.cumsum(mp.reshape(L, -1, qd), axis=2)  # (L, nc, qd) ordinals
        n_miss3 = j3[:, :, -1]                          # misses per chunk
        j3 = j3 - 1
        prod3 = inv_index[:, None, None] * j3           # g*j, all chunks
        back3 = prod3 + index_lat[:, None, None]        # undo + tier latency
        keep3 = ~mp.reshape(L, -1, qd)                  # hit positions
    lat = np.empty((L, n))
    starts = np.zeros((L, qd))           # ring: completions one chunk back
    index_free = np.zeros((L, 1))
    data_free = np.zeros((L, 1))
    a = np.empty((L, qd))
    b = np.empty((L, qd))
    for k, c0 in enumerate(range(0, n, qd)):
        c = min(qd, n - c0)
        u = starts[:, :c]
        if uniform:
            np.subtract(u, ramp_i[:, :c], out=a[:, :c])
            np.maximum.accumulate(a[:, :c], axis=1, out=a[:, :c])
            np.maximum(a[:, :c], index_free, out=a[:, :c])
            index_free = a[:, c - 1:c] + inv_index[:, None] * c
            np.add(a[:, :c], delta[:, :c], out=b[:, :c])
        elif needs_index:
            np.subtract(u, prod3[:, k, :c], out=a[:, :c])
            np.copyto(a[:, :c], -np.inf, where=keep3[:, k, :c])
            np.maximum.accumulate(a[:, :c], axis=1, out=a[:, :c])
            np.maximum(a[:, :c], index_free, out=a[:, :c])
            nm = n_miss3[:, k:k + 1]
            index_free = np.where(
                nm > 0, a[:, c - 1:c] + inv_index[:, None] * nm, index_free)
            w = np.add(a[:, :c], back3[:, k, :c], out=a[:, :c])
            np.copyto(w, u, where=keep3[:, k, :c])
            np.subtract(w, ramp[:, :c], out=b[:, :c])
        else:
            np.subtract(u, ramp[:, :c], out=b[:, :c])
        np.maximum.accumulate(b[:, :c], axis=1, out=b[:, :c])
        np.maximum(b[:, :c], data_free, out=b[:, :c])
        data_free = b[:, c - 1:c] + inv_data[:, None] * c
        if uniform:
            t = np.add(b[:, :c], ramp_lat[:, :c], out=b[:, :c])
        else:
            issue = np.add(b[:, :c], ramp[:, :c], out=b[:, :c])
            t = np.add(issue, data_lat_c, out=issue)
        np.subtract(t, u, out=lat[:, c0:c0 + c])
        starts[:, :c] = t                # FIFO: start_i = t_{i-qd}

    wall = starts[:, c - 1].copy()       # completions increase monotonically
    iops = n / wall
    p50, p99 = np.percentile(lat, (50, 99), axis=1)  # one partition pass
    return LaneResult(
        n_lanes=L, n_ios=n, wall_s=wall, iops=iops,
        mean_lat_s=lat.mean(axis=1),
        p50_lat_s=p50,
        p99_lat_s=p99,
        index_hit_ratio=(1.0 - miss.mean(axis=1) if needs_index
                         else np.ones(L)),
    )
