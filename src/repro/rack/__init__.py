"""repro.rack — rack-scale CXL pool simulation.

Three pillars (ISSUE 8 / ROADMAP "rack-scale pooling"):

  * :mod:`repro.rack.topology` — switched rack topology (hosts x
    expanders x switch tiers) with per-edge hop latency, per-port
    bandwidth, and correlated failure domains; ``path(host, expander)``
    is the cost function the tier model and the per-link arbiters
    consume.  Direct attach (the paper's setup) is the 1-switch
    degenerate case.
  * :mod:`repro.rack.des` — vectorized (numpy struct-of-arrays)
    discrete-event core: many device lanes advance in lockstep through
    the index/data stage recurrences in queue-depth-sized chunks, so a
    rack of hundreds of devices and millions of simulated IOs runs at
    tolerable wall-clock.  ``repro.sim.engine`` re-expresses its
    ``simulate*`` entry points on this core.
  * :mod:`repro.rack.scenarios` — rack-level experiments (hop-cost
    sweep, skewed vs pool-aware placement, correlated-failure recovery,
    pool-utilization sweep) published through ``benchmarks/run.py
    --only rack_sweep`` with declarative CI gates.
"""

from repro.rack.des import LaneResult, simulate_lanes
from repro.rack.topology import PathCost, RackTopology

__all__ = ["LaneResult", "PathCost", "RackTopology", "simulate_lanes"]
