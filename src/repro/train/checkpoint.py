"""Checkpointing: sharded save/restore with atomic manifests.

Fault-tolerance contract:
  * a checkpoint is only visible once its manifest is atomically renamed
    into place (no torn checkpoints after a crash);
  * saves can run asynchronously (background thread snapshots host copies);
  * restore works onto a DIFFERENT mesh/sharding (elastic re-mesh): arrays
    are loaded host-side and ``device_put`` with the new shardings;
  * the data pipeline is deterministic in (seed, step), so restore of
    (params, opt_state, step) fully determines the continuation.

Format: one .npz per pytree ("params", "opt_state") with flattened key
paths + a JSON manifest carrying step/metadata.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes  # noqa: F401 - registers bfloat16 & friends with numpy
import numpy as np

#: numpy can't serialize ml_dtypes (bfloat16 etc.); store a same-width
#: integer view + the real dtype name in the manifest
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name in _EXOTIC:
            flat[key] = arr.view(_EXOTIC[arr.dtype.name])
            flat[f"__dtype__/{key}"] = np.asarray(arr.dtype.name)
        else:
            flat[key] = arr
    return flat


def _unflatten_like(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        dt_key = f"__dtype__/{key}"
        if dt_key in flat:
            arr = arr.view(np.dtype(str(flat[dt_key])))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves)


def save_checkpoint(ckpt_dir: str, step: int, trees: Dict[str, Any],
                    metadata: Optional[dict] = None,
                    async_save: bool = False) -> threading.Thread | None:
    """Write ``trees`` under ckpt_dir/step_<step>/ with atomic manifest."""
    os.makedirs(ckpt_dir, exist_ok=True)
    # snapshot to host memory NOW (so training can mutate devices after)
    host = {name: _flatten(tree) for name, tree in trees.items()}

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for name, flat in host.items():
            np.savez(os.path.join(tmp, f"{name}.npz"), **flat)
        manifest = {"step": step, "trees": sorted(host),
                    "time": time.time(), **(metadata or {})}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic visibility

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, templates: Dict[str, Any],
                       step: Optional[int] = None,
                       shardings: Optional[Dict[str, Any]] = None,
                       ) -> Tuple[Dict[str, Any], int]:
    """Restore trees (optionally re-sharded onto a new mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    out = {}
    for name, template in templates.items():
        with np.load(os.path.join(d, f"{name}.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_like(template, flat)
        if shardings and name in shardings:
            tree = jax.tree_util.tree_map(
                lambda arr, sh: jax.device_put(arr, sh),
                tree, shardings[name])
        else:
            tree = jax.tree_util.tree_map(jax.numpy.asarray, tree)
        out[name] = tree
    return out, step
