"""Fault tolerance & straggler mitigation for the training launcher.

At 1000+ nodes, something is always failing.  The runnable pieces here are
host-level (they run identically in the single-process CI environment and
on a real multi-host pod):

  * **HeartbeatMonitor** — per-host liveness with deadline detection; the
    launcher registers hosts and marks them dead on missed beats.
  * **StragglerDetector** — per-step wall-time EWMA + MAD outlier flagging;
    the mitigation hook (re-shard or evict) is the launcher's choice.
  * **restart supervision** — ``run_supervised`` wraps the train loop,
    checkpoints periodically, and on (injected or real) failure restores
    the latest checkpoint and continues — the restart path the tests
    exercise.
  * **elastic re-mesh** — a checkpoint written on mesh A restores onto
    mesh B (``restore_checkpoint(..., shardings=new)``); combined with the
    deterministic data stream, training continues bit-exactly modulo
    reduction order.

LMB tie-in: the FabricManager journal makes pool state reconstructible
after an expander failover; LinkedBuffer consumers degrade to onboard-only
(capacity shed, not death) when no spare exists — see repro.core.fabric.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class HostState:
    host_id: str
    last_beat: float
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, deadline_s: float = 60.0):
        self.deadline_s = deadline_s
        self._hosts: Dict[str, HostState] = {}

    def register(self, host_id: str) -> None:
        self._hosts[host_id] = HostState(host_id, time.monotonic())

    def beat(self, host_id: str) -> None:
        st = self._hosts.get(host_id)
        if st:
            st.last_beat = time.monotonic()
            st.alive = True

    def check(self, now: Optional[float] = None) -> List[str]:
        """Returns newly-dead hosts."""
        now = now if now is not None else time.monotonic()
        dead = []
        for st in self._hosts.values():
            if st.alive and now - st.last_beat > self.deadline_s:
                st.alive = False
                dead.append(st.host_id)
        return dead

    @property
    def alive_hosts(self) -> List[str]:
        return [h for h, st in self._hosts.items() if st.alive]


class StragglerDetector:
    """Flags steps (or hosts) whose step time is a robust outlier.

    Mitigation at scale: the launcher can exclude the host from the next
    mesh (elastic re-mesh) or lower its data share; flagging is the part
    that must be correct and is what we test.
    """

    def __init__(self, window: int = 64, threshold: float = 3.0):
        self.window = window
        self.threshold = threshold
        self._times: deque = deque(maxlen=window)

    def observe(self, step_time_s: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        flagged = False
        if len(self._times) >= 8:
            med = sorted(self._times)[len(self._times) // 2]
            mad = sorted(abs(t - med) for t in self._times)[
                len(self._times) // 2]
            if step_time_s > med + self.threshold * max(mad, 0.05 * med):
                flagged = True
        self._times.append(step_time_s)
        return flagged


class FailureInjector:
    """Deterministic failure schedule for tests: fail at given steps."""

    def __init__(self, fail_at: Optional[set] = None):
        self.fail_at = fail_at or set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise RuntimeError(f"injected failure at step {step}")


def run_supervised(train_once: Callable[[int], int],
                   max_restarts: int = 3) -> int:
    """Run ``train_once(start_step) -> final_step``, restarting on failure.

    ``train_once`` is responsible for restoring from the latest checkpoint
    when start_step > 0 (the tests drive this with FailureInjector).
    """
    restarts = 0
    start = 0
    while True:
        try:
            return train_once(start)
        except RuntimeError:
            restarts += 1
            if restarts > max_restarts:
                raise
            start = -1  # sentinel: resume from latest checkpoint
