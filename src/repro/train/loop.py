"""Training step: grad accumulation, AdamW, optional LMB state offload
and gradient compression.

``make_train_step`` builds the pure function the dry-run lowers:

    step(params, opt_state, batch) -> (params, opt_state, metrics)

Gradient accumulation runs microbatches under ``lax.scan`` (memory-bound
shapes); the DP all-reduce happens implicitly via shardings.  With
``flags.offload_opt_state`` (TPU), optimizer-state operands/results are
annotated to ``pinned_host`` so XLA streams them HBM↔host around the update
(the in-jit LMB data path); on CPU the host-stage path in
``repro.train.offload_runner`` does the same movement eagerly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.models.zoo import Model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import ef_compress_tree, ef_state_init


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Dict[str, Any]
    step: int = 0


def train_state_init(model: Model, rng) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt_state=adamw_init(params))


def _split_micro(batch: Dict[str, jax.Array], n: int) -> Dict[str, jax.Array]:
    def f(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape(n, B // n, *x.shape[1:])
    return {k: f(v) for k, v in batch.items()}


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    grad_accum: int = 1,
                    compress_grads: bool = False) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, micro):
        return model.loss(params, micro)

    def step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micros = _split_micro(batch, grad_accum)

            def body(acc, micro):
                l, g = jax.value_and_grad(loss_fn)(params, micro)
                acc_l, acc_g = acc
                return (acc_l + l,
                        jax.tree_util.tree_map(jnp.add, acc_g, g)), None

            zero = (jnp.float32(0.0),
                    jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = jax.lax.scan(body, zero, micros)
            loss = loss / grad_accum
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)

        if compress_grads:
            grads, new_err = ef_compress_tree(grads, opt_state["ef_err"])
        new_params, new_opt, metrics = adamw_update(
            opt_cfg, grads, {k: v for k, v in opt_state.items()
                             if k != "ef_err"}, params)
        if compress_grads:
            new_opt["ef_err"] = new_err
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return step


def opt_state_init(params, compress_grads: bool = False):
    st = adamw_init(params)
    if compress_grads:
        st["ef_err"] = ef_state_init(params)
    return st


def abstract_train_state(model: Model, compress_grads: bool = False):
    """ShapeDtypeStructs of (params, opt_state) without allocation."""
    params = model.abstract_params()
    opt = jax.eval_shape(lambda p: opt_state_init(p, compress_grads), params)
    return params, opt
