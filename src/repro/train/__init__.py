from repro.train.loop import TrainState, make_train_step, train_state_init
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)

__all__ = ["TrainState", "make_train_step", "train_state_init",
           "latest_step", "restore_checkpoint", "save_checkpoint"]
