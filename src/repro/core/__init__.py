"""repro.core — the paper's contribution: the CXL Linked Memory Buffer.

Layering (bottom-up):
  tiers     — latency/bandwidth model of each memory tier (Fig 2)
  pool      — expander (GFD/DMP/DPA) + 256 MB block allocator (Fig 4, §3.2)
  placement — pluggable block→expander placement policies
  fabric    — Fabric Manager, SAT/IOMMU access control, failure handling
  api       — Table-2 kernel API: class-agnostic alloc / free / share
              (+ deprecated lmb_pcie_*/lmb_cxl_* shims), mmid regions
  client    — the public surface: LMBSystem sessions built from one
              declarative SystemSpec, typed MemoryHandle capabilities
              (StaleHandle on use-after-free / after-failover)
  policy    — eviction (LRU/CLOCK/cost-aware) + prefetch
  offload   — JAX realization of tier moves (memory_kind=pinned_host)
  buffer    — LinkedBuffer: paged logical arrays spanning tiers
"""

from repro.core.api import Allocation, LMBHost
from repro.core.buffer import LinkedBuffer
from repro.core.client import (DeviceSpec, ExpanderSpec, HostSpec,
                               LMBSystem, MemoryHandle, ObsSpec,
                               PrefetchSpec, StaleHandle, SystemSpec,
                               TenantSpec, system_for)
from repro.core.fabric import (AccessDenied, DeviceClass, DeviceInfo,
                               FabricManager, make_default_fabric,
                               make_multi_fabric)
from repro.core.faults import (FaultEvent, FaultInjector, FaultPlan,
                               RetryPolicy)
from repro.core.offload import TierExecutor, supports_in_jit_offload
from repro.core.overlap import (OverlapScheduler, exposed_latency_s,
                                hidden_fraction)
from repro.core.policy import Prefetcher, PrefetchRun
from repro.core.placement import (ExpanderView, HeatAwarePolicy,
                                  LeastLoadedPolicy, PlacementPolicy,
                                  PlacementRequest, TenantAffinityPolicy,
                                  make_placement_policy)
from repro.core.pool import (BLOCK_BYTES, BlockAllocator, Expander,
                             InvalidHandle, LMBError, MediaKind, OutOfMemory)
from repro.core.tiers import (TierKind, TierSpec, congested_latency,
                              paper_tiers, tpu_tiers)

__all__ = [
    "Allocation", "LMBHost", "LinkedBuffer", "AccessDenied", "DeviceClass",
    "DeviceInfo", "FabricManager", "make_default_fabric",
    "make_multi_fabric", "TierExecutor",
    "supports_in_jit_offload", "BLOCK_BYTES", "BlockAllocator", "Expander",
    "InvalidHandle", "LMBError", "MediaKind", "OutOfMemory", "TierKind",
    "TierSpec", "congested_latency", "paper_tiers", "tpu_tiers",
    # client API (the public surface)
    "LMBSystem", "MemoryHandle", "StaleHandle", "SystemSpec",
    "ExpanderSpec", "HostSpec", "DeviceSpec", "TenantSpec",
    "PrefetchSpec", "ObsSpec", "system_for",
    # prefetch + overlap scheduling
    "Prefetcher", "PrefetchRun", "OverlapScheduler",
    "exposed_latency_s", "hidden_fraction",
    # placement policies
    "PlacementPolicy", "PlacementRequest", "ExpanderView",
    "LeastLoadedPolicy", "HeatAwarePolicy", "TenantAffinityPolicy",
    "make_placement_policy",
    # chaos / fault injection
    "FaultEvent", "FaultPlan", "FaultInjector", "RetryPolicy",
]
