"""Capability-handle client API: the top of ``repro.core``.

The paper's Table-2 interface is a kernel ABI; this module is the client
surface every consumer in the repo programs against instead of raw
``mmid`` ints and hand-wired ``FabricManager``→``LMBHost`` plumbing.
Following the CXL pooling literature's framing of pooled memory as
*revocable capability grants with policy-driven placement*:

  * :class:`SystemSpec` — one declarative description of the stack
    (expanders, hosts, devices, tenants, placement policy, spare).
  * :class:`LMBSystem` — the session object built from a spec.  It owns
    the fabric/host/arbiter wiring (the ~10 lines previously copied into
    every launcher), has context-manager lifecycle (leaving the ``with``
    block frees every live grant), and mints capabilities.
  * :class:`MemoryHandle` — a typed capability for one allocation,
    carrying ``(host, device, mmid, generation)``.  It offers
    ``.share(dev)``, ``.free()``, ``.expander()`` and ``with``-scoped
    auto-free, and raises :class:`StaleHandle` instead of acting on
    dead memory: use-after-free (including an owner free invalidating
    sharer capabilities, and hot-page migration draining a LinkedBuffer
    chunk whose handle is then freed) and use-after-failover (the
    per-expander generation counters are bumped by the existing
    ``on_failover`` path in :class:`~repro.core.api.LMBHost`).

Raw ``mmid`` ints never cross this surface: a handle is the only way to
name memory, and a dead handle is typed-dead, not silently dangling.

Example::

    spec = SystemSpec(expanders=(ExpanderSpec(gib=8),),
                      hosts=("host0",),
                      devices=(DeviceSpec("ssd0"),
                               DeviceSpec("accel0", DeviceClass.CXL,
                                          spid=0x11)))
    with LMBSystem(spec) as sys:
        with sys.alloc("ssd0", 64 << 20) as h:
            peer = h.share("accel0")        # zero-copy capability
            print(h.expander(), peer.dpid)
        # h (and peer) freed here; quota released
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Union

from repro.core.api import Allocation, LMBHost
from repro.core.fabric import (DEFAULT_LINK_BW_Bps, DeviceClass, DeviceInfo,
                               FabricManager)
from repro.core.metrics import GLOBAL_METRICS, Metrics
from repro.core.placement import (PlacementPolicy, TenantAffinityPolicy,
                                  make_placement_policy)
from repro.core.pool import (DEFAULT_PAGE_BYTES, Expander, LMBError,
                             MediaKind)
from repro.obs.trace import (DEFAULT_RING_CAPACITY, GLOBAL_TRACER, Span,
                             SpanTracer)

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.buffer import LinkedBuffer
    from repro.core.faults import FaultInjector, FaultPlan, RetryPolicy


class StaleHandle(LMBError):
    """The capability no longer refers to live memory: it was freed, its
    owner freed the underlying region, or the backing expander failed
    over / was migrated since the grant (generation mismatch)."""


class MemoryHandle:
    """Typed capability for one LMB allocation.

    Replaces raw ``mmid`` ints at the client surface: the handle knows
    which host and device it belongs to, which expander backs it, and the
    expander's failover generation at grant time.  Every operation checks
    liveness first and raises :class:`StaleHandle` on use-after-free or
    stale-after-failover — the two bugs an integer mmid cannot catch.

    Handles are context managers: ``with system.alloc(...) as h:`` frees
    the grant (releasing host quota) on exit, unless something else
    already invalidated it.
    """

    __slots__ = ("host_id", "device_id", "mmid", "generation",
                 "_host", "_allocation", "_home", "_freed", "_owner",
                 "_sharers", "_session")

    def __init__(self, host: LMBHost, allocation: Allocation,
                 owner: Optional["MemoryHandle"] = None):
        self._host = host
        self._allocation = allocation
        self.host_id = host.host_id
        self.device_id = allocation.device_id
        self.mmid = allocation.mmid
        self._home = host.expander_of(allocation.mmid)
        self.generation = host.generation_of(self._home)
        self._freed = False
        #: the owning handle when this capability came from ``.share``
        self._owner = owner
        #: capabilities derived from this one (owner handles only)
        self._sharers: List["MemoryHandle"] = []
        #: session tracking this handle (LMBSystem), if any
        self._session: Optional["LMBSystem"] = None

    # ------------------------------------------------------------- minting
    @classmethod
    def alloc(cls, host: LMBHost, device_id: str, nbytes: int,
              expander_id: Optional[int] = None) -> "MemoryHandle":
        """Allocate through the class-agnostic Table-2 verb and wrap the
        grant in a capability."""
        return cls(host, host.alloc(device_id, nbytes,
                                    expander_id=expander_id))

    # ----------------------------------------------------------- liveness
    def _ensure_live(self) -> None:
        if self._freed:
            raise StaleHandle(
                f"handle mmid={self.mmid} ({self.device_id}@{self.host_id})"
                " was already freed")
        live_gen = self._host.generation_of(self._home)
        if live_gen != self.generation:
            raise StaleHandle(
                f"handle mmid={self.mmid} ({self.device_id}@{self.host_id})"
                f" is stale: expander {self._home} moved to generation "
                f"{live_gen} (granted at {self.generation}) — failover "
                "invalidated the region")

    @property
    def stale(self) -> bool:
        """True when any operation on this handle would raise
        :class:`StaleHandle` (non-throwing probe)."""
        try:
            self._ensure_live()
        except StaleHandle:
            return True
        return False

    # ----------------------------------------------------- capability ops
    @property
    def nbytes(self) -> int:
        return self._allocation.nbytes

    @property
    def hpa(self) -> int:
        """Host physical address of the region (stable for its lifetime)."""
        self._ensure_live()
        return self._allocation.hpa

    @property
    def bus_addr(self) -> int:
        """Device-visible address: IOVA for PCIe devices, HPA for CXL."""
        self._ensure_live()
        return self._allocation.bus_addr

    @property
    def dpid(self) -> Optional[int]:
        """Expander port id for CXL P2P (None on PCIe handles)."""
        return self._allocation.dpid

    def expander(self) -> int:
        """Which pooled expander backs this grant (placement query)."""
        self._ensure_live()
        return self._home

    def share(self, device_id: str) -> "MemoryHandle":
        """Grant another device zero-copy access; returns the sharer's own
        capability (invalidated with this one when the owner frees).

        One live capability per (allocation, device): sharing to a device
        that already holds one returns the existing handle instead of
        minting an alias — two handles over one underlying mapping would
        let freeing the first leave the second dangling."""
        self._ensure_live()
        root = self._owner if self._owner is not None else self
        if device_id == root.device_id and not root._freed:
            return root
        for s in root._sharers:
            if s.device_id == device_id and not s._freed:
                return s
        alloc = self._host.share(self.device_id, self.mmid, device_id)
        handle = MemoryHandle(self._host, alloc, owner=root)
        root._sharers.append(handle)
        return handle

    def free(self) -> None:
        """Release the capability.  For the owner: frees the region,
        revokes every sharer's access, and invalidates their handles.
        For a sharer: drops only its own mapping."""
        self._ensure_live()
        self._host.free(self.device_id, self.mmid)
        self._freed = True
        if self._owner is None:
            for s in self._sharers:
                s._freed = True
                s._untrack()
            self._sharers.clear()
        else:
            try:
                self._owner._sharers.remove(self)
            except ValueError:
                pass
        self._untrack()

    def _untrack(self) -> None:
        if self._session is not None:
            self._session._discard(self)
            self._session = None

    # ------------------------------------------------------ with-lifetime
    def __enter__(self) -> "MemoryHandle":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if not self.stale:
            self.free()

    def __repr__(self) -> str:
        state = "stale" if self.stale else "live"
        return (f"MemoryHandle(mmid={self.mmid}, device={self.device_id!r},"
                f" host={self.host_id!r}, expander={self._home},"
                f" gen={self.generation}, {self.nbytes}B, {state})")


# --------------------------------------------------------------------------
# Declarative system specification
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ExpanderSpec:
    """One pooled GFD expander."""

    gib: int = 4
    media: MediaKind = MediaKind.DRAM
    #: explicit pool id; defaults to the spec's position
    expander_id: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class HostSpec:
    """One host running an LMB kernel module instance."""

    host_id: str
    quota_bytes: Optional[int] = None
    page_bytes: int = DEFAULT_PAGE_BYTES


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One PCIe/CXL device attached to the fabric."""

    device_id: str
    device_class: DeviceClass = DeviceClass.PCIE
    #: Source PBR id — required for CXL devices
    spid: Optional[int] = None
    bw_weight: float = 1.0
    bw_burst_bytes: int = 0
    tenant: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant sharing the fabric (placement affinity + QoS identity)."""

    name: str
    #: seed the tenant-affinity policy's home expander for this tenant
    preferred_expander: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class PrefetchSpec:
    """Default prefetch/overlap knobs for buffers minted by this system.

    ``depth`` > 0 turns on the burst-native prefetcher for every
    :meth:`LMBSystem.buffer` that does not pass its own
    ``prefetch_depth``; ``overlap`` additionally wires an
    :class:`~repro.core.overlap.OverlapScheduler` over the fabric's link
    so prefetch bursts are admitted only while they fit behind the
    consumer's compute window (deferred otherwise, never dropped).
    """

    #: pages of lookahead per round (0 = prefetch off unless the buffer
    #: opts in itself)
    depth: int = 0
    #: scheduled-backlog cap, as a multiple of ``depth``
    backlog_factor: int = 8
    #: gate prefetch bursts behind the compute window
    overlap: bool = False
    #: initial compute-window estimate (seconds); consumers refine it
    #: via LinkedBuffer.note_compute_window
    compute_window_s: float = 0.0
    #: concurrent DMA streams the overlap budget assumes
    streams: int = 1

    def validate(self) -> None:
        if self.depth < 0:
            raise ValueError("prefetch depth must be >= 0")
        if self.backlog_factor < 1:
            raise ValueError("prefetch backlog_factor must be >= 1")


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """Observability knobs for one system (see ``repro.obs``).

    ``trace=True`` mints a PRIVATE :class:`~repro.obs.trace.SpanTracer`
    for the session and attaches it to the FM, every host, and every
    buffer/overlap-scheduler the session builds — spans from the whole
    data path land in one ring.  ``trace=False`` (the default) leaves
    components on the process-wide ``GLOBAL_TRACER``, which is disabled
    unless a harness (``benchmarks/run.py --trace``) turned it on; the
    disabled path is a single guard check per call site.
    """

    #: record spans into a session-private tracer
    trace: bool = False
    #: ring-buffer span capacity (oldest spans overwritten past this)
    trace_capacity: int = DEFAULT_RING_CAPACITY

    def validate(self) -> None:
        if self.trace_capacity < 1:
            raise ValueError("trace_capacity must be >= 1")


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """Everything needed to stand up one LMB stack, declaratively.

    Convenience coercions: ``expanders`` may be an int (that many default
    expanders) or a sequence of :class:`ExpanderSpec`; ``hosts`` entries
    may be bare host-id strings; ``tenants`` entries may be bare names;
    ``placement`` may be a policy name (``"least-loaded"``,
    ``"heat-aware"``, ``"tenant-affinity"``) or a
    :class:`~repro.core.placement.PlacementPolicy` instance.
    """

    expanders: Union[int, Sequence[ExpanderSpec]] = 1
    hosts: Sequence[Union[HostSpec, str]] = ("host0",)
    devices: Sequence[DeviceSpec] = ()
    tenants: Sequence[Union[TenantSpec, str]] = ()
    placement: Union[str, PlacementPolicy] = "least-loaded"
    #: add a passive standby expander the FM promotes on failure
    spare: bool = False
    link_bandwidth_Bps: float = DEFAULT_LINK_BW_Bps
    #: capacity of each default expander when ``expanders`` is an int
    pool_gib: int = 4
    #: default prefetch/overlap knobs for buffers minted by this system
    prefetch: PrefetchSpec = dataclasses.field(default_factory=PrefetchSpec)
    #: observability (span tracing) knobs for this system
    obs: ObsSpec = dataclasses.field(default_factory=ObsSpec)

    # -- normalized views ---------------------------------------------------
    def expander_specs(self) -> List[ExpanderSpec]:
        if isinstance(self.expanders, int):
            if self.expanders < 1:
                raise ValueError("at least one expander required")
            return [ExpanderSpec(gib=self.pool_gib)
                    for _ in range(self.expanders)]
        return list(self.expanders)

    def host_specs(self) -> List[HostSpec]:
        return [h if isinstance(h, HostSpec) else HostSpec(h)
                for h in self.hosts]

    def tenant_specs(self) -> List[TenantSpec]:
        return [t if isinstance(t, TenantSpec) else TenantSpec(t)
                for t in self.tenants]

    def validate(self) -> None:
        self.prefetch.validate()
        self.obs.validate()
        hosts = self.host_specs()
        if not hosts:
            raise ValueError("SystemSpec needs at least one host")
        ids = [h.host_id for h in hosts]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate host ids: {ids}")
        dev_ids = [d.device_id for d in self.devices]
        if len(set(dev_ids)) != len(dev_ids):
            raise ValueError(f"duplicate device ids: {dev_ids}")
        declared = {t.name for t in self.tenant_specs()}
        for d in self.devices:
            if d.device_class is DeviceClass.CXL and d.spid is None:
                raise ValueError(f"CXL device {d.device_id} needs an SPID")
            if d.tenant is not None and declared and d.tenant not in declared:
                raise ValueError(
                    f"device {d.device_id} names undeclared tenant "
                    f"{d.tenant!r} (declared: {sorted(declared)})")


class LMBSystem:
    """One LMB stack session, built from a :class:`SystemSpec`.

    Owns the ``FabricManager``, every ``LMBHost``, and the per-expander
    link arbiters, wired once here instead of per-entry-point.  All
    allocation flows through :meth:`alloc`, which returns
    :class:`MemoryHandle` capabilities; :meth:`close` (or leaving the
    ``with`` block) frees every live handle so quota cannot leak.
    """

    def __init__(self, spec: SystemSpec,
                 metrics: Optional[Metrics] = None):
        spec.validate()
        self.spec = spec
        exp_specs = spec.expander_specs()
        expanders = [
            Expander([(e.media, e.gib * 2**30)],
                     expander_id=(e.expander_id if e.expander_id is not None
                                  else i))
            for i, e in enumerate(exp_specs)]
        spare = None
        if spec.spare:
            tmpl = exp_specs[0]
            spare = Expander(
                [(tmpl.media, tmpl.gib * 2**30)],
                expander_id=max(e.expander_id for e in expanders) + 1)
        policy = spec.placement
        if isinstance(policy, str):
            kwargs = {}
            if policy == TenantAffinityPolicy.name:
                # seed declared tenant homes before the first placement;
                # a caller-supplied policy INSTANCE is taken as-is (the
                # caller owns its assignments) and never mutated here
                seeds = {t.name: t.preferred_expander
                         for t in spec.tenant_specs()
                         if t.preferred_expander is not None}
                if seeds:
                    kwargs["assignments"] = seeds
            policy = make_placement_policy(policy, **kwargs)
        self.fm = FabricManager(expanders, spare=spare,
                                link_bandwidth_Bps=spec.link_bandwidth_Bps,
                                placement=policy)
        self.placement_policy = policy
        self.metrics = metrics if metrics is not None else GLOBAL_METRICS
        #: the session's span tracer — private when the spec asks for
        #: tracing, else the process-wide (normally disabled) default.
        #: Attached to the FM, whose tracer every host/buffer reads.
        self.tracer: SpanTracer = (
            SpanTracer(capacity=spec.obs.trace_capacity)
            if spec.obs.trace else GLOBAL_TRACER)
        self.fm.tracer = self.tracer
        for d in spec.devices:
            self.fm.register_device(DeviceInfo(
                d.device_id, d.device_class, spid=d.spid,
                bw_weight=d.bw_weight, bw_burst_bytes=d.bw_burst_bytes,
                tenant=d.tenant))
        self._hosts: Dict[str, LMBHost] = {}
        for h in spec.host_specs():
            self.fm.bind_host(h.host_id, h.quota_bytes)
            self._hosts[h.host_id] = LMBHost(
                self.fm, h.host_id, page_bytes=h.page_bytes,
                metrics=metrics)
        # live-handle registry keyed by object id: freed handles drop out
        # (via MemoryHandle._untrack) so a long session does not
        # accumulate every capability it ever minted
        self._handles: Dict[int, MemoryHandle] = {}
        self._buffers: List["LinkedBuffer"] = []
        self._closed = False

    # ------------------------------------------------------------ topology
    def host(self, host_id: Optional[str] = None) -> LMBHost:
        """The named LMBHost — or the only one, when the spec has one."""
        if host_id is None:
            if len(self._hosts) != 1:
                raise ValueError(
                    f"system has {len(self._hosts)} hosts "
                    f"({sorted(self._hosts)}); name one")
            return next(iter(self._hosts.values()))
        host = self._hosts.get(host_id)
        if host is None:
            raise ValueError(f"unknown host {host_id!r} "
                             f"(declared: {sorted(self._hosts)})")
        return host

    @property
    def host_ids(self) -> List[str]:
        return sorted(self._hosts)

    def device(self, device_id: str) -> DeviceInfo:
        return self.fm.device(device_id)

    # --------------------------------------------------------- capabilities
    def alloc(self, device_id: str, nbytes: int, *,
              host_id: Optional[str] = None,
              expander_id: Optional[int] = None) -> MemoryHandle:
        """Allocate LMB memory for a device; returns a capability.  The
        device's registered class picks the PCIe/CXL path internally."""
        self._ensure_open()
        handle = MemoryHandle.alloc(self.host(host_id), device_id, nbytes,
                                    expander_id=expander_id)
        self._track(handle)
        return handle

    def share(self, handle: MemoryHandle,
              device_id: str) -> MemoryHandle:
        """Session-tracked :meth:`MemoryHandle.share`."""
        self._ensure_open()
        shared = handle.share(device_id)
        self._track(shared)
        return shared

    def _track(self, handle: MemoryHandle) -> None:
        handle._session = self
        self._handles[id(handle)] = handle

    def _discard(self, handle: MemoryHandle) -> None:
        self._handles.pop(id(handle), None)

    def free(self, handle: MemoryHandle) -> None:
        handle.free()

    def overlap_scheduler(self, compute_window_s: Optional[float] = None,
                          streams: Optional[int] = None):
        """An :class:`~repro.core.overlap.OverlapScheduler` modeling THIS
        fabric's expander link (CXL added latency at the spec's link
        bandwidth) — the admission gate that decides how much prefetch
        traffic hides behind a compute window.  Defaults come from the
        spec's :class:`PrefetchSpec`."""
        from repro.core.overlap import OverlapScheduler
        from repro.core.tiers import LMB_CXL_ADDED_S, TierKind, TierSpec
        pf = self.spec.prefetch
        tier = TierSpec(TierKind.LMB_CXL, LMB_CXL_ADDED_S,
                        self.spec.link_bandwidth_Bps)
        return OverlapScheduler(
            tier,
            compute_window_s=(pf.compute_window_s if compute_window_s
                              is None else compute_window_s),
            streams=pf.streams if streams is None else streams,
            trace=self.fm.tracer)

    def buffer(self, *, name: str, device_id: str,
               host_id: Optional[str] = None, **kwargs) -> "LinkedBuffer":
        """A LinkedBuffer wired to this system's host (the consumer-facing
        paged-array surface; see repro.core.buffer).  Session-tracked:
        :meth:`close` releases the buffer's LMB footprint too.  The
        spec's :class:`PrefetchSpec` supplies prefetch/overlap defaults
        for buffers that do not pass their own knobs."""
        from repro.core.buffer import LinkedBuffer
        self._ensure_open()
        pf = self.spec.prefetch
        if pf.depth and "prefetch_depth" not in kwargs:
            kwargs["prefetch_depth"] = pf.depth
            kwargs.setdefault("prefetch_backlog_factor", pf.backlog_factor)
        if (pf.overlap and kwargs.get("prefetch_depth")
                and "overlap" not in kwargs):
            kwargs["overlap"] = self.overlap_scheduler()
        buf = LinkedBuffer(name=name, device_id=device_id,
                           host=self.host(host_id), **kwargs)
        self._buffers.append(buf)
        return buf

    # ------------------------------------------------------------ operations
    def set_quota(self, host_id: str, quota_bytes: int) -> None:
        self.fm.set_quota(host_id, quota_bytes)

    def set_bw_share(self, device_id: str, weight: float,
                     burst_bytes: Optional[int] = None) -> None:
        self.fm.set_bw_share(device_id, weight, burst_bytes)

    def inject_failure(self, expander_id: Optional[int] = None) -> None:
        """Kill one expander (failure drill); handles homed on it go
        stale via the generation bump."""
        self.fm.inject_failure(expander_id)

    def readmit_expander(self, expander_id: int) -> None:
        """Repair drill: a failed expander rejoins the pool blank (see
        FabricManager.readmit_expander).  Pre-failure handles stay
        stale; buffers exit degraded mode."""
        self.fm.readmit_expander(expander_id)

    def attach_fault_injector(self, plan: "FaultPlan", *,
                              retry: Optional["RetryPolicy"] = None,
                              seed: int = 0) -> "FaultInjector":
        """Attach the chaos layer (repro.core.faults) to this session's
        fabric: the plan's timed faults fire as the fabric's link clock
        advances, and every metered transfer pays the active fault
        state's modeled cost.  Returns the injector (counters /
        snapshot live on it)."""
        from repro.core.faults import FaultInjector, RetryPolicy
        injector = FaultInjector(plan,
                                 retry=retry if retry is not None
                                 else RetryPolicy(),
                                 seed=seed)
        self.fm.attach_fault_injector(injector)
        return injector

    # ---------------------------------------------------------- introspection
    @property
    def healthy(self) -> bool:
        return self.fm.healthy

    def live_handles(self) -> List[MemoryHandle]:
        return [h for h in self._handles.values() if not h.stale]

    def snapshot(self) -> dict:
        snap = self.fm.snapshot()
        snap["live_handles"] = len(self.live_handles())
        # surface journal growth as registry gauges, so fleet-level
        # telemetry sees it without holding an FM reference
        js = snap["journal"]
        self.metrics.gauge("fm.journal_len", js["len"])
        for opname, n in js["by_op"].items():
            self.metrics.gauge(f"fm.journal.{opname}", n)
        snap["trace"] = self.tracer.snapshot()
        return snap

    # ------------------------------------------------------------- tracing
    def trace_spans(self) -> List[Span]:
        """Spans recorded by this session's tracer (oldest first)."""
        return self.tracer.spans()

    def export_trace(self, path: str) -> None:
        """Write this session's spans as Chrome trace-event JSON."""
        from repro.obs.export import write_chrome_trace
        write_chrome_trace(self.trace_spans(), path,
                           extra={"system": repr(self)})

    # -------------------------------------------------------------- lifecycle
    def _ensure_open(self) -> None:
        if self._closed:
            raise LMBError("LMBSystem session is closed")

    def close(self) -> None:
        """End the session: release every session-created buffer's LMB
        footprint, then free every live capability (sharers before
        owners, so owner frees see consistent sharer lists).  Quota held
        through this session cannot outlive it."""
        if self._closed:
            return
        for buf in self._buffers:
            buf.close()
        self._buffers.clear()
        for handle in sorted(self._handles.values(),
                             key=lambda h: h._owner is None):
            try:
                handle.free()
            except (StaleHandle, LMBError):
                continue       # already dead (failover, owner-free, ...)
        self._handles.clear()
        self._closed = True

    def __enter__(self) -> "LMBSystem":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def __repr__(self) -> str:
        return (f"LMBSystem(hosts={self.host_ids}, "
                f"expanders={self.fm.expander_ids}, "
                f"placement={self.placement_policy.name!r}, "
                f"{'closed' if self._closed else 'open'})")


def system_for(device_id: str = "dev0", *,
               host_id: str = "host0",
               pool_gib: int = 4,
               page_bytes: int = DEFAULT_PAGE_BYTES,
               n_expanders: int = 1,
               device_class: DeviceClass = DeviceClass.PCIE,
               spid: Optional[int] = None,
               spare: bool = False,
               placement: Union[str, PlacementPolicy] = "least-loaded",
               tenants: Sequence[Union[TenantSpec, str]] = (),
               link_bandwidth_Bps: float = DEFAULT_LINK_BW_Bps,
               metrics: Optional[Metrics] = None,
               obs: Optional[ObsSpec] = None) -> LMBSystem:
    """One-device convenience constructor for the overwhelmingly common
    single-host shape (launchers, benchmarks, tests).  ``tenants``
    declares the QoS/placement identities sharing the stack (bare names
    or :class:`TenantSpec`) and ``link_bandwidth_Bps`` sizes the
    expander links — the two knobs multi-tenant serve sweeps turn."""
    spec = SystemSpec(
        expanders=n_expanders,
        pool_gib=pool_gib,
        hosts=(HostSpec(host_id, page_bytes=page_bytes),),
        devices=(DeviceSpec(device_id, device_class, spid=spid),),
        tenants=tuple(tenants),
        spare=spare,
        placement=placement,
        link_bandwidth_Bps=link_bandwidth_Bps,
        obs=obs if obs is not None else ObsSpec())
    return LMBSystem(spec, metrics=metrics)
