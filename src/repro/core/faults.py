"""Transient-fault chaos layer for the CXL fabric.

Real CXL fabrics fail *transiently* long before they fail-stop: CRC
errors trigger link-level retry, links retrain (flap) after signal
loss, switches brown out under congestion or thermal pressure, and the
RAS machinery contains poison instead of killing the host.  The repo's
fail-stop path (``FabricManager.inject_failure`` → failover → degraded
mode) models only the terminal case; this module supplies everything
before it, plus the piece fail-stop never had — **repair**:

  * :class:`FaultEvent` / :class:`FaultPlan` — a declarative, timed
    script of faults (transient CRC-error windows, latency brownouts,
    link flaps with retrain delay, fail-stop, repair/re-admission),
    targeted at one expander, a topology failure domain, or the pool.
  * :class:`RetryPolicy` — bounded exponential backoff with seeded
    jitter and a per-link retry budget; transient errors cost modeled
    time (backoff + CRC-retry + retransmission wire time) and escalate
    to the existing failover path ONLY when the budget is exhausted.
  * :class:`FaultInjector` — attaches to a ``FabricManager``
    (:meth:`FabricManager.attach_fault_injector`), advances with the
    fabric's virtual link time, fires due events, and perturbs every
    ``meter_transfer`` according to the active fault state.

The graceful-degradation ladder this implements:

    healthy → brownout-aware placement/migration avoidance (the FM's
    placement views see a saturated link for browned-out expanders)
    → failover (budget-exhausted escalation or scripted fail-stop)
    → onboard-only degraded (``LinkedBuffer.degraded``)
    → repaired (``FabricManager.readmit_expander`` un-fails the
    expander blank and consumers exit degraded mode)

Determinism contract (the chaos_sweep CI gate pins it): a zero-fault
plan draws NO randomness and perturbs NO transfer — a run with an
attached zero-fault injector is byte-identical (tokens and per-class
``fm.op_bytes()``) to a run with no injector at all.  All randomness
is derived per-transfer from ``SeedSequence([seed, transfer_index])``,
so for a fixed seed the error draw of transfer *i* is independent of
how many retries earlier transfers performed — which also makes total
modeled retry time monotone in the error rate (the property suite
pins that too).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

import numpy as np

from repro.core.pool import LMBError

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.fabric import FabricManager

#: event kinds a FaultPlan may script
FAULT_KINDS = ("transient", "brownout", "link_flap", "fail_stop", "repair")

#: placement-view utilization reported for a browned-out expander —
#: saturated, so least-loaded/pool-aware policies (and the migration
#: engine's target query, which delegates to them) steer around it
BROWNOUT_VIEW_UTILIZATION = 1.0


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault, fired when injector time reaches ``t_s``.

    Targeting: ``expander_id`` names one expander; ``domain`` names a
    topology failure domain (every pooled expander in it); neither
    means every pooled expander.  Windowed kinds (transient, brownout)
    stay active for ``duration_s`` after firing; ``link_flap`` holds
    the link in retrain for ``retrain_s``; ``fail_stop`` and
    ``repair`` are instantaneous state changes.
    """

    t_s: float
    kind: str
    expander_id: Optional[int] = None
    domain: Optional[str] = None
    #: window length for "transient"/"brownout"
    duration_s: float = 0.0
    #: "transient": per-transfer CRC-error probability inside the window
    error_rate: float = 0.0
    #: "transient": modeled cost of one CRC retry round (link-level
    #: ack/replay latency), on top of backoff + retransmission wire time
    crc_retry_cost_s: float = 1e-6
    #: "brownout": multiplier on the modeled link delay inside the window
    latency_factor: float = 1.0
    #: "link_flap": retrain time the link is unusable for
    retrain_s: float = 0.0

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r} (one of {FAULT_KINDS})")
        if self.t_s < 0:
            raise ValueError("fault event time must be >= 0")
        if self.expander_id is not None and self.domain is not None:
            raise ValueError("target either an expander or a domain")
        if self.kind == "transient" and not 0.0 <= self.error_rate <= 1.0:
            raise ValueError("error_rate must be in [0, 1]")
        if self.kind == "brownout" and self.latency_factor < 1.0:
            raise ValueError("brownout latency_factor must be >= 1")
        if self.duration_s < 0 or self.retrain_s < 0:
            raise ValueError("durations must be >= 0")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A declarative fault script: timed events, executed in order.

    An empty plan is the determinism baseline — attaching an injector
    with it changes nothing observable.  Convenience constructors
    build the common storm shapes used by tests and chaos_sweep.
    """

    events: Tuple[FaultEvent, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda e: e.t_s)))

    def __len__(self) -> int:
        return len(self.events)

    @staticmethod
    def storm(*, t0_s: float, duration_s: float, error_rate: float,
              expander_id: Optional[int] = None,
              crc_retry_cost_s: float = 1e-6) -> "FaultPlan":
        """A single transient-error window (the canonical CRC storm)."""
        return FaultPlan((FaultEvent(
            t0_s, "transient", expander_id=expander_id,
            duration_s=duration_s, error_rate=error_rate,
            crc_retry_cost_s=crc_retry_cost_s),))


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for transient link errors.

    Per-transfer: up to ``max_retries`` attempts, each costing
    ``backoff_s(attempt)`` (seeded jitter) + the event's CRC-retry cost
    + the retransmission's wire time (re-metered through the link
    arbiter, so retries contend like real traffic).  Per-link: a
    ``link_retry_budget`` shared across transfers — once spent, the
    next transient error escalates to the failover path instead of
    retrying (the link is declared dead at the next fabric heartbeat).
    ``max_retries=0`` disables retries outright: the first transient
    error escalates.
    """

    max_retries: int = 4
    backoff_base_s: float = 2e-6
    backoff_multiplier: float = 2.0
    backoff_max_s: float = 1e-3
    #: symmetric jitter fraction applied to each backoff (seeded draw)
    jitter: float = 0.1
    #: total retries one link may spend before escalation; None = unbounded
    link_retry_budget: Optional[int] = 256

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_base_s < 0 or self.backoff_max_s < 0:
            raise ValueError("backoff times must be >= 0")
        if self.backoff_multiplier < 1.0:
            raise ValueError("backoff_multiplier must be >= 1")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError("jitter must be in [0, 1]")
        if (self.link_retry_budget is not None
                and self.link_retry_budget < 0):
            raise ValueError("link_retry_budget must be >= 0 or None")

    def backoff_s(self, attempt: int, u: float) -> float:
        """Backoff before retry ``attempt`` (0-based); ``u`` in [0, 1)
        supplies the jitter draw."""
        base = min(self.backoff_base_s * self.backoff_multiplier ** attempt,
                   self.backoff_max_s)
        return base * (1.0 + self.jitter * (2.0 * u - 1.0))


@dataclasses.dataclass
class _LinkFaultState:
    """Mutable per-expander fault state (windows expire passively)."""

    error_rate: float = 0.0
    error_until: float = 0.0
    crc_retry_cost_s: float = 0.0
    brownout_factor: float = 1.0
    brownout_until: float = 0.0
    retrain_until: float = 0.0
    budget_left: Optional[int] = None
    escalated: bool = False
    # counters
    transient_errors: int = 0
    retries: int = 0
    retry_bytes: int = 0
    retry_delay_s: float = 0.0
    brownout_delay_s: float = 0.0
    flap_delay_s: float = 0.0
    escalations: int = 0


class FaultInjector:
    """Executes a :class:`FaultPlan` against one ``FabricManager``.

    Attach with :meth:`FabricManager.attach_fault_injector`; the FM
    advances injector time from ``advance_links`` (the same virtual
    clock the link arbiters drain on) and consults
    :meth:`on_transfer` from ``meter_transfer``.  Scripted fail-stop /
    repair events call the FM's own ``inject_failure`` /
    ``readmit_expander``; budget-exhausted escalations are deferred to
    the next :meth:`advance` tick (the management-plane heartbeat), so
    a failover never fires mid-burst under a consumer's feet.
    """

    def __init__(self, plan: FaultPlan,
                 retry: RetryPolicy = RetryPolicy(),
                 seed: int = 0):
        self.plan = plan
        self.retry = retry
        self.seed = int(seed)
        self.now_s = 0.0
        self._events: List[FaultEvent] = list(plan.events)
        self._next_event = 0
        self._fm: Optional["FabricManager"] = None
        self._links: Dict[int, _LinkFaultState] = {}
        self._pending_escalation: List[int] = []
        self._xfer_count = 0

    # --------------------------------------------------------------- wiring
    def bind(self, fm: "FabricManager") -> None:
        if self._fm is not None and self._fm is not fm:
            raise LMBError("FaultInjector is already bound to a fabric")
        self._fm = fm

    def _state(self, expander_id: int) -> _LinkFaultState:
        st = self._links.get(expander_id)
        if st is None:
            st = _LinkFaultState(budget_left=self.retry.link_retry_budget)
            self._links[expander_id] = st
        return st

    def _targets(self, ev: FaultEvent) -> List[int]:
        fm = self._fm
        if ev.expander_id is not None:
            return [ev.expander_id]
        if ev.domain is not None:
            if fm.topology is None:
                raise LMBError(
                    f"fault event targets domain {ev.domain!r} but the "
                    "fabric has no topology")
            return [e for e in fm.topology.expanders_in_domain(ev.domain)
                    if e in fm.expander_ids]
        return list(fm.expander_ids)

    # ----------------------------------------------------------- time/plan
    def advance(self, dt_s: float) -> None:
        """Advance injector time with the fabric's link clock; fire due
        events and apply deferred escalations."""
        self.now_s += dt_s
        while (self._next_event < len(self._events)
               and self._events[self._next_event].t_s <= self.now_s):
            self._fire(self._events[self._next_event])
            self._next_event += 1
        if self._pending_escalation:
            pend, self._pending_escalation = self._pending_escalation, []
            for eid in pend:
                # idempotent: inject_failure no-ops (with a journal
                # entry) if a scripted fail_stop beat the escalation
                self._fm.inject_failure(eid)

    def _fire(self, ev: FaultEvent) -> None:
        tr = self._fm.tracer
        for eid in self._targets(ev):
            st = self._state(eid)
            if ev.kind == "transient":
                st.error_rate = ev.error_rate
                st.error_until = self.now_s + ev.duration_s
                st.crc_retry_cost_s = ev.crc_retry_cost_s
            elif ev.kind == "brownout":
                st.brownout_factor = ev.latency_factor
                st.brownout_until = self.now_s + ev.duration_s
            elif ev.kind == "link_flap":
                st.retrain_until = self.now_s + ev.retrain_s
            elif ev.kind == "fail_stop":
                self._fm.inject_failure(eid)
            elif ev.kind == "repair":
                self._fm.readmit_expander(eid)
                # repaired link comes back clean: windows closed, budget
                # refilled, escalation latch released
                self._links[eid] = _LinkFaultState(
                    budget_left=self.retry.link_retry_budget)
            if tr.enabled:
                tr.event(f"fault.{ev.kind}", op="fault", expander=eid,
                         t_s=ev.t_s, duration_s=ev.duration_s,
                         error_rate=ev.error_rate,
                         latency_factor=ev.latency_factor,
                         retrain_s=ev.retrain_s)

    # ------------------------------------------------------------ data path
    def _xfer_rng(self) -> np.random.Generator:
        """A per-transfer seeded substream: transfer *i*'s draws do not
        depend on how many draws earlier transfers consumed.  This is
        what makes retry time monotone in error rate (coupled uniforms)
        and keeps the zero-fault path RNG-free."""
        self._xfer_count += 1
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, self._xfer_count]))

    def on_transfer(self, device_id: str, expander_id: int, nbytes: int,
                    op: str, base_delay_s: float,
                    charge) -> Tuple[float, int]:
        """Perturb one metered transfer on ``expander_id``.

        Returns ``(extra_delay_s, retry_bytes)``: modeled time added on
        top of the base grant, and bytes retransmitted (the FM accrues
        them under the ``"retry"`` op class).  ``charge(nbytes)`` meters
        a retransmission through the link arbiter and returns its wire
        delay.  The no-active-fault path touches no RNG and returns
        ``(0.0, 0)``.
        """
        st = self._links.get(expander_id)
        if st is None:
            return 0.0, 0
        now = self.now_s
        extra = 0.0
        retry_bytes = 0
        if now < st.retrain_until:
            # link is retraining: the transfer queues until it is back up
            d = st.retrain_until - now
            st.flap_delay_s += d
            extra += d
        if now < st.brownout_until and st.brownout_factor > 1.0:
            d = base_delay_s * (st.brownout_factor - 1.0)
            st.brownout_delay_s += d
            extra += d
        if now < st.error_until and st.error_rate > 0.0:
            rng = self._xfer_rng()
            if float(rng.random()) < st.error_rate:
                d, retry_bytes = self._transient(
                    st, expander_id, device_id, nbytes, op, rng, charge)
                extra += d
        return extra, retry_bytes

    def _transient(self, st: _LinkFaultState, expander_id: int,
                   device_id: str, nbytes: int, op: str,
                   rng: np.random.Generator,
                   charge) -> Tuple[float, int]:
        """One transfer hit a CRC error: retry per policy, escalate on
        budget exhaustion.  Returns (extra_delay_s, retried_bytes)."""
        pol = self.retry
        st.transient_errors += 1
        extra = 0.0
        retry_bytes = 0
        recovered = False
        for attempt in range(pol.max_retries):
            if st.budget_left is not None and st.budget_left <= 0:
                break
            if st.budget_left is not None:
                st.budget_left -= 1
            st.retries += 1
            d = (pol.backoff_s(attempt, float(rng.random()))
                 + st.crc_retry_cost_s + charge(nbytes))
            st.retry_delay_s += d
            extra += d
            retry_bytes += nbytes
            st.retry_bytes += nbytes
            if float(rng.random()) >= st.error_rate:
                recovered = True
                break
        if not recovered:
            # link-level retry keeps the transfer alive while budget
            # remains (the cost is modeled above); escalation to the
            # fail-stop/failover path happens only once the link's retry
            # budget is spent — or immediately when retries are disabled
            budget_spent = (st.budget_left is not None
                            and st.budget_left <= 0)
            if pol.max_retries == 0 or budget_spent:
                self._escalate(st, expander_id)
        tr = self._fm.tracer
        if tr.enabled:
            tr.add("fault.transient", tr.now(), extra, op=op,
                   expander=expander_id, nbytes=nbytes, device=device_id,
                   retries=st.retries, recovered=recovered)
        return extra, retry_bytes

    def _escalate(self, st: _LinkFaultState, expander_id: int) -> None:
        """Retry budget exhausted (or retries disabled): hand the link
        to the failover path at the next management heartbeat."""
        if st.escalated:
            return
        st.escalated = True
        st.escalations += 1
        self._pending_escalation.append(expander_id)
        tr = self._fm.tracer
        if tr.enabled:
            tr.event("fault.escalate", op="fault", expander=expander_id,
                     budget_left=st.budget_left)

    # ---------------------------------------------------- placement ladder
    def brownout_active(self, expander_id: int) -> bool:
        st = self._links.get(expander_id)
        if st is None:
            return False
        return ((self.now_s < st.brownout_until
                 and st.brownout_factor > 1.0)
                or self.now_s < st.retrain_until)

    def degrade_view(self, expander_id: int, utilization: float) -> float:
        """Placement-view utilization through the fault lens: a
        browned-out (or retraining) expander reports a saturated link,
        so placement and migration steer new pages elsewhere for the
        window — rung two of the degradation ladder."""
        if self.brownout_active(expander_id):
            return max(utilization, BROWNOUT_VIEW_UTILIZATION)
        return utilization

    # ----------------------------------------------------------- telemetry
    def counters(self) -> Dict[str, float]:
        """Aggregate fault counters.  ``retry_bytes`` reconciles exactly
        with ``fm.op_bytes()["retry"]``."""
        agg = {"transient_errors": 0, "retries": 0, "retry_bytes": 0,
               "retry_delay_s": 0.0, "brownout_delay_s": 0.0,
               "flap_delay_s": 0.0, "escalations": 0}
        for st in self._links.values():
            agg["transient_errors"] += st.transient_errors
            agg["retries"] += st.retries
            agg["retry_bytes"] += st.retry_bytes
            agg["retry_delay_s"] += st.retry_delay_s
            agg["brownout_delay_s"] += st.brownout_delay_s
            agg["flap_delay_s"] += st.flap_delay_s
            agg["escalations"] += st.escalations
        return agg

    def snapshot(self) -> dict:
        return {
            "now_s": self.now_s,
            "events_fired": self._next_event,
            "events_total": len(self._events),
            "counters": self.counters(),
            "links": {
                eid: {
                    "error_active": self.now_s < st.error_until,
                    "brownout_active": self.brownout_active(eid),
                    "retraining": self.now_s < st.retrain_until,
                    "budget_left": st.budget_left,
                    "escalated": st.escalated,
                    "retries": st.retries,
                    "transient_errors": st.transient_errors,
                }
                for eid, st in sorted(self._links.items())
            },
        }
