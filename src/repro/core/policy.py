"""Eviction and prefetch policies for the LinkedBuffer.

The paper's §4.1.2 observes that hot-index locality "considerably dismisses"
the CXL latency penalty — these policies are what creates that locality: the
onboard tier is a cache over the linked tier, and the policy decides which
pages stay onboard.

Policies operate on opaque page keys; the LinkedBuffer calls:
    on_access(key)   every time a page is touched onboard
    victim()         when space is needed — returns the page to demote
    on_insert(key) / on_remove(key)
The Prefetcher issues lookahead hints (sequential and stride detection —
fio-style sequential workloads are the paper's best case).
"""

from __future__ import annotations

import abc
import dataclasses
from collections import OrderedDict, deque
from typing import Dict, Hashable, List, Optional, Sequence, Tuple


class EvictionPolicy(abc.ABC):
    @abc.abstractmethod
    def on_insert(self, key: Hashable) -> None: ...

    @abc.abstractmethod
    def on_access(self, key: Hashable) -> None: ...

    @abc.abstractmethod
    def on_remove(self, key: Hashable) -> None: ...

    @abc.abstractmethod
    def victim(self) -> Optional[Hashable]: ...

    def victims(self, k: int) -> List[Hashable]:
        """Up to ``k`` distinct eviction victims in ONE policy call — the
        bulk-eviction hook for batched faults.  The default reproduces
        ``k`` successive victim()/on_remove() selections without mutating
        residency bookkeeping (chosen keys are temporarily pinned so the
        next victim() pick skips them); policies with cheap ordered state
        may override with a direct scan."""
        chosen: List[Hashable] = []
        pinned = self._pinned()
        try:
            for _ in range(max(k, 0)):
                v = self.victim()
                if v is None:
                    break
                chosen.append(v)
                pinned.add(v)
        finally:
            for v in chosen:
                pinned.discard(v)
        return chosen

    def pin(self, key: Hashable) -> None:
        self._pinned().add(key)

    def unpin(self, key: Hashable) -> None:
        self._pinned().discard(key)

    def _pinned(self) -> set:
        if not hasattr(self, "_pins"):
            self._pins = set()
        return self._pins


class LRU(EvictionPolicy):
    def __init__(self) -> None:
        self._order: "OrderedDict[Hashable, None]" = OrderedDict()

    def on_insert(self, key: Hashable) -> None:
        self._order[key] = None
        self._order.move_to_end(key)

    def on_access(self, key: Hashable) -> None:
        if key in self._order:
            self._order.move_to_end(key)

    def on_remove(self, key: Hashable) -> None:
        self._order.pop(key, None)

    def victim(self) -> Optional[Hashable]:
        for key in self._order:
            if key not in self._pinned():
                return key
        return None

    def victims(self, k: int) -> List[Hashable]:
        # one ordered scan = the first k unpinned keys, exactly what k
        # successive victim()+on_remove() rounds would pick
        pinned = self._pinned()
        out: List[Hashable] = []
        for key in self._order:
            if len(out) >= k:
                break
            if key not in pinned:
                out.append(key)
        return out


class Clock(EvictionPolicy):
    """Second-chance CLOCK — cheaper bookkeeping than strict LRU; what an
    actual firmware/kernel implementation would use."""

    def __init__(self) -> None:
        self._ref: Dict[Hashable, bool] = {}
        self._ring: List[Hashable] = []
        self._hand = 0

    def on_insert(self, key: Hashable) -> None:
        if key not in self._ref:
            self._ring.append(key)
        self._ref[key] = True

    def on_access(self, key: Hashable) -> None:
        if key in self._ref:
            self._ref[key] = True

    def on_remove(self, key: Hashable) -> None:
        if key in self._ref:
            del self._ref[key]
            idx = self._ring.index(key)
            self._ring.pop(idx)
            if idx < self._hand:
                self._hand -= 1
            if self._ring:
                self._hand %= len(self._ring)
            else:
                self._hand = 0

    def victim(self) -> Optional[Hashable]:
        if not self._ring:
            return None
        scanned = 0
        # two sweeps max: first clears ref bits, second must find a victim
        while scanned < 2 * len(self._ring):
            key = self._ring[self._hand]
            self._hand = (self._hand + 1) % len(self._ring)
            scanned += 1
            if key in self._pinned():
                continue
            if self._ref.get(key, False):
                self._ref[key] = False
            else:
                return key
        # everything pinned or referenced: pick first unpinned
        for key in self._ring:
            if key not in self._pinned():
                return key
        return None


class CostAwareLRU(LRU):
    """LRU weighted by refetch cost: pages that are cheap to refetch (clean,
    small) are preferred victims over dirty pages that must be written back
    first.  TPU adaptation detail: a dirty page costs a D2H *and* a later H2D.
    """

    def __init__(self) -> None:
        super().__init__()
        self._dirty: set = set()

    def mark_dirty(self, key: Hashable, dirty: bool = True) -> None:
        (self._dirty.add if dirty else self._dirty.discard)(key)

    def on_remove(self, key: Hashable) -> None:
        super().on_remove(key)
        self._dirty.discard(key)

    def victim(self) -> Optional[Hashable]:
        # prefer the least-recent CLEAN page; fall back to LRU order
        for key in self._order:
            if key in self._pinned():
                continue
            if key not in self._dirty:
                return key
        return super().victim()

    def victims(self, k: int) -> List[Hashable]:
        # clean pages in LRU order first, then dirty — the order k
        # successive victim() rounds would produce
        pinned = self._pinned()
        clean: List[Hashable] = []
        dirty: List[Hashable] = []
        for key in self._order:
            if key in pinned:
                continue
            (dirty if key in self._dirty else clean).append(key)
        return (clean + dirty)[:k]


def make_policy(name: str) -> EvictionPolicy:
    return {"lru": LRU, "clock": Clock, "cost": CostAwareLRU}[name]()


@dataclasses.dataclass(frozen=True)
class PrefetchRun:
    """One chunk-aligned run of pages to move as a single coalesced
    burst.  ``source`` records how the run was predicted: ``scheduled``
    (exact future knowledge from a scheduler) outranks ``stride``
    (heuristic extrapolation) at admission time."""

    pages: Tuple[int, ...]
    source: str                  # "scheduled" | "stride"

    @property
    def npages(self) -> int:
        return len(self.pages)


class Prefetcher:
    """Burst-native sequential/stride prefetcher over page indices.

    ``observe`` consumes the access stream (stride detection: confidence
    builds on repeated strides, resets on a change, fires at >= 2,
    saturates at 4 — pinned by a regression test); ``schedule`` takes
    exact future knowledge from a scheduler, which always takes priority
    over stride guesses.

    The consumer-facing surface is :meth:`suggest_runs`: up to ``depth``
    pages per round, emitted as chunk-aligned :class:`PrefetchRun`\\ s so
    every prefetch burst rides the coalesced data path (one transfer +
    one link charge per run) instead of page-at-a-time moves.  The
    legacy :meth:`suggest` flat view remains for callers that predate
    the run API.

    Backlog discipline (the scheduled queue is a deque, not an
    unbounded list):

      * capped at ``backlog_factor * depth`` pages — overflow drops the
        OLDEST hints (they are the ones demand is about to overtake);
      * a scheduled page that gets demand-faulted first is dropped
        lazily (``observe`` marks it stale; the pop skips it) instead
        of being prefetched after the fact;
      * runs the overlap scheduler could not fit behind compute are
        ``defer``-ed back to the FRONT of the queue, preserving order —
        deferred exact knowledge is re-issued next round, never lost.
    """

    def __init__(self, depth: int = 4, backlog_factor: int = 8):
        self.depth = depth
        self.backlog = max(int(backlog_factor) * max(depth, 1), 1)
        self._last: Optional[int] = None
        self._stride: Optional[int] = None
        self._confidence = 0
        self._scheduled: deque[int] = deque()
        self._backlogged: set = set()    # members of _scheduled
        self._stale: set = set()         # demand-faulted before issue
        self.dropped_overflow = 0
        self.dropped_stale = 0

    # ---------------------------------------------------------- scheduling
    def schedule(self, pages: Sequence[int]) -> None:
        """Exact future knowledge from the scheduler (takes priority).
        Duplicates already backlogged are ignored; overflow beyond the
        backlog cap sheds the OLDEST entries."""
        for p in pages:
            if p in self._backlogged:
                self._stale.discard(p)   # re-scheduled: live again
                continue
            self._scheduled.append(p)
            self._backlogged.add(p)
        while len(self._scheduled) > self.backlog:
            old = self._scheduled.popleft()
            self._backlogged.discard(old)
            self._stale.discard(old)
            self.dropped_overflow += 1

    def defer(self, pages: Sequence[int]) -> None:
        """Re-queue pages an admission decision could not issue this
        round, at the FRONT (they keep their priority next round)."""
        fresh = [p for p in pages if p not in self._backlogged]
        self._scheduled.extendleft(reversed(fresh))
        self._backlogged.update(fresh)

    def pending(self) -> int:
        """Backlogged scheduled pages still waiting to be issued."""
        return len(self._scheduled)

    # ------------------------------------------------------------- stream
    def observe(self, page: int) -> None:
        """Consume one access.  Also invalidates a backlogged hint for
        this page: demand beat the prefetch, so issuing it later would
        move bytes nobody is waiting for."""
        if page in self._backlogged:
            self._stale.add(page)
        if self._last is not None:
            stride = page - self._last
            if stride != 0:
                if stride == self._stride:
                    self._confidence = min(self._confidence + 1, 4)
                else:
                    self._stride = stride
                    self._confidence = 1
        self._last = page

    # ---------------------------------------------------------- suggestion
    def _pop_scheduled(self, max_page: int, budget: int) -> List[int]:
        """Up to ``budget`` live scheduled pages, FIFO, stale-skipped."""
        out: List[int] = []
        while self._scheduled and len(out) < budget:
            p = self._scheduled.popleft()
            self._backlogged.discard(p)
            if p in self._stale:
                self._stale.discard(p)
                self.dropped_stale += 1
                continue
            if 0 <= p <= max_page and p not in out:
                out.append(p)
        return out

    def _stride_guesses(self, max_page: int, budget: int) -> List[int]:
        if budget <= 0 or self._confidence < 2 or not self._stride \
                or self._last is None:
            return []
        out: List[int] = []
        nxt = self._last
        for _ in range(budget):
            nxt += self._stride
            if 0 <= nxt <= max_page:
                out.append(nxt)
        return out

    @staticmethod
    def _group_runs(pages: Sequence[int], run_pages: int,
                    source: str) -> List[PrefetchRun]:
        """Group pages into chunk-aligned runs (same ``page // run_pages``
        extent), preserving first-seen order of the extents."""
        runs: "OrderedDict[int, List[int]]" = OrderedDict()
        for p in pages:
            runs.setdefault(p // run_pages, []).append(p)
        return [PrefetchRun(tuple(ps), source) for ps in runs.values()]

    def suggest_runs(self, max_page: int,
                     run_pages: int = 1) -> List[PrefetchRun]:
        """Up to ``depth`` predicted pages as chunk-aligned runs.

        Scheduled pages are consumed first (and grouped per ``run_pages``
        extent — the LinkedBuffer passes its LMB chunk size so each run
        maps to one (chunk, expander) burst); any remaining budget is
        filled by promoting the stride detector to a run extent: the
        next ``depth`` strides ahead of the last access, grouped the
        same way.
        """
        run_pages = max(run_pages, 1)
        taken = self._pop_scheduled(max_page, self.depth)
        runs = self._group_runs(taken, run_pages, "scheduled")
        guesses = [g for g in
                   self._stride_guesses(max_page, self.depth - len(taken))
                   if g not in taken]
        runs.extend(self._group_runs(guesses, run_pages, "stride"))
        return runs

    def suggest(self, max_page: int) -> List[int]:
        """Legacy flat view of :meth:`suggest_runs` (single-page grain)."""
        return [p for run in self.suggest_runs(max_page)
                for p in run.pages]
