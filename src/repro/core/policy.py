"""Eviction and prefetch policies for the LinkedBuffer.

The paper's §4.1.2 observes that hot-index locality "considerably dismisses"
the CXL latency penalty — these policies are what creates that locality: the
onboard tier is a cache over the linked tier, and the policy decides which
pages stay onboard.

Policies operate on opaque page keys; the LinkedBuffer calls:
    on_access(key)   every time a page is touched onboard
    victim()         when space is needed — returns the page to demote
    on_insert(key) / on_remove(key)
The Prefetcher issues lookahead hints (sequential and stride detection —
fio-style sequential workloads are the paper's best case).
"""

from __future__ import annotations

import abc
from collections import OrderedDict
from typing import Dict, Hashable, List, Optional


class EvictionPolicy(abc.ABC):
    @abc.abstractmethod
    def on_insert(self, key: Hashable) -> None: ...

    @abc.abstractmethod
    def on_access(self, key: Hashable) -> None: ...

    @abc.abstractmethod
    def on_remove(self, key: Hashable) -> None: ...

    @abc.abstractmethod
    def victim(self) -> Optional[Hashable]: ...

    def victims(self, k: int) -> List[Hashable]:
        """Up to ``k`` distinct eviction victims in ONE policy call — the
        bulk-eviction hook for batched faults.  The default reproduces
        ``k`` successive victim()/on_remove() selections without mutating
        residency bookkeeping (chosen keys are temporarily pinned so the
        next victim() pick skips them); policies with cheap ordered state
        may override with a direct scan."""
        chosen: List[Hashable] = []
        pinned = self._pinned()
        try:
            for _ in range(max(k, 0)):
                v = self.victim()
                if v is None:
                    break
                chosen.append(v)
                pinned.add(v)
        finally:
            for v in chosen:
                pinned.discard(v)
        return chosen

    def pin(self, key: Hashable) -> None:
        self._pinned().add(key)

    def unpin(self, key: Hashable) -> None:
        self._pinned().discard(key)

    def _pinned(self) -> set:
        if not hasattr(self, "_pins"):
            self._pins = set()
        return self._pins


class LRU(EvictionPolicy):
    def __init__(self) -> None:
        self._order: "OrderedDict[Hashable, None]" = OrderedDict()

    def on_insert(self, key: Hashable) -> None:
        self._order[key] = None
        self._order.move_to_end(key)

    def on_access(self, key: Hashable) -> None:
        if key in self._order:
            self._order.move_to_end(key)

    def on_remove(self, key: Hashable) -> None:
        self._order.pop(key, None)

    def victim(self) -> Optional[Hashable]:
        for key in self._order:
            if key not in self._pinned():
                return key
        return None

    def victims(self, k: int) -> List[Hashable]:
        # one ordered scan = the first k unpinned keys, exactly what k
        # successive victim()+on_remove() rounds would pick
        pinned = self._pinned()
        out: List[Hashable] = []
        for key in self._order:
            if len(out) >= k:
                break
            if key not in pinned:
                out.append(key)
        return out


class Clock(EvictionPolicy):
    """Second-chance CLOCK — cheaper bookkeeping than strict LRU; what an
    actual firmware/kernel implementation would use."""

    def __init__(self) -> None:
        self._ref: Dict[Hashable, bool] = {}
        self._ring: List[Hashable] = []
        self._hand = 0

    def on_insert(self, key: Hashable) -> None:
        if key not in self._ref:
            self._ring.append(key)
        self._ref[key] = True

    def on_access(self, key: Hashable) -> None:
        if key in self._ref:
            self._ref[key] = True

    def on_remove(self, key: Hashable) -> None:
        if key in self._ref:
            del self._ref[key]
            idx = self._ring.index(key)
            self._ring.pop(idx)
            if idx < self._hand:
                self._hand -= 1
            if self._ring:
                self._hand %= len(self._ring)
            else:
                self._hand = 0

    def victim(self) -> Optional[Hashable]:
        if not self._ring:
            return None
        scanned = 0
        # two sweeps max: first clears ref bits, second must find a victim
        while scanned < 2 * len(self._ring):
            key = self._ring[self._hand]
            self._hand = (self._hand + 1) % len(self._ring)
            scanned += 1
            if key in self._pinned():
                continue
            if self._ref.get(key, False):
                self._ref[key] = False
            else:
                return key
        # everything pinned or referenced: pick first unpinned
        for key in self._ring:
            if key not in self._pinned():
                return key
        return None


class CostAwareLRU(LRU):
    """LRU weighted by refetch cost: pages that are cheap to refetch (clean,
    small) are preferred victims over dirty pages that must be written back
    first.  TPU adaptation detail: a dirty page costs a D2H *and* a later H2D.
    """

    def __init__(self) -> None:
        super().__init__()
        self._dirty: set = set()

    def mark_dirty(self, key: Hashable, dirty: bool = True) -> None:
        (self._dirty.add if dirty else self._dirty.discard)(key)

    def on_remove(self, key: Hashable) -> None:
        super().on_remove(key)
        self._dirty.discard(key)

    def victim(self) -> Optional[Hashable]:
        # prefer the least-recent CLEAN page; fall back to LRU order
        for key in self._order:
            if key in self._pinned():
                continue
            if key not in self._dirty:
                return key
        return super().victim()

    def victims(self, k: int) -> List[Hashable]:
        # clean pages in LRU order first, then dirty — the order k
        # successive victim() rounds would produce
        pinned = self._pinned()
        clean: List[Hashable] = []
        dirty: List[Hashable] = []
        for key in self._order:
            if key in pinned:
                continue
            (dirty if key in self._dirty else clean).append(key)
        return (clean + dirty)[:k]


def make_policy(name: str) -> EvictionPolicy:
    return {"lru": LRU, "clock": Clock, "cost": CostAwareLRU}[name]()


class Prefetcher:
    """Sequential/stride prefetcher over page indices.

    ``observe`` consumes the access stream; ``suggest`` returns up to
    ``depth`` page indices predicted next.  Matches the paper's observation
    that sequential fio workloads are the friendly case; on TPU the serving
    engine also feeds *scheduled* future accesses (next decode step's pages),
    which take priority over the heuristic stream.
    """

    def __init__(self, depth: int = 4):
        self.depth = depth
        self._last: Optional[int] = None
        self._stride: Optional[int] = None
        self._confidence = 0
        self._scheduled: List[int] = []

    def schedule(self, pages: List[int]) -> None:
        """Exact future knowledge from the scheduler (takes priority)."""
        self._scheduled.extend(pages)

    def observe(self, page: int) -> None:
        if self._last is not None:
            stride = page - self._last
            if stride != 0:
                if stride == self._stride:
                    self._confidence = min(self._confidence + 1, 4)
                else:
                    self._stride = stride
                    self._confidence = 1
        self._last = page

    def suggest(self, max_page: int) -> List[int]:
        out: List[int] = []
        while self._scheduled and len(out) < self.depth:
            p = self._scheduled.pop(0)
            if 0 <= p <= max_page:
                out.append(p)
        if (len(out) < self.depth and self._confidence >= 2
                and self._last is not None and self._stride):
            nxt = self._last
            for _ in range(self.depth - len(out)):
                nxt += self._stride
                if 0 <= nxt <= max_page:
                    out.append(nxt)
        return out
