"""Fabric Manager (FM) and access control (SAT / IOMMU) for LMB.

The FM "controls aspects of the system related to binding and management of
pooled ports and devices" (paper Table 1).  Here it:

  * owns a **pooled set of Expanders** (GFDs) and grants/releases 256 MB
    blocks, tracking which expander backs each block (block→expander
    placement) and arbitrating each expander's link independently,
  * maintains the **SAT** (SPID Access Table) authorizing CXL devices, and
    IOMMU-style per-PCIe-device mapping tables,
  * supports **dynamic capacity**: per-host quotas that can be raised or
    lowered at runtime (CXL DCD semantics),
  * supports **failure injection + recovery** — the paper calls out that "a
    single failure in the memory expander can render all devices unavailable";
    we journal every grant so that consumers can rebuild after fail-over to a
    spare expander (or onto the surviving pooled expanders),
  * keeps an **allocation journal** that makes the pool reconstructible
    (needed by the training checkpoint/restore path); hot-page migrations
    (repro.qos.migration) are journaled the same way DCD capacity events are.
"""

from __future__ import annotations

import dataclasses
import enum
import threading
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional, Sequence,
                    Set, Tuple, Union)

if TYPE_CHECKING:  # rack sits above core in the layering; annotation only
    from repro.core.faults import FaultInjector
    from repro.rack.topology import PathCost, RackTopology

from repro.core.placement import (ExpanderView, PlacementPolicy,
                                  PlacementRequest, make_placement_policy)
from repro.core.pool import (BLOCK_BYTES, BlockGrant, Expander,
                             InvalidHandle, LMBError, MediaKind,
                             OutOfMemory)
from repro.obs.trace import GLOBAL_TRACER, SpanTracer
from repro.qos.arbiter import LinkArbiter, TransferGrant

#: default per-expander link bandwidth (matches the LMB_CXL tier's 30 GB/s)
DEFAULT_LINK_BW_Bps = 30e9


class DeviceClass(enum.Enum):
    PCIE = "pcie"   # host-forwarded path; isolation via IOMMU tables
    CXL = "cxl"     # P2P path; isolation via SPID Access Table (SAT)


@dataclasses.dataclass(frozen=True)
class DeviceInfo:
    device_id: str
    device_class: DeviceClass
    #: Source PBR ID for CXL devices (paper Table 1); None for PCIe devices
    spid: Optional[int] = None
    #: weighted-fair share of the expander link (repro.qos.arbiter)
    bw_weight: float = 1.0
    #: token-bucket burst allowance on the link; 0 = no burst credit
    bw_burst_bytes: int = 0
    #: tenant this device belongs to — placement policies (e.g.
    #: tenant-affinity) and per-tenant QoS key on it; None = untenanted
    tenant: Optional[str] = None


class AccessDenied(LMBError):
    pass


class SAT:
    """SPID Access Table: (spid → set of block_ids it may touch).

    Matches the paper's GFD access control: "GFD can identify the CXL device
    or host that initiates the request according to the SPID field"; entries
    are updated on alloc/free/share via the GFD Component Management Command
    Set.
    """

    def __init__(self) -> None:
        self._table: Dict[int, Set[int]] = {}

    def add(self, spid: int, block_id: int) -> None:
        self._table.setdefault(spid, set()).add(block_id)

    def remove(self, spid: int, block_id: int) -> None:
        self._table.get(spid, set()).discard(block_id)

    def check(self, spid: int, block_id: int) -> bool:
        return block_id in self._table.get(spid, set())

    def purge_block(self, block_id: int) -> None:
        """Drop every SPID's authorization for a block that no longer
        exists (failover re-grant of a dead expander's block)."""
        for spids in self._table.values():
            spids.discard(block_id)

    def entries(self) -> Dict[int, Set[int]]:
        return {k: set(v) for k, v in self._table.items()}


class IOMMUTable:
    """Per-PCIe-device allowed (block_id, page range) mappings.

    Models the kernel module creating IOMMU page tables for allocated memory
    (paper §3.3).  Granularity is the allocator page.
    """

    def __init__(self) -> None:
        # device_id -> block_id -> set of page indices
        self._maps: Dict[str, Dict[int, Set[int]]] = {}

    def map(self, device_id: str, block_id: int, page_start: int,
            npages: int) -> None:
        pages = self._maps.setdefault(device_id, {}).setdefault(
            block_id, set())
        pages.update(range(page_start, page_start + npages))

    def unmap(self, device_id: str, block_id: int, page_start: int,
              npages: int) -> None:
        pages = self._maps.get(device_id, {}).get(block_id)
        if pages:
            pages.difference_update(range(page_start, page_start + npages))

    def check(self, device_id: str, block_id: int, page: int) -> bool:
        return page in self._maps.get(device_id, {}).get(block_id, set())

    def purge_block(self, block_id: int) -> None:
        """Drop every device's mappings into a block that no longer
        exists (failover re-grant of a dead expander's block)."""
        for blocks in self._maps.values():
            blocks.pop(block_id, None)

    def mapped_pages(self, device_id: str) -> int:
        return sum(len(p) for p in self._maps.get(device_id, {}).values())


@dataclasses.dataclass
class JournalEntry:
    op: str                    # "grant" | "release" | "bind" | "fail" | ...
    host_id: str
    block_id: Optional[int] = None
    detail: str = ""


class FabricManager:
    """FM: binds hosts/devices to pooled expander capacity; single control
    point.

    ``expander`` may be one :class:`Expander` (the paper's single-GFD setup)
    or a sequence of them (pooled multi-expander fabric).  Each expander has
    its own CXL link, arbitrated by its own :class:`LinkArbiter`; block
    grants record which expander backs them so the data path charges the
    right link and hot-page migration can rebalance placement.

    ``topology`` (optional) places the pool behind a switched rack fabric
    (:class:`repro.rack.topology.RackTopology`): every pooled expander must
    be attached in it, each expander's arbiter is sized to ITS port
    bandwidth, placement policies see per-host path latencies and failure
    domains, and :meth:`inject_domain_failure` can take out a whole
    switch/power domain at once.  Without one, behaviour is exactly the
    pre-topology direct-attach model.
    """

    def __init__(self, expander: Union[Expander, Sequence[Expander]],
                 spare: Optional[Expander] = None,
                 link_bandwidth_Bps: float = DEFAULT_LINK_BW_Bps,
                 placement: Union[str, PlacementPolicy, None] = None,
                 topology: Optional["RackTopology"] = None):
        self._lock = threading.RLock()
        #: block→expander placement policy (repro.core.placement);
        #: injected via SystemSpec, defaults to least-loaded
        self._placement: PlacementPolicy = make_placement_policy(placement)
        exps = (list(expander) if isinstance(expander, (list, tuple))
                else [expander])
        if not exps:
            raise ValueError("at least one expander required")
        ids = [e.expander_id for e in exps]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate expander ids: {ids}")
        self.topology = topology
        if topology is not None:
            known = set(topology.expander_ids)
            missing = [i for i in ids if i not in known]
            if missing:
                raise ValueError(
                    f"expanders {missing} not attached in topology")
        self._link_bandwidth_Bps = float(link_bandwidth_Bps)
        self._expanders: Dict[int, Expander] = {
            e.expander_id: e for e in exps}
        self._arbiters: Dict[int, LinkArbiter] = {
            eid: LinkArbiter(self._port_bw(eid)) for eid in self._expanders}
        self._spare = spare
        if spare is not None and spare.expander_id in self._expanders:
            # standby joins the pool on promotion; give it a free id now
            # (refuses if the spare already granted blocks)
            spare.renumber(max(self._expanders) + 1)
        self._hosts: Dict[str, int] = {}       # host_id -> quota bytes
        self._devices: Dict[str, DeviceInfo] = {}
        self._granted: Dict[str, List[BlockGrant]] = {}
        self._block_home: Dict[int, int] = {}  # block_id -> expander_id
        self.sat = SAT()
        self.iommu = IOMMUTable()
        self.journal: List[JournalEntry] = []
        self._failover_listeners: List[Callable[[int], None]] = []
        self._repair_listeners: List[Callable[[int], None]] = []
        #: chaos layer (repro.core.faults), attached via
        #: attach_fault_injector; None = no fault perturbation at all
        self.fault_injector: Optional["FaultInjector"] = None
        #: bytes metered per traffic class ("demand" | "prefetch" | ...):
        #: lets consumers prove prefetch traffic is tagged and bounded
        self._op_bytes: Dict[str, int] = {}
        #: span tracer — every metered transfer emits one "link.xfer"
        #: span here (the single point where op class, expander, tenant
        #: and the modeled link delay are all known), which is what
        #: makes trace-derived byte totals reconcile with op_bytes().
        #: Defaults to the (disabled) global tracer; LMBSystem swaps in
        #: a private one when SystemSpec.obs.trace is set.
        self.tracer: SpanTracer = GLOBAL_TRACER

    # -- expander set --------------------------------------------------------
    def _port_bw(self, expander_id: int) -> float:
        """An expander's link bandwidth: its topology port when racked,
        else the uniform fabric default (also spares promoted from
        outside the topology)."""
        if self.topology is not None:
            try:
                return self.topology.port_bandwidth_Bps(expander_id)
            except Exception:
                pass
        return self._link_bandwidth_Bps

    def path_cost(self, host_id: str, expander_id: int) -> "PathCost":
        """Fabric cost of ``host_id`` reaching ``expander_id``.  Without
        a topology (or for hosts/expanders outside it) this is the
        direct-attach degenerate cost: 1 hop, zero latency, the
        expander's link bandwidth."""
        from repro.rack.topology import PathCost, TopologyError
        if self.topology is not None:
            try:
                return self.topology.path(host_id, expander_id)
            except TopologyError:
                pass
        return PathCost(hops=1, latency_s=0.0,
                        bandwidth_Bps=self._port_bw(expander_id))

    def domain_of(self, expander_id: int) -> Optional[str]:
        """The expander's correlated failure domain, None when no
        topology is configured (direct attach has no shared domains)."""
        if self.topology is None:
            return None
        try:
            return self.topology.domain_of(expander_id)
        except Exception:
            return None

    @property
    def expander_ids(self) -> List[int]:
        return list(self._expanders)

    @property
    def arbiter(self) -> LinkArbiter:
        """The first HEALTHY expander's link arbiter (single-expander
        back-compat; also the metering fallback when a transfer can't be
        attributed to a block) — a dead expander's frozen arbiter would
        swallow traffic invisibly."""
        healthy = self._healthy_expanders()
        eid = (healthy[0].expander_id if healthy
               else next(iter(self._expanders)))
        return self._arbiters[eid]

    def _healthy_expanders(self) -> List[Expander]:
        return [e for e in self._expanders.values() if not e.failed]

    def expander_of(self, block_id: int) -> int:
        eid = self._block_home.get(block_id)
        if eid is None:
            raise InvalidHandle(f"block {block_id} has no home expander")
        return eid

    def _views(self, media: MediaKind,
               exclude: Sequence[int] = (),
               require_room: bool = True,
               host_id: Optional[str] = None) -> List[ExpanderView]:
        """Candidate expanders as the placement policy sees them: healthy,
        not excluded, and (unless ``require_room`` is off) with at least
        one free block of ``media``.  With a topology, each view carries
        the requesting host's path latency (0.0 for hosts outside the
        topology) and the expander's failure domain, which is what makes
        the pool-aware policy prefer near capacity.  With a fault
        injector attached, browned-out/retraining expanders report a
        saturated link so placement (and migration targets, which
        delegate here) avoid them for the window."""
        inj = self.fault_injector
        return [ExpanderView(
                    expander_id=e.expander_id,
                    free_bytes=e.free_bytes(media),
                    utilization=(
                        self._arbiters[e.expander_id].utilization()
                        if inj is None else inj.degrade_view(
                            e.expander_id,
                            self._arbiters[e.expander_id].utilization())),
                    path_latency_s=(
                        self.path_cost(host_id, e.expander_id).latency_s
                        if host_id is not None and self.topology is not None
                        else 0.0),
                    domain=self.domain_of(e.expander_id))
                for e in self._healthy_expanders()
                if e.expander_id not in exclude
                and (not require_room
                     or e.free_bytes(media) >= BLOCK_BYTES)]

    def _request_for(self, media: MediaKind, host_id: Optional[str] = None,
                     device_id: Optional[str] = None) -> PlacementRequest:
        info = self._devices.get(device_id) if device_id else None
        return PlacementRequest(media=media, host_id=host_id,
                                device_id=device_id,
                                tenant=info.tenant if info else None)

    def _pick_expander(self, media: MediaKind,
                       expander_id: Optional[int] = None,
                       host_id: Optional[str] = None,
                       device_id: Optional[str] = None) -> Expander:
        """Block placement: requested expander, else whatever the injected
        placement policy picks from the healthy-with-room candidates."""
        if expander_id is not None:
            exp = self._expanders.get(expander_id)
            if exp is None:
                raise InvalidHandle(f"unknown expander {expander_id}")
            if exp.failed:
                raise LMBError(f"expander {expander_id} failed")
            return exp
        healthy = self._healthy_expanders()
        if not healthy:
            raise LMBError("no healthy expander in the pool")
        eid = self._placement.choose(
            self._request_for(media, host_id, device_id),
            self._views(media, host_id=host_id))
        exp = self._expanders.get(eid) if eid is not None else None
        if exp is None or exp.failed:
            return healthy[0]               # let grant_block raise OOM
        return exp

    # -- binding -------------------------------------------------------------
    def bind_host(self, host_id: str, quota_bytes: Optional[int] = None) -> None:
        """Bind a host (idempotent).  Re-binding an already-bound host is
        a no-op unless an explicit quota is given, in which case it acts
        like :meth:`set_quota` — it never silently resets a configured
        quota back to the pool total."""
        with self._lock:
            if host_id in self._hosts:
                if (quota_bytes is not None
                        and quota_bytes != self._hosts[host_id]):
                    self.set_quota(host_id, quota_bytes)
                return
            quota = (quota_bytes if quota_bytes is not None
                     else self.total_bytes)
            self._hosts[host_id] = quota
            self._granted.setdefault(host_id, [])
            self.journal.append(JournalEntry("bind", host_id))

    @property
    def total_bytes(self) -> int:
        return sum(e.total_bytes for e in self._expanders.values())

    def set_quota(self, host_id: str, quota_bytes: int) -> None:
        """Dynamic capacity (DCD): change a host's allowance at runtime."""
        with self._lock:
            if host_id not in self._hosts:
                raise InvalidHandle(f"host {host_id} not bound")
            self._hosts[host_id] = quota_bytes
            self.journal.append(
                JournalEntry("quota", host_id, detail=str(quota_bytes)))

    def register_device(self, info: DeviceInfo) -> None:
        with self._lock:
            if info.device_class is DeviceClass.CXL and info.spid is None:
                raise ValueError("CXL device needs an SPID")
            self._devices[info.device_id] = info
            for arb in self._arbiters.values():
                arb.register(info.device_id, weight=info.bw_weight,
                             burst_bytes=info.bw_burst_bytes)

    def device(self, device_id: str) -> DeviceInfo:
        info = self._devices.get(device_id)
        if info is None:
            raise InvalidHandle(f"device {device_id} not registered")
        return info

    # -- block grant/release (called by host BlockAllocators) ----------------
    def request_block(self, host_id: str,
                      media: MediaKind = MediaKind.DRAM,
                      expander_id: Optional[int] = None,
                      device_id: Optional[str] = None) -> BlockGrant:
        with self._lock:
            if host_id not in self._hosts:
                raise InvalidHandle(f"host {host_id} not bound")
            held = len(self._granted[host_id]) * BLOCK_BYTES
            if held + BLOCK_BYTES > self._hosts[host_id]:
                raise OutOfMemory(
                    f"host {host_id} quota exceeded "
                    f"({held + BLOCK_BYTES} > {self._hosts[host_id]})")
            exp = self._pick_expander(media, expander_id,
                                      host_id=host_id, device_id=device_id)
            grant = exp.grant_block(host_id, media)
            self._granted[host_id].append(grant)
            self._block_home[grant.block_id] = exp.expander_id
            self.journal.append(
                JournalEntry("grant", host_id, grant.block_id,
                             detail=f"expander={exp.expander_id}"))
            return grant

    def return_block(self, host_id: str, block_id: int) -> None:
        with self._lock:
            grants = self._granted.get(host_id, [])
            for i, g in enumerate(grants):
                if g.block_id == block_id:
                    grants.pop(i)
                    eid = self._block_home.pop(block_id, None)
                    exp = self._expanders.get(eid)
                    if exp is not None and not exp.failed:
                        exp.release_block(block_id)
                    self.journal.append(
                        JournalEntry("release", host_id, block_id))
                    return
            raise InvalidHandle(
                f"host {host_id} does not hold block {block_id}")

    def held_bytes(self, host_id: str) -> int:
        with self._lock:
            return len(self._granted.get(host_id, [])) * BLOCK_BYTES

    def held_grants(self, host_id: str) -> List[BlockGrant]:
        """The host's live block grants (failover replacements included) —
        lets a host allocator reconcile after a re-grant."""
        with self._lock:
            return list(self._granted.get(host_id, []))

    def healthy_expander_ids(self) -> List[int]:
        return [e.expander_id for e in self._healthy_expanders()]

    # -- bandwidth quotas (the DCD analogue for the shared links) -------------
    def set_bw_share(self, device_id: str, weight: float,
                     burst_bytes: Optional[int] = None) -> None:
        """Grant/revoke link-bandwidth share at runtime, like set_quota does
        for capacity.  Weight is relative (weighted-fair), so 'revoking'
        is lowering a weight — the links themselves are never left idle.
        Applied to every expander's arbiter in the pool."""
        with self._lock:
            info = self.device(device_id)
            self._devices[device_id] = dataclasses.replace(
                info, bw_weight=weight,
                bw_burst_bytes=(info.bw_burst_bytes if burst_bytes is None
                                else burst_bytes))
            for arb in self._arbiters.values():
                arb.register(
                    device_id, weight=weight,
                    burst_bytes=self._devices[device_id].bw_burst_bytes)
            self.journal.append(
                JournalEntry("bw_share", device_id, detail=str(weight)))

    def meter_transfer(self, device_id: str, nbytes: int,
                       block_id: Optional[int] = None,
                       op: str = "demand") -> TransferGrant:
        """Charge a data-path transfer against the device's link share on
        the expander backing ``block_id`` (first expander when unknown).

        ``op`` classes the traffic ("demand" faults/evictions vs
        "prefetch" bursts); per-class byte totals are kept in
        :meth:`op_bytes`.  Hot path (every LinkedBuffer demote/fault):
        deliberately not journaled — aggregate occupancy lives in the
        arbiter snapshots — but non-demand classes (prefetch, already-
        coalesced bursts at scheduler cadence) ARE journaled, like
        migration traffic."""
        info = self.device(device_id)  # InvalidHandle on unknown devices
        with self._lock:
            self._op_bytes[op] = self._op_bytes.get(op, 0) + nbytes
            if op != "demand":
                self.journal.append(JournalEntry(
                    op, device_id, block_id=block_id, detail=f"{nbytes}B"))
        eid = (self._block_home.get(block_id)
               if block_id is not None else None)
        if eid is None or eid not in self._arbiters:
            healthy = self._healthy_expanders()
            eid = (healthy[0].expander_id if healthy
                   else next(iter(self._expanders)))
        grant = self._arbiters[eid].meter(device_id, nbytes)
        inj = self.fault_injector
        if inj is not None:
            # chaos layer: active faults on this link add modeled delay
            # (retry backoff + CRC cost + retransmission wire time,
            # brownout inflation, retrain wait); retransmitted bytes
            # accrue under the "retry" op class so the injector's
            # counters reconcile with op_bytes()
            extra_s, retry_bytes = inj.on_transfer(
                device_id, eid, nbytes, op, grant.delay_s,
                charge=lambda n: self._arbiters[eid].meter(
                    device_id, n).delay_s)
            if retry_bytes:
                with self._lock:
                    self._op_bytes["retry"] = (
                        self._op_bytes.get("retry", 0) + retry_bytes)
            if extra_s > 0.0:
                grant = dataclasses.replace(
                    grant, delay_s=grant.delay_s + extra_s,
                    completion_s=grant.completion_s + extra_s)
        tr = self.tracer
        if tr.enabled:
            # dur is the MODELED link delay (virtual seconds), so span
            # sums over a trace equal the fabric's wait counters
            dom = self.domain_of(eid)
            extra = {"domain": dom} if dom is not None else {}
            tr.add("link.xfer", tr.now(), grant.delay_s, op=op,
                   tenant=info.tenant, expander=eid, nbytes=nbytes,
                   device=device_id, **extra)
        return grant

    def op_bytes(self) -> Dict[str, int]:
        """Metered bytes per traffic class (e.g. demand vs prefetch)."""
        with self._lock:
            return dict(self._op_bytes)

    def advance_links(self, dt_s: float) -> None:
        """Let ``dt_s`` of virtual time pass on every expander link with
        no new traffic — compute running while the wire drains.  The
        overlap benchmarks/tests call this between metered steps so a
        prefetch burst issued during one compute window has actually
        left the wire by the next (otherwise every transfer since t=0
        queues behind its predecessors and modeled delays grow without
        bound).  Doubles as the chaos layer's clock: an attached fault
        injector advances with the links and fires its due events here
        (outside the lock — event handlers re-enter FM methods and
        notify consumer callbacks)."""
        with self._lock:
            for arb in self._arbiters.values():
                arb.advance(dt_s)
        if self.fault_injector is not None:
            self.fault_injector.advance(dt_s)

    def attach_fault_injector(self, injector: "FaultInjector") -> None:
        """Attach the chaos layer (repro.core.faults): the injector
        advances with :meth:`advance_links` and perturbs every
        :meth:`meter_transfer` per its FaultPlan.  One injector per
        fabric; attaching a second replaces the first."""
        injector.bind(self)
        self.fault_injector = injector

    def meter_calls(self) -> int:
        """Total arbitration round-trips across every expander's link —
        the overhead metric the batched data path minimizes (bytes move
        in coalesced bursts, so call count grows with batches, not
        pages).  Counts frozen (failed) arbiters too: their historical
        calls happened."""
        return sum(arb.meter_calls for arb in self._arbiters.values())

    def link_utilization(self, expander_id: Optional[int] = None) -> float:
        """One expander's EWMA link utilization, or the pool-wide max
        (the pressure signal consumers degrade on).  Failed expanders'
        frozen arbiters are excluded from the pool-wide view."""
        if expander_id is not None:
            return self._arbiters[expander_id].utilization()
        utils = self.link_utilizations()
        if not utils:
            return 0.0
        return max(utils.values())

    def link_utilizations(self) -> Dict[int, float]:
        """Per-expander EWMA link utilization (healthy expanders only)."""
        return {e.expander_id: self._arbiters[e.expander_id].utilization()
                for e in self._healthy_expanders()}

    def least_loaded_expander(
            self, exclude: Sequence[int] = (),
            media: MediaKind = MediaKind.DRAM) -> Optional[int]:
        """Migration target: delegated to the SAME placement policy block
        placement uses, so the two cannot drift.  When no expander has a
        whole free block, falls back to candidates without room — the
        migration may fit a consumer's EXISTING free slots there, and
        migrate_pages stops cleanly if growth is refused.  None only when
        the pool offers no alternative expander at all."""
        views = self._views(media, exclude)
        if not views:
            views = self._views(media, exclude, require_room=False)
        return self._placement.choose(self._request_for(media), views)

    def record_migration(self, device_id: str, src_expander: int,
                         dst_expander: int, npages: int,
                         nbytes: int) -> None:
        """Journal a hot-page migration like a DCD capacity event."""
        with self._lock:
            self.journal.append(JournalEntry(
                "migrate", device_id,
                detail=(f"{src_expander}->{dst_expander} "
                        f"pages={npages} bytes={nbytes}")))

    # -- access control -------------------------------------------------------
    def authorize(self, device_id: str, block_id: int, page_start: int,
                  npages: int) -> None:
        info = self.device(device_id)
        if info.device_class is DeviceClass.CXL:
            self.sat.add(info.spid, block_id)
        else:
            self.iommu.map(device_id, block_id, page_start, npages)

    def revoke(self, device_id: str, block_id: int, page_start: int,
               npages: int) -> None:
        info = self.device(device_id)
        if info.device_class is DeviceClass.CXL:
            # SAT is block-granular; only drop when device holds nothing else
            self.sat.remove(info.spid, block_id)
        else:
            self.iommu.unmap(device_id, block_id, page_start, npages)

    def check_access(self, device_id: str, block_id: int, page: int) -> None:
        info = self.device(device_id)
        if info.device_class is DeviceClass.CXL:
            ok = self.sat.check(info.spid, block_id)
        else:
            ok = self.iommu.check(device_id, block_id, page)
        if not ok:
            raise AccessDenied(
                f"{device_id} may not access block {block_id} page {page}")

    # -- failure handling -----------------------------------------------------
    def on_failover(self, cb: Callable[[int], None]) -> None:
        """Register a consumer callback invoked with the failed expander's
        id after its blocks have been re-granted elsewhere."""
        self._failover_listeners.append(cb)

    def off_failover(self, cb: Callable[[int], None]) -> None:
        """Deregister a failover callback (consumer teardown, e.g.
        LinkedBuffer.close) — keeps churned consumers from accumulating
        on the FM for its lifetime.  Unknown callbacks are a no-op."""
        try:
            self._failover_listeners.remove(cb)
        except ValueError:
            pass

    def _promote_spare(self) -> Expander:
        """Standby joins the pool: fresh arbiter seeded with every device's
        CURRENT bandwidth share (weights + burst replayed, like the
        capacity re-grants) so QoS state survives failover too."""
        spare = self._spare
        self._spare = None
        self._expanders[spare.expander_id] = spare
        arb = LinkArbiter(self._port_bw(spare.expander_id))
        self._arbiters[spare.expander_id] = arb
        self.journal.append(JournalEntry(
            "promote", "*", detail=f"expander={spare.expander_id}"))
        for info in self._devices.values():
            arb.register(info.device_id, weight=info.bw_weight,
                         burst_bytes=info.bw_burst_bytes)
            self.journal.append(JournalEntry(
                "bw_share", info.device_id,
                detail=f"{info.bw_weight} (failover replay)"))
        return spare

    def _fail_locked(self, eids: Sequence[int],
                     domain: Optional[str] = None) -> None:
        """Fail every expander in ``eids``, then run ONE re-grant pass.

        Marking them ALL dead before re-granting is what makes
        correlated (domain-wide) failures correct: a per-expander loop
        would re-grant the first casualty's blocks onto siblings that
        are about to die with the same switch/power domain, losing them
        twice.  Caller holds the lock and notifies listeners after."""
        doomed = set()
        for eid in eids:
            exp = self._expanders.get(eid)
            if exp is None:
                raise InvalidHandle(f"unknown expander {eid}")
            doomed.add(eid)
        for eid in doomed:
            self._expanders[eid].failed = True
            detail = f"expander={eid}" + (
                f" domain={domain}" if domain is not None else "")
            self.journal.append(JournalEntry("fail", "*", detail=detail))
        if self._spare is not None:
            self._promote_spare()
        if not self._healthy_expanders():
            # nowhere to re-grant — consumers still hear about the
            # failure (listener callbacks) and enter degraded mode
            return
        for host_id, grants in self._granted.items():
            regrants = []
            for g in grants:
                if self._block_home.get(g.block_id) not in doomed:
                    regrants.append(g)    # homed elsewhere: untouched
                    continue
                # the old block id ceases to exist either way: stale
                # SAT/IOMMU authorizations for it must not outlive it
                self.sat.purge_block(g.block_id)
                self.iommu.purge_block(g.block_id)
                try:
                    texp = self._pick_expander(g.media)
                    ng = texp.grant_block(host_id, g.media)
                except (OutOfMemory, LMBError):
                    self._block_home.pop(g.block_id, None)
                    self.journal.append(
                        JournalEntry("lost", host_id, g.block_id))
                    continue
                self._block_home.pop(g.block_id, None)
                self._block_home[ng.block_id] = texp.expander_id
                regrants.append(ng)
                self.journal.append(
                    JournalEntry("regrant", host_id, ng.block_id,
                                 detail=f"was {g.block_id} now "
                                        f"expander={texp.expander_id}"))
            self._granted[host_id] = regrants

    def inject_failure(self, expander_id: Optional[int] = None) -> None:
        """One expander dies.  With somewhere to go (a passive spare, or
        surviving pooled expanders): re-grant every block homed on the dead
        expander and notify consumers (they must re-populate contents —
        data loss is the consumer's recovery problem, availability is ours).
        With nowhere to go: subsequent requests raise, consumers degrade to
        onboard-only mode (see LinkedBuffer.degraded).

        Idempotent and safe: injecting an already-failed expander is a
        journaled no-op (``fail.noop``) — running ``_fail_locked`` again
        would re-journal the death and re-notify listeners against
        already-purged grant state.  Injecting with no healthy expander
        left (and no explicit target) raises instead of silently
        re-killing a corpse."""
        with self._lock:
            if expander_id is not None:
                exp = self._expanders.get(expander_id)
                if exp is None:
                    raise InvalidHandle(f"unknown expander {expander_id}")
                if exp.failed:
                    self.journal.append(JournalEntry(
                        "fail.noop", "*",
                        detail=f"expander={expander_id} already failed"))
                    return
                eid = expander_id
            else:
                healthy = self._healthy_expanders()
                if not healthy:
                    raise LMBError(
                        "no healthy expander left to fail (pool is "
                        "already empty; name a target explicitly for a "
                        "journaled no-op)")
                eid = healthy[0].expander_id
            self._fail_locked([eid])
        for cb in self._failover_listeners:
            cb(eid)

    def inject_domain_failure(self, domain: str) -> List[int]:
        """Correlated failure: a switch/power domain dies, taking every
        pooled expander behind it at once (paper: "a single failure in
        the memory expander can render all devices unavailable" — a rack
        makes that plural).  Requires a topology; returns the failed
        expander ids.  Re-grants land only on expanders OUTSIDE the dead
        domain (plus a promoted spare, if any)."""
        if self.topology is None:
            raise LMBError("no topology: failure domains undefined")
        eids = [e for e in self.topology.expanders_in_domain(domain)
                if e in self._expanders]
        if not eids:
            raise InvalidHandle(
                f"no pooled expander in failure domain {domain!r}")
        with self._lock:
            self._fail_locked(eids, domain=domain)
        for cb in self._failover_listeners:
            for eid in eids:
                cb(eid)
        return eids

    # -- repair / re-admission -------------------------------------------------
    def on_repair(self, cb: Callable[[int], None]) -> None:
        """Register a consumer callback invoked with the repaired
        expander's id after it rejoins the pool (blank)."""
        self._repair_listeners.append(cb)

    def off_repair(self, cb: Callable[[int], None]) -> None:
        """Deregister a repair callback (consumer teardown); unknown
        callbacks are a no-op."""
        try:
            self._repair_listeners.remove(cb)
        except ValueError:
            pass

    def readmit_expander(self, expander_id: int) -> None:
        """Repair: a failed expander rejoins the pool BLANK (the FRU was
        replaced) — before this, a dead expander was dead forever.

        The expander's grant state is reset (old block ids never return;
        the id namespace keeps advancing, so stale capabilities cannot
        collide with post-repair grants), its arbiter is rebuilt fresh
        with every device's CURRENT bandwidth share replayed (exactly as
        spare promotion does), and any grants still homed on it — the
        total-pool-failure case, where ``_fail_locked`` had nowhere to
        re-grant — are journaled ``lost`` and purged from the SAT/IOMMU
        tables.  Consumers hear about it via :meth:`on_repair` (e.g.
        ``LinkedBuffer`` exits degraded mode); host-side generation
        counters are NOT rolled back, so handles that went stale at
        failure stay stale after repair."""
        with self._lock:
            exp = self._expanders.get(expander_id)
            if exp is None:
                raise InvalidHandle(f"unknown expander {expander_id}")
            if not exp.failed:
                raise LMBError(
                    f"expander {expander_id} is not failed; nothing to "
                    "readmit")
            # grants that were never re-granted elsewhere (total-pool
            # failure) are gone for good: the repaired expander is blank
            for host_id, grants in self._granted.items():
                kept = []
                for g in grants:
                    if self._block_home.get(g.block_id) != expander_id:
                        kept.append(g)
                        continue
                    self._block_home.pop(g.block_id, None)
                    self.sat.purge_block(g.block_id)
                    self.iommu.purge_block(g.block_id)
                    self.journal.append(JournalEntry(
                        "lost", host_id, g.block_id,
                        detail="discovered at repair"))
                self._granted[host_id] = kept
            exp.reset()
            exp.failed = False
            arb = LinkArbiter(self._port_bw(expander_id))
            self._arbiters[expander_id] = arb
            for info in self._devices.values():
                arb.register(info.device_id, weight=info.bw_weight,
                             burst_bytes=info.bw_burst_bytes)
            self.journal.append(JournalEntry(
                "repair", "*", detail=f"expander={expander_id}"))
        tr = self.tracer
        if tr.enabled:
            tr.event("fault.repair.admitted", op="fault",
                     expander=expander_id)
        for cb in self._repair_listeners:
            cb(expander_id)

    @property
    def healthy(self) -> bool:
        return bool(self._healthy_expanders()) or self._spare is not None

    # -- journal telemetry / compaction ---------------------------------------
    def journal_stats(self) -> Dict[str, object]:
        """Journal growth telemetry: length + per-op-class counts."""
        with self._lock:
            by_op: Dict[str, int] = {}
            for e in self.journal:
                by_op[e.op] = by_op.get(e.op, 0) + 1
            return {"len": len(self.journal), "by_op": by_op}

    def compact(self) -> int:
        """Fold superseded grant/release pairs out of the journal.

        A ``grant`` (or failover ``regrant``) whose block was later
        ``release``d by the same host carries no live state — replaying
        the journal yields the same held-block set without the pair.
        Only exactly-matched pairs are removed (most recent pending
        grant per (host, block)); every other entry class (bind, quota,
        bw_share, fail, promote, lost, migrate, prefetch bursts, ...)
        is preserved verbatim and in order.  Returns the number of
        entries removed.
        """
        with self._lock:
            pending: Dict[Tuple[str, Optional[int]], List[int]] = {}
            dead: Set[int] = set()
            for i, e in enumerate(self.journal):
                key = (e.host_id, e.block_id)
                if e.op in ("grant", "regrant"):
                    pending.setdefault(key, []).append(i)
                elif e.op == "release":
                    stack = pending.get(key)
                    if stack:
                        dead.add(stack.pop())
                        dead.add(i)
            if not dead:
                return 0
            self.journal = [e for i, e in enumerate(self.journal)
                            if i not in dead]
            return len(dead)

    # -- introspection --------------------------------------------------------
    def placement(self) -> Dict[int, int]:
        """blocks held per expander (the block→expander placement map)."""
        out = {eid: 0 for eid in self._expanders}
        for eid in self._block_home.values():
            out[eid] = out.get(eid, 0) + 1
        return out

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "hosts": dict(self._hosts),
                "held_blocks": {h: [g.block_id for g in gs]
                                for h, gs in self._granted.items()},
                "free_bytes": sum(e.free_bytes()
                                  for e in self._healthy_expanders()),
                "journal_len": len(self.journal),
                "journal": self.journal_stats(),
                "healthy": self.healthy,
                "placement_policy": self._placement.name,
                "link": self.arbiter.snapshot(),
                "placement": self.placement(),
                "topology": (self.topology.snapshot()
                             if self.topology is not None else None),
                "faults": (self.fault_injector.snapshot()
                           if self.fault_injector is not None else None),
                "expanders": {
                    eid: {
                        "failed": e.failed,
                        "free_bytes": e.free_bytes(),
                        "utilization": self._arbiters[eid].utilization(),
                        "link": self._arbiters[eid].snapshot(),
                        "domain": self.domain_of(eid),
                    }
                    for eid, e in self._expanders.items()
                },
            }


def make_default_fabric(pool_gib: int = 64,
                        spare: bool = False,
                        link_bandwidth_Bps: float = DEFAULT_LINK_BW_Bps,
                        ) -> Tuple[FabricManager, Expander]:
    """One DRAM expander of ``pool_gib`` (+ optional passive spare), one FM."""
    exp = Expander([(MediaKind.DRAM, pool_gib * 2**30)], expander_id=0)
    sp = (Expander([(MediaKind.DRAM, pool_gib * 2**30)], expander_id=1)
          if spare else None)
    return FabricManager(exp, spare=sp,
                         link_bandwidth_Bps=link_bandwidth_Bps), exp


def make_multi_fabric(n_expanders: int = 2,
                      pool_gib: int = 64,
                      link_bandwidth_Bps: float = DEFAULT_LINK_BW_Bps,
                      spare: bool = False,
                      topology: Optional["RackTopology"] = None,
                      placement: Union[str, PlacementPolicy, None] = None,
                      ) -> Tuple[FabricManager, List[Expander]]:
    """Pooled fabric: ``n_expanders`` DRAM expanders of ``pool_gib`` each,
    one FM arbitrating each expander's link independently.  ``topology``
    racks the pool behind a switched fabric (expander ids 0..n-1 must be
    attached in it)."""
    exps = [Expander([(MediaKind.DRAM, pool_gib * 2**30)], expander_id=i)
            for i in range(n_expanders)]
    sp = (Expander([(MediaKind.DRAM, pool_gib * 2**30)],
                   expander_id=n_expanders) if spare else None)
    fm = FabricManager(exps, spare=sp,
                       link_bandwidth_Bps=link_bandwidth_Bps,
                       placement=placement, topology=topology)
    return fm, exps
