"""Fabric Manager (FM) and access control (SAT / IOMMU) for LMB.

The FM "controls aspects of the system related to binding and management of
pooled ports and devices" (paper Table 1).  Here it:

  * owns one or more Expanders (GFDs) and grants/releases 256 MB blocks,
  * maintains the **SAT** (SPID Access Table) authorizing CXL devices, and
    IOMMU-style per-PCIe-device mapping tables,
  * supports **dynamic capacity**: per-host quotas that can be raised or
    lowered at runtime (CXL DCD semantics),
  * supports **failure injection + recovery** — the paper calls out that "a
    single failure in the memory expander can render all devices unavailable";
    we journal every grant so that consumers can rebuild after fail-over to a
    spare expander,
  * keeps an **allocation journal** that makes the pool reconstructible
    (needed by the training checkpoint/restore path).
"""

from __future__ import annotations

import dataclasses
import enum
import threading
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.core.pool import (BLOCK_BYTES, BlockGrant, Expander, InvalidHandle,
                             LMBError, MediaKind, OutOfMemory)
from repro.qos.arbiter import LinkArbiter, TransferGrant

#: default per-expander link bandwidth (matches the LMB_CXL tier's 30 GB/s)
DEFAULT_LINK_BW_Bps = 30e9


class DeviceClass(enum.Enum):
    PCIE = "pcie"   # host-forwarded path; isolation via IOMMU tables
    CXL = "cxl"     # P2P path; isolation via SPID Access Table (SAT)


@dataclasses.dataclass(frozen=True)
class DeviceInfo:
    device_id: str
    device_class: DeviceClass
    #: Source PBR ID for CXL devices (paper Table 1); None for PCIe devices
    spid: Optional[int] = None
    #: weighted-fair share of the expander link (repro.qos.arbiter)
    bw_weight: float = 1.0
    #: token-bucket burst allowance on the link; 0 = no burst credit
    bw_burst_bytes: int = 0


class AccessDenied(LMBError):
    pass


class SAT:
    """SPID Access Table: (spid → set of block_ids it may touch).

    Matches the paper's GFD access control: "GFD can identify the CXL device
    or host that initiates the request according to the SPID field"; entries
    are updated on alloc/free/share via the GFD Component Management Command
    Set.
    """

    def __init__(self) -> None:
        self._table: Dict[int, Set[int]] = {}

    def add(self, spid: int, block_id: int) -> None:
        self._table.setdefault(spid, set()).add(block_id)

    def remove(self, spid: int, block_id: int) -> None:
        self._table.get(spid, set()).discard(block_id)

    def check(self, spid: int, block_id: int) -> bool:
        return block_id in self._table.get(spid, set())

    def entries(self) -> Dict[int, Set[int]]:
        return {k: set(v) for k, v in self._table.items()}


class IOMMUTable:
    """Per-PCIe-device allowed (block_id, page range) mappings.

    Models the kernel module creating IOMMU page tables for allocated memory
    (paper §3.3).  Granularity is the allocator page.
    """

    def __init__(self) -> None:
        # device_id -> block_id -> set of page indices
        self._maps: Dict[str, Dict[int, Set[int]]] = {}

    def map(self, device_id: str, block_id: int, page_start: int,
            npages: int) -> None:
        pages = self._maps.setdefault(device_id, {}).setdefault(
            block_id, set())
        pages.update(range(page_start, page_start + npages))

    def unmap(self, device_id: str, block_id: int, page_start: int,
              npages: int) -> None:
        pages = self._maps.get(device_id, {}).get(block_id)
        if pages:
            pages.difference_update(range(page_start, page_start + npages))

    def check(self, device_id: str, block_id: int, page: int) -> bool:
        return page in self._maps.get(device_id, {}).get(block_id, set())

    def mapped_pages(self, device_id: str) -> int:
        return sum(len(p) for p in self._maps.get(device_id, {}).values())


@dataclasses.dataclass
class JournalEntry:
    op: str                    # "grant" | "release" | "bind" | "fail" | ...
    host_id: str
    block_id: Optional[int] = None
    detail: str = ""


class FabricManager:
    """FM: binds hosts/devices to expander capacity; single control point."""

    def __init__(self, expander: Expander,
                 spare: Optional[Expander] = None,
                 link_bandwidth_Bps: float = DEFAULT_LINK_BW_Bps):
        self._lock = threading.RLock()
        self._expander = expander
        self._spare = spare
        self._hosts: Dict[str, int] = {}       # host_id -> quota bytes
        self._devices: Dict[str, DeviceInfo] = {}
        self._granted: Dict[str, List[BlockGrant]] = {}
        self.sat = SAT()
        self.iommu = IOMMUTable()
        self.journal: List[JournalEntry] = []
        self._failover_listeners: List[Callable[[], None]] = []
        #: link-bandwidth arbiter — the bandwidth analogue of the capacity
        #: quotas above; devices are its tenants (registered on
        #: register_device, re-weighted through set_bw_share)
        self.arbiter = LinkArbiter(link_bandwidth_Bps)

    # -- binding -------------------------------------------------------------
    def bind_host(self, host_id: str, quota_bytes: Optional[int] = None) -> None:
        with self._lock:
            quota = (quota_bytes if quota_bytes is not None
                     else self._expander.total_bytes)
            self._hosts[host_id] = quota
            self._granted.setdefault(host_id, [])
            self.journal.append(JournalEntry("bind", host_id))

    def set_quota(self, host_id: str, quota_bytes: int) -> None:
        """Dynamic capacity (DCD): change a host's allowance at runtime."""
        with self._lock:
            if host_id not in self._hosts:
                raise InvalidHandle(f"host {host_id} not bound")
            self._hosts[host_id] = quota_bytes
            self.journal.append(
                JournalEntry("quota", host_id, detail=str(quota_bytes)))

    def register_device(self, info: DeviceInfo) -> None:
        with self._lock:
            if info.device_class is DeviceClass.CXL and info.spid is None:
                raise ValueError("CXL device needs an SPID")
            self._devices[info.device_id] = info
            self.arbiter.register(info.device_id, weight=info.bw_weight,
                                  burst_bytes=info.bw_burst_bytes)

    def device(self, device_id: str) -> DeviceInfo:
        info = self._devices.get(device_id)
        if info is None:
            raise InvalidHandle(f"device {device_id} not registered")
        return info

    # -- block grant/release (called by host BlockAllocators) ----------------
    def request_block(self, host_id: str,
                      media: MediaKind = MediaKind.DRAM) -> BlockGrant:
        with self._lock:
            if host_id not in self._hosts:
                raise InvalidHandle(f"host {host_id} not bound")
            held = len(self._granted[host_id]) * BLOCK_BYTES
            if held + BLOCK_BYTES > self._hosts[host_id]:
                raise OutOfMemory(
                    f"host {host_id} quota exceeded "
                    f"({held + BLOCK_BYTES} > {self._hosts[host_id]})")
            grant = self._active().grant_block(host_id, media)
            self._granted[host_id].append(grant)
            self.journal.append(JournalEntry("grant", host_id, grant.block_id))
            return grant

    def return_block(self, host_id: str, block_id: int) -> None:
        with self._lock:
            grants = self._granted.get(host_id, [])
            for i, g in enumerate(grants):
                if g.block_id == block_id:
                    grants.pop(i)
                    self._active().release_block(block_id)
                    self.journal.append(
                        JournalEntry("release", host_id, block_id))
                    return
            raise InvalidHandle(
                f"host {host_id} does not hold block {block_id}")

    def held_bytes(self, host_id: str) -> int:
        with self._lock:
            return len(self._granted.get(host_id, [])) * BLOCK_BYTES

    # -- bandwidth quotas (the DCD analogue for the shared link) --------------
    def set_bw_share(self, device_id: str, weight: float,
                     burst_bytes: Optional[int] = None) -> None:
        """Grant/revoke link-bandwidth share at runtime, like set_quota does
        for capacity.  Weight is relative (weighted-fair), so 'revoking'
        is lowering a weight — the link itself is never left idle."""
        with self._lock:
            info = self.device(device_id)
            self._devices[device_id] = dataclasses.replace(
                info, bw_weight=weight,
                bw_burst_bytes=(info.bw_burst_bytes if burst_bytes is None
                                else burst_bytes))
            self.arbiter.register(
                device_id, weight=weight,
                burst_bytes=self._devices[device_id].bw_burst_bytes)
            self.journal.append(
                JournalEntry("bw_share", device_id, detail=str(weight)))

    def meter_transfer(self, device_id: str, nbytes: int) -> TransferGrant:
        """Charge a data-path transfer against the device's link share.

        Hot path (every LinkedBuffer demote/fault): deliberately not
        journaled — aggregate occupancy lives in the arbiter snapshot."""
        self.device(device_id)  # InvalidHandle on unknown devices
        return self.arbiter.meter(device_id, nbytes)

    def link_utilization(self) -> float:
        return self.arbiter.utilization()

    # -- access control -------------------------------------------------------
    def authorize(self, device_id: str, block_id: int, page_start: int,
                  npages: int) -> None:
        info = self.device(device_id)
        if info.device_class is DeviceClass.CXL:
            self.sat.add(info.spid, block_id)
        else:
            self.iommu.map(device_id, block_id, page_start, npages)

    def revoke(self, device_id: str, block_id: int, page_start: int,
               npages: int) -> None:
        info = self.device(device_id)
        if info.device_class is DeviceClass.CXL:
            # SAT is block-granular; only drop when device holds nothing else
            self.sat.remove(info.spid, block_id)
        else:
            self.iommu.unmap(device_id, block_id, page_start, npages)

    def check_access(self, device_id: str, block_id: int, page: int) -> None:
        info = self.device(device_id)
        if info.device_class is DeviceClass.CXL:
            ok = self.sat.check(info.spid, block_id)
        else:
            ok = self.iommu.check(device_id, block_id, page)
        if not ok:
            raise AccessDenied(
                f"{device_id} may not access block {block_id} page {page}")

    # -- failure handling -------------------------------------------------------
    def _active(self) -> Expander:
        if self._expander.failed and self._spare is not None:
            return self._spare
        return self._expander

    def on_failover(self, cb: Callable[[], None]) -> None:
        self._failover_listeners.append(cb)

    def inject_failure(self) -> None:
        """Primary expander dies.  With a spare: re-grant every held block on
        the spare and notify consumers (they must re-populate contents —
        data loss is the consumer's recovery problem, availability is ours).
        Without a spare: subsequent requests raise, consumers degrade to
        onboard-only mode (see LinkedBuffer.degraded)."""
        with self._lock:
            self._expander.failed = True
            self.journal.append(JournalEntry("fail", "*"))
            if self._spare is None:
                return
            for host_id, grants in self._granted.items():
                regrants = []
                for g in grants:
                    ng = self._spare.grant_block(host_id)
                    regrants.append(ng)
                    self.journal.append(
                        JournalEntry("regrant", host_id, ng.block_id,
                                     detail=f"was {g.block_id}"))
                self._granted[host_id] = regrants
        for cb in self._failover_listeners:
            cb()

    @property
    def healthy(self) -> bool:
        return not self._expander.failed or self._spare is not None

    # -- introspection ----------------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            return {
                "hosts": dict(self._hosts),
                "held_blocks": {h: [g.block_id for g in gs]
                                for h, gs in self._granted.items()},
                "free_bytes": self._active().free_bytes(),
                "journal_len": len(self.journal),
                "healthy": self.healthy,
                "link": self.arbiter.snapshot(),
            }


def make_default_fabric(pool_gib: int = 64,
                        spare: bool = False,
                        link_bandwidth_Bps: float = DEFAULT_LINK_BW_Bps,
                        ) -> Tuple[FabricManager, Expander]:
    """One DRAM expander of ``pool_gib`` (+ optional spare), one FM."""
    exp = Expander([(MediaKind.DRAM, pool_gib * 2**30)])
    sp = Expander([(MediaKind.DRAM, pool_gib * 2**30)]) if spare else None
    return FabricManager(exp, spare=sp,
                         link_bandwidth_Bps=link_bandwidth_Bps), exp
