"""LinkedBuffer: a logical paged array spanning onboard memory and the LMB.

This is the consumer-facing realization of the paper's idea: a device whose
working set exceeds onboard memory sees one flat buffer; hot pages live in
the **onboard tier** (a bounded device pool — HBM on TPU), cold pages live in
the **LMB tier** (expander-backed, allocated through the Table-2 API).  The
page table plays the role the L2P table plays in the SSD: every access
resolves logical page → (tier, slot) host-side (allocator metadata stays in
host memory, §3.2), then the data path touches exactly one tier.

Capabilities:
  * demand paging with pluggable eviction (LRU/CLOCK/cost-aware) + prefetch
  * **batched data path** — :meth:`read_many` / :meth:`write_many` resolve
    the page table up front, group the pages into per-(chunk, expander)
    runs, and move each run as ONE coalesced transfer with ONE link-arbiter
    charge for the burst (real CXL/PCIe stacks amortize doorbells and
    arbitration over bursts; the scalar path pays them per page).  Bulk
    eviction (:meth:`_evict_many`) frees K onboard slots with one policy
    call and coalesced per-chunk write-back bursts.
  * dirty tracking with write-back (single-writer "uncached" semantics — the
    paper's PCIe devices don't participate in coherence, and neither do we:
    ownership transfer is explicit)
  * pin/unpin for pages a compiled step will touch (DMA in flight)
  * refcounted page sharing + copy-on-write (zero-copy prefix sharing, the
    paper's SSD→accelerator shared-buffer scenario)
  * degraded mode on expander failure (availability: fall back to
    onboard-only, shedding capacity rather than dying); on a pooled
    fabric a partial failure only invalidates the pages homed on the
    dead expander
  * optional **int8 page compression on demotion** (``compress_lmb``) —
    beyond-paper: cold pages cost 1/4 the pool bytes and PCIe traffic
    (per-page absmax scale kept in HOST metadata, like all LMB metadata);
    lossy (~1e-2 relative) — suited to KV caches, not optimizer state
  * **per-page access heat** (exponentially-decayed touch counters fed by
    the link-metering path, numpy-backed so batch updates are one
    vectorized decay instead of a dict walk; decayed-cold entries are
    flushed to zero so long-lived buffers don't accumulate stale heat)
    + :meth:`migrate_pages`, the mechanism the MigrationEngine
    (repro.qos.migration) uses to move hot LMB pages off a saturated
    expander link onto a cooler one

Batched-vs-scalar equivalence: the batched paths move the same bytes over
the same links, produce bit-identical page contents, and leave the same
logical page-table state as the scalar loop.  Two deliberate improvements:
(1) a batch frees its fault sources *before* allocating eviction
destinations, so a burst can recycle its own sources' slots — the batch
never grows more LMB chunks than the scalar interleave, occasionally
fewer; (2) eviction victims are chosen from the PRE-batch resident set
(one ``policy.victims(k)`` call), so a gather can never demote its own
just-faulted members — the scalar interleave could, and under
CostAwareLRU's clean-page preference routinely did (self-thrash).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import LMBHost
from repro.core.client import MemoryHandle
from repro.core.metrics import Metrics, GLOBAL_METRICS
from repro.core.offload import TierExecutor
from repro.core.overlap import OverlapScheduler
from repro.core.policy import EvictionPolicy, Prefetcher, make_policy
from repro.core.pool import OutOfMemory
from repro.obs.trace import SpanTracer

ONBOARD = "onboard"
LMB = "lmb"


@dataclasses.dataclass
class PageEntry:
    tier: Optional[str] = None   # None = never written (implicit zeros)
    slot: int = -1
    dirty: bool = False
    refcount: int = 1


class LinkedBuffer:
    """A paged logical buffer over (onboard pool, LMB pool)."""

    def __init__(self, *,
                 name: str,
                 device_id: str,
                 host: LMBHost,
                 executor: Optional[TierExecutor] = None,
                 page_shape: Tuple[int, ...],
                 dtype=jnp.float32,
                 onboard_pages: int,
                 policy: str | EvictionPolicy = "lru",
                 prefetch_depth: int = 0,
                 prefetch_backlog_factor: int = 8,
                 prefetch_min_burst: Optional[int] = None,
                 overlap: Optional[OverlapScheduler] = None,
                 lmb_chunk_pages: int = 64,
                 compress_lmb: bool = False,
                 metrics: Optional[Metrics] = None):
        self.name = name
        self.device_id = device_id
        self.host = host
        self.executor = executor or TierExecutor()
        self.page_shape = tuple(page_shape)
        self.dtype = dtype
        self.onboard_pages = int(onboard_pages)
        self.compress_lmb = compress_lmb
        self.page_bytes = int(np.prod(self.page_shape)) * jnp.dtype(dtype).itemsize
        #: bytes a page occupies in the LMB tier (int8 + host-side scale)
        self.lmb_page_bytes = (int(np.prod(self.page_shape))
                               if compress_lmb else self.page_bytes)
        self.metrics = metrics or GLOBAL_METRICS
        self.policy: EvictionPolicy = (
            make_policy(policy) if isinstance(policy, str) else policy)
        self.prefetcher = (Prefetcher(prefetch_depth,
                                      prefetch_backlog_factor)
                           if prefetch_depth else None)
        #: hysteresis for STRIDE-source runs: heuristic guesses are held
        #: back until at least this many pages accumulate, so steady-state
        #: lookahead moves chunk-sized bursts (one arbiter charge each)
        #: instead of advancing the frontier one page per access.  Exact
        #: scheduled knowledge is never held back.
        self.prefetch_min_burst = (max(prefetch_depth // 4, 1)
                                   if prefetch_min_burst is None
                                   else max(prefetch_min_burst, 1))
        #: overlap scheduler gating prefetch bursts behind compute; when
        #: set, admitted prefetch wait accrues to ``prefetch_hidden_s``
        #: (it rides under the compute window) instead of link_wait_s
        self.overlap = overlap
        #: pages brought onboard by prefetch, not yet demand-read
        self._prefetched: set = set()
        self.prefetch_bursts = 0
        self.prefetch_pages_total = 0
        self.prefetch_used = 0
        self.prefetch_wasted = 0
        self.prefetch_deferred = 0
        self.prefetch_hidden_s = 0.0
        self.degraded = False
        self._closed = False
        host.fm.on_failover(self._on_failover)
        host.fm.on_repair(self._on_repair)
        # QoS link metering: every byte crossing to/from the LMB tier is
        # charged to this device's share of the expander link.  If the
        # caller's executor carries a meter hook AND actually fires it
        # (only on real host tiers — in pure modeling mode the executor
        # can't tell LMB pools from device arrays), defer to it to avoid
        # double-charging the same page move.  On a POOLED fabric the
        # buffer always meters itself: only it knows which expander backs
        # the touched chunk, while an executor hook is a bare meter(nbytes)
        # that would dump everything on the fallback link — so don't bind
        # an executor meter over a multi-expander FM.
        pooled = len(host.fm.healthy_expander_ids()) > 1
        if (pooled and self.executor.meter is not None
                and self.executor.real_host_tier):
            raise ValueError(
                f"{name}: an executor-level meter hook cannot attribute "
                "transfers to an expander on a pooled fabric (and the "
                "buffer's own per-block metering would double-charge); "
                "construct the TierExecutor without meter= and let the "
                "buffer meter")
        self._meter_via_executor = (self.executor.meter is not None
                                    and self.executor.real_host_tier)
        self.link_wait_s = 0.0

        # pools
        self._onboard_pool = self.executor.alloc_pool(
            self.onboard_pages, self.page_shape, dtype, tier="onboard")
        self._onboard_free: List[int] = list(range(self.onboard_pages))[::-1]
        self._onboard_owner: Dict[int, int] = {}  # slot -> logical page

        self._lmb_chunk_pages = lmb_chunk_pages
        self._lmb_scales: Dict[int, float] = {}   # slot -> absmax scale
        self._lmb_pools: List[Optional[jax.Array]] = []  # None = reclaimed
        #: per-chunk capability for the backing LMB allocation
        self._lmb_allocs: List[Optional[MemoryHandle]] = []
        #: per-expander free lists (LIFO): expander id -> free lmb slots.
        #: Replaces the old flat list whose expander-filtered allocation
        #: was an O(n) scan — migration placement now pops O(1).
        self._lmb_free: Dict[int, List[int]] = {}
        self._lmb_owner: Dict[int, int] = {}
        self._lmb_homes: List[int] = []           # chunk -> expander id
        self._lmb_used: List[int] = []            # chunk -> occupied slots

        # access heat: exponentially-decayed touch counters, bumped on the
        # link-metering path (every byte a page moves over an expander link
        # is a vote for migrating it somewhere cooler).  Numpy-backed
        # structure-of-arrays with lazy decay: store (value, clock-at-touch)
        # per page and age on read; batch touches decay a whole burst in
        # one vectorized update.  Entries whose decayed value drops below
        # ``heat_epsilon`` are flushed to zero during batch updates.
        self.heat_decay = 0.95
        self.heat_epsilon = 1e-4
        self._heat_val = np.zeros(0, np.float64)
        self._heat_at = np.zeros(0, np.int64)
        self._heat_clock = 0

        self._pages: List[PageEntry] = []

    # ----------------------------------------------------------------- tracing
    @property
    def trace(self) -> SpanTracer:
        """The FM's span tracer, read through the host so a tracer
        attached after construction (ServeEngine, benchmarks) is seen.
        Hot paths guard every use with ``tr.enabled`` — the scalar hit
        path never touches this property at all."""
        return self.host.fm.tracer

    # ------------------------------------------------------------------ sizing
    @property
    def num_pages(self) -> int:
        return len(self._pages)

    def logical_bytes(self) -> int:
        return self.num_pages * self.page_bytes

    def onboard_bytes(self) -> int:
        return self.onboard_pages * self.page_bytes

    def tier_of(self, page: int) -> Optional[str]:
        """Which tier currently holds a logical page: ``"onboard"``,
        ``"lmb"``, or ``None`` for a never-materialized page — the
        public residency query (serving stats report how much admitted
        KV the LMB pool, not HBM, is carrying)."""
        return self._pages[page].tier

    # --------------------------------------------------------------- allocation
    def append_pages(self, n: int = 1) -> List[int]:
        """Extend the logical buffer by ``n`` zero pages; returns indices."""
        base = len(self._pages)
        self._pages.extend(PageEntry() for _ in range(n))
        need = len(self._pages)
        if need > len(self._heat_val):
            # geometric growth: decode appends one page at a time, and a
            # copy-per-append would make buffer growth quadratic
            cap = max(need, 2 * len(self._heat_val), 16)
            val = np.zeros(cap, np.float64)
            val[:base] = self._heat_val[:base]
            at = np.full(cap, self._heat_clock, np.int64)
            at[:base] = self._heat_at[:base]
            self._heat_val, self._heat_at = val, at
        else:
            self._heat_at[base:need] = self._heat_clock
        return list(range(base, base + n))

    def _grow_lmb(self, expander_id: Optional[int] = None) -> None:
        if self.degraded:
            raise OutOfMemory(f"{self.name}: LMB tier unavailable (degraded)")
        chunk_bytes = self._lmb_chunk_pages * self.lmb_page_bytes
        # class-agnostic capability alloc: the host dispatches PCIe/CXL
        handle = MemoryHandle.alloc(self.host, self.device_id, chunk_bytes,
                                    expander_id=expander_id)
        pool = self.executor.alloc_pool(
            self._lmb_chunk_pages, self.page_shape,
            jnp.int8 if self.compress_lmb else self.dtype, tier="lmb")
        chunk_idx = len(self._lmb_pools)
        self._lmb_pools.append(pool)
        self._lmb_allocs.append(handle)
        self._lmb_homes.append(handle.expander())
        self._lmb_used.append(0)
        base = chunk_idx * self._lmb_chunk_pages
        self._lmb_free.setdefault(handle.expander(), []).extend(
            range(base, base + self._lmb_chunk_pages))

    def _lmb_slot_alloc(self, expander_id: Optional[int] = None) -> int:
        """Take a free LMB slot; ``expander_id`` restricts the slot to a
        chunk homed on that expander (migration placement).  O(1) pops
        from per-expander free lists."""
        if expander_id is None:
            slot = None
            for lst in self._lmb_free.values():
                if lst:
                    slot = lst.pop()
                    break
            if slot is None:
                self._grow_lmb()
                slot = next(lst.pop() for lst in self._lmb_free.values()
                            if lst)
        else:
            lst = self._lmb_free.get(expander_id)
            if not lst:
                self._grow_lmb(expander_id)
                lst = self._lmb_free[expander_id]
            slot = lst.pop()
        self._lmb_used[slot // self._lmb_chunk_pages] += 1
        return slot

    def _lmb_slot_alloc_many(self, k: int,
                             expander_id: Optional[int] = None) -> List[int]:
        """``k`` free LMB slots as one batch; atomic — on OutOfMemory the
        already-claimed slots are returned before re-raising."""
        slots: List[int] = []
        try:
            for _ in range(k):
                slots.append(self._lmb_slot_alloc(expander_id))
        except OutOfMemory:
            for s in slots:
                self._lmb_slot_free(s)
            raise
        return slots

    def _lmb_slot_free(self, slot: int) -> None:
        home = self._lmb_homes[slot // self._lmb_chunk_pages]
        self._lmb_free.setdefault(home, []).append(slot)
        self._lmb_used[slot // self._lmb_chunk_pages] -= 1
        self._lmb_scales.pop(slot, None)

    # ------------------------------------------------------------------- heat
    def _touch_heat(self, page: int) -> None:
        self._heat_clock += 1
        age = self._heat_clock - self._heat_at[page]
        self._heat_val[page] = (self._heat_val[page]
                                * self.heat_decay ** age + 1.0)
        self._heat_at[page] = self._heat_clock

    def _touch_heat_batch(self, pages: Sequence[int]) -> None:
        """One vectorized decay+bump for a burst of page touches (replaces
        len(pages) dict walks); then flush decayed-cold entries."""
        if not pages:
            return
        u, counts = np.unique(np.asarray(pages, np.int64),
                              return_counts=True)
        self._heat_clock += len(pages)
        age = self._heat_clock - self._heat_at[u]
        self._heat_val[u] = (self._heat_val[u]
                             * self.heat_decay ** age + counts)
        self._heat_at[u] = self._heat_clock
        self._flush_cold_heat()

    def _flush_cold_heat(self) -> None:
        """Zero entries whose decayed heat fell below ``heat_epsilon`` —
        bounds stale-heat noise in long-lived buffers (the dict-era leak:
        every page ever touched kept an entry forever)."""
        n = len(self._pages)
        if self.heat_epsilon <= 0 or not n:
            return
        val, at = self._heat_val[:n], self._heat_at[:n]
        # restrict the decay computation to live entries: the flush runs
        # on every metering burst, and a full-array power over a large,
        # mostly-cold buffer would defeat the lazy-decay design
        (live,) = np.nonzero(val)
        if not len(live):
            return
        dec = val[live] * self.heat_decay ** (self._heat_clock - at[live])
        cold = live[dec < self.heat_epsilon]
        if len(cold):
            val[cold] = 0.0
            at[cold] = self._heat_clock

    def page_heat(self, page: int) -> float:
        """Decayed touch count: how hot this page runs on the LMB link."""
        age = self._heat_clock - self._heat_at[page]
        return float(self._heat_val[page] * self.heat_decay ** age)

    # ---------------------------------------------------------------- metering
    def _meter_link(self, chunk: Optional[int] = None,
                    page: Optional[int] = None) -> None:
        if page is not None:
            self._touch_heat(page)
        if not self._meter_via_executor:
            alloc = (self._lmb_allocs[chunk]
                     if chunk is not None else None)
            self.link_wait_s += self.host.meter_transfer(
                self.device_id, self.lmb_page_bytes,
                mmid=alloc.mmid if alloc is not None else None)

    def _charge_links(self, charges: List[Tuple[int, Optional[int]]],
                      pages: Sequence[int], op: str = "demand") -> None:
        """Flush a batch's accumulated link charges as one burst: one
        vectorized heat update, then ONE arbiter call per backing
        expander (LMBHost.meter_transfer_many merges same-link runs).
        ``op`` tags the traffic class; prefetch bursts admitted under an
        overlap window accrue their modeled wait to ``prefetch_hidden_s``
        (hidden behind compute) instead of the demand-visible
        ``link_wait_s``."""
        if pages:
            self._touch_heat_batch(pages)
        if not self._meter_via_executor and charges:
            delay = self.host.meter_transfer_many(
                self.device_id, charges, op=op)
            if op == "prefetch" and self.overlap is not None:
                self.prefetch_hidden_s += delay
            else:
                self.link_wait_s += delay

    # --------------------------------------------------- coalesced chunk runs
    def _lmb_read_run(self, chunk: int, offs: Sequence[int]) -> jax.Array:
        """Coalesced read of several slots of ONE chunk: one access check,
        one slice gather.  Caller meters (append the run's charge)."""
        self.host.check_access(self.device_id, self._lmb_allocs[chunk].mmid)
        data = self.executor.read_pages(self._lmb_pools[chunk], offs)
        if self.compress_lmb:
            base = chunk * self._lmb_chunk_pages
            scales = jnp.asarray(
                [self._lmb_scales.pop(base + off, 0.0) for off in offs],
                jnp.float32)
            scales = scales.reshape((-1,) + (1,) * len(self.page_shape))
            data = (data.astype(jnp.float32) * scales).astype(self.dtype)
        return data

    def _lmb_write_run(self, chunk: int, offs: Sequence[int],
                       data: jax.Array) -> None:
        """Coalesced write of ``data[i] -> chunk slot offs[i]``: one access
        check, one slice scatter, vectorized compression.  Caller meters."""
        self.host.check_access(self.device_id, self._lmb_allocs[chunk].mmid)
        if self.compress_lmb:
            f = data.astype(jnp.float32)
            axes = tuple(range(1, f.ndim))
            amax = np.asarray(jnp.max(jnp.abs(f), axis=axes),
                              np.float64) + 1e-12
            base = chunk * self._lmb_chunk_pages
            for off, a in zip(offs, amax):
                self._lmb_scales[base + off] = float(a) / 127.0
            inv = jnp.asarray(127.0 / amax, jnp.float32)
            inv = inv.reshape((-1,) + (1,) * len(self.page_shape))
            data = jnp.clip(jnp.round(f * inv), -127, 127).astype(jnp.int8)
        self._lmb_pools[chunk] = self.executor.write_pages(
            self._lmb_pools[chunk], offs, data)

    def _runs_by_chunk(self, slots: Sequence[int]) -> Dict[int, List[int]]:
        """Group batch positions by the chunk their slot lives in."""
        runs: Dict[int, List[int]] = {}
        for i, s in enumerate(slots):
            runs.setdefault(s // self._lmb_chunk_pages, []).append(i)
        return runs

    def _read_runs(self, slots: Sequence[int],
                   charges: List[Tuple[int, Optional[int]]]) -> List:
        """Read arbitrary LMB slots as coalesced per-chunk runs; returns
        page data aligned with ``slots`` and appends one link charge per
        run (the caller flushes the burst)."""
        data: Dict[int, jax.Array] = {}
        for chunk, idxs in self._runs_by_chunk(slots).items():
            offs = [slots[i] % self._lmb_chunk_pages for i in idxs]
            arr = self._lmb_read_run(chunk, offs)
            for j, i in enumerate(idxs):
                data[i] = arr[j]
            charges.append((len(idxs) * self.lmb_page_bytes,
                            self._lmb_allocs[chunk].mmid))
        return [data[i] for i in range(len(slots))]

    def _write_runs(self, slots: Sequence[int], rows,
                    charges: List[Tuple[int, Optional[int]]]) -> None:
        """Write ``rows[i] -> slots[i]`` as coalesced per-chunk runs;
        appends one link charge per run.  ``rows`` is a stacked array or
        a list of pages."""
        for chunk, idxs in self._runs_by_chunk(slots).items():
            offs = [slots[i] % self._lmb_chunk_pages for i in idxs]
            sub = (rows[np.asarray(idxs)] if hasattr(rows, "ndim")
                   else jnp.stack([rows[i] for i in idxs]))
            self._lmb_write_run(chunk, offs, sub)
            charges.append((len(idxs) * self.lmb_page_bytes,
                            self._lmb_allocs[chunk].mmid))

    def _lmb_read(self, slot: int, page: Optional[int] = None) -> jax.Array:
        chunk, off = divmod(slot, self._lmb_chunk_pages)
        # access-control check on the data path (IOMMU/SAT)
        self.host.check_access(self.device_id, self._lmb_allocs[chunk].mmid)
        self._meter_link(chunk, page)
        page_data = self.executor.read_page(self._lmb_pools[chunk], off)
        if self.compress_lmb:
            scale = self._lmb_scales.pop(slot, 0.0)
            page_data = (page_data.astype(jnp.float32)
                         * scale).astype(self.dtype)
        return page_data

    def _lmb_write(self, slot: int, data: jax.Array,
                   page: Optional[int] = None) -> None:
        chunk, off = divmod(slot, self._lmb_chunk_pages)
        self.host.check_access(self.device_id, self._lmb_allocs[chunk].mmid)
        self._meter_link(chunk, page)
        if self.compress_lmb:
            f = data.astype(jnp.float32)
            amax = float(jnp.max(jnp.abs(f))) + 1e-12
            self._lmb_scales[slot] = amax / 127.0
            data = jnp.clip(jnp.round(f * (127.0 / amax)),
                            -127, 127).astype(jnp.int8)
        self._lmb_pools[chunk] = self.executor.write_page(
            self._lmb_pools[chunk], off, data)

    # ------------------------------------------------------------------ paging
    def _evict_one(self) -> int:
        """Demote one onboard page to the LMB tier; return the freed slot."""
        victim = self.policy.victim()
        if victim is None:
            raise OutOfMemory(
                f"{self.name}: onboard tier full and nothing evictable "
                f"(all {self.onboard_pages} pages pinned)")
        entry = self._pages[victim]
        assert entry.tier == ONBOARD
        slot = entry.slot
        if self.degraded:
            raise OutOfMemory(
                f"{self.name}: degraded mode — working set exceeds onboard "
                "capacity and the LMB tier is gone")
        lmb_slot = self._lmb_slot_alloc()
        page = self.executor.read_page(self._onboard_pool, slot)
        self._lmb_write(lmb_slot, page, victim)
        self.metrics.record_move(self.name, ONBOARD, LMB,
                                 self.lmb_page_bytes)
        entry.tier, entry.slot, entry.dirty = LMB, lmb_slot, False
        self._lmb_owner[lmb_slot] = victim
        self.policy.on_remove(victim)
        self._note_prefetch_evict(victim)
        del self._onboard_owner[slot]
        return slot

    def _evict_many(self, k: int,
                    sink: Optional[Tuple[list, list]] = None) -> List[int]:
        """Bulk eviction: demote ``k`` victims chosen in ONE policy call,
        written back as coalesced per-chunk bursts (one slice scatter +
        one link charge per destination chunk, instead of k round-trips).
        Returns the freed onboard slots in victim order.  ``sink`` is an
        optional ``(charges, heat_pages)`` pair a batch caller passes to
        defer the metering flush to one combined burst."""
        if k <= 0:
            return []
        tr = self.trace
        t0 = tr.now() if tr.enabled else 0.0
        victims = self.policy.victims(k)
        if len(victims) < k:
            raise OutOfMemory(
                f"{self.name}: onboard tier full and only "
                f"{len(victims)}/{k} evictable pages "
                f"(of {self.onboard_pages}; rest pinned)")
        if self.degraded:
            raise OutOfMemory(
                f"{self.name}: degraded mode — working set exceeds onboard "
                "capacity and the LMB tier is gone")
        dsts = self._lmb_slot_alloc_many(k)
        data = self.executor.read_pages(
            self._onboard_pool, [self._pages[v].slot for v in victims])
        charges, heat = sink if sink is not None else ([], [])
        self._write_runs(dsts, data, charges)
        heat.extend(victims)
        self.metrics.record_move(self.name, ONBOARD, LMB,
                                 k * self.lmb_page_bytes)
        freed: List[int] = []
        for v, dst in zip(victims, dsts):
            entry = self._pages[v]
            slot = entry.slot
            entry.tier, entry.slot, entry.dirty = LMB, dst, False
            self._lmb_owner[dst] = v
            self.policy.on_remove(v)
            self._note_prefetch_evict(v)
            del self._onboard_owner[slot]
            freed.append(slot)
        if sink is None:
            self._charge_links(charges, heat)
        if tr.enabled:
            tr.add("evict.batch", t0, tr.now() - t0, op="demand",
                   nbytes=k * self.lmb_page_bytes, pages=k)
        return freed

    def _onboard_slot_alloc(self) -> int:
        if self._onboard_free:
            return self._onboard_free.pop()
        return self._evict_one()

    def _fault_in(self, page: int) -> int:
        """Bring a page onboard; returns the onboard slot."""
        entry = self._pages[page]
        if entry.tier == ONBOARD:
            self.metrics.record_hit(self.name, ONBOARD, self.page_bytes)
            self.policy.on_access(page)
            if self.prefetcher:
                # hits feed the stride detector too — a prefetcher that
                # only learns from misses stalls the moment it succeeds
                # (every access hits, nothing advances the lookahead)
                self._note_prefetch_hit(page)
                self.prefetcher.observe(page)
                self._prefetch_runs()
            return entry.slot
        self.metrics.record_miss(self.name, ONBOARD, self.page_bytes)
        tr = self.trace
        t0 = tr.now() if tr.enabled else 0.0
        slot = self._onboard_slot_alloc()
        if entry.tier == LMB:
            data = self._lmb_read(entry.slot, page)
            self._onboard_pool = self.executor.write_page(
                self._onboard_pool, slot, data)
            self.metrics.record_move(self.name, LMB, ONBOARD,
                                     self.lmb_page_bytes)
            self._lmb_slot_free(entry.slot)
            self._lmb_owner.pop(entry.slot, None)
        else:
            # first touch: zero-fill
            self._onboard_pool = self.executor.write_page(
                self._onboard_pool, slot,
                jnp.zeros(self.page_shape, self.dtype))
        entry.tier, entry.slot, entry.dirty = ONBOARD, slot, False
        self._onboard_owner[slot] = page
        self.policy.on_insert(page)
        if tr.enabled:
            tr.add("fault", t0, tr.now() - t0, op="demand",
                   nbytes=self.page_bytes, page=page)
        if self.prefetcher:
            self.prefetcher.observe(page)
            self._prefetch_runs()
        return slot

    # --------------------------------------------------------- batched paging
    def _fault_in_many(self, pages: Sequence[int],
                       co_resident: bool = False) -> Dict[int, int]:
        """Batched fault: bring a set of pages onboard with coalesced
        per-chunk transfers, bulk eviction, and one metering burst.
        Returns {page: onboard slot}.  The batch's distinct pages must
        fit the onboard tier at once — every returned slot is live when
        the caller gathers/scatters through it (read_many/write_many
        wave LARGER batches themselves, capturing each wave's data
        before the next may evict it); an oversized fault raises
        OutOfMemory from the eviction shortfall.  Pages already onboard
        are guarded against the batch's own evictions — a burst is one
        access epoch, so its hits must still be resident on return.
        ``co_resident`` additionally pre-checks the whole batch fits
        (the pin contract), raising like the scalar pin loop did when
        it ran out of evictable slots."""
        slots: Dict[int, int] = {}
        faulting: List[int] = []
        hits: List[int] = []
        deferred: List[int] = []
        missed = set()
        for p in pages:
            self._check(p)
            if self.prefetcher:
                self.prefetcher.observe(p)
            entry = self._pages[p]
            if entry.tier == ONBOARD or p in missed:
                # second+ occurrence of a faulting page counts as a hit,
                # exactly like the scalar loop's repeat read would
                self.metrics.record_hit(self.name, ONBOARD, self.page_bytes)
                if p in missed:
                    # recency bump must land AFTER the page is inserted
                    # into the policy (scalar order: insert, then the
                    # repeat read's access) — fired post-wave below
                    deferred.append(p)
                else:
                    self.policy.on_access(p)
                    self._note_prefetch_hit(p)
                    slots[p] = entry.slot
                    hits.append(p)
            else:
                self.metrics.record_miss(self.name, ONBOARD,
                                         self.page_bytes)
                missed.add(p)
                faulting.append(p)
        if co_resident:
            distinct = len(missed) + len(set(hits))
            avail = self._batch_capacity(list(missed) + hits)
            if distinct > avail:
                raise OutOfMemory(
                    f"{self.name}: batch of {distinct} pages cannot "
                    f"co-reside in the onboard tier ({avail} of "
                    f"{self.onboard_pages} slots unpinned)")
        # guard this batch's hit pages against its own evictions: the
        # caller reads/writes through slots[] after we return.  Pin via
        # the public API (a policy may mirror pins into its own
        # structures); _pinned() is only consulted to avoid releasing a
        # caller's pre-existing pin
        guard = [p for p in dict.fromkeys(hits)
                 if p not in self.policy._pinned()]
        for p in guard:
            self.policy.pin(p)
        try:
            if faulting and self.trace.enabled:
                with self.trace.span(
                        "fault.batch", op="demand", pages=len(faulting),
                        nbytes=len(faulting) * self.page_bytes):
                    self._fault_wave(faulting)
            else:
                self._fault_wave(faulting)
        finally:
            for p in guard:
                self.policy.unpin(p)
        for p in deferred:
            self.policy.on_access(p)
        for p in faulting:
            slots[p] = self._pages[p].slot
        if self.prefetcher:
            self._prefetch_runs()
        return slots

    def _fault_wave(self, faulting: List[int]) -> None:
        """One capacity-bounded wave of the batched fault path: coalesced
        LMB reads per source chunk, bulk eviction for the shortfall, one
        coalesced onboard scatter, one metering burst."""
        if not faulting:
            return
        charges: List[Tuple[int, Optional[int]]] = []
        heat: List[int] = []
        # 1. coalesced reads of LMB-resident sources, then free their
        # slots — freeing BEFORE the eviction allocates destinations lets
        # the burst recycle its own sources (never grows more chunks than
        # the scalar interleave would)
        lmb_pages = [p for p in faulting if self._pages[p].tier == LMB]
        src_slots = [self._pages[p].slot for p in lmb_pages]
        # snapshot (page, slot, scale) so a failed eviction below can
        # restore the sources (pool contents stay valid until step 4)
        src_saved = [(p, s, self._lmb_scales.get(s))
                     for p, s in zip(lmb_pages, src_slots)]
        data = dict(zip(lmb_pages, self._read_runs(src_slots, charges)))
        heat.extend(lmb_pages)
        for p in lmb_pages:
            entry = self._pages[p]
            self._lmb_slot_free(entry.slot)
            self._lmb_owner.pop(entry.slot, None)
        # 2. bulk-evict the shortfall (coalesced write-back, shared burst)
        try:
            freed = self._evict_many(
                len(faulting) - len(self._onboard_free),
                sink=(charges, heat))
        except OutOfMemory:
            # eviction failed before any pool write: re-claim the exact
            # source slots so every page keeps its pre-call state — but
            # the source reads DID move bytes over the link, so flush
            # their charges first (the scalar path metered each read
            # before failing too)
            self._charge_links(charges, heat)
            for p, slot, scale in src_saved:
                home = self._lmb_homes[slot // self._lmb_chunk_pages]
                self._lmb_free[home].remove(slot)
                self._lmb_used[slot // self._lmb_chunk_pages] += 1
                if scale is not None:
                    self._lmb_scales[slot] = scale
                self._lmb_owner[slot] = p
            raise
        if lmb_pages:
            self.metrics.record_move(self.name, LMB, ONBOARD,
                                     len(lmb_pages) * self.lmb_page_bytes)
        # 3. assign slots: free list (LIFO, scalar order) first, then the
        # eviction-freed slots in victim order
        assigned = [self._onboard_free.pop() if self._onboard_free
                    else freed.pop(0) for _ in faulting]
        # 4. one coalesced onboard scatter (zeros for first-touch pages)
        zero = jnp.zeros(self.page_shape, self.dtype)
        batch = jnp.stack([data.get(p, zero) for p in faulting])
        self._onboard_pool = self.executor.write_pages(
            self._onboard_pool, assigned, batch)
        for p, slot in zip(faulting, assigned):
            entry = self._pages[p]
            entry.tier, entry.slot, entry.dirty = ONBOARD, slot, False
            self._onboard_owner[slot] = p
            self.policy.on_insert(p)
        self._charge_links(charges, heat)

    def _batch_capacity(self, batch: Sequence[int] = ()) -> int:
        """Onboard slots a batch can actually occupy: the tier minus
        pages pinned OUTSIDE the batch.  The scalar loop could thrash a
        working set through whatever unpinned remainder existed, one
        page at a time — batch waves must size to the same remainder or
        a gather under pin pressure would spuriously raise."""
        members = set(batch)
        pinned = sum(1 for p in self.policy._pinned()
                     if p not in members and 0 <= p < len(self._pages)
                     and self._pages[p].tier == ONBOARD)
        return max(self.onboard_pages - pinned, 1)

    def _record_dup_hits(self, page: int, n: int) -> None:
        """Account ``n`` duplicate occurrences of a single-page burst as
        onboard hits, like the scalar loop's repeat reads would."""
        for _ in range(n):
            self.metrics.record_hit(self.name, ONBOARD, self.page_bytes)
            self.policy.on_access(page)

    def _single_wave_fits(self, order: Sequence[int]) -> bool:
        """Whether the whole batch can co-reside onboard right now:
        pinned-resident members already hold their slots; the rest must
        fit in the unpinned remainder."""
        pinned = self.policy._pinned()
        member_pins = sum(1 for p in order if p in pinned
                          and self._pages[p].tier == ONBOARD)
        all_pins = sum(1 for p in pinned
                       if 0 <= p < len(self._pages)
                       and self._pages[p].tier == ONBOARD)
        return (len(order) - member_pins
                <= max(self.onboard_pages - all_pins, 0))

    def _iter_waves(self, pages: Sequence[int], order: Sequence[int]):
        """Split a too-large batch into processable waves, yielding
        ``(wave, occ)`` — the wave's distinct pages and their duplicate-
        preserving occurrences.  Pinned-resident members go first (pure
        hits, no eviction needed); the rest waves through the unpinned
        capacity, recomputed each round since a wave may fault a pinned
        page onboard."""
        remaining = list(order)
        while remaining:
            pinned = self.policy._pinned()
            wave = [p for p in remaining if p in pinned
                    and self._pages[p].tier == ONBOARD]
            if not wave:
                wave = remaining[:self._batch_capacity()]
            members = set(wave)
            yield wave, [p for p in pages if p in members]
            remaining = [p for p in remaining if p not in members]

    def _prefetch(self, page: int) -> None:
        self._prefetch_many([page])

    def _note_prefetch_evict(self, page: int) -> None:
        """A prefetched page got demoted before anyone read it: wasted
        link bytes (the fault-rate-delta signal the prefetch_sweep
        benchmark reports)."""
        if page in self._prefetched:
            self._prefetched.discard(page)
            self.prefetch_wasted += 1

    def _note_prefetch_hit(self, page: int) -> None:
        """A demand read landed on a prefetched page: the prefetch was
        useful (its LMB round-trip was paid early, hidden or not)."""
        if page in self._prefetched:
            self._prefetched.discard(page)
            self.prefetch_used += 1

    def _prefetch_runs(self) -> int:
        """One prefetch round: pull chunk-aligned run suggestions, keep
        only LMB-resident pages, cap at the free-slot budget (prefetch
        NEVER evicts a resident page), let the overlap scheduler admit
        what fits behind the current compute window, and hand the
        remainder back to the backlog (deferred, not dropped).  All
        admitted pages move as ONE coalesced burst.  Returns the number
        of pages issued."""
        if not self.prefetcher:
            return 0
        runs = self.prefetcher.suggest_runs(self.num_pages - 1,
                                            self._lmb_chunk_pages)
        if not runs:
            return 0
        live: List[Tuple[str, List[int]]] = []
        seen: set = set()
        for run in runs:
            pages = [p for p in run.pages
                     if p not in seen and 0 <= p < len(self._pages)
                     and self._pages[p].tier == LMB]
            seen.update(pages)
            if pages:
                live.append((run.source, pages))
        #: original priority position of every candidate page — deferred
        #: pages re-queue in THIS order, whichever budget pass cut them
        #: (a free-slot tail must not jump ahead of an overlap-deferred
        #: run that preceded it)
        priority = {p: i for i, p in
                    enumerate(p for _, pages in live for p in pages)}
        deferred: List[Tuple[str, int]] = []   # (source, page)
        issued = 0
        try:
            if not live:
                return 0
            # hard budget first: free onboard slots only.  A run that
            # half-fits is truncated (still one burst); the cut tail and
            # everything after defer.
            free = len(self._onboard_free)
            fitted: List[Tuple[str, List[int]]] = []
            for source, pages in live:
                take = pages[:free]
                free -= len(take)
                if take:
                    fitted.append((source, take))
                deferred.extend((source, p) for p in pages[len(take):])
            # burst hysteresis: stride guesses below the min-burst size
            # wait (regenerated next round, when the frontier has grown)
            # so steady-state lookahead stays burst-shaped; scheduled
            # pages always go now
            stride_pages = sum(len(pages) for source, pages in fitted
                               if source == "stride")
            if 0 < stride_pages < self.prefetch_min_burst:
                fitted = [(s, pages) for s, pages in fitted
                          if s != "stride"]
            # overlap admission: whole runs, in priority order, while
            # they fit behind the compute window
            if self.overlap is not None and fitted:
                n_admit, _ = self.overlap.admit(
                    [len(pages) for _, pages in fitted],
                    self.lmb_page_bytes)
                deferred.extend((source, p)
                                for source, pages in fitted[n_admit:]
                                for p in pages)
                fitted = fitted[:n_admit]
            issue = [p for _, pages in fitted for p in pages]
            if issue:
                self._prefetch_many(issue)
                issued = len(issue)
            return issued
        finally:
            # exact scheduled knowledge is deferred back to the front of
            # the backlog in original priority order; stride guesses are
            # regenerated for free next round, so re-queueing them would
            # only pollute it
            requeue = sorted(
                (p for source, p in deferred if source == "scheduled"),
                key=priority.__getitem__)
            if requeue:
                self.prefetcher.defer(requeue)
                self.prefetch_deferred += len(requeue)
                tr = self.trace
                if tr.enabled:
                    tr.event("prefetch.defer", op="prefetch",
                             pages=len(requeue),
                             nbytes=len(requeue) * self.lmb_page_bytes)

    def _prefetch_many(self, pages: Sequence[int]) -> None:
        """Opportunistic LMB->onboard copies bounded by FREE onboard slots
        (never evicts to prefetch), moved as coalesced per-chunk runs with
        one metering burst tagged ``op="prefetch"`` — prefetch traffic is
        distinguishable from demand on the FM's journal/byte counters and
        never pays per-page arbitration."""
        cands = [p for p in dict.fromkeys(pages)
                 if 0 <= p < len(self._pages)
                 and self._pages[p].tier == LMB]
        cands = cands[:len(self._onboard_free)]
        if not cands:
            return
        tr = self.trace
        t0 = tr.now() if tr.enabled else 0.0
        charges: List[Tuple[int, Optional[int]]] = []
        src_slots = [self._pages[p].slot for p in cands]
        data = self._read_runs(src_slots, charges)
        self.metrics.record_move(self.name, LMB, ONBOARD,
                                 len(cands) * self.lmb_page_bytes)
        assigned = [self._onboard_free.pop() for _ in cands]
        self._onboard_pool = self.executor.write_pages(
            self._onboard_pool, assigned, jnp.stack(data))
        for p, slot in zip(cands, assigned):
            entry = self._pages[p]
            self._lmb_slot_free(entry.slot)
            self._lmb_owner.pop(entry.slot, None)
            entry.tier, entry.slot, entry.dirty = ONBOARD, slot, False
            self._onboard_owner[slot] = p
            self.policy.on_insert(p)
        self._prefetched.update(cands)
        self.prefetch_bursts += 1
        self.prefetch_pages_total += len(cands)
        self._charge_links(charges, cands, op="prefetch")
        if tr.enabled:
            tr.add("prefetch.burst", t0, tr.now() - t0, op="prefetch",
                   nbytes=len(cands) * self.lmb_page_bytes,
                   pages=len(cands))

    # ------------------------------------------------------------------- API
    def read(self, page: int) -> jax.Array:
        self._check(page)
        slot = self._fault_in(page)
        return self.executor.read_page(self._onboard_pool, slot)

    def write(self, page: int, data) -> None:
        self._check(page)
        entry = self._pages[page]
        if entry.refcount > 1:
            self._cow(page)
            entry = self._pages[page]
        data = jnp.asarray(data, self.dtype)
        if data.shape != self.page_shape:
            raise ValueError(
                f"{self.name}: page shape {data.shape} != {self.page_shape}")
        slot = self._fault_in(page)
        self._onboard_pool = self.executor.write_page(
            self._onboard_pool, slot, data)
        self._pages[page].dirty = True
        if hasattr(self.policy, "mark_dirty"):
            self.policy.mark_dirty(page, True)

    def read_many(self, pages: Sequence[int]) -> jax.Array:
        """Batched :meth:`read`: fault the pages in with coalesced
        per-chunk transfers and bulk eviction, then return them stacked
        ``[len(pages), *page_shape]`` via one gather against the onboard
        pool.  Duplicates allowed.  Batches larger than the onboard tier
        are served in capacity-sized waves."""
        pages = list(pages)
        if not pages:
            return jnp.zeros((0, *self.page_shape), self.dtype)
        order = list(dict.fromkeys(pages))
        if len(order) == 1:
            # a 1-page "burst" IS the scalar path (same bytes, same
            # single-digit arbiter calls) minus the gather machinery;
            # data[None] over jnp.stack keeps the decode path at true
            # scalar dispatch cost
            data = self.read(order[0])
            self._record_dup_hits(order[0], len(pages) - 1)
            if len(pages) == 1:
                return data[None]
            return jnp.stack([data] * len(pages))
        if self._single_wave_fits(order):
            slotmap = self._fault_in_many(pages)
            return self.executor.read_pages(
                self._onboard_pool, [slotmap[p] for p in pages])
        # batch exceeds the batch-usable onboard capacity: wave through,
        # capturing each wave's data before the next wave may evict it
        datas: Dict[int, jax.Array] = {}
        for wave, occ in self._iter_waves(pages, order):
            slotmap = self._fault_in_many(occ)
            arr = self.executor.read_pages(
                self._onboard_pool, [slotmap[p] for p in wave])
            for j, p in enumerate(wave):
                datas[p] = arr[j]
        return jnp.stack([datas[p] for p in pages])

    def write_many(self, pages: Sequence[int], data) -> None:
        """Batched :meth:`write`: ``data[i]`` -> ``pages[i]`` with one
        coalesced onboard scatter after a batched fault (duplicate pages:
        last write wins, like the scalar loop)."""
        pages = list(pages)
        data = jnp.asarray(data, self.dtype)
        if data.shape != (len(pages), *self.page_shape):
            raise ValueError(
                f"{self.name}: batch shape {data.shape} != "
                f"{(len(pages), *self.page_shape)}")
        for p in dict.fromkeys(pages):
            self._check(p)
            if self._pages[p].refcount > 1:
                self._cow(p)
        order = list(dict.fromkeys(pages))
        last = {p: i for i, p in enumerate(pages)}
        if len(order) == 1:
            self.write(order[0], data[last[order[0]]])
            self._record_dup_hits(order[0], len(pages) - 1)
            return
        for wave, occ in self._iter_waves(pages, order):
            slotmap = self._fault_in_many(occ)
            self._onboard_pool = self.executor.write_pages(
                self._onboard_pool, [slotmap[p] for p in wave],
                data[np.asarray([last[p] for p in wave])])
            # dirty-mark per wave: a later wave may evict these pages,
            # and eviction must observe (and clear) their dirty state
            # exactly as the scalar interleave would
            for p in wave:
                self._pages[p].dirty = True
                if hasattr(self.policy, "mark_dirty"):
                    self.policy.mark_dirty(p, True)

    def gather(self, pages: Sequence[int]) -> jax.Array:
        """Stack several logical pages (faulting them in) — kernel feed.
        Built on :meth:`read_many`: coalesced transfers, bulk eviction,
        one arbiter charge per touched expander link."""
        return self.read_many(pages)

    def pin(self, page: int) -> None:
        self._fault_in(page)
        self.policy.pin(page)

    def unpin(self, page: int) -> None:
        self.policy.unpin(page)

    def pin_many(self, pages: Sequence[int]) -> None:
        """Batched :meth:`pin`: one coalesced fault burst, then pin.
        Raises OutOfMemory when the pages cannot all co-reside onboard
        (the scalar pin loop raised once pins exhausted the tier; a
        silent partial pin would hand the DMA scheduler LMB slots)."""
        self._fault_in_many(pages, co_resident=True)
        for p in dict.fromkeys(pages):
            self.policy.pin(p)

    def unpin_many(self, pages: Sequence[int]) -> None:
        for p in dict.fromkeys(pages):
            self.policy.unpin(p)

    def schedule_prefetch(self, pages: Sequence[int]) -> None:
        """Feed exact future page knowledge (a scheduler's next-round
        access list) to the prefetcher and issue as much of it as fits
        RIGHT NOW — free onboard slots and the overlap window budget —
        as coalesced per-(chunk, expander) bursts.  The seed truncated
        the list to the first ``depth`` pages and silently discarded the
        rest; now the remainder stays in the bounded backlog (or is
        deferred by the overlap scheduler) and issues on later rounds."""
        if not self.prefetcher:
            return
        self.prefetcher.schedule(list(pages))
        while self.prefetcher.pending():
            before = self.prefetcher.pending()
            self._prefetch_runs()
            if self.prefetcher.pending() >= before:
                break       # budgets exhausted (deferred) — later rounds

    def note_compute_window(self, seconds: float,
                            observed: bool = True) -> None:
        """Open a new overlap window sized to the consumer's compute
        step.  ``observed=True`` folds the sample into the scheduler's
        EWMA estimate (the serving engine feeds measured decode-round
        times); ``observed=False`` pins the window exactly (benchmarks
        and simulators declaring a known compute budget).  No-op without
        an overlap scheduler."""
        if self.overlap is None:
            return
        if observed:
            self.overlap.observe_compute(seconds)
            self.overlap.start_window()
        else:
            self.overlap.start_window(seconds)

    # ------------------------------------------------------------- share / COW
    def share(self, page: int) -> int:
        """Refcount++ (zero-copy share). Returns the same logical index."""
        self._check(page)
        self._pages[page].refcount += 1
        return page

    def share_many(self, pages: Sequence[int]) -> List[int]:
        """Batched :meth:`share` (one call for a whole sequence fork)."""
        out = []
        for p in pages:
            self._check(p)
            self._pages[p].refcount += 1
            out.append(p)
        return out

    def release(self, page: int) -> None:
        """Refcount--; frees storage at zero."""
        self._check(page)
        entry = self._pages[page]
        entry.refcount -= 1
        if entry.refcount > 0:
            return
        self._prefetched.discard(page)
        if entry.tier == ONBOARD:
            self.policy.on_remove(page)
            self._onboard_free.append(entry.slot)
            self._onboard_owner.pop(entry.slot, None)
        elif entry.tier == LMB:
            self._lmb_slot_free(entry.slot)
            self._lmb_owner.pop(entry.slot, None)
        entry.tier, entry.slot, entry.dirty = None, -1, False
        entry.refcount = 0

    def _cow(self, page: int) -> None:
        """Copy-on-write: writer gets a private copy of a shared page."""
        entry = self._pages[page]
        data = self.read(page)
        entry.refcount -= 1
        new = PageEntry()
        self._pages[page] = new
        slot = self._onboard_slot_alloc()
        self._onboard_pool = self.executor.write_page(
            self._onboard_pool, slot, data)
        new.tier, new.slot, new.dirty = ONBOARD, slot, True
        self._onboard_owner[slot] = page
        self.policy.on_insert(page)
        # the old physical page stays where it is, now owned by the sharers;
        # bookkeeping for "who else maps it" lives in the serving layer,
        # which tracks logical page ids per request.

    # --------------------------------------------------------- hot-page moves
    def page_expander(self, page: int) -> Optional[int]:
        """Which expander homes this page's LMB slot (None if not in LMB)."""
        entry = self._pages[page]
        if entry.tier != LMB:
            return None
        return self._lmb_homes[entry.slot // self._lmb_chunk_pages]

    def lmb_placement(self) -> Dict[int, int]:
        """LMB-resident page count per home expander."""
        out: Dict[int, int] = {}
        for e in self._pages:
            if e.tier == LMB:
                home = self._lmb_homes[e.slot // self._lmb_chunk_pages]
                out[home] = out.get(home, 0) + 1
        return out

    def hottest_pages(self, limit: int,
                      expander_id: Optional[int] = None,
                      min_heat: float = 0.0) -> List[int]:
        """LMB-resident pages by descending access heat — the migration
        candidates for one saturated expander.  One vectorized decay over
        the heat arrays instead of a per-page dict walk."""
        if not self._pages:
            return []
        n = len(self._pages)
        dec = (self._heat_val[:n]
               * self.heat_decay ** (self._heat_clock - self._heat_at[:n]))
        cands = []
        for p, e in enumerate(self._pages):
            if e.tier != LMB:
                continue
            if (expander_id is not None
                    and self.page_expander(p) != expander_id):
                continue
            h = float(dec[p])
            if h < min_heat:
                continue
            cands.append((h, p))
        cands.sort(reverse=True)
        return [p for _, p in cands[:limit]]

    def migrate_pages(self, pages: Sequence[int], dst_expander: int) -> int:
        """Move LMB-resident pages onto chunks homed on ``dst_expander``.

        Contents are preserved (read from the source chunks, written to
        the destination chunks — coalesced per-chunk runs, one arbiter
        charge per touched link instead of per page); both links are
        metered, so migration traffic is visible as occupancy on each
        side.  Source chunks left empty are reclaimed, which frees their
        allocation and revokes the device's SAT/IOMMU entries on the
        source blocks — the destination grant was authorized when its
        chunk was allocated (the failover re-grant machinery).  Returns
        the number of pages actually moved: when the destination refuses
        growth (quota or pool exhausted) the batch stops early with every
        remaining page intact on its source."""
        movers: List[int] = []
        # dedupe: the scalar loop skipped a repeated page because its
        # home had already changed by the second occurrence
        for page in dict.fromkeys(pages):
            self._check(page)
            entry = self._pages[page]
            if entry.tier != LMB:
                continue
            src_home = self._lmb_homes[entry.slot // self._lmb_chunk_pages]
            if src_home == dst_expander:
                continue
            movers.append(page)
        # claim every destination slot FIRST: an OutOfMemory (quota, full
        # pool) must fire before any source page is touched — with
        # compress_lmb a read pops the source's scale, so failing
        # mid-move would corrupt the page.  A refusal truncates the batch
        # to the prefix that got slots (scalar stop-early semantics).
        dsts: List[int] = []
        for _ in movers:
            try:
                dsts.append(self._lmb_slot_alloc(expander_id=dst_expander))
            except OutOfMemory:
                break
        movers = movers[:len(dsts)]
        if not movers:
            return 0
        charges: List[Tuple[int, Optional[int]]] = []
        src_slots = [self._pages[p].slot for p in movers]
        src_homes = [self._lmb_homes[s // self._lmb_chunk_pages]
                     for s in src_slots]
        data = self._read_runs(src_slots, charges)     # meters source links
        self._write_runs(dsts, data, charges)          # meters dest link
        # scalar parity: migration traffic does NOT bump access heat
        self._charge_links(charges, [])
        moved_by_home: Dict[int, int] = {}
        for i, page in enumerate(movers):
            entry = self._pages[page]
            entry.slot = dsts[i]
            self._lmb_owner[dsts[i]] = page
            self._lmb_owner.pop(src_slots[i], None)
            self._lmb_slot_free(src_slots[i])
            moved_by_home[src_homes[i]] = moved_by_home.get(
                src_homes[i], 0) + 1
        for home, n in moved_by_home.items():
            self.metrics.record_move(self.name, f"{LMB}@{home}",
                                     f"{LMB}@{dst_expander}",
                                     n * self.lmb_page_bytes)
        self._reclaim_empty_chunks()
        tr = self.trace
        if tr.enabled:
            tr.event("migrate.batch", op="migrate",
                     expander=dst_expander, pages=len(movers),
                     nbytes=len(movers) * self.lmb_page_bytes,
                     sources=sorted(moved_by_home))
        return len(movers)

    def _reclaim_empty_chunks(self) -> None:
        """Free fully-empty LMB chunks back through the Table-2 API (which
        revokes this device's SAT/IOMMU entries and may return the 256 MB
        block to the FM)."""
        for chunk, used in enumerate(self._lmb_used):
            if used != 0 or self._lmb_pools[chunk] is None:
                continue
            base = chunk * self._lmb_chunk_pages
            home = self._lmb_homes[chunk]
            if home in self._lmb_free:
                self._lmb_free[home] = [
                    s for s in self._lmb_free[home]
                    if not base <= s < base + self._lmb_chunk_pages]
            self._lmb_allocs[chunk].free()
            self._lmb_pools[chunk] = None
            self._lmb_allocs[chunk] = None
            self._lmb_homes[chunk] = -1

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Release the buffer's entire LMB footprint: every live chunk
        capability is freed back through the Table-2 path (revoking
        SAT/IOMMU entries, returning drained blocks to the FM).  LMB-
        resident pages revert to 'never written'; the buffer enters
        degraded (onboard-only) mode so later paging cannot silently
        re-acquire LMB quota, and its failover callback is removed from
        the FM.  Called by LMBSystem.close() so a session cannot leak
        quota through its buffers."""
        self.degraded = True
        self._closed = True
        self.host.fm.off_failover(self._on_failover)
        self.host.fm.off_repair(self._on_repair)
        for chunk, handle in enumerate(self._lmb_allocs):
            if handle is None:
                continue
            if not handle.stale:
                handle.free()
            self._lmb_pools[chunk] = None
            self._lmb_allocs[chunk] = None
            self._lmb_homes[chunk] = -1
            self._lmb_used[chunk] = 0
        for e in self._pages:
            if e.tier == LMB:
                e.tier, e.slot, e.dirty = None, -1, False
        self._lmb_owner.clear()
        self._lmb_scales.clear()
        self._lmb_free = {}

    # ------------------------------------------------------------ failure path
    def _on_failover(self, expander_id: Optional[int] = None) -> None:
        """An expander failed.  Pages homed on it are gone (re-granted
        blocks are blank): they revert to 'never written' (zeros on next
        touch); consumers holding a journal (checkpoint) re-populate.
        Pages homed on surviving pooled expanders are untouched.  With
        nowhere to fail over to we enter degraded mode instead (see
        inject_failure in fabric.py)."""
        if not self.host.fm.healthy:
            # last expander died: the LMB tier is gone for good — shed its
            # pages below, and refuse future growth
            self.degraded = True
        dead = {chunk for chunk, home in enumerate(self._lmb_homes)
                if self._lmb_pools[chunk] is not None
                and (expander_id is None or home == expander_id)}
        if not dead:
            return
        for e in self._pages:
            if e.tier == LMB and e.slot // self._lmb_chunk_pages in dead:
                e.tier, e.slot, e.dirty = None, -1, False
        for slot in [s for s in self._lmb_owner
                     if s // self._lmb_chunk_pages in dead]:
            del self._lmb_owner[slot]
        for slot in [s for s in self._lmb_scales
                     if s // self._lmb_chunk_pages in dead]:
            del self._lmb_scales[slot]
        self._lmb_free = {
            eid: [s for s in lst
                  if s // self._lmb_chunk_pages not in dead]
            for eid, lst in self._lmb_free.items()}
        for chunk in dead:
            # the FM re-granted the underlying blocks blank; the old
            # allocation bookkeeping is unrecoverable, so drop references
            # without freeing (the journal is the recovery source of truth)
            self._lmb_pools[chunk] = None
            self._lmb_allocs[chunk] = None
            self._lmb_homes[chunk] = -1
            self._lmb_used[chunk] = 0
        self.metrics.event(
            self.name, "failover: LMB pages on expander "
                       f"{'*' if expander_id is None else expander_id} "
                       "invalidated")

    def _on_repair(self, expander_id: int) -> None:
        """A failed expander was readmitted (blank).  If the pool is
        healthy again, exit degraded mode: paging may grow fresh LMB
        chunks — with fresh capabilities and fresh SAT/IOMMU mappings —
        on the repaired capacity.  Nothing is restored retroactively:
        pages invalidated at failure stay 'never written', and chunk
        handles freed (or orphaned) while degraded stay stale.  A
        CLOSED buffer never leaves degraded mode — close() means the
        footprint was released for good."""
        if self._closed:
            return
        if self.degraded and self.host.fm.healthy:
            self.degraded = False
            self.metrics.event(
                self.name,
                f"repair: expander {expander_id} readmitted; LMB tier "
                "available again")

    # --------------------------------------------------------------- validation
    def _check(self, page: int) -> None:
        if not 0 <= page < len(self._pages):
            raise IndexError(f"{self.name}: page {page} out of range")

    def check_invariants(self) -> None:
        """Structural invariants (exercised by hypothesis tests)."""
        onboard_slots = [e.slot for e in self._pages if e.tier == ONBOARD]
        assert len(onboard_slots) == len(set(onboard_slots)), "slot aliasing"
        assert len(onboard_slots) + len(self._onboard_free) == \
            self.onboard_pages, "onboard slot leak"
        lmb_slots = [e.slot for e in self._pages if e.tier == LMB]
        assert len(lmb_slots) == len(set(lmb_slots)), "lmb slot aliasing"
        alive = [c for c, p in enumerate(self._lmb_pools) if p is not None]
        total_lmb = len(alive) * self._lmb_chunk_pages
        free_flat = [s for lst in self._lmb_free.values() for s in lst]
        assert len(free_flat) == len(set(free_flat)), "free slot aliasing"
        assert len(lmb_slots) + len(free_flat) == total_lmb, \
            "lmb slot leak"
        for eid, lst in self._lmb_free.items():
            for s in lst:
                assert self._lmb_homes[s // self._lmb_chunk_pages] == eid, \
                    "free-list home drift"
        for slot in lmb_slots + free_flat:
            assert self._lmb_pools[slot // self._lmb_chunk_pages] \
                is not None, "slot points at reclaimed chunk"
        for chunk in alive:
            base = chunk * self._lmb_chunk_pages
            used = sum(1 for s in lmb_slots
                       if base <= s < base + self._lmb_chunk_pages)
            assert used == self._lmb_used[chunk], "chunk occupancy drift"
        for slot, page in self._onboard_owner.items():
            e = self._pages[page]
            assert e.tier == ONBOARD and e.slot == slot, "owner map stale"
        assert len(self._heat_val) >= len(self._pages), "heat array drift"

    def prefetch_stats(self) -> dict:
        """Prefetch-path health: burst counts, usefulness (used vs
        wasted), deferrals, and the wait the overlap window hid."""
        st = {
            "enabled": self.prefetcher is not None,
            "bursts": self.prefetch_bursts,
            "pages": self.prefetch_pages_total,
            "used": self.prefetch_used,
            "wasted": self.prefetch_wasted,
            "unread": len(self._prefetched),
            "deferred": self.prefetch_deferred,
            "hidden_wait_s": self.prefetch_hidden_s,
            "backlog": self.prefetcher.pending() if self.prefetcher else 0,
        }
        if self.overlap is not None:
            st["overlap"] = self.overlap.snapshot()
        return st

    def stats(self) -> dict:
        tiers = {ONBOARD: 0, LMB: 0, "unmaterialized": 0}
        for e in self._pages:
            tiers[e.tier if e.tier else "unmaterialized"] += 1
        c = self.metrics.tier(self.name, ONBOARD)
        return {
            "pages": self.num_pages,
            "resident": tiers,
            "hit_ratio": c.hit_ratio,
            "lmb_bytes_held": self.host.owned_bytes(self.device_id),
            "degraded": self.degraded,
            "link_wait_s": self.link_wait_s,
            "link_utilization": self.host.fm.link_utilization(),
            "lmb_placement": self.lmb_placement(),
            "prefetch": self.prefetch_stats(),
        }
