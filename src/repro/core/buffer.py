"""LinkedBuffer: a logical paged array spanning onboard memory and the LMB.

This is the consumer-facing realization of the paper's idea: a device whose
working set exceeds onboard memory sees one flat buffer; hot pages live in
the **onboard tier** (a bounded device pool — HBM on TPU), cold pages live in
the **LMB tier** (expander-backed, allocated through the Table-2 API).  The
page table plays the role the L2P table plays in the SSD: every access
resolves logical page → (tier, slot) host-side (allocator metadata stays in
host memory, §3.2), then the data path touches exactly one tier.

Capabilities:
  * demand paging with pluggable eviction (LRU/CLOCK/cost-aware) + prefetch
  * dirty tracking with write-back (single-writer "uncached" semantics — the
    paper's PCIe devices don't participate in coherence, and neither do we:
    ownership transfer is explicit)
  * pin/unpin for pages a compiled step will touch (DMA in flight)
  * refcounted page sharing + copy-on-write (zero-copy prefix sharing, the
    paper's SSD→accelerator shared-buffer scenario)
  * degraded mode on expander failure (availability: fall back to
    onboard-only, shedding capacity rather than dying); on a pooled
    fabric a partial failure only invalidates the pages homed on the
    dead expander
  * optional **int8 page compression on demotion** (``compress_lmb``) —
    beyond-paper: cold pages cost 1/4 the pool bytes and PCIe traffic
    (per-page absmax scale kept in HOST metadata, like all LMB metadata);
    lossy (~1e-2 relative) — suited to KV caches, not optimizer state
  * **per-page access heat** (exponentially-decayed touch counters fed by
    the link-metering path) + :meth:`migrate_pages`, the mechanism the
    MigrationEngine (repro.qos.migration) uses to move hot LMB pages off
    a saturated expander link onto a cooler one
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import LMBHost
from repro.core.client import MemoryHandle
from repro.core.metrics import Metrics, GLOBAL_METRICS
from repro.core.offload import TierExecutor
from repro.core.policy import EvictionPolicy, Prefetcher, make_policy
from repro.core.pool import OutOfMemory

ONBOARD = "onboard"
LMB = "lmb"


@dataclasses.dataclass
class PageEntry:
    tier: Optional[str] = None   # None = never written (implicit zeros)
    slot: int = -1
    dirty: bool = False
    refcount: int = 1


class LinkedBuffer:
    """A paged logical buffer over (onboard pool, LMB pool)."""

    def __init__(self, *,
                 name: str,
                 device_id: str,
                 host: LMBHost,
                 executor: Optional[TierExecutor] = None,
                 page_shape: Tuple[int, ...],
                 dtype=jnp.float32,
                 onboard_pages: int,
                 policy: str | EvictionPolicy = "lru",
                 prefetch_depth: int = 0,
                 lmb_chunk_pages: int = 64,
                 compress_lmb: bool = False,
                 metrics: Optional[Metrics] = None):
        self.name = name
        self.device_id = device_id
        self.host = host
        self.executor = executor or TierExecutor()
        self.page_shape = tuple(page_shape)
        self.dtype = dtype
        self.onboard_pages = int(onboard_pages)
        self.compress_lmb = compress_lmb
        self.page_bytes = int(np.prod(self.page_shape)) * jnp.dtype(dtype).itemsize
        #: bytes a page occupies in the LMB tier (int8 + host-side scale)
        self.lmb_page_bytes = (int(np.prod(self.page_shape))
                               if compress_lmb else self.page_bytes)
        self.metrics = metrics or GLOBAL_METRICS
        self.policy: EvictionPolicy = (
            make_policy(policy) if isinstance(policy, str) else policy)
        self.prefetcher = Prefetcher(prefetch_depth) if prefetch_depth else None
        self.degraded = False
        host.fm.on_failover(self._on_failover)
        # QoS link metering: every byte crossing to/from the LMB tier is
        # charged to this device's share of the expander link.  If the
        # caller's executor carries a meter hook AND actually fires it
        # (only on real host tiers — in pure modeling mode the executor
        # can't tell LMB pools from device arrays), defer to it to avoid
        # double-charging the same page move.  On a POOLED fabric the
        # buffer always meters itself: only it knows which expander backs
        # the touched chunk, while an executor hook is a bare meter(nbytes)
        # that would dump everything on the fallback link — so don't bind
        # an executor meter over a multi-expander FM.
        pooled = len(host.fm.healthy_expander_ids()) > 1
        if (pooled and self.executor.meter is not None
                and self.executor.real_host_tier):
            raise ValueError(
                f"{name}: an executor-level meter hook cannot attribute "
                "transfers to an expander on a pooled fabric (and the "
                "buffer's own per-block metering would double-charge); "
                "construct the TierExecutor without meter= and let the "
                "buffer meter")
        self._meter_via_executor = (self.executor.meter is not None
                                    and self.executor.real_host_tier)
        self.link_wait_s = 0.0

        # pools
        self._onboard_pool = self.executor.alloc_pool(
            self.onboard_pages, self.page_shape, dtype, tier="onboard")
        self._onboard_free: List[int] = list(range(self.onboard_pages))[::-1]
        self._onboard_owner: Dict[int, int] = {}  # slot -> logical page

        self._lmb_chunk_pages = lmb_chunk_pages
        self._lmb_scales: Dict[int, float] = {}   # slot -> absmax scale
        self._lmb_pools: List[Optional[jax.Array]] = []  # None = reclaimed
        #: per-chunk capability for the backing LMB allocation
        self._lmb_allocs: List[Optional[MemoryHandle]] = []
        self._lmb_free: List[int] = []            # global lmb slot ids
        self._lmb_owner: Dict[int, int] = {}
        self._lmb_homes: List[int] = []           # chunk -> expander id
        self._lmb_used: List[int] = []            # chunk -> occupied slots

        # access heat: exponentially-decayed touch counters, bumped on the
        # link-metering path (every byte a page moves over an expander link
        # is a vote for migrating it somewhere cooler).  Lazy decay: store
        # (value, clock-at-touch) and age on read.
        self.heat_decay = 0.95
        self._heat: Dict[int, Tuple[float, int]] = {}
        self._heat_clock = 0

        self._pages: List[PageEntry] = []

    # ------------------------------------------------------------------ sizing
    @property
    def num_pages(self) -> int:
        return len(self._pages)

    def logical_bytes(self) -> int:
        return self.num_pages * self.page_bytes

    def onboard_bytes(self) -> int:
        return self.onboard_pages * self.page_bytes

    # --------------------------------------------------------------- allocation
    def append_pages(self, n: int = 1) -> List[int]:
        """Extend the logical buffer by ``n`` zero pages; returns indices."""
        base = len(self._pages)
        self._pages.extend(PageEntry() for _ in range(n))
        return list(range(base, base + n))

    def _grow_lmb(self, expander_id: Optional[int] = None) -> None:
        if self.degraded:
            raise OutOfMemory(f"{self.name}: LMB tier unavailable (degraded)")
        chunk_bytes = self._lmb_chunk_pages * self.lmb_page_bytes
        # class-agnostic capability alloc: the host dispatches PCIe/CXL
        handle = MemoryHandle.alloc(self.host, self.device_id, chunk_bytes,
                                    expander_id=expander_id)
        pool = self.executor.alloc_pool(
            self._lmb_chunk_pages, self.page_shape,
            jnp.int8 if self.compress_lmb else self.dtype, tier="lmb")
        chunk_idx = len(self._lmb_pools)
        self._lmb_pools.append(pool)
        self._lmb_allocs.append(handle)
        self._lmb_homes.append(handle.expander())
        self._lmb_used.append(0)
        base = chunk_idx * self._lmb_chunk_pages
        self._lmb_free.extend(range(base, base + self._lmb_chunk_pages))

    def _lmb_slot_alloc(self, expander_id: Optional[int] = None) -> int:
        """Take a free LMB slot; ``expander_id`` restricts the slot to a
        chunk homed on that expander (migration placement)."""
        if expander_id is None:
            if not self._lmb_free:
                self._grow_lmb()
            slot = self._lmb_free.pop()
        else:
            idx = next(
                (i for i, s in enumerate(self._lmb_free)
                 if self._lmb_homes[s // self._lmb_chunk_pages]
                 == expander_id), None)
            if idx is None:
                self._grow_lmb(expander_id)
                idx = len(self._lmb_free) - 1
            slot = self._lmb_free.pop(idx)
        self._lmb_used[slot // self._lmb_chunk_pages] += 1
        return slot

    def _lmb_slot_free(self, slot: int) -> None:
        self._lmb_free.append(slot)
        self._lmb_used[slot // self._lmb_chunk_pages] -= 1
        self._lmb_scales.pop(slot, None)

    def _touch_heat(self, page: int) -> None:
        self._heat_clock += 1
        val, at = self._heat.get(page, (0.0, self._heat_clock))
        val *= self.heat_decay ** (self._heat_clock - at)
        self._heat[page] = (val + 1.0, self._heat_clock)

    def page_heat(self, page: int) -> float:
        """Decayed touch count: how hot this page runs on the LMB link."""
        val, at = self._heat.get(page, (0.0, self._heat_clock))
        return val * self.heat_decay ** (self._heat_clock - at)

    def _meter_link(self, chunk: Optional[int] = None,
                    page: Optional[int] = None) -> None:
        if page is not None:
            self._touch_heat(page)
        if not self._meter_via_executor:
            alloc = (self._lmb_allocs[chunk]
                     if chunk is not None else None)
            self.link_wait_s += self.host.meter_transfer(
                self.device_id, self.lmb_page_bytes,
                mmid=alloc.mmid if alloc is not None else None)

    def _lmb_read(self, slot: int, page: Optional[int] = None) -> jax.Array:
        chunk, off = divmod(slot, self._lmb_chunk_pages)
        # access-control check on the data path (IOMMU/SAT)
        self.host.check_access(self.device_id, self._lmb_allocs[chunk].mmid)
        self._meter_link(chunk, page)
        page_data = self.executor.read_page(self._lmb_pools[chunk], off)
        if self.compress_lmb:
            scale = self._lmb_scales.pop(slot, 0.0)
            page_data = (page_data.astype(jnp.float32)
                         * scale).astype(self.dtype)
        return page_data

    def _lmb_write(self, slot: int, data: jax.Array,
                   page: Optional[int] = None) -> None:
        chunk, off = divmod(slot, self._lmb_chunk_pages)
        self.host.check_access(self.device_id, self._lmb_allocs[chunk].mmid)
        self._meter_link(chunk, page)
        if self.compress_lmb:
            f = data.astype(jnp.float32)
            amax = float(jnp.max(jnp.abs(f))) + 1e-12
            self._lmb_scales[slot] = amax / 127.0
            data = jnp.clip(jnp.round(f * (127.0 / amax)),
                            -127, 127).astype(jnp.int8)
        self._lmb_pools[chunk] = self.executor.write_page(
            self._lmb_pools[chunk], off, data)

    # ------------------------------------------------------------------ paging
    def _evict_one(self) -> int:
        """Demote one onboard page to the LMB tier; return the freed slot."""
        victim = self.policy.victim()
        if victim is None:
            raise OutOfMemory(
                f"{self.name}: onboard tier full and nothing evictable "
                f"(all {self.onboard_pages} pages pinned)")
        entry = self._pages[victim]
        assert entry.tier == ONBOARD
        slot = entry.slot
        if self.degraded:
            raise OutOfMemory(
                f"{self.name}: degraded mode — working set exceeds onboard "
                "capacity and the LMB tier is gone")
        lmb_slot = self._lmb_slot_alloc()
        page = self.executor.read_page(self._onboard_pool, slot)
        self._lmb_write(lmb_slot, page, victim)
        self.metrics.record_move(self.name, ONBOARD, LMB,
                                 self.lmb_page_bytes)
        entry.tier, entry.slot, entry.dirty = LMB, lmb_slot, False
        self._lmb_owner[lmb_slot] = victim
        self.policy.on_remove(victim)
        del self._onboard_owner[slot]
        return slot

    def _onboard_slot_alloc(self) -> int:
        if self._onboard_free:
            return self._onboard_free.pop()
        return self._evict_one()

    def _fault_in(self, page: int) -> int:
        """Bring a page onboard; returns the onboard slot."""
        entry = self._pages[page]
        if entry.tier == ONBOARD:
            self.metrics.record_hit(self.name, ONBOARD, self.page_bytes)
            self.policy.on_access(page)
            return entry.slot
        self.metrics.record_miss(self.name, ONBOARD, self.page_bytes)
        slot = self._onboard_slot_alloc()
        if entry.tier == LMB:
            data = self._lmb_read(entry.slot, page)
            self._onboard_pool = self.executor.write_page(
                self._onboard_pool, slot, data)
            self.metrics.record_move(self.name, LMB, ONBOARD,
                                     self.lmb_page_bytes)
            self._lmb_slot_free(entry.slot)
            self._lmb_owner.pop(entry.slot, None)
        else:
            # first touch: zero-fill
            self._onboard_pool = self.executor.write_page(
                self._onboard_pool, slot,
                jnp.zeros(self.page_shape, self.dtype))
        entry.tier, entry.slot, entry.dirty = ONBOARD, slot, False
        self._onboard_owner[slot] = page
        self.policy.on_insert(page)
        if self.prefetcher:
            self.prefetcher.observe(page)
            for p in self.prefetcher.suggest(self.num_pages - 1):
                if self._pages[p].tier == LMB and self._onboard_free:
                    try:
                        self._prefetch(p)
                    except OutOfMemory:
                        break
        return slot

    def _prefetch(self, page: int) -> None:
        entry = self._pages[page]
        if entry.tier != LMB:
            return
        if not self._onboard_free:
            return  # never evict to prefetch
        slot = self._onboard_free.pop()
        data = self._lmb_read(entry.slot, page)
        self._onboard_pool = self.executor.write_page(
            self._onboard_pool, slot, data)
        self.metrics.record_move(self.name, LMB, ONBOARD,
                                 self.lmb_page_bytes)
        self._lmb_slot_free(entry.slot)
        self._lmb_owner.pop(entry.slot, None)
        entry.tier, entry.slot, entry.dirty = ONBOARD, slot, False
        self._onboard_owner[slot] = page
        self.policy.on_insert(page)

    # ------------------------------------------------------------------- API
    def read(self, page: int) -> jax.Array:
        self._check(page)
        slot = self._fault_in(page)
        return self.executor.read_page(self._onboard_pool, slot)

    def write(self, page: int, data) -> None:
        self._check(page)
        entry = self._pages[page]
        if entry.refcount > 1:
            self._cow(page)
            entry = self._pages[page]
        data = jnp.asarray(data, self.dtype)
        if data.shape != self.page_shape:
            raise ValueError(
                f"{self.name}: page shape {data.shape} != {self.page_shape}")
        slot = self._fault_in(page)
        self._onboard_pool = self.executor.write_page(
            self._onboard_pool, slot, data)
        self._pages[page].dirty = True
        if hasattr(self.policy, "mark_dirty"):
            self.policy.mark_dirty(page, True)

    def gather(self, pages: Sequence[int]) -> jax.Array:
        """Stack several logical pages (faulting them in) — kernel feed."""
        return jnp.stack([self.read(p) for p in pages])

    def pin(self, page: int) -> None:
        self._fault_in(page)
        self.policy.pin(page)

    def unpin(self, page: int) -> None:
        self.policy.unpin(page)

    def schedule_prefetch(self, pages: Sequence[int]) -> None:
        if self.prefetcher:
            self.prefetcher.schedule(list(pages))
            for p in list(pages)[: self.prefetcher.depth]:
                try:
                    self._prefetch(p)
                except OutOfMemory:
                    break

    # ------------------------------------------------------------- share / COW
    def share(self, page: int) -> int:
        """Refcount++ (zero-copy share). Returns the same logical index."""
        self._check(page)
        self._pages[page].refcount += 1
        return page

    def release(self, page: int) -> None:
        """Refcount--; frees storage at zero."""
        self._check(page)
        entry = self._pages[page]
        entry.refcount -= 1
        if entry.refcount > 0:
            return
        if entry.tier == ONBOARD:
            self.policy.on_remove(page)
            self._onboard_free.append(entry.slot)
            self._onboard_owner.pop(entry.slot, None)
        elif entry.tier == LMB:
            self._lmb_slot_free(entry.slot)
            self._lmb_owner.pop(entry.slot, None)
        entry.tier, entry.slot, entry.dirty = None, -1, False
        entry.refcount = 0

    def _cow(self, page: int) -> None:
        """Copy-on-write: writer gets a private copy of a shared page."""
        entry = self._pages[page]
        data = self.read(page)
        entry.refcount -= 1
        new = PageEntry()
        self._pages[page] = new
        slot = self._onboard_slot_alloc()
        self._onboard_pool = self.executor.write_page(
            self._onboard_pool, slot, data)
        new.tier, new.slot, new.dirty = ONBOARD, slot, True
        self._onboard_owner[slot] = page
        self.policy.on_insert(page)
        # the old physical page stays where it is, now owned by the sharers;
        # bookkeeping for "who else maps it" lives in the serving layer,
        # which tracks logical page ids per request.

    # --------------------------------------------------------- hot-page moves
    def page_expander(self, page: int) -> Optional[int]:
        """Which expander homes this page's LMB slot (None if not in LMB)."""
        entry = self._pages[page]
        if entry.tier != LMB:
            return None
        return self._lmb_homes[entry.slot // self._lmb_chunk_pages]

    def lmb_placement(self) -> Dict[int, int]:
        """LMB-resident page count per home expander."""
        out: Dict[int, int] = {}
        for e in self._pages:
            if e.tier == LMB:
                home = self._lmb_homes[e.slot // self._lmb_chunk_pages]
                out[home] = out.get(home, 0) + 1
        return out

    def hottest_pages(self, limit: int,
                      expander_id: Optional[int] = None,
                      min_heat: float = 0.0) -> List[int]:
        """LMB-resident pages by descending access heat — the migration
        candidates for one saturated expander."""
        cands = []
        for p, e in enumerate(self._pages):
            if e.tier != LMB:
                continue
            if (expander_id is not None
                    and self.page_expander(p) != expander_id):
                continue
            h = self.page_heat(p)
            if h < min_heat:
                continue
            cands.append((h, p))
        cands.sort(reverse=True)
        return [p for _, p in cands[:limit]]

    def migrate_pages(self, pages: Sequence[int], dst_expander: int) -> int:
        """Move LMB-resident pages onto chunks homed on ``dst_expander``.

        Contents are preserved (read from the source chunk, written to the
        destination chunk); both links are metered, so migration traffic is
        visible as occupancy on each side.  Source chunks left empty are
        reclaimed, which frees their allocation and revokes the device's
        SAT/IOMMU entries on the source blocks — the destination grant was
        authorized when its chunk was allocated (the failover re-grant
        machinery).  Returns the number of pages actually moved: when the
        destination refuses growth (quota or pool exhausted) the batch
        stops early with every remaining page intact on its source."""
        moved = 0
        for page in pages:
            self._check(page)
            entry = self._pages[page]
            if entry.tier != LMB:
                continue
            src_slot = entry.slot
            src_home = self._lmb_homes[src_slot // self._lmb_chunk_pages]
            if src_home == dst_expander:
                continue
            # allocate the destination FIRST: an OutOfMemory (quota, full
            # pool) must fire before the source page is touched — with
            # compress_lmb a read pops the source's scale, so failing
            # mid-move would corrupt the page
            try:
                dst_slot = self._lmb_slot_alloc(expander_id=dst_expander)
            except OutOfMemory:
                break
            data = self._lmb_read(src_slot, None)       # meters source link
            self._lmb_write(dst_slot, data, None)       # meters dest link
            entry.slot = dst_slot
            self._lmb_owner[dst_slot] = page
            self._lmb_owner.pop(src_slot, None)
            self._lmb_slot_free(src_slot)
            self.metrics.record_move(self.name, f"{LMB}@{src_home}",
                                     f"{LMB}@{dst_expander}",
                                     self.lmb_page_bytes)
            moved += 1
        if moved:
            self._reclaim_empty_chunks()
        return moved

    def _reclaim_empty_chunks(self) -> None:
        """Free fully-empty LMB chunks back through the Table-2 API (which
        revokes this device's SAT/IOMMU entries and may return the 256 MB
        block to the FM)."""
        for chunk, used in enumerate(self._lmb_used):
            if used != 0 or self._lmb_pools[chunk] is None:
                continue
            base = chunk * self._lmb_chunk_pages
            self._lmb_free = [
                s for s in self._lmb_free
                if not base <= s < base + self._lmb_chunk_pages]
            self._lmb_allocs[chunk].free()
            self._lmb_pools[chunk] = None
            self._lmb_allocs[chunk] = None
            self._lmb_homes[chunk] = -1

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Release the buffer's entire LMB footprint: every live chunk
        capability is freed back through the Table-2 path (revoking
        SAT/IOMMU entries, returning drained blocks to the FM).  LMB-
        resident pages revert to 'never written'; the buffer enters
        degraded (onboard-only) mode so later paging cannot silently
        re-acquire LMB quota, and its failover callback is removed from
        the FM.  Called by LMBSystem.close() so a session cannot leak
        quota through its buffers."""
        self.degraded = True
        self.host.fm.off_failover(self._on_failover)
        for chunk, handle in enumerate(self._lmb_allocs):
            if handle is None:
                continue
            if not handle.stale:
                handle.free()
            self._lmb_pools[chunk] = None
            self._lmb_allocs[chunk] = None
            self._lmb_homes[chunk] = -1
            self._lmb_used[chunk] = 0
        for e in self._pages:
            if e.tier == LMB:
                e.tier, e.slot, e.dirty = None, -1, False
        self._lmb_owner.clear()
        self._lmb_scales.clear()
        self._lmb_free = []

    # ------------------------------------------------------------ failure path
    def _on_failover(self, expander_id: Optional[int] = None) -> None:
        """An expander failed.  Pages homed on it are gone (re-granted
        blocks are blank): they revert to 'never written' (zeros on next
        touch); consumers holding a journal (checkpoint) re-populate.
        Pages homed on surviving pooled expanders are untouched.  With
        nowhere to fail over to we enter degraded mode instead (see
        inject_failure in fabric.py)."""
        if not self.host.fm.healthy:
            # last expander died: the LMB tier is gone for good — shed its
            # pages below, and refuse future growth
            self.degraded = True
        dead = {chunk for chunk, home in enumerate(self._lmb_homes)
                if self._lmb_pools[chunk] is not None
                and (expander_id is None or home == expander_id)}
        if not dead:
            return
        for e in self._pages:
            if e.tier == LMB and e.slot // self._lmb_chunk_pages in dead:
                e.tier, e.slot, e.dirty = None, -1, False
        for slot in [s for s in self._lmb_owner
                     if s // self._lmb_chunk_pages in dead]:
            del self._lmb_owner[slot]
        for slot in [s for s in self._lmb_scales
                     if s // self._lmb_chunk_pages in dead]:
            del self._lmb_scales[slot]
        self._lmb_free = [s for s in self._lmb_free
                          if s // self._lmb_chunk_pages not in dead]
        for chunk in dead:
            # the FM re-granted the underlying blocks blank; the old
            # allocation bookkeeping is unrecoverable, so drop references
            # without freeing (the journal is the recovery source of truth)
            self._lmb_pools[chunk] = None
            self._lmb_allocs[chunk] = None
            self._lmb_homes[chunk] = -1
            self._lmb_used[chunk] = 0
        self.metrics.event(
            self.name, "failover: LMB pages on expander "
                       f"{'*' if expander_id is None else expander_id} "
                       "invalidated")

    # --------------------------------------------------------------- validation
    def _check(self, page: int) -> None:
        if not 0 <= page < len(self._pages):
            raise IndexError(f"{self.name}: page {page} out of range")

    def check_invariants(self) -> None:
        """Structural invariants (exercised by hypothesis tests)."""
        onboard_slots = [e.slot for e in self._pages if e.tier == ONBOARD]
        assert len(onboard_slots) == len(set(onboard_slots)), "slot aliasing"
        assert len(onboard_slots) + len(self._onboard_free) == \
            self.onboard_pages, "onboard slot leak"
        lmb_slots = [e.slot for e in self._pages if e.tier == LMB]
        assert len(lmb_slots) == len(set(lmb_slots)), "lmb slot aliasing"
        alive = [c for c, p in enumerate(self._lmb_pools) if p is not None]
        total_lmb = len(alive) * self._lmb_chunk_pages
        assert len(lmb_slots) + len(self._lmb_free) == total_lmb, \
            "lmb slot leak"
        for slot in lmb_slots + self._lmb_free:
            assert self._lmb_pools[slot // self._lmb_chunk_pages] \
                is not None, "slot points at reclaimed chunk"
        for chunk in alive:
            base = chunk * self._lmb_chunk_pages
            used = sum(1 for s in lmb_slots
                       if base <= s < base + self._lmb_chunk_pages)
            assert used == self._lmb_used[chunk], "chunk occupancy drift"
        for slot, page in self._onboard_owner.items():
            e = self._pages[page]
            assert e.tier == ONBOARD and e.slot == slot, "owner map stale"

    def stats(self) -> dict:
        tiers = {ONBOARD: 0, LMB: 0, "unmaterialized": 0}
        for e in self._pages:
            tiers[e.tier if e.tier else "unmaterialized"] += 1
        c = self.metrics.tier(self.name, ONBOARD)
        return {
            "pages": self.num_pages,
            "resident": tiers,
            "hit_ratio": c.hit_ratio,
            "lmb_bytes_held": self.host.owned_bytes(self.device_id),
            "degraded": self.degraded,
            "link_wait_s": self.link_wait_s,
            "link_utilization": self.host.fm.link_utilization(),
            "lmb_placement": self.lmb_placement(),
        }
