"""Per-consumer counters for the LMB framework.

Tracks what the paper's evaluation tracks implicitly: how many accesses hit
the onboard tier vs. went to the linked buffer, and how many bytes moved per
tier.  Consumers (the serving engine, the optimizer-state pager, tests) read
these to report hit ratios and to validate locality claims (§4.1.2).
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Dict


@dataclasses.dataclass
class TierCounters:
    hits: int = 0
    misses: int = 0
    bytes_in: int = 0      # bytes paged INTO this tier
    bytes_out: int = 0     # bytes paged OUT of this tier
    accesses: int = 0

    @property
    def hit_ratio(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class Metrics:
    """Hierarchical counters: consumer -> tier name -> TierCounters."""

    def __init__(self) -> None:
        self._by_consumer: Dict[str, Dict[str, TierCounters]] = defaultdict(
            lambda: defaultdict(TierCounters))
        self._events: list[tuple[float, str, str]] = []
        self._t0 = time.monotonic()

    def tier(self, consumer: str, tier_name: str) -> TierCounters:
        return self._by_consumer[consumer][tier_name]

    def record_hit(self, consumer: str, tier_name: str, nbytes: int = 0) -> None:
        c = self.tier(consumer, tier_name)
        c.hits += 1
        c.accesses += 1

    def record_miss(self, consumer: str, tier_name: str, nbytes: int = 0) -> None:
        c = self.tier(consumer, tier_name)
        c.misses += 1
        c.accesses += 1

    def record_move(self, consumer: str, src: str, dst: str, nbytes: int) -> None:
        self.tier(consumer, src).bytes_out += nbytes
        self.tier(consumer, dst).bytes_in += nbytes

    def event(self, consumer: str, what: str) -> None:
        self._events.append((time.monotonic() - self._t0, consumer, what))

    def snapshot(self) -> Dict[str, Dict[str, dict]]:
        return {
            consumer: {t: dataclasses.asdict(c) for t, c in tiers.items()}
            for consumer, tiers in self._by_consumer.items()
        }

    def reset(self) -> None:
        self._by_consumer.clear()
        self._events.clear()


#: process-global default registry (consumers may also own private ones)
GLOBAL_METRICS = Metrics()
