"""Unified metrics registry for the LMB framework.

One registry, three instrument kinds, one ``snapshot()``:

  * **tier counters** — the original per-consumer hit/miss/byte
    accounting (hit ratios, locality claims, §4.1.2);
  * **counters / gauges** — monotonic counts and last-write-wins
    values (journal length, shed requests, ...);
  * **histograms** — log-bucket latency/size distributions
    (``repro.obs.hist``) with p50/p90/p99 in the snapshot, the
    percentile machinery the serve harness reports TTFT and
    inter-token gaps against.

``snapshot()`` schema (every key always present)::

    {"tiers":      {consumer: {tier: {hits, misses, bytes_hit,
                                      bytes_missed, bytes_in,
                                      bytes_out, accesses}}},
     "counters":   {name: float},
     "gauges":     {name: float},
     "histograms": {name: {count, sum, mean, min, max, p50, p90, p99}},
     "events":     {count, capacity, total}}

Registries are mergeable: workers record into private ``Metrics`` and
``merge()`` them into ``GLOBAL_METRICS``.  The event log is bounded by
the same ring cap as the span tracer, so a long-lived registry cannot
grow without bound.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Deque, Dict, Tuple

from repro.obs.hist import Histogram
from repro.obs.trace import DEFAULT_RING_CAPACITY


@dataclasses.dataclass
class TierCounters:
    hits: int = 0
    misses: int = 0
    bytes_hit: int = 0     # bytes served from this tier on hits
    bytes_missed: int = 0  # bytes requested that missed this tier
    bytes_in: int = 0      # bytes paged INTO this tier
    bytes_out: int = 0     # bytes paged OUT of this tier
    accesses: int = 0

    @property
    def hit_ratio(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def merge(self, other: "TierCounters") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


class Metrics:
    """Unified registry: tier counters + counters + gauges + hists."""

    def __init__(self, max_events: int = DEFAULT_RING_CAPACITY) -> None:
        self._by_consumer: Dict[str, Dict[str, TierCounters]] = defaultdict(
            lambda: defaultdict(TierCounters))
        self._events: Deque[Tuple[float, str, str]] = deque(
            maxlen=max_events)
        self._events_total = 0
        self._counters: Dict[str, float] = defaultdict(float)
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, Histogram] = {}
        self._t0 = time.monotonic()

    # -- tier counters ---------------------------------------------
    def tier(self, consumer: str, tier_name: str) -> TierCounters:
        return self._by_consumer[consumer][tier_name]

    def record_hit(self, consumer: str, tier_name: str,
                   nbytes: int = 0) -> None:
        c = self.tier(consumer, tier_name)
        c.hits += 1
        c.accesses += 1
        c.bytes_hit += nbytes

    def record_miss(self, consumer: str, tier_name: str,
                    nbytes: int = 0) -> None:
        c = self.tier(consumer, tier_name)
        c.misses += 1
        c.accesses += 1
        c.bytes_missed += nbytes

    def record_move(self, consumer: str, src: str, dst: str,
                    nbytes: int) -> None:
        self.tier(consumer, src).bytes_out += nbytes
        self.tier(consumer, dst).bytes_in += nbytes

    # -- counters / gauges / histograms ----------------------------
    def inc(self, name: str, value: float = 1.0) -> None:
        self._counters[name] += value

    def gauge(self, name: str, value: float) -> None:
        self._gauges[name] = float(value)

    def hist(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram()
        return h

    def observe(self, name: str, value: float) -> None:
        self.hist(name).record(value)

    # -- event log (bounded) ---------------------------------------
    def event(self, consumer: str, what: str) -> None:
        self._events.append((time.monotonic() - self._t0, consumer, what))
        self._events_total += 1

    # -- combining -------------------------------------------------
    def merge(self, other: "Metrics") -> "Metrics":
        """Fold another registry's samples into this one.

        Tier counters and counters add; gauges take ``other``'s value
        (last write wins); histograms merge bucket-wise; events append
        (still bounded by this registry's cap).
        """
        for consumer, tiers in other._by_consumer.items():
            for tname, c in tiers.items():
                self.tier(consumer, tname).merge(c)
        for name, v in other._counters.items():
            self._counters[name] += v
        self._gauges.update(other._gauges)
        for name, h in other._hists.items():
            self.hist(name).merge(h)
        self._events.extend(other._events)
        self._events_total += other._events_total
        return self

    # -- reading ---------------------------------------------------
    def snapshot(self) -> Dict[str, dict]:
        return {
            "tiers": {
                consumer: {t: dataclasses.asdict(c)
                           for t, c in tiers.items()}
                for consumer, tiers in self._by_consumer.items()
            },
            "counters": dict(self._counters),
            "gauges": dict(self._gauges),
            "histograms": {n: h.snapshot()
                           for n, h in self._hists.items()},
            "events": {"count": len(self._events),
                       "capacity": self._events.maxlen,
                       "total": self._events_total},
        }

    def reset(self) -> None:
        self._by_consumer.clear()
        self._events.clear()
        self._events_total = 0
        self._counters.clear()
        self._gauges.clear()
        self._hists.clear()


#: process-global default registry (consumers may also own private ones)
GLOBAL_METRICS = Metrics()
