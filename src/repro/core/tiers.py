"""Memory-tier registry and latency/bandwidth model.

Encodes the paper's Figure 2 latency estimates plus the TPU-side constants we
adapt them to.  Every tier is described by an access latency (per transaction)
and a streaming bandwidth; the cost model is used by

  * the discrete-event SSD simulator (``repro.sim``) — with the paper's
    CXL/PCIe constants, to reproduce Fig 6, and
  * the serving/training schedulers — with TPU constants, to decide
    eviction/prefetch and to predict whether paging can hide behind compute.

All latencies in seconds, bandwidths in bytes/second.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict


class TierKind(enum.Enum):
    """Physical tier classes, ordered fastest-first."""

    ONBOARD = "onboard"          # device-local DRAM / TPU HBM
    LMB_CXL = "lmb_cxl"          # CXL P2P path to the expander (direct)
    LMB_PCIE_GEN4 = "lmb_pcie4"  # host-forwarded path, PCIe Gen4 device
    LMB_PCIE_GEN5 = "lmb_pcie5"  # host-forwarded path, PCIe Gen5 device
    HOST_DRAM = "host_dram"      # plain host memory over PCIe (HMB-style)
    FLASH = "flash"              # NAND flash (DFTL fallback)


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """Cost description of one memory tier."""

    kind: TierKind
    #: extra latency per access vs. the onboard tier (paper Fig 2 / §4)
    added_latency_s: float
    #: sustainable streaming bandwidth for bulk page moves
    bandwidth_Bps: float
    #: capacity available in this tier (None = unbounded for modeling)
    capacity_bytes: int | None = None

    def access_time(self, nbytes: int, utilization: float = 0.0) -> float:
        """Latency + transfer time for an ``nbytes`` access.

        ``utilization`` is the load on the shared link behind this tier
        (0 = uncontended, the seed behaviour).  The fixed
        ``added_latency_s`` inflates with queueing delay per
        :func:`congested_latency`; the transfer term stays nominal because
        bandwidth *shares* are the arbiter's job (repro.qos.arbiter), not
        the per-access cost model's.
        """
        return (congested_latency(self.added_latency_s, utilization)
                + nbytes / self.bandwidth_Bps)


def tier_over_path(tier: TierSpec, path) -> TierSpec:
    """``tier`` as seen across a rack fabric path: the path's hop latency
    adds to every access and its bottleneck bandwidth caps streaming.

    ``path`` is duck-typed (``latency_s`` + ``bandwidth_Bps``, i.e. a
    :class:`repro.rack.topology.PathCost` — tiers sits below rack in the
    layering, so no import).  A zero-latency path whose bandwidth matches
    the tier returns an equal spec: direct attach is the degenerate case.
    """
    return dataclasses.replace(
        tier,
        added_latency_s=tier.added_latency_s + path.latency_s,
        bandwidth_Bps=min(tier.bandwidth_Bps, path.bandwidth_Bps))


# ---------------------------------------------------------------------------
# Shared-link congestion (repro.qos)
# ---------------------------------------------------------------------------

#: utilization is clamped here so the M/M/1-style queueing term stays finite
#: even when demand exceeds link capacity (rho >= 1 in the open model)
CONGESTION_RHO_MAX = 0.97
#: how strongly queueing delay scales with utilization; 1.0 = M/M/1 waiting
#: time (W = rho/(1-rho) service times) — CXL fabric measurements (Samsung
#: CMM-H characterization; Zhong et al. pooling study) sit near this shape
CONGESTION_SENSITIVITY = 1.0


def congested_latency(base_latency_s: float, utilization: float,
                      sensitivity: float = CONGESTION_SENSITIVITY) -> float:
    """Effective access latency on a shared link at ``utilization``.

    Monotone non-decreasing in ``utilization`` and equal to
    ``base_latency_s`` at zero load — the seed's fixed-latency model is the
    uncontended special case.  Used by the Fig-6 multi-device simulator and
    the serving admission controller (repro.qos.slo).
    """
    rho = min(max(utilization, 0.0), CONGESTION_RHO_MAX)
    return base_latency_s * (1.0 + sensitivity * rho / (1.0 - rho))


# ---------------------------------------------------------------------------
# Paper constants (Fig 2, §4 "Prototype implementation")
# ---------------------------------------------------------------------------

#: CXL port latency (Sharma, HOTI'22)
CXL_PORT_LATENCY_S = 25e-9
#: CXL switch + HDM access (Pond, ASPLOS'23)
CXL_SWITCH_HDM_LATENCY_S = 70e-9
#: PCIe 5.0 device accessing host memory (Fig 2)
PCIE5_HOST_ACCESS_S = 780e-9

#: Added L2P-lookup latencies used by the paper's simulation (§4):
DFTL_FLASH_READ_S = 25e-6       # one flash read per L2P miss
LMB_CXL_ADDED_S = 190e-9        # CXL device → expander, P2P
LMB_PCIE_GEN4_ADDED_S = 880e-9  # PCIe Gen4 device, host-forwarded
LMB_PCIE_GEN5_ADDED_S = 1190e-9 # PCIe Gen5 device, host-forwarded


def paper_tiers() -> Dict[TierKind, TierSpec]:
    """Tier table with the paper's constants (used by the Fig 6 simulator)."""
    return {
        TierKind.ONBOARD: TierSpec(TierKind.ONBOARD, 0.0, 50e9),
        TierKind.LMB_CXL: TierSpec(TierKind.LMB_CXL, LMB_CXL_ADDED_S, 30e9),
        TierKind.LMB_PCIE_GEN4: TierSpec(
            TierKind.LMB_PCIE_GEN4, LMB_PCIE_GEN4_ADDED_S, 16e9),
        TierKind.LMB_PCIE_GEN5: TierSpec(
            TierKind.LMB_PCIE_GEN5, LMB_PCIE_GEN5_ADDED_S, 32e9),
        TierKind.HOST_DRAM: TierSpec(
            TierKind.HOST_DRAM, PCIE5_HOST_ACCESS_S, 32e9),
        TierKind.FLASH: TierSpec(TierKind.FLASH, DFTL_FLASH_READ_S, 3e9),
    }


# ---------------------------------------------------------------------------
# TPU adaptation constants (v5e target; see DESIGN.md §2)
# ---------------------------------------------------------------------------

#: peak bf16 FLOP/s per chip
TPU_PEAK_FLOPS = 197e12
#: HBM bandwidth per chip
TPU_HBM_BW_Bps = 819e9
#: ICI bandwidth per link
TPU_ICI_BW_Bps = 50e9
#: host<->device PCIe bandwidth (the "LMB pool" path on a TPU host)
TPU_PCIE_BW_Bps = 32e9
#: HBM capacity per v5e chip
TPU_HBM_BYTES = 16 * 2**30
#: PCIe DMA kick-off latency (the TPU analogue of the CXL added latency)
TPU_PCIE_LATENCY_S = 2e-6


def tpu_tiers(host_pool_bytes: int | None = None) -> Dict[TierKind, TierSpec]:
    """Tier table for the TPU adaptation: HBM = onboard, host pool = LMB."""
    return {
        TierKind.ONBOARD: TierSpec(
            TierKind.ONBOARD, 0.0, TPU_HBM_BW_Bps, TPU_HBM_BYTES),
        TierKind.HOST_DRAM: TierSpec(
            TierKind.HOST_DRAM, TPU_PCIE_LATENCY_S, TPU_PCIE_BW_Bps,
            host_pool_bytes),
    }


def hideable_page_bytes(compute_time_s: float,
                        tier: TierSpec,
                        streams: int = 1) -> int:
    """How many bytes can be paged from ``tier`` while compute runs.

    Used by the prefetcher: paging is "free" (hidden) as long as the bytes
    moved per step stay under this bound.  ``streams`` models multiple DMA
    engines.
    """
    usable = max(compute_time_s - tier.added_latency_s, 0.0)
    return int(usable * tier.bandwidth_Bps * streams)
