"""CXL expander pool model: DMPs, DPA space, and the 256 MB block allocator.

Follows the paper's §3.1/§3.2 and Fig 4:

  * The **Expander** is a GFD exposing a DPA (device physical address) space
    organized into **DMPs** (Device Media Partitions), each with a media
    attribute (DRAM or PM).
  * Hosts obtain memory from the expander in **256 MB blocks** through the
    Fabric Manager; a host-side **BlockAllocator** sub-allocates device
    requests inside those blocks and releases a block back to the FM when
    everything inside it has been freed.
  * All allocator metadata is host-resident (the paper: "We keep the memory
    allocator metadata in the host ... avoid triggering multiple CXL memory
    accesses").

This module is pure bookkeeping — no JAX.  The live backing store (JAX arrays
or host numpy) is attached by ``repro.core.offload``; the discrete-event
simulator uses the same allocator with no backing store at all.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterator, List, Optional, Tuple

#: the paper's host-request granularity (§3.2)
BLOCK_BYTES = 256 * 2**20
#: sub-block allocation granularity — page-granular DMA on TPU (DESIGN.md §2);
#: CXL would allow cache-line granularity, TPU DMA wants big pages.
DEFAULT_PAGE_BYTES = 256 * 2**10


class MediaKind(enum.Enum):
    DRAM = "dram"
    PM = "pm"


class LMBError(Exception):
    """Base class for pool errors."""


class OutOfMemory(LMBError):
    pass


class InvalidHandle(LMBError):
    pass


@dataclasses.dataclass(frozen=True)
class DMP:
    """Device Media Partition: a DPA range with a media attribute (Fig 4)."""

    dmp_id: int
    media: MediaKind
    dpa_base: int
    nbytes: int

    def contains(self, dpa: int) -> bool:
        return self.dpa_base <= dpa < self.dpa_base + self.nbytes


#: block-id namespace stride per expander — keeps block ids globally unique
#: across a pooled multi-expander fabric (an expander never hands out more
#: than BLOCK_ID_STRIDE blocks; 2**20 blocks = 256 TiB per expander)
BLOCK_ID_STRIDE = 1 << 20


@dataclasses.dataclass
class BlockGrant:
    """A 256 MB block granted by the FM to one host."""

    block_id: int
    dmp_id: int
    dpa_base: int
    host_id: str
    nbytes: int = BLOCK_BYTES
    #: which expander in the FM's pooled set backs this block
    expander_id: int = 0
    #: media of the backing DMP — a failover re-grant must match it
    media: MediaKind = MediaKind.DRAM


class Expander:
    """A GFD memory expander: DMPs + block-granular grants to hosts.

    The expander only hands out whole blocks; fine-grained allocation is the
    host allocator's job.  It also implements the HPA→DPA translation the
    paper's Fig 4 shows (identity-with-offset per grant here).

    ``expander_id`` names the expander inside a pooled fabric; block ids are
    carved from a per-expander namespace (``expander_id * BLOCK_ID_STRIDE``)
    so grants from different expanders never collide in the FM's tables.
    """

    def __init__(self, dmps: List[Tuple[MediaKind, int]],
                 expander_id: int = 0):
        base = 0
        self._dmps: List[DMP] = []
        for i, (media, nbytes) in enumerate(dmps):
            if nbytes % BLOCK_BYTES:
                raise ValueError("DMP size must be a multiple of BLOCK_BYTES")
            self._dmps.append(DMP(i, media, base, nbytes))
            base += nbytes
        # free block DPA bases per DMP
        self._free: Dict[int, List[int]] = {
            d.dmp_id: list(range(d.dpa_base, d.dpa_base + d.nbytes,
                                 BLOCK_BYTES))
            for d in self._dmps
        }
        self._grants: Dict[int, BlockGrant] = {}
        self.expander_id = expander_id
        self._next_block_id = expander_id * BLOCK_ID_STRIDE
        self.failed = False  # failure-injection flag (see fabric.py)

    def renumber(self, expander_id: int) -> None:
        """Move this expander to another block-id namespace.  Only legal
        before any grant — outstanding block ids would keep the old
        namespace and collide with the FM's placement tables."""
        if self._grants:
            raise LMBError(
                f"cannot renumber expander {self.expander_id}: "
                f"{len(self._grants)} blocks outstanding")
        self.expander_id = expander_id
        self._next_block_id = expander_id * BLOCK_ID_STRIDE

    def reset(self) -> None:
        """Blank-media repair: forget every grant and rebuild the free
        lists (the FRU was swapped — its contents are gone).  The
        block-id counter is NOT rewound: ids from before the reset never
        come back, so stale references cannot alias post-repair grants.
        The FM's ``readmit_expander`` is the only caller; it also clears
        ``failed`` and purges its own tables."""
        self._grants.clear()
        self._free = {
            d.dmp_id: list(range(d.dpa_base, d.dpa_base + d.nbytes,
                                 BLOCK_BYTES))
            for d in self._dmps
        }

    # -- capacity ----------------------------------------------------------
    @property
    def total_bytes(self) -> int:
        return sum(d.nbytes for d in self._dmps)

    def free_bytes(self, media: Optional[MediaKind] = None) -> int:
        total = 0
        for d in self._dmps:
            if media is not None and d.media is not media:
                continue
            total += len(self._free[d.dmp_id]) * BLOCK_BYTES
        return total

    # -- block grant / release (FM-mediated) --------------------------------
    def grant_block(self, host_id: str,
                    media: MediaKind = MediaKind.DRAM) -> BlockGrant:
        if self.failed:
            raise LMBError("expander failed")
        if self._next_block_id >= (self.expander_id + 1) * BLOCK_ID_STRIDE:
            raise LMBError(
                f"expander {self.expander_id} exhausted its block-id "
                f"namespace ({BLOCK_ID_STRIDE} grants)")
        for d in self._dmps:
            if d.media is media and self._free[d.dmp_id]:
                dpa = self._free[d.dmp_id].pop()
                grant = BlockGrant(self._next_block_id, d.dmp_id, dpa, host_id,
                                   expander_id=self.expander_id, media=media)
                self._next_block_id += 1
                self._grants[grant.block_id] = grant
                return grant
        raise OutOfMemory(
            f"expander out of {media.value} blocks "
            f"(free={self.free_bytes(media)})")

    def release_block(self, block_id: int) -> None:
        grant = self._grants.pop(block_id, None)
        if grant is None:
            raise InvalidHandle(f"unknown block {block_id}")
        self._free[grant.dmp_id].append(grant.dpa_base)

    def grants_for(self, host_id: str) -> List[BlockGrant]:
        return [g for g in self._grants.values() if g.host_id == host_id]

    def translate(self, block_id: int, offset: int) -> int:
        """HPA-relative (block, offset) → DPA (Fig 4 address mapping)."""
        grant = self._grants.get(block_id)
        if grant is None:
            raise InvalidHandle(f"unknown block {block_id}")
        if not 0 <= offset < grant.nbytes:
            raise InvalidHandle(
                f"offset {offset} outside block {block_id}")
        return grant.dpa_base + offset


@dataclasses.dataclass
class Region:
    """A page-aligned sub-block allocation owned by one device (mmid)."""

    mmid: int
    block_id: int
    page_start: int       # first page index within the block
    npages: int
    page_bytes: int
    owner: str            # device id

    @property
    def nbytes(self) -> int:
        return self.npages * self.page_bytes

    @property
    def offset(self) -> int:
        return self.page_start * self.page_bytes


class _BlockState:
    """Host-side per-block page bitmap (next-fit contiguous runs).

    Next-fit with a rotating hint + free-page counter: O(1) rejection of
    full blocks and amortized-short scans keep the Table-2 alloc path in
    the microsecond range (benchmarks/run.py::allocator)."""

    __slots__ = ("grant", "page_bytes", "npages", "used", "free_pages",
                 "_hint")

    def __init__(self, grant: BlockGrant, page_bytes: int):
        self.grant = grant
        self.page_bytes = page_bytes
        self.npages = grant.nbytes // page_bytes
        self.used = bytearray(self.npages)  # 0 = free, 1 = used
        self.free_pages = self.npages
        self._hint = 0

    def _scan(self, start: int, stop: int, npages: int) -> Optional[int]:
        run = 0
        for i in range(start, stop):
            run = 0 if self.used[i] else run + 1
            if run == npages:
                return i - npages + 1
        return None

    def find_run(self, npages: int) -> Optional[int]:
        if npages > self.free_pages:
            return None
        pos = self._scan(self._hint, self.npages, npages)
        if pos is None and self._hint:
            pos = self._scan(0, min(self._hint + npages, self.npages),
                             npages)
        return pos

    def mark(self, start: int, npages: int, used: bool) -> None:
        val = 1 if used else 0
        for i in range(start, start + npages):
            if self.used[i] == val:
                raise LMBError(
                    f"page {i} already {'used' if used else 'free'}")
            self.used[i] = val
        self.free_pages += -npages if used else npages
        if used:
            self._hint = start + npages
        else:
            self._hint = min(self._hint, start)

    @property
    def used_pages(self) -> int:
        return self.npages - self.free_pages


class BlockAllocator:
    """Host-side allocator sub-allocating device requests inside FM blocks.

    ``request_block`` / ``return_block`` are callbacks into the Fabric
    Manager; the allocator asks for one block at a time when it cannot
    satisfy a request (paper §3.2) and returns a block as soon as it is
    entirely free.  ``request_block(expander_id, owner)`` takes an
    optional expander hint so placement-aware callers (hot-page
    migration) can direct a region onto a specific expander's blocks,
    plus the requesting device so the FM's placement policy can key on
    its tenant (repro.core.placement).
    """

    def __init__(self, request_block, return_block,
                 page_bytes: int = DEFAULT_PAGE_BYTES):
        if BLOCK_BYTES % page_bytes:
            raise ValueError("page_bytes must divide BLOCK_BYTES")
        self._request_block = request_block
        self._return_block = return_block
        self.page_bytes = page_bytes
        self._blocks: Dict[int, _BlockState] = {}
        self._regions: Dict[int, Region] = {}
        self._next_mmid = 1

    # -- queries -------------------------------------------------------------
    @property
    def regions(self) -> Dict[int, Region]:
        return dict(self._regions)

    @property
    def block_count(self) -> int:
        return len(self._blocks)

    def owned_bytes(self, owner: str) -> int:
        return sum(r.nbytes for r in self._regions.values()
                   if r.owner == owner)

    def utilization(self) -> float:
        if not self._blocks:
            return 0.0
        used = sum(b.used_pages for b in self._blocks.values())
        total = sum(b.npages for b in self._blocks.values())
        return used / total

    # -- alloc / free ---------------------------------------------------------
    def _pages_for(self, nbytes: int) -> int:
        if nbytes <= 0:
            raise ValueError("allocation size must be positive")
        return -(-nbytes // self.page_bytes)

    def alloc(self, owner: str, nbytes: int,
              expander_id: Optional[int] = None) -> Region:
        """Allocate a region; ``expander_id`` restricts it to blocks backed
        by that expander (placement hint for migration/striping)."""
        npages = self._pages_for(nbytes)
        if npages > BLOCK_BYTES // self.page_bytes:
            return self._alloc_multiblock(owner, npages)
        for bs in self._blocks.values():
            if (expander_id is not None
                    and bs.grant.expander_id != expander_id):
                continue
            start = bs.find_run(npages)
            if start is not None:
                return self._commit(owner, bs, start, npages)
        # no room: request one more block from the FM (paper §3.2)
        grant = self._request_block(expander_id, owner)
        bs = _BlockState(grant, self.page_bytes)
        self._blocks[grant.block_id] = bs
        start = bs.find_run(npages)
        assert start is not None
        return self._commit(owner, bs, start, npages)

    def _alloc_multiblock(self, owner: str, npages: int) -> Region:
        # Large allocations (> one block) are split by the caller layer
        # (LinkedBuffer pages never exceed a block); reject here to keep the
        # DPA-contiguity invariant that a Region lives inside one block.
        raise OutOfMemory(
            f"single region of {npages} pages exceeds one {BLOCK_BYTES}-byte "
            "block; allocate per-page via LinkedBuffer instead")

    def _commit(self, owner: str, bs: _BlockState, start: int,
                npages: int) -> Region:
        bs.mark(start, npages, True)
        region = Region(self._next_mmid, bs.grant.block_id, start, npages,
                        self.page_bytes, owner)
        self._next_mmid += 1
        self._regions[region.mmid] = region
        return region

    def free(self, mmid: int, owner: Optional[str] = None) -> None:
        region = self._regions.pop(mmid, None)
        if region is None:
            raise InvalidHandle(f"unknown mmid {mmid}")
        if owner is not None and region.owner != owner:
            self._regions[mmid] = region
            raise LMBError(
                f"device {owner!r} cannot free mmid {mmid} owned by "
                f"{region.owner!r}")
        bs = self._blocks[region.block_id]
        bs.mark(region.page_start, region.npages, False)
        if bs.used_pages == 0:
            # whole block free → return to the FM (paper §3.2)
            del self._blocks[region.block_id]
            self._return_block(region.block_id)

    def region(self, mmid: int) -> Region:
        r = self._regions.get(mmid)
        if r is None:
            raise InvalidHandle(f"unknown mmid {mmid}")
        return r

    def expander_of(self, mmid: int) -> int:
        """Which pooled expander backs this region's block."""
        region = self.region(mmid)
        return self._blocks[region.block_id].grant.expander_id

    def adopt_block(self, grant: BlockGrant) -> bool:
        """Start tracking a block the FM granted out-of-band (a blank
        failover replacement): it joins empty and its free runs satisfy
        future allocations, so re-granted capacity stays usable and the
        block can eventually be returned.  No-op for known blocks."""
        if grant.block_id in self._blocks:
            return False
        self._blocks[grant.block_id] = _BlockState(grant, self.page_bytes)
        return True

    def drop_expander(self, expander_id: int) -> List[int]:
        """Forget every block (and the regions inside) backed by a failed
        expander.  Called on failover: the FM already re-granted or lost
        those blocks, so nothing is returned to it — without this, the
        dead blocks' free runs would keep satisfying new allocations and
        silently place fresh regions on the failed expander.  Returns the
        dropped mmids."""
        dead = {bid for bid, bs in self._blocks.items()
                if bs.grant.expander_id == expander_id}
        for bid in dead:
            del self._blocks[bid]
        dropped = [mmid for mmid, r in self._regions.items()
                   if r.block_id in dead]
        for mmid in dropped:
            del self._regions[mmid]
        return dropped

    def iter_regions(self) -> Iterator[Region]:
        return iter(self._regions.values())
