"""Overlap scheduling: how much prefetch traffic hides behind compute.

The paper's sequential fio results are the friendly case for LMB because
the CXL round-trip can ride *under* ongoing work — the link keeps enough
outstanding transfers in flight that by the time the device touches the
next pages, their bytes already arrived (latency hiding via outstanding
transfers, the standard CXL-interconnect argument; pool papers make the
same case for scheduled bulk moves amortizing pool bandwidth).

This module is the decision point between the burst-native
:class:`~repro.core.policy.Prefetcher` (which proposes chunk-aligned
runs) and the :class:`~repro.core.buffer.LinkedBuffer` data path (which
moves them):

  * :func:`exposed_latency_s` / :func:`hidden_fraction` — the pure cost
    math, shared with the discrete-event simulator (``repro.sim.engine``
    models a prefetching device's external L2P access as hidden up to
    its lookahead window).
  * :class:`OverlapScheduler` — per-buffer runtime state: tracks the
    current compute window (either declared per step or EWMA-learned
    from observed step times), converts it to a byte budget with
    :func:`repro.core.tiers.hideable_page_bytes`, and admits whole runs
    in priority order until the budget is spent.  Runs that do not fit
    are DEFERRED (handed back to the prefetcher's backlog), never
    dropped: exact scheduled knowledge stays exact.

Admission is order-preserving: runs arrive scheduled-first (exact future
knowledge) then stride guesses, and admission stops at the first run
that does not fit — a later, smaller run must not jump a deferred
scheduled run, or the "scheduled pages take priority" invariant breaks.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.tiers import TierSpec, hideable_page_bytes
from repro.obs.trace import GLOBAL_TRACER, SpanTracer


def exposed_latency_s(added_latency_s: float,
                      compute_window_s: float) -> float:
    """Latency still visible after hiding behind a compute window.

    A prefetched access issued ``compute_window_s`` ahead of its use
    exposes only the part of the tier latency the window could not
    cover.  Never negative; window <= 0 exposes everything (the
    demand-paging case).
    """
    return max(added_latency_s - max(compute_window_s, 0.0), 0.0)


def hidden_fraction(added_latency_s: float,
                    compute_window_s: float) -> float:
    """Fraction of the tier latency a compute window hides (0..1)."""
    if added_latency_s <= 0:
        return 1.0
    exposed = exposed_latency_s(added_latency_s, compute_window_s)
    return 1.0 - exposed / added_latency_s


@dataclasses.dataclass
class OverlapStats:
    """Running totals one OverlapScheduler accumulates."""

    admitted_runs: int = 0
    deferred_runs: int = 0
    admitted_pages: int = 0
    deferred_pages: int = 0
    hidden_bytes: int = 0


class OverlapScheduler:
    """Decides how many prefetch runs fit behind the compute window.

    ``tier`` is the cost model of the link the prefetch traffic rides
    (bandwidth + added latency); ``streams`` models multiple DMA
    engines.  The compute window can be driven two ways, composable:

      * ``start_window(seconds)`` — the consumer declares the next
        step's compute time up front (simulators, benchmarks);
      * ``observe_compute(seconds)`` — EWMA over measured step times
        (the serving engine feeds its decode-round wall time), then
        ``start_window()`` with no argument opens the next window at
        the learned estimate.

    Each window has a byte budget
    (:func:`~repro.core.tiers.hideable_page_bytes`); :meth:`admit`
    spends it on whole runs in arrival order and defers the rest.
    """

    def __init__(self, tier: TierSpec, *,
                 compute_window_s: float = 0.0,
                 streams: int = 1,
                 ewma_alpha: float = 0.3,
                 trace: Optional[SpanTracer] = None):
        self.tier = tier
        self.streams = max(int(streams), 1)
        self._window_s = max(compute_window_s, 0.0)
        self._alpha = ewma_alpha
        self._spent_bytes = 0
        self.stats = OverlapStats()
        self.trace = trace if trace is not None else GLOBAL_TRACER

    # ------------------------------------------------------------- window
    @property
    def window_s(self) -> float:
        """Current compute-window estimate (seconds)."""
        return self._window_s

    def observe_compute(self, seconds: float) -> None:
        """Fold one measured compute-step duration into the estimate."""
        seconds = max(seconds, 0.0)
        if self._window_s <= 0.0:
            self._window_s = seconds
        else:
            self._window_s += self._alpha * (seconds - self._window_s)

    def start_window(self, compute_window_s: Optional[float] = None) -> None:
        """Open a new compute window: reset the spent-budget counter and
        (optionally) pin the window length for this step."""
        if compute_window_s is not None:
            self._window_s = max(compute_window_s, 0.0)
        self._spent_bytes = 0

    # ------------------------------------------------------------- budget
    def budget_bytes(self) -> int:
        """Total bytes hideable behind the current window."""
        return hideable_page_bytes(self._window_s, self.tier, self.streams)

    def remaining_bytes(self) -> int:
        return max(self.budget_bytes() - self._spent_bytes, 0)

    def admit(self, run_sizes: Sequence[int],
              page_bytes: int) -> Tuple[int, List[int]]:
        """Admit whole runs, in order, while they fit the window budget.

        ``run_sizes`` is the page count of each candidate run (priority
        order: scheduled first).  Returns ``(n_admitted, sizes)`` — the
        number of leading runs admitted and, for convenience, the
        per-run sizes actually charged.  Admission stops at the first
        run that does not fit; everything after it is counted deferred
        (the caller re-queues those pages, it does not drop them).
        """
        admitted = 0
        charged: List[int] = []
        for size in run_sizes:
            nbytes = size * page_bytes
            if nbytes > self.remaining_bytes():
                break
            self._spent_bytes += nbytes
            self.stats.admitted_runs += 1
            self.stats.admitted_pages += size
            self.stats.hidden_bytes += nbytes
            charged.append(size)
            admitted += 1
        for size in run_sizes[admitted:]:
            self.stats.deferred_runs += 1
            self.stats.deferred_pages += size
        tr = self.trace
        if tr.enabled and run_sizes:
            tr.event("overlap.admit", op="prefetch",
                     nbytes=sum(charged) * page_bytes,
                     runs=admitted, pages=sum(charged),
                     deferred_runs=len(run_sizes) - admitted,
                     window_s=self._window_s)
        return admitted, charged

    def snapshot(self) -> dict:
        return {
            "window_s": self._window_s,
            "budget_bytes": self.budget_bytes(),
            "remaining_bytes": self.remaining_bytes(),
            "streams": self.streams,
            **dataclasses.asdict(self.stats),
        }
