"""JAX execution of LMB tier moves.

The LMB pool's *live* backing store on a TPU host is pinned host memory —
the byte-addressable, larger, slower tier behind PCIe (DESIGN.md §2).  JAX
exposes it via sharding ``memory_kind``:

  * ``device``       — HBM (the "onboard" tier)
  * ``pinned_host``  — host DRAM reachable by the TPU DMA engines (the "LMB"
                       tier; DMA-able without a bounce buffer = the paper's
                       P2P/CXL.mem path)
  * ``unpinned_host``— pageable host memory (needs a staging copy = the
                       paper's host-forwarded PCIe path)

Two execution modes, auto-detected:

  * **in-jit** (TPU): steps are compiled with ``memory_kind`` annotations on
    offloaded operands/results so XLA schedules the HBM↔host DMAs and can
    overlap them with compute.
  * **host-stage** (CPU backend — used by tests/CI): the CPU runtime has no
    ``annotate_device_placement`` custom-call, so tier residency is realized
    with eager ``jax.device_put`` between compiled steps.  Functionally
    identical, same accounting, no overlap.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, SingleDeviceSharding

from repro.obs.trace import GLOBAL_TRACER, SpanTracer

DEVICE = "device"
PINNED_HOST = "pinned_host"
UNPINNED_HOST = "unpinned_host"


@functools.cache
def backend_memory_kinds() -> tuple:
    dev = jax.devices()[0]
    try:
        return tuple(m.kind for m in dev.addressable_memories())
    except Exception:
        return (DEVICE,)


@functools.cache
def supports_in_jit_offload() -> bool:
    """Whether ``memory_kind`` annotations survive compile on this backend."""
    dev = jax.devices()[0]
    if PINNED_HOST not in backend_memory_kinds():
        return False
    try:
        s = SingleDeviceSharding(dev, memory_kind=PINNED_HOST)
        jax.jit(lambda a: a * 2, out_shardings=s).lower(
            jax.ShapeDtypeStruct((1,), jnp.float32)).compile()
        return True
    except Exception:
        return False


def with_memory_kind(sharding, memory_kind: str):
    """Rebuild a (Named|SingleDevice)Sharding with a different memory kind."""
    if isinstance(sharding, NamedSharding):
        return NamedSharding(sharding.mesh, sharding.spec,
                             memory_kind=memory_kind)
    if isinstance(sharding, SingleDeviceSharding):
        return SingleDeviceSharding(sharding._device,
                                    memory_kind=memory_kind)
    raise TypeError(f"cannot retier {type(sharding)}")


def _aval_on_host(x: jax.Array) -> bool:
    """True if the array's *aval* carries Host memory space.  JAX 0.8 CPU
    quirk: slices of pinned_host arrays keep a sticky <host> aval even
    through device_put(memory_kind='device'), and mixed-space operands are
    rejected by ops like dynamic_update_slice — detect via the aval, not
    the (sometimes lying) sharding.memory_kind."""
    ms = getattr(x.aval, "memory_space", None)
    return ms is not None and "host" in str(ms).lower()


def put_tier(x: jax.Array, memory_kind: str) -> jax.Array:
    """Eagerly move an array to a tier (host-stage mode data path)."""
    on_host = _aval_on_host(x)
    if memory_kind == DEVICE:
        if not on_host and getattr(x.sharding, "memory_kind",
                                   DEVICE) in (None, DEVICE):
            return x
        # host->device via a host copy: the only path that clears the
        # sticky Host aval on the CPU backend (a real DMA on TPU would be
        # the in-jit path instead — see module docstring)
        return jnp.asarray(np.asarray(x))
    if on_host and getattr(x.sharding, "memory_kind", None) == memory_kind:
        return x
    return jax.device_put(x, with_memory_kind(x.sharding, memory_kind))


def tree_put_tier(tree: Any, memory_kind: str) -> Any:
    return jax.tree_util.tree_map(lambda x: put_tier(x, memory_kind), tree)


def tier_of(x: jax.Array) -> str:
    if _aval_on_host(x):
        mk = getattr(x.sharding, "memory_kind", None)
        return mk if mk not in (None, DEVICE) else PINNED_HOST
    return getattr(x.sharding, "memory_kind", None) or DEVICE


def offload_shardings(shardings: Any, memory_kind: str = PINNED_HOST) -> Any:
    """Map a pytree of shardings to the offload tier (for in-jit mode)."""
    return jax.tree_util.tree_map(
        lambda s: with_memory_kind(s, memory_kind), shardings)


def nbytes_of(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
               for l in leaves)


class TierExecutor:
    """Executes LinkedBuffer page moves on JAX arrays.

    Pages live in a pool array per tier; moves are slice copies.  In
    host-stage mode the LMB-tier pool is a pinned-host array (real host
    residency); if the backend has no host memories at all, the LMB tier is
    a plain device array and only the accounting distinguishes tiers (pure
    modeling mode — still exercises every allocator/policy path).
    """

    def __init__(self, lmb_memory_kind: Optional[str] = None,
                 meter: Optional[Callable[[int], float]] = None,
                 trace: Optional[SpanTracer] = None):
        kinds = backend_memory_kinds()
        if lmb_memory_kind is None:
            lmb_memory_kind = PINNED_HOST if PINNED_HOST in kinds else DEVICE
        self.lmb_memory_kind = lmb_memory_kind
        self.real_host_tier = lmb_memory_kind != DEVICE
        #: span tracer for coalesced pool transfers (wall-clock spans —
        #: the executor runs real JAX ops, unlike the modeled link path)
        self.trace = trace if trace is not None else GLOBAL_TRACER
        #: QoS hook: charged with nbytes for every page crossing the
        #: host<->device boundary (the expander-link analogue on a TPU
        #: host); typically LMBHost.meter_transfer bound to a device id.
        #: In pure modeling mode (no host memories) executor-level moves
        #: are indistinguishable from device ops, so consumers that still
        #: want link accounting meter at their own layer (LinkedBuffer).
        self.meter = meter

    def _meter(self, pool: jax.Array, nbytes: int) -> None:
        if self.meter is not None and tier_of(pool) != DEVICE:
            self.meter(nbytes)

    @staticmethod
    def _page_bytes(pool: jax.Array) -> int:
        return int(np.prod(pool.shape[1:])) * jnp.dtype(pool.dtype).itemsize

    def alloc_pool(self, npages: int, page_shape: tuple, dtype,
                   tier: str) -> jax.Array:
        shape = (npages, *page_shape)
        x = jnp.zeros(shape, dtype=dtype)
        if tier == "lmb":
            x = put_tier(x, self.lmb_memory_kind)
        return x

    def read_page(self, pool: jax.Array, slot: int) -> jax.Array:
        self._meter(pool, self._page_bytes(pool))
        page = pool[slot]
        return put_tier(page, DEVICE)

    def write_page(self, pool: jax.Array, slot: int,
                   page: jax.Array) -> jax.Array:
        tier = tier_of(pool)
        self._meter(pool, self._page_bytes(pool))
        page = put_tier(page, tier)
        new = pool.at[slot].set(page)
        return put_tier(new, tier)  # .at[].set may drop the memory kind

    # ---- coalesced multi-page transfers (the batched data path) ----
    # One gather/scatter against the pool instead of N slice copies: on
    # TPU this is one DMA descriptor per run, and the meter hook (when
    # bound) sees ONE charge for the burst's total bytes — the overlap
    # scheduler then has whole runs, not single pages, to hide behind
    # compute.

    def read_pages(self, pool: jax.Array,
                   slots: Sequence[int]) -> jax.Array:
        """Coalesced read: ``[len(slots), *page_shape]`` stacked onboard.
        Duplicate slots are allowed (a gather may repeat pages)."""
        self._meter(pool, self._page_bytes(pool) * len(slots))
        tr = self.trace
        if tr.enabled:
            with tr.span("exec.read_pages", op="demand",
                         nbytes=self._page_bytes(pool) * len(slots),
                         pages=len(slots), tier=tier_of(pool)):
                return self._read_pages(pool, slots)
        return self._read_pages(pool, slots)

    def _read_pages(self, pool: jax.Array,
                    slots: Sequence[int]) -> jax.Array:
        if len(slots) == 1:
            # basic indexing beats a 1-element gather by ~10x in eager
            # dispatch — the decode path (1 page per step) lives here
            return put_tier(pool[int(slots[0])], DEVICE)[None]
        batch = pool[jnp.asarray(np.asarray(slots, np.int32))]
        return put_tier(batch, DEVICE)

    def write_pages(self, pool: jax.Array, slots: Sequence[int],
                    pages: jax.Array) -> jax.Array:
        """Coalesced write of ``pages[i] -> pool[slots[i]]``.  Slots must
        be distinct (scatter order over duplicates is undefined)."""
        tier = tier_of(pool)
        self._meter(pool, self._page_bytes(pool) * len(slots))
        tr = self.trace
        if tr.enabled:
            with tr.span("exec.write_pages", op="demand",
                         nbytes=self._page_bytes(pool) * len(slots),
                         pages=len(slots), tier=tier):
                return self._write_pages(pool, slots, pages, tier)
        return self._write_pages(pool, slots, pages, tier)

    def _write_pages(self, pool: jax.Array, slots: Sequence[int],
                     pages: jax.Array, tier: str) -> jax.Array:
        pages = put_tier(jnp.asarray(pages), tier)
        if len(slots) == 1:
            new = pool.at[int(slots[0])].set(pages[0])
        else:
            idx = jnp.asarray(np.asarray(slots, np.int32))
            new = pool.at[idx].set(pages)
        return put_tier(new, tier)  # .at[].set may drop the memory kind

    def move_page(self, src_pool: jax.Array, src_slot: int,
                  dst_pool: jax.Array, dst_slot: int) -> jax.Array:
        return self.write_page(dst_pool, dst_slot,
                               self.read_page(src_pool, src_slot))
