"""Block→expander placement policies for the pooled fabric.

The CXL pooling literature frames pooled memory as *revocable capability
grants with policy-driven placement* (Das Sharma et al., "An Introduction
to the CXL Interconnect"; Zhong et al., "My CXL Pool Obviates Your PCIe
Switch").  This module is the "policy-driven" half: the Fabric Manager
delegates every unhinted block-placement (and migration-target) decision
to a :class:`PlacementPolicy`, injected through
:class:`repro.core.client.SystemSpec`.

A policy sees only a :class:`PlacementRequest` (who is asking, for what
media, on behalf of which tenant) and a list of :class:`ExpanderView`
candidates (healthy expanders, their free capacity and link heat) — never
the FabricManager itself, so policies can be swapped or unit-tested
without touching fabric internals.

Policies:
  * :class:`LeastLoadedPolicy` — the default; coolest link wins, free
    space breaks ties (the criterion block placement and migration
    targeting shared before this module existed, so behavior under the
    default is unchanged).
  * :class:`HeatAwarePolicy` — capacity-balances across *cool* links
    (most free bytes wins while every link is below ``hot_threshold``),
    falling back to least-loaded once links run hot.  Packs a quiet pool
    by capacity instead of ping-ponging on utilization noise.
  * :class:`TenantAffinityPolicy` — sticky tenant→expander homes
    (seeded explicitly or assigned round-robin on first sight), so one
    tenant's traffic stays off its neighbors' links; falls back to
    least-loaded for tenantless requests or when the home has no room.

This module deliberately imports only ``repro.core.pool`` — it sits
below ``fabric`` in the layering.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Protocol, Sequence, Union, runtime_checkable

from repro.core.pool import MediaKind


@dataclasses.dataclass(frozen=True)
class ExpanderView:
    """What a policy may know about one candidate expander."""

    expander_id: int
    #: free bytes of the requested media on this expander
    free_bytes: int
    #: the expander link's EWMA utilization in [0, 1]
    utilization: float
    #: fabric path latency from the requesting host (rack topology hop
    #: cost); 0.0 = direct attach or no topology configured
    path_latency_s: float = 0.0
    #: correlated failure domain (rack topology); None when unknown
    domain: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class PlacementRequest:
    """One block-placement (or migration-target) question."""

    media: MediaKind = MediaKind.DRAM
    host_id: Optional[str] = None
    #: device the region is being allocated for (None for host-level
    #: re-grants, e.g. the failover path)
    device_id: Optional[str] = None
    #: tenant the device belongs to (from DeviceInfo.tenant), if any
    tenant: Optional[str] = None


@runtime_checkable
class PlacementPolicy(Protocol):
    """Pick an expander for a request; ``None`` means "no preference"
    (the FM then falls back to any healthy expander and lets the grant
    path raise OutOfMemory if the pool is truly full)."""

    name: str

    def choose(self, request: PlacementRequest,
               views: Sequence[ExpanderView]) -> Optional[int]:
        ...  # pragma: no cover - protocol


class LeastLoadedPolicy:
    """Coolest healthy link wins; more free bytes, then lower id, break
    ties.  This is the exact criterion the pre-policy FabricManager
    hard-wired, shared by block placement and migration targeting so the
    two cannot drift."""

    name = "least-loaded"

    def choose(self, request: PlacementRequest,
               views: Sequence[ExpanderView]) -> Optional[int]:
        if not views:
            return None
        best = min(views, key=lambda v: (v.utilization, -v.free_bytes,
                                         v.expander_id))
        return best.expander_id


class HeatAwarePolicy:
    """Capacity-balance while the pool is cool, heat-balance once it is
    not: among links below ``hot_threshold`` the most free bytes wins
    (utilization EWMAs on an idle pool are noise — packing by capacity
    keeps block counts even), otherwise defer to least-loaded."""

    name = "heat-aware"

    def __init__(self, hot_threshold: float = 0.5):
        if not 0.0 < hot_threshold <= 1.0:
            raise ValueError(f"hot_threshold {hot_threshold} not in (0, 1]")
        self.hot_threshold = hot_threshold
        self._fallback = LeastLoadedPolicy()

    def choose(self, request: PlacementRequest,
               views: Sequence[ExpanderView]) -> Optional[int]:
        cool = [v for v in views if v.utilization < self.hot_threshold]
        if cool:
            best = max(cool, key=lambda v: (v.free_bytes, -v.expander_id))
            return best.expander_id
        return self._fallback.choose(request, views)


class TenantAffinityPolicy:
    """Sticky tenant→expander homes.

    A tenant's first placement assigns it a home expander — from the
    ``assignments`` seed (e.g. ``TenantSpec.preferred_expander``) or
    round-robin over the candidates — and every later request for that
    tenant lands there while the home is healthy and has room.  Requests
    with no tenant, and tenants whose home cannot take the block, fall
    back to least-loaded placement."""

    name = "tenant-affinity"

    def __init__(self, assignments: Optional[Dict[str, int]] = None):
        self._assignments: Dict[str, int] = dict(assignments or {})
        self._rr = 0
        self._fallback = LeastLoadedPolicy()

    @property
    def assignments(self) -> Dict[str, int]:
        """tenant → home expander (introspection; a copy)."""
        return dict(self._assignments)

    def choose(self, request: PlacementRequest,
               views: Sequence[ExpanderView]) -> Optional[int]:
        if not views:
            return None
        if request.tenant is None:
            return self._fallback.choose(request, views)
        home = self._assignments.get(request.tenant)
        if home is None:
            ids = sorted(v.expander_id for v in views)
            home = ids[self._rr % len(ids)]
            self._rr += 1
            self._assignments[request.tenant] = home
        if any(v.expander_id == home for v in views):
            return home
        return self._fallback.choose(request, views)


class PoolAwarePolicy:
    """Topology-aware placement for switched racks: the NEAREST cool
    expander wins.

    Among candidates whose link utilization is below ``hot_threshold``,
    the lowest fabric path latency wins (same-leaf beats cross-leaf
    beats cross-spine), with coolest link then most free bytes breaking
    ties.  When every candidate runs hot, distance stops mattering and
    the policy degrades to pure least-loaded — a saturated near link is
    worse than an idle far one.  Without a topology every
    ``path_latency_s`` is 0.0 and this behaves exactly like
    least-loaded."""

    name = "pool-aware"

    def __init__(self, hot_threshold: float = 0.7):
        if not 0.0 < hot_threshold <= 1.0:
            raise ValueError(f"hot_threshold {hot_threshold} not in (0, 1]")
        self.hot_threshold = hot_threshold
        self._fallback = LeastLoadedPolicy()

    def choose(self, request: PlacementRequest,
               views: Sequence[ExpanderView]) -> Optional[int]:
        if not views:
            return None
        cool = [v for v in views if v.utilization < self.hot_threshold]
        if not cool:
            return self._fallback.choose(request, views)
        best = min(cool, key=lambda v: (v.path_latency_s, v.utilization,
                                        -v.free_bytes, v.expander_id))
        return best.expander_id


#: registry for SystemSpec's string-named policies
_POLICIES = {
    LeastLoadedPolicy.name: LeastLoadedPolicy,
    HeatAwarePolicy.name: HeatAwarePolicy,
    TenantAffinityPolicy.name: TenantAffinityPolicy,
    PoolAwarePolicy.name: PoolAwarePolicy,
}


def make_placement_policy(
        policy: Union[str, PlacementPolicy, None], **kwargs
) -> PlacementPolicy:
    """Resolve a policy name (or pass an instance through).  ``None``
    means the default least-loaded policy."""
    if policy is None:
        return LeastLoadedPolicy()
    if isinstance(policy, str):
        cls = _POLICIES.get(policy)
        if cls is None:
            raise ValueError(
                f"unknown placement policy {policy!r}; "
                f"choose from {sorted(_POLICIES)}")
        return cls(**kwargs)
    return policy
