"""LMB kernel-module API (paper Table 2).

``LMBHost`` plays the role of the LMB kernel module on one host: it owns a
``BlockAllocator`` fed by the Fabric Manager, exposes the device-class-
agnostic verbs

    alloc(dev, size)               -> Allocation(hpa, mmid[, dpid])
    free(dev, mmid)
    share(dev, mmid, target)       -> Allocation for the target device

which dispatch on the registered device's :class:`DeviceClass` internally
(PCIe → IOMMU mappings + IOVA bus addresses; CXL → SAT entries + HPA bus
addresses + expander DPID for P2P).  The paper's Table-2 names

    lmb_pcie_alloc / lmb_cxl_alloc / lmb_pcie_free / lmb_cxl_free
    lmb_pcie_share / lmb_cxl_share

remain as thin deprecated shims so the paper mapping stays legible; new
code should go through :class:`repro.core.client.LMBSystem`, which wraps
these verbs in typed :class:`~repro.core.client.MemoryHandle` capabilities.

``LMBHost`` maintains the HPA/bus-address ↔ physical mapping plus the
access-control entries (IOMMU/SAT) through the FM, and a per-expander
**generation counter** bumped on every failover — the staleness signal
``MemoryHandle`` capabilities check before acting.  The paper's "loading
priority" concern (LMB must exist before device drivers initialize) maps
to LMBHost being constructed before any consumer in our launchers.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional, Sequence, Tuple

from repro.core.fabric import (AccessDenied, DeviceClass, DeviceInfo,
                               FabricManager)
from repro.core.metrics import GLOBAL_METRICS, Metrics
from repro.core.pool import (DEFAULT_PAGE_BYTES, BlockAllocator, LMBError,
                             MediaKind, Region)
from repro.obs.trace import SpanTracer

#: HPA window where expander blocks get mapped on the host (arbitrary base
#: chosen above typical host DRAM; purely a modeling constant).
HPA_WINDOW_BASE = 0x4000_0000_0000

#: IOVA window PCIe devices see through their IOMMU domain.  Identity-
#: mapped *within* the window (same block/offset layout as the HPA
#: window) but at a distinct base: a PCIe device's DMA address is an
#: IOMMU translation, not a host physical address, and conflating the
#: two would hide exactly the PCIe-vs-CXL addressing split the paper's
#: Table 2 encodes in its verb names.
PCIE_IOVA_BASE = 0x8000_0000_0000


@dataclasses.dataclass(frozen=True)
class Allocation:
    """What a device driver gets back from an alloc/share call (Table 2)."""

    mmid: int              # unique memory id in the local host
    hpa: int               # host physical address of the region
    bus_addr: int          # device-visible bus address (PCIe) or HPA (CXL)
    nbytes: int
    device_id: str
    #: global PID of the expander, for CXL devices to initiate P2P (Table 2)
    dpid: Optional[int] = None


class LMBHost:
    """The LMB kernel module instance for one host."""

    def __init__(self, fm: FabricManager, host_id: str,
                 page_bytes: int = DEFAULT_PAGE_BYTES,
                 media: MediaKind = MediaKind.DRAM,
                 metrics: Optional[Metrics] = None,
                 expander_dpid: int = 0x7):
        self.fm = fm
        self.host_id = host_id
        self.media = media
        self.metrics = metrics or GLOBAL_METRICS
        self._expander_dpid = expander_dpid
        fm.bind_host(host_id)           # idempotent: no-op if already bound
        self.allocator = BlockAllocator(
            request_block=lambda eid=None, dev=None: fm.request_block(
                host_id, media, expander_id=eid, device_id=dev),
            return_block=lambda bid: fm.return_block(host_id, bid),
            page_bytes=page_bytes)
        # mmid -> set of device_ids with access (owner first)
        self._sharers: Dict[int, list[str]] = {}
        # expander_id -> generation, bumped on every failover touching it;
        # MemoryHandle capabilities record the generation at grant time
        # and refuse to act once it moves (StaleHandle)
        self._generation: Dict[int, int] = {}
        # registered BEFORE any LinkedBuffer (they attach to this host
        # afterwards), so allocator state for a dead expander is gone by
        # the time consumers handle the same failover notification
        fm.on_failover(self._on_failover)

    @property
    def trace(self) -> SpanTracer:
        """The FM's span tracer — hosts and their LinkedBuffers share
        it so fault/burst spans and the link.xfer spans they trigger
        land in one trace with parent links intact."""
        return self.fm.tracer

    def _on_failover(self, expander_id: int) -> None:
        """Drop allocator bookkeeping for the failed expander's blocks —
        the FM re-granted (or lost) them; keeping their free runs around
        would let new allocations land on the dead expander.  Then adopt
        the blank replacement grants, so the capacity the FM preserved
        (and still charges against our quota) is actually allocatable."""
        # invalidate capabilities first: any handle granted on this
        # expander must observe the generation bump before it can race
        # a free/share against the dropped allocator state
        self._generation[expander_id] = self.generation_of(expander_id) + 1
        for mmid in self.allocator.drop_expander(expander_id):
            self._sharers.pop(mmid, None)
        # adopt only replacements on HEALTHY expanders — after a total-pool
        # failure held_grants still lists dead blocks, and re-adopting them
        # would let allocations silently land on dead capacity
        healthy = set(self.fm.healthy_expander_ids())
        for grant in self.fm.held_grants(self.host_id):
            if grant.expander_id in healthy:
                self.allocator.adopt_block(grant)

    # -- HPA mapping -----------------------------------------------------------
    def _hpa_of(self, region: Region) -> int:
        # block_id-indexed window keeps HPAs stable across block reuse
        return (HPA_WINDOW_BASE + region.block_id * (256 * 2**20)
                + region.offset)

    def _bus_addr_of(self, region: Region, device: DeviceInfo) -> int:
        if device.device_class is DeviceClass.PCIE:
            # PCIe devices DMA through the IOMMU: identity-mapped IOVA
            # window at a base distinct from the HPA window
            return (PCIE_IOVA_BASE
                    + (self._hpa_of(region) - HPA_WINDOW_BASE))
        # CXL devices address the expander with the HPA directly (P2P)
        return self._hpa_of(region)

    # -- generations (capability staleness) ------------------------------------
    def generation_of(self, expander_id: int) -> int:
        """Current failover generation of one expander; a MemoryHandle
        minted at generation g is stale once this moves past g."""
        return self._generation.get(expander_id, 0)

    # -- alloc (device-class-agnostic; dispatches on DeviceClass) ---------------
    def alloc(self, device_id: str, nbytes: int,
              expander_id: Optional[int] = None) -> Allocation:
        """Allocate LMB memory for a device (Table-2 alloc, class-agnostic):
        the registered DeviceClass decides IOMMU-vs-SAT authorization and
        the bus-address window, so callers never branch on bus type."""
        device = self.fm.device(device_id)
        region = self.allocator.alloc(device_id, nbytes,
                                      expander_id=expander_id)
        self.fm.authorize(device_id, region.block_id, region.page_start,
                          region.npages)
        self._sharers[region.mmid] = [device_id]
        self.metrics.event(device_id, f"alloc mmid={region.mmid} {nbytes}B")
        return Allocation(
            mmid=region.mmid,
            hpa=self._hpa_of(region),
            bus_addr=self._bus_addr_of(region, device),
            nbytes=region.nbytes,
            device_id=device_id,
            dpid=(self._expander_dpid
                  if device.device_class is DeviceClass.CXL else None))

    def _warn_shim(self, shim: str, repl: str) -> None:
        warnings.warn(
            f"LMBHost.{shim} is a deprecated Table-2 paper-name shim; "
            f"use the class-dispatched LMBHost.{repl} (or the "
            "repro.core.client.LMBSystem capability API)",
            DeprecationWarning, stacklevel=3)

    def lmb_pcie_alloc(self, device_id: str, nbytes: int,
                       expander_id: Optional[int] = None) -> Allocation:
        """Deprecated Table-2 shim: ``alloc`` restricted to PCIe devices."""
        self._warn_shim("lmb_pcie_alloc", "alloc")
        if self.fm.device(device_id).device_class is not DeviceClass.PCIE:
            raise LMBError(f"{device_id} is not a PCIe device")
        return self.alloc(device_id, nbytes, expander_id)

    def lmb_cxl_alloc(self, device_id: str, nbytes: int,
                      expander_id: Optional[int] = None) -> Allocation:
        """Deprecated Table-2 shim: ``alloc`` restricted to CXL devices."""
        self._warn_shim("lmb_cxl_alloc", "alloc")
        if self.fm.device(device_id).device_class is not DeviceClass.CXL:
            raise LMBError(f"{device_id} is not a CXL device")
        return self.alloc(device_id, nbytes, expander_id)

    # -- free (device-class-agnostic) -------------------------------------------
    def free(self, device_id: str, mmid: int) -> None:
        """Free (owner) or drop a mapping of (sharer) an allocation
        (Table-2 free, class-agnostic)."""
        region = self.allocator.region(mmid)
        sharers = self._sharers.get(mmid, [])
        if device_id not in sharers:
            raise AccessDenied(
                f"{device_id} does not hold mmid {mmid}")
        if device_id != region.owner:
            # a sharer "freeing" just drops its mapping
            self.fm.revoke(device_id, region.block_id, region.page_start,
                           region.npages)
            sharers.remove(device_id)
            return
        # owner free: revoke everyone, then release pages
        for dev in sharers:
            self.fm.revoke(dev, region.block_id, region.page_start,
                           region.npages)
        del self._sharers[mmid]
        self.allocator.free(mmid, owner=device_id)
        self.metrics.event(device_id, f"free mmid={mmid}")

    def lmb_pcie_free(self, device_id: str, mmid: int) -> None:
        """Deprecated Table-2 shim for :meth:`free`."""
        self._warn_shim("lmb_pcie_free", "free")
        self.free(device_id, mmid)

    def lmb_cxl_free(self, device_id: str, mmid: int) -> None:
        """Deprecated Table-2 shim for :meth:`free`."""
        self._warn_shim("lmb_cxl_free", "free")
        self.free(device_id, mmid)

    # -- share (device-class-agnostic) ------------------------------------------
    def share(self, src_device: str, mmid: int,
              dst_device: str) -> Allocation:
        """Grant ``dst_device`` zero-copy access to ``src_device``'s
        allocation (Table-2 share, class-agnostic): the destination's
        DeviceClass decides SAT-vs-IOMMU authorization and the returned
        bus address/DPID."""
        region = self.allocator.region(mmid)
        sharers = self._sharers.get(mmid, [])
        if src_device not in sharers:
            raise AccessDenied(
                f"{src_device} cannot share mmid {mmid} it does not hold")
        dst = self.fm.device(dst_device)
        self.fm.authorize(dst_device, region.block_id, region.page_start,
                          region.npages)
        if dst_device not in sharers:
            sharers.append(dst_device)
        self.metrics.event(
            src_device, f"share mmid={mmid} -> {dst_device}")
        return Allocation(
            mmid=mmid,
            hpa=self._hpa_of(region),
            bus_addr=self._bus_addr_of(region, dst),
            nbytes=region.nbytes,
            device_id=dst_device,
            dpid=(self._expander_dpid
                  if dst.device_class is DeviceClass.CXL else None))

    def lmb_pcie_share(self, device_id: str, mmid: int,
                       target_device: str) -> Allocation:
        """Deprecated Table-2 shim for :meth:`share`."""
        self._warn_shim("lmb_pcie_share", "share")
        return self.share(device_id, mmid, target_device)

    def lmb_cxl_share(self, device_id: str, mmid: int,
                      target_device: str) -> Allocation:
        """Deprecated Table-2 shim for :meth:`share`."""
        self._warn_shim("lmb_cxl_share", "share")
        return self.share(device_id, mmid, target_device)

    # -- data-path access check (used by LinkedBuffer + tests) ---------------------
    def check_access(self, device_id: str, mmid: int, page: int = 0) -> None:
        region = self.allocator.region(mmid)
        self.fm.check_access(device_id, region.block_id,
                             region.page_start + page)

    def meter_transfer(self, device_id: str, nbytes: int,
                       mmid: Optional[int] = None,
                       op: str = "demand") -> float:
        """Charge an expander-link transfer to this device's QoS share;
        returns the modeled delay (queue + wire) in seconds.  Every byte a
        consumer moves to/from the LMB tier should pass through here so the
        FM's arbiters see true link occupancy.  ``mmid`` routes the charge
        to the link of the expander actually backing the region; ``op``
        classes the traffic (demand vs prefetch) for the FM's per-class
        accounting."""
        block_id = (self.allocator.region(mmid).block_id
                    if mmid is not None else None)
        return self.fm.meter_transfer(device_id, nbytes,
                                      block_id=block_id, op=op).delay_s

    def meter_transfer_many(
            self, device_id: str,
            charges: Sequence[Tuple[int, Optional[int]]],
            op: str = "demand") -> float:
        """Batched :meth:`meter_transfer`: charge a whole burst in one
        arbitration round-trip per backing link.

        ``charges`` is ``[(nbytes, mmid-or-None), ...]`` — one entry per
        coalesced run the caller already grouped (LinkedBuffer groups by
        chunk).  Runs backed by the SAME expander are merged into a
        single arbiter call carrying their total bytes: fairness
        accounting is byte-denominated, so the schedule and token-bucket
        math are unchanged; only the per-transfer arbitration overhead
        (N calls -> 1 per link) is saved.  ``op`` tags every merged
        charge (the prefetch path passes ``"prefetch"`` so its traffic
        is distinguishable in the FM journal and per-class byte totals).
        Returns the summed modeled delay in seconds."""
        # expander -> [total bytes, representative block_id]
        per_link: Dict[Optional[int], list] = {}
        for nbytes, mmid in charges:
            if nbytes <= 0:
                continue
            block_id = (self.allocator.region(mmid).block_id
                        if mmid is not None else None)
            eid = (self.allocator.expander_of(mmid)
                   if mmid is not None else None)
            acc = per_link.setdefault(eid, [0, block_id])
            acc[0] += nbytes
        delay = 0.0
        for nbytes, block_id in per_link.values():
            delay += self.fm.meter_transfer(device_id, nbytes,
                                            block_id=block_id,
                                            op=op).delay_s
        tr = self.trace
        if tr.enabled and per_link:
            # burst-coalescing telemetry: how many caller runs were
            # merged into how many arbiter round-trips
            tr.event("host.meter.burst", op=op,
                     nbytes=sum(v[0] for v in per_link.values()),
                     runs=len(charges), links=len(per_link),
                     delay_s=delay, device=device_id)
        return delay

    def expander_of(self, mmid: int) -> int:
        """Which pooled expander backs this allocation (placement query)."""
        return self.allocator.expander_of(mmid)

    def owned_bytes(self, device_id: str) -> int:
        return self.allocator.owned_bytes(device_id)
