"""LMB kernel-module API (paper Table 2).

``LMBHost`` plays the role of the LMB kernel module on one host: it owns a
``BlockAllocator`` fed by the Fabric Manager, exposes the Table-2 interface

    lmb_pcie_alloc(dev, size)      -> Allocation(hpa, mmid)
    lmb_cxl_alloc(cxld, size)      -> Allocation(hpa, mmid, dpid)
    lmb_pcie_free(dev, mmid)
    lmb_cxl_free(cxld, mmid)
    lmb_pcie_share(dev, mmid)      -> Allocation for the target device
    lmb_cxl_share(cxld, mmid)

and maintains the HPA/bus-address ↔ physical mapping plus the access-control
entries (IOMMU/SAT) through the FM.  The paper's "loading priority" concern
(LMB must exist before device drivers initialize) maps to LMBHost being
constructed before any consumer in our launchers.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.fabric import (AccessDenied, DeviceClass, DeviceInfo,
                               FabricManager)
from repro.core.metrics import GLOBAL_METRICS, Metrics
from repro.core.pool import (DEFAULT_PAGE_BYTES, BlockAllocator, LMBError,
                             MediaKind, Region)

#: HPA window where expander blocks get mapped on the host (arbitrary base
#: chosen above typical host DRAM; purely a modeling constant).
HPA_WINDOW_BASE = 0x4000_0000_0000


@dataclasses.dataclass(frozen=True)
class Allocation:
    """What a device driver gets back from an alloc/share call (Table 2)."""

    mmid: int              # unique memory id in the local host
    hpa: int               # host physical address of the region
    bus_addr: int          # device-visible bus address (PCIe) or HPA (CXL)
    nbytes: int
    device_id: str
    #: global PID of the expander, for CXL devices to initiate P2P (Table 2)
    dpid: Optional[int] = None


class LMBHost:
    """The LMB kernel module instance for one host."""

    def __init__(self, fm: FabricManager, host_id: str,
                 page_bytes: int = DEFAULT_PAGE_BYTES,
                 media: MediaKind = MediaKind.DRAM,
                 metrics: Optional[Metrics] = None,
                 expander_dpid: int = 0x7):
        self.fm = fm
        self.host_id = host_id
        self.media = media
        self.metrics = metrics or GLOBAL_METRICS
        self._expander_dpid = expander_dpid
        fm.bind_host(host_id) if host_id not in fm.snapshot()["hosts"] else None
        self.allocator = BlockAllocator(
            request_block=lambda eid=None: fm.request_block(
                host_id, media, expander_id=eid),
            return_block=lambda bid: fm.return_block(host_id, bid),
            page_bytes=page_bytes)
        # mmid -> set of device_ids with access (owner first)
        self._sharers: Dict[int, list[str]] = {}
        # registered BEFORE any LinkedBuffer (they attach to this host
        # afterwards), so allocator state for a dead expander is gone by
        # the time consumers handle the same failover notification
        fm.on_failover(self._on_failover)

    def _on_failover(self, expander_id: int) -> None:
        """Drop allocator bookkeeping for the failed expander's blocks —
        the FM re-granted (or lost) them; keeping their free runs around
        would let new allocations land on the dead expander.  Then adopt
        the blank replacement grants, so the capacity the FM preserved
        (and still charges against our quota) is actually allocatable."""
        for mmid in self.allocator.drop_expander(expander_id):
            self._sharers.pop(mmid, None)
        # adopt only replacements on HEALTHY expanders — after a total-pool
        # failure held_grants still lists dead blocks, and re-adopting them
        # would let allocations silently land on dead capacity
        healthy = set(self.fm.healthy_expander_ids())
        for grant in self.fm.held_grants(self.host_id):
            if grant.expander_id in healthy:
                self.allocator.adopt_block(grant)

    # -- HPA mapping -----------------------------------------------------------
    def _hpa_of(self, region: Region) -> int:
        # block_id-indexed window keeps HPAs stable across block reuse
        return (HPA_WINDOW_BASE + region.block_id * (256 * 2**20)
                + region.offset)

    def _bus_addr_of(self, region: Region, device: DeviceInfo) -> int:
        if device.device_class is DeviceClass.PCIE:
            # IOVA == HPA in our model (identity-mapped IOMMU domain)
            return self._hpa_of(region)
        return self._hpa_of(region)

    # -- Table 2: alloc ----------------------------------------------------------
    def _alloc(self, device_id: str, nbytes: int,
               expander_id: Optional[int] = None) -> Allocation:
        device = self.fm.device(device_id)
        region = self.allocator.alloc(device_id, nbytes,
                                      expander_id=expander_id)
        self.fm.authorize(device_id, region.block_id, region.page_start,
                          region.npages)
        self._sharers[region.mmid] = [device_id]
        self.metrics.event(device_id, f"alloc mmid={region.mmid} {nbytes}B")
        return Allocation(
            mmid=region.mmid,
            hpa=self._hpa_of(region),
            bus_addr=self._bus_addr_of(region, device),
            nbytes=region.nbytes,
            device_id=device_id,
            dpid=(self._expander_dpid
                  if device.device_class is DeviceClass.CXL else None))

    def lmb_pcie_alloc(self, device_id: str, nbytes: int,
                       expander_id: Optional[int] = None) -> Allocation:
        if self.fm.device(device_id).device_class is not DeviceClass.PCIE:
            raise LMBError(f"{device_id} is not a PCIe device")
        return self._alloc(device_id, nbytes, expander_id)

    def lmb_cxl_alloc(self, device_id: str, nbytes: int,
                      expander_id: Optional[int] = None) -> Allocation:
        if self.fm.device(device_id).device_class is not DeviceClass.CXL:
            raise LMBError(f"{device_id} is not a CXL device")
        return self._alloc(device_id, nbytes, expander_id)

    # -- Table 2: free -------------------------------------------------------------
    def _free(self, device_id: str, mmid: int) -> None:
        region = self.allocator.region(mmid)
        sharers = self._sharers.get(mmid, [])
        if device_id not in sharers:
            raise AccessDenied(
                f"{device_id} does not hold mmid {mmid}")
        if device_id != region.owner:
            # a sharer "freeing" just drops its mapping
            self.fm.revoke(device_id, region.block_id, region.page_start,
                           region.npages)
            sharers.remove(device_id)
            return
        # owner free: revoke everyone, then release pages
        for dev in sharers:
            self.fm.revoke(dev, region.block_id, region.page_start,
                           region.npages)
        del self._sharers[mmid]
        self.allocator.free(mmid, owner=device_id)
        self.metrics.event(device_id, f"free mmid={mmid}")

    def lmb_pcie_free(self, device_id: str, mmid: int) -> None:
        self._free(device_id, mmid)

    def lmb_cxl_free(self, device_id: str, mmid: int) -> None:
        self._free(device_id, mmid)

    # -- Table 2: share ---------------------------------------------------------------
    def _share(self, src_device: str, mmid: int,
               dst_device: str) -> Allocation:
        region = self.allocator.region(mmid)
        sharers = self._sharers.get(mmid, [])
        if src_device not in sharers:
            raise AccessDenied(
                f"{src_device} cannot share mmid {mmid} it does not hold")
        dst = self.fm.device(dst_device)
        self.fm.authorize(dst_device, region.block_id, region.page_start,
                          region.npages)
        if dst_device not in sharers:
            sharers.append(dst_device)
        self.metrics.event(
            src_device, f"share mmid={mmid} -> {dst_device}")
        return Allocation(
            mmid=mmid,
            hpa=self._hpa_of(region),
            bus_addr=self._bus_addr_of(region, dst),
            nbytes=region.nbytes,
            device_id=dst_device,
            dpid=(self._expander_dpid
                  if dst.device_class is DeviceClass.CXL else None))

    def lmb_pcie_share(self, device_id: str, mmid: int,
                       target_device: str) -> Allocation:
        return self._share(device_id, mmid, target_device)

    def lmb_cxl_share(self, device_id: str, mmid: int,
                      target_device: str) -> Allocation:
        return self._share(device_id, mmid, target_device)

    # -- data-path access check (used by LinkedBuffer + tests) ---------------------
    def check_access(self, device_id: str, mmid: int, page: int = 0) -> None:
        region = self.allocator.region(mmid)
        self.fm.check_access(device_id, region.block_id,
                             region.page_start + page)

    def meter_transfer(self, device_id: str, nbytes: int,
                       mmid: Optional[int] = None) -> float:
        """Charge an expander-link transfer to this device's QoS share;
        returns the modeled delay (queue + wire) in seconds.  Every byte a
        consumer moves to/from the LMB tier should pass through here so the
        FM's arbiters see true link occupancy.  ``mmid`` routes the charge
        to the link of the expander actually backing the region."""
        block_id = (self.allocator.region(mmid).block_id
                    if mmid is not None else None)
        return self.fm.meter_transfer(device_id, nbytes,
                                      block_id=block_id).delay_s

    def expander_of(self, mmid: int) -> int:
        """Which pooled expander backs this allocation (placement query)."""
        return self.allocator.expander_of(mmid)

    def owned_bytes(self, device_id: str) -> int:
        return self.allocator.owned_bytes(device_id)
