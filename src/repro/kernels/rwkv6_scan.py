"""RWKV-6 chunked WKV scan — Pallas TPU kernel.

The GPU reference (RWKV CUDA kernel) walks tokens serially per thread
block.  TPU adaptation: process the sequence in chunks of T tokens held in
VMEM; intra-chunk work becomes [T,T(,N)] matmul/elementwise blocks for the
MXU/VPU, the [N,N] state is carried in VMEM scratch across the sequential
grid dimension (same math as ``repro.models.rwkv6.wkv_chunked`` — the two
are cross-checked in tests, both against the naive-recurrence oracle).

Grid: (B, H, nc) with nc sequential.  Block shapes: r/k/v/logw [T, N];
VMEM working set ≈ 4·T·N·4 + T·T·N·4 ≈ 1.1 MB at T=64, N=64 — comfortably
inside VMEM; T=64 keeps the [T,T,N] pairwise-decay tensor the right size
to trade VPU exp throughput against MXU matmul width.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                o_ref, sout_ref, state_ref, *, chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)
    T = chunk

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = s0_ref[...].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)       # [T, N]
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    lw = lw_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)       # [1, N] block
    state = state_ref[...]                   # [N, N]

    cum = jnp.cumsum(lw, axis=0)             # [T, N] inclusive
    cum_excl = cum - lw
    A = jnp.exp(cum_excl)

    # inter-chunk: o_t += (r_t * A_t) @ state
    r_dec = r * A
    inter = jax.lax.dot_general(r_dec, state, (((1,), (0,)), ((), ())))

    # intra-chunk (s < t): pairwise exponent diff, all exponents <= 0
    diff = cum_excl[:, None, :] - cum[None, :, :]          # [T, T, N]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    tri = (s_idx < t_idx)[:, :, None]
    decay = jnp.exp(jnp.where(tri, diff, -jnp.inf))        # [T, T, N]
    scores = jnp.einsum("tn,sn,tsn->ts", r, k, decay)
    intra = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())))

    bonus = jnp.sum(r * (u * k), axis=1, keepdims=True) * v

    o_ref[...] = (inter + intra + bonus).astype(o_ref.dtype)

    # carry: S' = diag(prod_chunk) S + sum_s (prod_{>s} w) k_s v_s
    total = cum[-1]                                        # [N]
    k_carry = k * jnp.exp(total[None, :] - cum)
    state_ref[...] = state * jnp.exp(total)[:, None] + \
        jax.lax.dot_general(k_carry, v, (((0,), (0,)), ((), ())))

    @pl.when(ic == nc - 1)
    def _done():
        sout_ref[...] = state_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, state: jax.Array, *, chunk: int = 64,
               interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """r,k,v,w [B,S,H,N]; u [H,N]; state [B,H,N,N] -> (out, state')."""
    B, S, H, N = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    logw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38))

    seq_spec = pl.BlockSpec((None, chunk, None, N),
                            lambda b, h, c: (b, c, h, 0))
    out, state_out = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=(B, H, nc),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((None, N), lambda b, h, c: (h, 0)),
            pl.BlockSpec((None, None, N, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((None, None, N, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, N), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, state)
    return out, state_out
