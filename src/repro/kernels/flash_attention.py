"""Flash attention (fwd) — Pallas TPU kernel.

Blocked online-softmax with causal/SWA block skipping and GQA.

TPU mapping:
  * grid (B, H, nQ, nK) — the K dimension is the sequential ("arbitrary")
    axis; running max/sum/accumulator live in VMEM scratch across K steps.
  * BlockSpecs tile q/o on (block_q, head_dim) and k/v on (block_k,
    head_dim); head_dim stays whole (128 — MXU-aligned), block_q/block_k
    default 128/256 to keep the working set
    (q + k + v + acc + s ≈ (bq + 2·bk)·hd·4 + bq·bk·4 ≈ 0.5 MB) well under
    the ~16 MB VMEM budget while giving the MXU 128-wide matmuls.
  * causal/SWA: blocks fully outside the mask are skipped via pl.when
    (zero compute, not just masked) — the kernel-level equivalent of the
    XLA path's ``causal_skip`` flag.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref,
               *, scale: float, block_q: int, block_k: int,
               seq_len: int, causal: bool, window: Optional[int]):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # block-level skip: any (q, k) work in range?
    live = True
    if causal:
        live = k_start <= q_start + block_q - 1
    if window is not None:
        live = jnp.logical_and(
            live, k_start + block_k - 1 > q_start - window) \
            if not isinstance(live, bool) else \
            (k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _body():
        q = q_ref[...].astype(jnp.float32)            # [bq, hd]
        k = k_ref[...].astype(jnp.float32)            # [bk, hd]
        v = v_ref[...].astype(jnp.float32)            # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale   # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_len
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                           # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)               # [bq, 1]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _out():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q [B,S,H,hd]; k,v [B,S,KV,hd] -> [B,S,H,hd]."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq = -(-S // block_q)
    nk = -(-S // block_k)
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _fa_kernel, scale=1.0 / math.sqrt(hd), block_q=block_q,
        block_k=block_k, seq_len=S, causal=causal, window=window)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, None, hd),
                         lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((None, block_k, None, hd),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((None, block_k, None, hd),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, None, hd),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
