"""Pallas TPU kernels for the performance hot spots.

  flash_attention — blocked online-softmax attention (causal/SWA/GQA)
  rwkv6_scan      — chunked WKV6 with data-dependent decay
  paged_attention — decode attention through a page table (the LMB/L2P
                    data path; see DESIGN.md §4)

Each kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper), ref.py (pure-jnp oracle).  Validated with interpret=True on CPU;
shape/dtype sweeps in tests/test_kernels_*.py.
"""
