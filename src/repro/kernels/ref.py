"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are deliberately the SIMPLEST correct implementations (naive exact
softmax, per-token recurrence) — slow, obviously right, and independent of
the chunked/blocked math used by the kernels and the model's XLA path.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q [B,S,H,hd]; k,v [B,S,KV,hd] -> [B,S,H,hd].  Exact softmax."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kf) / math.sqrt(hd)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, vf)
    return o.reshape(B, S, H, hd).astype(q.dtype)


def rwkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, state: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Naive per-token WKV6 recurrence.

    r,k,v,w [B,S,H,N]; u [H,N]; state [B,H,N,N] -> (out [B,S,H,N], state').
      o_t = r_t (S_{t-1} + diag(u) k_t^T v_t);  S_t = diag(w_t) S_{t-1} + k_t^T v_t
    """
    f32 = jnp.float32
    r, k, v, w = (a.astype(f32) for a in (r, k, v, w))

    def step(s, inp):
        rt, kt, vt, wt = inp        # [B,H,N]
        kv = jnp.einsum("bhn,bhm->bhnm", kt, vt)
        o = jnp.einsum("bhn,bhnm->bhm", rt, s + u[None, ..., None] * kv)
        s = s * wt[..., None] + kv
        return s, o

    xs = tuple(a.swapaxes(0, 1) for a in (r, k, v, w))  # [S,B,H,N]
    state, outs = jax.lax.scan(step, state.astype(f32), xs)
    return outs.swapaxes(0, 1), state


def ssd_ref(xh: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
            Cm: jax.Array, state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Naive per-token SSD recurrence (see repro.models.ssm)."""
    f32 = jnp.float32
    xh, dt, Bm, Cm = (a.astype(f32) for a in (xh, dt, Bm, Cm))

    def step(s, inp):
        xt, dtt, bt, ct = inp       # [B,H,P], [B,H], [B,N], [B,N]
        a = jnp.exp(dtt * A[None, :])
        s = s * a[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, bt)
        y = jnp.einsum("bhpn,bn->bhp", s, ct)
        return s, y

    xs = (xh.swapaxes(0, 1), dt.swapaxes(0, 1),
          Bm.swapaxes(0, 1), Cm.swapaxes(0, 1))
    state, ys = jax.lax.scan(step, state.astype(f32), xs)
    return ys.swapaxes(0, 1), state


def paged_attention_ref(q: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, page_table: jax.Array,
                        lengths: jax.Array) -> jax.Array:
    """Decode attention through a page table (the L2P-lookup analogue).

    q [B,H,hd]; k_pages/v_pages [P, T, KV, hd]; page_table [B, MP] int32
    (-1 = unmapped); lengths [B] valid token count -> out [B,H,hd].
    """
    B, H, hd = q.shape
    P, T, KV, _ = k_pages.shape
    MP = page_table.shape[1]
    G = H // KV
    safe = jnp.maximum(page_table, 0)
    k = k_pages[safe]               # [B, MP, T, KV, hd]
    v = v_pages[safe]
    k = k.reshape(B, MP * T, KV, hd).astype(jnp.float32)
    v = v.reshape(B, MP * T, KV, hd).astype(jnp.float32)
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k) / math.sqrt(hd)
    pos = jnp.arange(MP * T)[None]
    valid = (pos < lengths[:, None]) & \
        (jnp.repeat(page_table >= 0, T, axis=1))
    s = jnp.where(valid[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v)
    return o.reshape(B, H, hd).astype(q.dtype)
