"""Paged decode attention — Pallas TPU kernel (the LMB data path).

This kernel IS the paper's L2P scenario on a TPU: the KV cache lives in a
paged pool (HBM tier of the LinkedBuffer); each request's logical sequence
is scattered across pool pages; the **page table is consulted on every
access** exactly like the SSD firmware consults its L2P table.  The table
rides in SMEM via scalar prefetch — the Pallas equivalent of "allocator
metadata stays host-side / on-board" (§3.2): the lookup never touches the
paged data tier.

Grid (B, KV): the page walk happens INSIDE the kernel as a fori_loop over
the sequence's live pages, with **double-buffered K/V page loads** — while
page i feeds the softmax/matmul, page i+1's DMA from the HBM pool is
already in flight (the PR 5 link-layer overlap idea pushed down into the
kernel; see the double-buffering pattern in the Pallas guide).  The pool
arrays stay in ``TPUMemorySpace.ANY`` (HBM) and only the two in-flight
pages ever occupy VMEM, so pool size is bounded by HBM, not VMEM.

Unmapped pages (table entry -1) are clamped to page 0 for the DMA and
masked out of the softmax — reads are always in-bounds (IOMMU discipline)
and their probability mass is exactly zero.

``paged_attention_xla`` is the byte-compatible decode fallback for
off-TPU runs: it reproduces the dense decode path's einsum/softmax
ordering bit-for-bit (same contraction equation, f32 accumulation, -1e30
masking, post-einsum scaling) so the serve engine's paged decode emits
byte-identical tokens to the retired dense-slot path on CPU CI.
"""

from __future__ import annotations

import functools
import math
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pa_kernel(table_ref, len_ref, q_ref, k_hbm, v_hbm, o_ref,
               k_buf, v_buf, sem, m_ref, l_ref, acc_ref,
               *, page_tokens: int):
    b = pl.program_id(0)
    h = pl.program_id(1)
    T = page_tokens
    length = len_ref[b]
    n_pages = (length + T - 1) // T

    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def page_dma(slot, ip):
        """Async copies pool page table[b, ip] (head h) into VMEM slot."""
        page = jnp.maximum(table_ref[b, ip], 0)
        return (pltpu.make_async_copy(k_hbm.at[page, :, h],
                                      k_buf.at[slot], sem.at[slot, 0]),
                pltpu.make_async_copy(v_hbm.at[page, :, h],
                                      v_buf.at[slot], sem.at[slot, 1]))

    @pl.when(n_pages > 0)
    def _warmup():
        for cp in page_dma(0, 0):
            cp.start()

    q = q_ref[...].astype(jnp.float32)              # [G, hd]

    def body(ip, _):
        slot = jax.lax.rem(ip, 2)

        # hide the next page load behind this page's softmax/matmul
        @pl.when(ip + 1 < n_pages)
        def _start_next():
            for cp in page_dma(jax.lax.rem(ip + 1, 2), ip + 1):
                cp.start()

        for cp in page_dma(slot, ip):
            cp.wait()
        k = k_buf[slot].astype(jnp.float32)         # [T, hd]
        v = v_buf[slot].astype(jnp.float32)         # [T, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())))         # [G, T]
        pos = ip * T + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = (pos < length) & (table_ref[b, ip] >= 0)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        # masked lanes contribute exactly zero even when the whole page
        # is masked (m stays at NEG_INF, so exp(s - m) would be 1, not 0)
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new
        return 0

    jax.lax.fori_loop(0, n_pages, body, 0)
    l = jnp.maximum(l_ref[...], 1e-20)              # length-0 rows -> 0
    o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale_override", "interpret"))
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, lengths: jax.Array,
                    *, scale_override: float | None = None,
                    interpret: bool = False) -> jax.Array:
    """q [B,H,hd]; k/v_pages [P,T,KV,hd]; page_table [B,MP] int32 (-1 =
    unmapped); lengths [B] -> out [B,H,hd]."""
    B, H, hd = q.shape
    P, T, KV, _ = k_pages.shape
    G = H // KV
    scale = 1.0 / math.sqrt(hd) if scale_override is None else \
        scale_override
    qs = (q.reshape(B, KV, G, hd) * scale).astype(q.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV),
        in_specs=[
            pl.BlockSpec((None, None, G, hd),
                         lambda b, h, tbl, ln: (b, h, 0, 0)),
            # the pool stays in HBM; the kernel DMAs pages on demand
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
        ],
        out_specs=pl.BlockSpec((None, None, G, hd),
                               lambda b, h, tbl, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, T, hd), k_pages.dtype),   # double buffer: K
            pltpu.VMEM((2, T, hd), v_pages.dtype),   # double buffer: V
            pltpu.SemaphoreType.DMA((2, 2)),         # [slot, k/v]
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_pa_kernel, page_tokens=T),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      qs, k_pages, v_pages)
    return out.reshape(B, H, hd)


def paged_attention_xla(q, k_pages, v_pages, page_table, lengths,
                        *, scale_override: float | None = None):
    """Decode-shaped XLA fallback, byte-compatible with the dense path.

    Semantics match :func:`paged_attention`; numerics match the dense
    decode attention (`models.attention._scores_softmax_out`) **bitwise**:
    the same einsum contraction (f32 accumulation, scale applied after),
    -1e30 masking before a plain softmax, and the probabilities cast back
    to the V dtype for the output contraction.  Masked lanes underflow to
    exactly 0 after softmax, so clamped-page garbage never leaks — this
    is what lets the serve engine swap its dense slot cache for the paged
    pool without perturbing a single emitted token on CPU CI.
    """
    B, H, hd = q.shape
    P, T, KV, _ = k_pages.shape
    MP = page_table.shape[1]
    G = H // KV
    scale = 1.0 / math.sqrt(hd) if scale_override is None else \
        scale_override
    safe = jnp.maximum(page_table, 0)
    k = k_pages[safe].reshape(B, MP * T, KV, hd)
    v = v_pages[safe].reshape(B, MP * T, KV, hd)
    qg = q.reshape(B, 1, KV, G, hd)
    pos = jnp.arange(MP * T)[None, :]
    valid = (pos < lengths[:, None]) & \
        jnp.repeat(page_table >= 0, T, axis=1)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    # all-masked rows (length 0): softmax degenerates to uniform over
    # NEG_INF lanes; zero them like the kernel does
    any_valid = jnp.any(valid, axis=1)[:, None, None, None, None]
    o = jnp.where(any_valid, o, 0.0)
    return o.reshape(B, H, hd).astype(q.dtype)
