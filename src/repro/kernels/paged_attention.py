"""Paged decode attention — Pallas TPU kernel (the LMB data path).

This kernel IS the paper's L2P scenario on a TPU: the KV cache lives in a
paged pool (HBM tier of the LinkedBuffer); each request's logical sequence
is scattered across pool pages; the **page table is consulted on every
access** exactly like the SSD firmware consults its L2P table.  The table
rides in SMEM via scalar prefetch — the Pallas equivalent of "allocator
metadata stays host-side / on-board" (§3.2): the lookup never touches the
paged data tier.

Grid (B, KV, nP): pages are the sequential axis; the online-softmax state
(m, l, acc per GQA group) lives in VMEM scratch.  Block = one KV page
[page_tokens, hd] per head — DMA-friendly contiguous reads from the pool,
regardless of how the logical sequence is fragmented.

Unmapped pages (table entry -1) are clamped to page 0 for the DMA and
masked out of the softmax — reads are always in-bounds (IOMMU discipline).
"""

from __future__ import annotations

import functools
import math
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pa_kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref, *, page_tokens: int):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]

    # pages are allocated densely per request: slot ip is live iff any of
    # its positions is below the request length (dead pages are skipped,
    # not just masked — and the clamped table keeps their DMA in-bounds)
    @pl.when(ip * page_tokens < length)
    def _body():
        q = q_ref[...].astype(jnp.float32)          # [G, hd]
        k = k_ref[...].astype(jnp.float32)          # [T, hd]
        v = v_ref[...].astype(jnp.float32)          # [T, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())))         # [G, T]
        pos = ip * page_tokens + \
            jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ip == np_ - 1)
    def _out():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale_override", "interpret"))
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, lengths: jax.Array,
                    *, scale_override: float | None = None,
                    interpret: bool = False) -> jax.Array:
    """q [B,H,hd]; k/v_pages [P,T,KV,hd]; page_table [B,MP] int32 (-1 =
    unmapped); lengths [B] -> out [B,H,hd]."""
    B, H, hd = q.shape
    P, T, KV, _ = k_pages.shape
    MP = page_table.shape[1]
    G = H // KV
    scale = scale_override or 1.0 / math.sqrt(hd)
    qs = (q.reshape(B, KV, G, hd) * scale).astype(q.dtype)
    safe_table = jnp.maximum(page_table, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, MP),
        in_specs=[
            pl.BlockSpec((None, None, G, hd),
                         lambda b, h, ip, tbl, ln: (b, h, 0, 0)),
            pl.BlockSpec((None, T, None, hd),
                         lambda b, h, ip, tbl, ln: (tbl[b, ip], 0, h, 0)),
            pl.BlockSpec((None, T, None, hd),
                         lambda b, h, ip, tbl, ln: (tbl[b, ip], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, hd),
                               lambda b, h, ip, tbl, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_pa_kernel, page_tokens=T),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(safe_table, lengths.astype(jnp.int32), qs, k_pages, v_pages)
    return out.reshape(B, H, hd)


def paged_attention_xla(q, k_pages, v_pages, page_table, lengths):
    """XLA fallback with identical semantics (used off-TPU)."""
    from repro.kernels.ref import paged_attention_ref
    return paged_attention_ref(q, k_pages, v_pages, page_table, lengths)
