"""jit'd public wrappers for the Pallas kernels.

On TPU these lower the real kernels; elsewhere they run interpret mode
(kernel body executed op-by-op on CPU — same math, validated against
ref.py).  Model code calls these via ``flags.use_kernels``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa
from repro.kernels import rwkv6_scan as _rw


@functools.cache
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True,
                    window: Optional[int] = None) -> jax.Array:
    """[B,S,H,hd] x [B,S,KV,hd] -> [B,S,H,hd]."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=_interpret())


def rwkv6_scan(r, k, v, w, u, state, chunk: int = 64
               ) -> Tuple[jax.Array, jax.Array]:
    out, st = _rw.rwkv6_scan(r, k, v, w, u, state, chunk=chunk,
                             interpret=_interpret())
    return out, st


def ssd_scan(xh, dt, A, Bm, Cm, state):
    """SSD inner scan: the chunked XLA form already IS matmul-blocked;
    a dedicated Pallas kernel adds nothing until the attention branch is
    kernelized too, so this dispatches to the shared chunked path."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(xh, dt, A, Bm, Cm, state)


def paged_attention(q, k_pages, v_pages, page_table, lengths) -> jax.Array:
    return _pa.paged_attention(q, k_pages, v_pages, page_table, lengths,
                               interpret=_interpret())


# trace-time call counter for the decode dispatcher: incremented when the
# paged kernel is staged into a compiled step, so a >0 delta proves the
# serve engine's decode actually runs through paged attention (asserted
# by tests and the decode_sweep identity gate) even though the jitted
# function itself only retraces once per shape
_pa_decode_traces = 0


def paged_attention_decode_traces() -> int:
    return _pa_decode_traces


def paged_attention_decode(q, k_pages, v_pages, page_table,
                           lengths) -> jax.Array:
    """Decode-path dispatcher: the double-buffered Pallas kernel on TPU;
    off-TPU the XLA fallback whose numerics are byte-compatible with the
    dense decode attention (interpret-mode kernel execution is reserved
    for the kernel tests — far too slow for a serving loop)."""
    global _pa_decode_traces
    _pa_decode_traces += 1
    if _interpret():
        return _pa.paged_attention_xla(q, k_pages, v_pages, page_table,
                                       lengths)
    return _pa.paged_attention(q, k_pages, v_pages, page_table, lengths)
