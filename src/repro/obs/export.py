"""Trace exporters: Chrome trace-event JSON and JSONL round-trip.

Chrome format (the ``chrome://tracing`` / Perfetto "JSON object
format"): one ``"X"`` (complete) event per span with ``ts``/``dur`` in
microseconds, plus ``"M"`` metadata events naming the tracks.  Track
layout:

  * pid ``1`` ("fabric links") — one tid (thread row) per expander id;
    every span tagged with an expander lands here.
  * pid ``2`` ("tenants") — one tid per tenant name; every span tagged
    with a tenant lands here.  A span carrying both tags is emitted on
    *both* tracks (same ``id`` in args), which is what makes the
    per-link and per-tenant views each complete in Perfetto.
  * pid ``3`` ("failure domains") — one tid per rack failure domain;
    every span whose args carry a ``domain`` tag (rack-topology-aware
    link transfers) also lands here, giving the blast-radius view.
  * pid ``0`` ("engine") — spans with none of the tags (serve rounds,
    migration rounds, ...).

Every event's ``args`` carries the full structured span (op class,
nbytes, tenant, expander, span id, parent, dur in seconds, plus any
emitter extras), so the Chrome JSON is *parseable back into spans* —
``load_trace`` accepts either format and ``tools/lmbtrace.py`` never
needs the JSONL twin to exist.
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional

from repro.obs.trace import Span

_PID_ENGINE = 0
_PID_LINKS = 1
_PID_TENANTS = 2
_PID_DOMAINS = 3


def _span_args(s: Span) -> Dict[str, Any]:
    a = {"id": s.span_id, "op": s.op, "nbytes": s.nbytes,
         "dur_s": s.dur, "t0_s": s.t0}
    if s.parent_id is not None:
        a["parent"] = s.parent_id
    if s.tenant is not None:
        a["tenant"] = s.tenant
    if s.expander is not None:
        a["expander"] = s.expander
    a.update(s.args)
    return a


def chrome_trace_events(spans: Iterable[Span]) -> List[Dict[str, Any]]:
    """Spans -> list of Chrome trace-event dicts (with track metadata)."""
    events: List[Dict[str, Any]] = []
    tenants: Dict[str, int] = {}
    domains: Dict[str, int] = {}
    expanders: set = set()

    def emit(s: Span, pid: int, tid: int) -> None:
        events.append({
            "name": s.name, "ph": "X", "pid": pid, "tid": tid,
            "ts": s.t0 * 1e6, "dur": s.dur * 1e6,
            "cat": s.op or "span", "args": _span_args(s),
        })

    for s in spans:
        placed = False
        if s.expander is not None:
            expanders.add(s.expander)
            emit(s, _PID_LINKS, int(s.expander))
            placed = True
        if s.tenant is not None:
            tid = tenants.setdefault(s.tenant, len(tenants))
            emit(s, _PID_TENANTS, tid)
            placed = True
        dom = s.args.get("domain")
        if dom is not None:
            tid = domains.setdefault(str(dom), len(domains))
            emit(s, _PID_DOMAINS, tid)
            placed = True
        if not placed:
            emit(s, _PID_ENGINE, 0)

    meta: List[Dict[str, Any]] = [
        {"name": "process_name", "ph": "M", "pid": _PID_ENGINE, "tid": 0,
         "args": {"name": "engine"}},
        {"name": "process_name", "ph": "M", "pid": _PID_LINKS, "tid": 0,
         "args": {"name": "fabric links"}},
        {"name": "process_name", "ph": "M", "pid": _PID_TENANTS, "tid": 0,
         "args": {"name": "tenants"}},
        {"name": "process_name", "ph": "M", "pid": _PID_DOMAINS, "tid": 0,
         "args": {"name": "failure domains"}},
    ]
    for eid in sorted(expanders):
        meta.append({"name": "thread_name", "ph": "M", "pid": _PID_LINKS,
                     "tid": int(eid),
                     "args": {"name": f"expander {eid} link"}})
    for tenant, tid in sorted(tenants.items(), key=lambda kv: kv[1]):
        meta.append({"name": "thread_name", "ph": "M",
                     "pid": _PID_TENANTS, "tid": tid,
                     "args": {"name": f"tenant {tenant}"}})
    for dom, tid in sorted(domains.items(), key=lambda kv: kv[1]):
        meta.append({"name": "thread_name", "ph": "M",
                     "pid": _PID_DOMAINS, "tid": tid,
                     "args": {"name": f"domain {dom}"}})
    return meta + events


def write_chrome_trace(spans: Iterable[Span], path: str,
                       extra: Optional[Dict[str, Any]] = None) -> None:
    payload = {"traceEvents": chrome_trace_events(spans),
               "displayTimeUnit": "ms",
               "otherData": {"generator": "repro.obs", **(extra or {})}}
    with open(path, "w") as f:
        json.dump(payload, f)


# -- JSONL ---------------------------------------------------------
def span_to_dict(s: Span) -> Dict[str, Any]:
    return {"name": s.name, "t0": s.t0, "dur": s.dur, "op": s.op,
            "tenant": s.tenant, "expander": s.expander,
            "nbytes": s.nbytes, "span_id": s.span_id,
            "parent_id": s.parent_id, "args": s.args}


def span_from_dict(d: Dict[str, Any]) -> Span:
    return Span(name=d["name"], t0=d["t0"], dur=d["dur"],
                op=d.get("op", ""), tenant=d.get("tenant"),
                expander=d.get("expander"), nbytes=d.get("nbytes", 0),
                span_id=d.get("span_id", 0),
                parent_id=d.get("parent_id"), args=d.get("args", {}))


def write_jsonl(spans: Iterable[Span], path: str) -> None:
    with open(path, "w") as f:
        for s in spans:
            f.write(json.dumps(span_to_dict(s)) + "\n")


def read_jsonl(path: str) -> List[Span]:
    out: List[Span] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(span_from_dict(json.loads(line)))
    return out


def _span_from_chrome(ev: Dict[str, Any]) -> Span:
    a = dict(ev.get("args", {}))
    sid = a.pop("id", 0)
    extras = {k: v for k, v in a.items()
              if k not in ("op", "nbytes", "dur_s", "t0_s", "parent",
                           "tenant", "expander")}
    return Span(name=ev["name"], t0=a.get("t0_s", ev["ts"] / 1e6),
                dur=a.get("dur_s", ev.get("dur", 0.0) / 1e6),
                op=a.get("op", ev.get("cat", "")),
                tenant=a.get("tenant"), expander=a.get("expander"),
                nbytes=a.get("nbytes", 0), span_id=sid,
                parent_id=a.get("parent"), args=extras)


def load_trace(path: str) -> List[Span]:
    """Load spans from either export format (sniffed by content).

    Chrome traces deduplicate by span id (a tenant+expander span is
    emitted on two tracks but is one logical span).
    """
    with open(path) as f:
        head = f.read(1)
        f.seek(0)
        if head != "{":
            return read_jsonl(path)
        first = f.readline()
        try:
            doc = json.loads(first)
            # single-line JSONL file whose first record parsed fine
            if "traceEvents" not in doc:
                return read_jsonl(path)
        except json.JSONDecodeError:
            f.seek(0)
            doc = json.load(f)
    if "traceEvents" not in doc:
        raise ValueError(f"{path}: not a trace file")
    seen: Dict[int, Span] = {}
    anon: List[Span] = []
    for ev in doc["traceEvents"]:
        if ev.get("ph") != "X":
            continue
        s = _span_from_chrome(ev)
        if s.span_id:
            seen.setdefault(s.span_id, s)
        else:
            anon.append(s)
    return sorted(seen.values(), key=lambda s: (s.t0, s.span_id)) + anon
