"""Log-spaced-bucket histograms with percentile estimation.

The CXL tier papers (2306.11227, 2503.22017) make the case that tail
latency — not the mean — is what separates memory tiers, so the metrics
registry needs percentiles that are cheap to record and cheap to merge.
A fixed log-spaced bucket layout gives both: ``record`` is one
``searchsorted``, ``merge`` is one vector add, and any percentile is
reconstructed from cumulative bucket counts with bounded relative error
(at most the bucket width — ~15% at the default 8 buckets/decade).

All histograms built with the same ``(lo, hi, buckets_per_decade)``
share an edge vector and can be merged; merging mismatched layouts
raises.  Values at or below zero land in the underflow bucket, values
above ``hi`` in the overflow bucket; observed ``min``/``max`` are kept
exactly so the extreme percentiles (p0/p100) are not quantized.
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

#: default range covers 1 ns .. ~3 h when recording seconds, and
#: 1 B .. 10 TB when recording byte counts — one layout for both uses.
DEFAULT_LO = 1e-9
DEFAULT_HI = 1e4
DEFAULT_BUCKETS_PER_DECADE = 8

_EDGE_CACHE: Dict[Tuple[float, float, int], np.ndarray] = {}


def _edges(lo: float, hi: float, per_decade: int) -> np.ndarray:
    key = (lo, hi, per_decade)
    e = _EDGE_CACHE.get(key)
    if e is None:
        decades = math.log10(hi / lo)
        n = max(1, int(round(decades * per_decade)))
        e = np.logspace(math.log10(lo), math.log10(hi), n + 1)
        _EDGE_CACHE[key] = e
    return e


class Histogram:
    """Mergeable log-bucket histogram of non-negative samples."""

    __slots__ = ("edges", "counts", "count", "sum", "min", "max",
                 "_layout")

    def __init__(self, lo: float = DEFAULT_LO, hi: float = DEFAULT_HI,
                 buckets_per_decade: int = DEFAULT_BUCKETS_PER_DECADE):
        if not (0.0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got {lo}, {hi}")
        self._layout = (float(lo), float(hi), int(buckets_per_decade))
        self.edges = _edges(*self._layout)
        # counts[0] = underflow (<= lo), counts[-1] = overflow (> hi)
        self.counts = np.zeros(len(self.edges) + 1, dtype=np.int64)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    # -- recording -------------------------------------------------
    def record(self, value: float) -> None:
        v = float(value)
        self.counts[int(np.searchsorted(self.edges, v, side="left"))] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def record_many(self, values: Iterable[float]) -> None:
        arr = np.asarray(list(values) if not isinstance(
            values, np.ndarray) else values, dtype=np.float64)
        if arr.size == 0:
            return
        idx = np.searchsorted(self.edges, arr, side="left")
        np.add.at(self.counts, idx, 1)
        self.count += int(arr.size)
        self.sum += float(arr.sum())
        self.min = min(self.min, float(arr.min()))
        self.max = max(self.max, float(arr.max()))

    # -- reading ---------------------------------------------------
    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Estimate the q-th percentile (0..100) from bucket counts.

        Returns the geometric midpoint of the bucket holding the
        target rank, clamped to the exact observed [min, max].
        """
        if self.count == 0:
            return 0.0
        if q <= 0:
            return self.min
        if q >= 100:
            return self.max
        target = q / 100.0 * self.count
        cum = np.cumsum(self.counts)
        b = int(np.searchsorted(cum, target, side="left"))
        if b == 0:                      # underflow bucket: exact min
            est = self.min
        elif b >= len(self.edges):      # overflow bucket: exact max
            est = self.max
        else:
            est = math.sqrt(self.edges[b - 1] * self.edges[b])
        return min(max(est, self.min), self.max)

    def quantiles(self, qs: Iterable[float]) -> List[float]:
        return [self.percentile(q) for q in qs]

    # -- combining -------------------------------------------------
    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other``'s samples into self (same layout required)."""
        if other._layout != self._layout:
            raise ValueError(
                f"histogram layout mismatch: {self._layout} vs "
                f"{other._layout}")
        self.counts += other.counts
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    def copy(self) -> "Histogram":
        h = Histogram(*self._layout)
        h.merge(self)
        return h

    def reset(self) -> None:
        self.counts[:] = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def snapshot(self) -> Dict[str, float]:
        """Uniform summary used by ``Metrics.snapshot()``."""
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0,
                    "max": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
        p50, p90, p99 = self.quantiles((50, 90, 99))
        return {"count": self.count, "sum": self.sum, "mean": self.mean,
                "min": self.min, "max": self.max,
                "p50": p50, "p90": p90, "p99": p99}

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Histogram(count={self.count}, mean={self.mean:.3g}, "
                f"p99={self.percentile(99):.3g})")


def merge_all(hists: Iterable[Optional["Histogram"]]) -> Optional[Histogram]:
    """Merge any number of same-layout histograms into a fresh one."""
    out: Optional[Histogram] = None
    for h in hists:
        if h is None:
            continue
        if out is None:
            out = h.copy()
        else:
            out.merge(h)
    return out
