"""repro.obs — observability: span tracing, histograms, exporters.

This package is a dependency *leaf*: it imports nothing from
``repro.core`` / ``repro.serve`` / ``repro.qos`` (only numpy and the
stdlib), so every layer of the system can import it freely without
creating cycles.

  trace  — bounded ring-buffer span tracer (off by default; the
           disabled path is a single attribute check per call site)
  hist   — log-spaced-bucket histograms with mergeable counts and
           percentile estimation (numpy-backed)
  export — Chrome trace-event JSON (perfetto-viewable) + JSONL span
           round-trip; consumed by ``tools/lmbtrace.py``
"""

from repro.obs.hist import Histogram
from repro.obs.trace import (DEFAULT_RING_CAPACITY, GLOBAL_TRACER, Span,
                             SpanTracer, disable_tracing, enable_tracing)

__all__ = [
    "Histogram", "Span", "SpanTracer", "GLOBAL_TRACER",
    "DEFAULT_RING_CAPACITY", "enable_tracing", "disable_tracing",
]
