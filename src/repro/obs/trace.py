"""Bounded ring-buffer span tracer for the LMB data path.

Design constraints, in order:

1. **Near-zero disabled path.**  Tracing is off by default; every
   instrumented call site guards with ``if tracer.enabled:`` (one
   attribute load + branch) before touching anything else, and the
   ``span(...)`` context manager returns a shared no-op object when
   disabled.  The hot paths (scalar fault, per-page meter) pay nothing
   measurable.
2. **Bounded memory.**  Spans land in a preallocated ring; once
   ``capacity`` is reached the oldest spans are overwritten and
   ``dropped`` counts them, so a tracer left on for a long sweep can
   never grow without bound (the same cap bounds ``Metrics._events``).
3. **Attributable.**  Every span carries tenant, expander, op class
   (demand / prefetch / migrate / ...), byte count, and a parent span
   id (maintained by a per-tracer stack of open spans) so exporters can
   reconstruct the fault → burst → link-charge hierarchy and group
   tracks per expander link and per tenant.

Clocks: ``t0`` is wall time (``time.monotonic``) relative to the
tracer's epoch.  ``dur`` is *whatever the emitter says it is* — wall
seconds for compute-side spans, **modeled virtual seconds** for link
transfer spans (the arbiter's ``TransferGrant.delay_s``), which is what
makes span sums reconcile exactly with the fabric byte/wait counters.
Exporters record which convention a span used via its name/args.
"""

from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

#: shared cap for the span ring and for ``Metrics._events``
DEFAULT_RING_CAPACITY = 65536


@dataclass
class Span:
    """One structured trace record (a closed interval or an instant)."""

    name: str                       # e.g. "link.xfer", "fault.batch"
    t0: float                       # seconds since tracer epoch
    dur: float                      # seconds (0.0 for instant events)
    op: str = ""                    # traffic class: demand/prefetch/...
    tenant: Optional[str] = None
    expander: Optional[int] = None
    nbytes: int = 0
    span_id: int = 0
    parent_id: Optional[int] = None
    args: Dict[str, Any] = field(default_factory=dict)


class _NullSpan:
    """Singleton no-op context manager for the disabled path."""

    __slots__ = ()

    def __enter__(self) -> None:
        return None

    def __exit__(self, *exc: object) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class SpanTracer:
    """Thread-safe bounded span recorder.

    ``enabled`` may be flipped at any time; call sites re-check it per
    operation.  All mutation happens under one lock — contention is a
    non-issue at the span rates the model produces, and correctness
    under the serve engine's future threading is free.
    """

    def __init__(self, capacity: int = DEFAULT_RING_CAPACITY,
                 enabled: bool = True):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._buf: List[Optional[Span]] = [None] * self.capacity
        self._head = 0              # next write slot
        self._count = 0             # live spans (<= capacity)
        self.dropped = 0            # spans overwritten after wrap
        self._next_id = 1
        self._stack: List[int] = []  # open span ids (for parenting)
        self._epoch = time.monotonic()

    # -- clock -----------------------------------------------------
    def now(self) -> float:
        """Wall seconds since this tracer's epoch."""
        return time.monotonic() - self._epoch

    # -- recording -------------------------------------------------
    def add(self, name: str, t0: float, dur: float, *, op: str = "",
            tenant: Optional[str] = None, expander: Optional[int] = None,
            nbytes: int = 0, parent_id: Optional[int] = None,
            span_id: Optional[int] = None, **args: Any) -> int:
        """Record a closed span; returns its id.  No-op when disabled."""
        if not self.enabled:
            return 0
        with self._lock:
            if span_id is None:
                span_id = self._next_id
                self._next_id += 1
            if parent_id is None and self._stack:
                parent_id = self._stack[-1]
            s = Span(name=name, t0=t0, dur=dur, op=op, tenant=tenant,
                     expander=expander, nbytes=nbytes, span_id=span_id,
                     parent_id=parent_id, args=args)
            if self._buf[self._head] is not None:
                self.dropped += 1
            else:
                self._count += 1
            self._buf[self._head] = s
            self._head = (self._head + 1) % self.capacity
            return span_id

    def event(self, name: str, **kw: Any) -> int:
        """Record an instant (zero-duration) event at ``now()``."""
        if not self.enabled:
            return 0
        return self.add(name, self.now(), 0.0, **kw)

    @contextmanager
    def _span_cm(self, name: str, kw: Dict[str, Any]) -> Iterator[int]:
        with self._lock:
            sid = self._next_id
            self._next_id += 1
            parent = self._stack[-1] if self._stack else None
            self._stack.append(sid)
        t0 = self.now()
        try:
            yield sid
        finally:
            dur = self.now() - t0
            with self._lock:
                if self._stack and self._stack[-1] == sid:
                    self._stack.pop()
                elif sid in self._stack:    # unbalanced exit
                    self._stack.remove(sid)
            self.add(name, t0, dur, parent_id=parent, span_id=sid, **kw)

    def span(self, name: str, **kw: Any):
        """Context manager recording a wall-clock span around a block.

        Children recorded while the block is open (via nested ``span``
        or plain ``add``/``event``) get this span as their parent.
        When disabled, returns a shared no-op — no allocation.
        """
        if not self.enabled:
            return _NULL_SPAN
        return self._span_cm(name, kw)

    # -- reading ---------------------------------------------------
    def spans(self) -> List[Span]:
        """Live spans, oldest first (post-wrap order preserved)."""
        with self._lock:
            if self._count < self.capacity:
                out = [s for s in self._buf[:self._count]]
            else:
                out = self._buf[self._head:] + self._buf[:self._head]
            return [s for s in out if s is not None]

    def __len__(self) -> int:
        return self._count

    def clear(self) -> None:
        with self._lock:
            self._buf = [None] * self.capacity
            self._head = 0
            self._count = 0
            self.dropped = 0
            self._stack.clear()
            self._epoch = time.monotonic()

    def snapshot(self) -> Dict[str, Any]:
        return {"enabled": self.enabled, "capacity": self.capacity,
                "count": self._count, "dropped": self.dropped}


#: process-wide default tracer — disabled; every component that is not
#: handed an explicit tracer falls back to this one, so flipping it on
#: (``enable_tracing``) instruments systems built afterwards *and*
#: already-running ones with zero plumbing.
GLOBAL_TRACER = SpanTracer(capacity=DEFAULT_RING_CAPACITY, enabled=False)


def enable_tracing(capacity: Optional[int] = None) -> SpanTracer:
    """Turn on the process-wide tracer (optionally resizing) and
    return it.  Clears previously recorded spans."""
    if capacity is not None and capacity != GLOBAL_TRACER.capacity:
        GLOBAL_TRACER.capacity = int(capacity)
    GLOBAL_TRACER.clear()
    GLOBAL_TRACER.enabled = True
    return GLOBAL_TRACER


def disable_tracing() -> None:
    """Turn the process-wide tracer back off (spans are kept)."""
    GLOBAL_TRACER.enabled = False
