"""Gradient compression with error feedback (large-scale DP option).

int8 per-tensor-scaled quantization with an error-feedback residual: the
update applied is ``Q(g + e)`` and ``e' = (g + e) - Q(g + e)``.  On a real
multi-host mesh this wraps the data-parallel all-reduce (quantize →
reduce → dequantize) via shard_map; here the quantizer is exact-shape
functional so the training loop and tests exercise the numerics, and the
dry-run measures the collective-bytes reduction (4x over fp32) in §Perf.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quant_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (decompressed update, new error residual)."""
    t = g.astype(jnp.float32) + err
    q, s = _quant_int8(t)
    d = _dequant(q, s)
    return d.astype(g.dtype), t - d


def ef_compress_tree(grads: Any, err_tree: Any) -> Tuple[Any, Any]:
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    outs = [ef_compress(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def ef_state_init(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
