from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import ef_compress_tree

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "ef_compress_tree"]
