"""AdamW with fp32 master copies for low-precision params.

Pure-pytree implementation (no optax in this environment).  The optimizer
state is the tensor that outgrows device memory in training (2–3× params in
fp32) — exactly the paper's "index that doesn't fit on-board"; the LMB
integration (state offloaded to the host pool, paged per layer) lives in
``repro.train.loop`` / ``repro.core.offload``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, cos)


def adamw_init(params: Any) -> Dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    master = jax.tree_util.tree_map(
        lambda p: p.astype(jnp.float32), params)
    return {
        "m": jax.tree_util.tree_map(f32, params),
        "v": jax.tree_util.tree_map(f32, params),
        "master": master,
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, grads: Any, state: Dict[str, Any],
                 params: Any) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
    """One step.  Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = schedule(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bc1 = 1 - b1 ** c
    bc2 = 1 - b2 ** c

    def upd(g, m, v, master, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        step_ = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        decay = cfg.weight_decay if master.ndim >= 2 else 0.0
        master = master - lr * (step_ + decay * master)
        return m, v, master, master.astype(p.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_ma = treedef.flatten_up_to(state["master"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(*t) for t in zip(flat_g, flat_m, flat_v, flat_ma, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_ma = treedef.unflatten([o[2] for o in out])
    new_p = treedef.unflatten([o[3] for o in out])
    new_state = {"m": new_m, "v": new_v, "master": new_ma, "count": count}
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
