from repro.sharding.partition import (batch_sharding, batch_spec,
                                      cache_shardings, param_shardings,
                                      spec_report)

__all__ = ["batch_sharding", "batch_spec", "cache_shardings",
           "param_shardings", "spec_report"]
