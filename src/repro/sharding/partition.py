"""PartitionSpec rules: DP / TP / EP / SP with divisibility fallback.

Strategy (GSPMD + NamedSharding; mesh axes ``("pod",) "data", "model"``):

  * **DP** — batch over ``(pod, data)``; gradients all-reduce over it.
  * **TP (megatron)** — attention Q heads and FFN hidden column-parallel on
    ``model``; output projections row-parallel (psum).  GQA KV projections
    replicate when ``kv_heads % model_size != 0`` (the standard GQA-TP
    choice — KV projections are small).
  * **EP** — MoE expert axis on ``model`` when ``E % model_size == 0``
    (dbrx 16e); otherwise per-expert FFN hidden TP (mixtral 8e).
  * **SP (decode)** — KV-cache sequence dim on ``model`` (KV heads rarely
    divide 16); for ``long_500k`` (batch=1) the cache seq dim also takes
    ``data`` so the data axis isn't idle.

Every rule is validated against the actual dim size: a non-divisible axis
falls back to replication (e.g. hymba's 25 heads, qwen2's 12) — recorded by
``spec_report`` so the dry-run output shows exactly what sharded how.
"""

from __future__ import annotations

from typing import Any, List, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

_REPORT: List[Tuple[str, Tuple[int, ...], P]] = []


def mesh_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis] if axis in mesh.shape else 1


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_spec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    """Spec for [B, ...] inputs; replicates B if it doesn't divide."""
    ax = batch_axes(mesh)
    n = int(np.prod([mesh_size(mesh, a) for a in ax]))
    if batch % n == 0:
        return P(ax, *([None] * extra_dims))
    # try data-only
    if batch % mesh_size(mesh, "data") == 0:
        return P("data", *([None] * extra_dims))
    return P(*([None] * (extra_dims + 1)))


def batch_sharding(mesh: Mesh, batch: int, extra_dims: int = 1):
    return NamedSharding(mesh, batch_spec(mesh, batch, extra_dims))


def _ok(dim: int, mesh: Mesh, axis: str) -> bool:
    return dim % mesh_size(mesh, axis) == 0


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", k)) for k in path)


def _spec_for_param(path: str, shape: Tuple[int, ...], mesh: Mesh,
                    cfg: ArchConfig) -> P:
    """Rule table.  ``shape`` includes the stacked [L] leading axis for
    trunk params (path contains 'trunk')."""
    parts = path.split("/")
    is_bias = parts[-1] == "b"
    if parts[-1] in ("w", "b"):   # dense_init nests {"w": ..., "b": ...}
        name = parts[-2]
        parent = parts[-3] if len(parts) > 2 else ""
    else:
        name = parts[-1]
        parent = parts[-2] if len(parts) > 1 else ""
    stacked = "trunk" in path
    core = shape[1:] if stacked else shape
    pre = (None,) if stacked else ()

    def spec(*axes) -> P:
        return P(*pre, *axes)

    if is_bias:   # biases are tiny: replicate (XLA reshards as needed)
        return spec(*([None] * len(core)))

    ms = mesh_size(mesh, "model")
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_

    # ---- embedding -------------------------------------------------------
    if name == "table":
        V, D = core
        if V % ms == 0:
            return spec("model", None)
        if D % ms == 0:
            return spec(None, "model")
        return spec(None, None)

    # ---- attention -------------------------------------------------------
    if parent in ("attn", "cross"):
        if name == "wq":
            return spec(None, "model") if H % ms == 0 else spec(None, None)
        if name in ("wk", "wv"):
            return spec(None, "model") if KV % ms == 0 else spec(None, None)
        if name == "wo":
            return spec("model", None) if H % ms == 0 else spec(None, None)
        if name == "b":  # qkv biases: tiny, replicate
            return spec(*([None] * len(core)))

    # ---- dense / moe FFN ---------------------------------------------------
    if name in ("w_gate", "w_up"):
        if len(core) == 3:  # MoE [E, D, F]
            E, D, F = core
            if E % ms == 0:
                return spec("model", None, None)
            if F % ms == 0:
                return spec(None, None, "model")
            return spec(None, None, None)
        D, F = core
        return spec(None, "model") if F % ms == 0 else spec(None, None)
    if name == "w_down":
        if len(core) == 3:  # MoE [E, F, D]
            E, F, D = core
            if E % ms == 0:
                return spec("model", None, None)
            if F % ms == 0:
                return spec(None, "model", None)
            return spec(None, None, None)
        F, D = core
        return spec("model", None) if F % ms == 0 else spec(None, None)
    if name == "router":
        return spec(None, None)

    # ---- rwkv ----------------------------------------------------------------
    if parent == "rwkv":
        Hr = cfg.d_model // cfg.rwkv_head_dim
        col_ok = Hr % ms == 0
        if name in ("wr", "wk", "wv", "wg"):
            return spec(None, "model") if col_ok else spec(None, None)
        if name == "wo":
            return spec("model", None) if col_ok else spec(None, None)
        if name == "cm_k":
            return spec(None, "model") if core[1] % ms == 0 else spec(None, None)
        if name == "cm_v":
            return spec("model", None) if core[0] % ms == 0 else spec(None, None)
        if name == "cm_r":
            return spec(None, "model") if core[1] % ms == 0 else spec(None, None)
        if name in ("decay_A",):
            return spec(None, None)
        if name == "decay_B":
            return spec(None, "model") if core[1] % ms == 0 else spec(None, None)
        if name == "bonus_u":
            return spec(*([None] * len(core)))

    # ---- ssm (hybrid) ----------------------------------------------------------
    if parent == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        if name == "in_proj":
            return spec(None, "model") if (2 * d_in) % ms == 0 \
                else spec(None, None)
        if name == "out_proj":
            return spec("model", None) if d_in % ms == 0 else spec(None, None)
        if name in ("bc_proj", "dt_proj"):
            # small N/H outputs; keep input dim sharded to match conv output
            return spec(None, None)
        if name in ("conv_w", "conv_b", "A_log", "D_skip"):
            return spec(*([None] * len(core)))

    # ---- norms / scalars: replicate -----------------------------------------
    return spec(*([None] * len(core)))


def param_shardings(param_shapes: Any, mesh: Mesh, cfg: ArchConfig,
                    report: bool = False, fsdp: bool = False) -> Any:
    """Tree of NamedShardings matching a (possibly abstract) param tree.

    ``fsdp=True`` additionally shards the largest still-unsharded dim of
    every >=2-d param over the batch axes (ZeRO-3 / FSDP): per-device
    state shrinks by |data|x at the cost of per-layer weight all-gathers
    (which overlap with compute on real hardware)."""
    _REPORT.clear()
    bat = batch_axes(mesh)

    def f(path, leaf):
        p = _path_str(path)
        spec = _spec_for_param(p, tuple(leaf.shape), mesh, cfg)
        # final validation: every named axis must divide
        fixed = []
        for dim, ax in zip(leaf.shape, spec + (None,) * len(leaf.shape)):
            if ax is None:
                fixed.append(None)
            else:
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = int(np.prod([mesh_size(mesh, a) for a in axes]))
                fixed.append(ax if dim % n == 0 else None)
        if fsdp and len(leaf.shape) >= 2:
            nbat = int(np.prod([mesh_size(mesh, a) for a in bat]))
            # biggest unsharded dim that divides; skip tiny tensors
            cands = sorted(
                (i for i, (d, ax) in enumerate(zip(leaf.shape, fixed))
                 if ax is None and d % nbat == 0 and d >= nbat),
                key=lambda i: -leaf.shape[i])
            if cands and int(np.prod(leaf.shape)) >= 1 << 16:
                fixed[cands[0]] = bat
        spec = P(*fixed)
        if report:
            _REPORT.append((p, tuple(leaf.shape), spec))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(f, param_shapes)


def spec_report() -> List[Tuple[str, Tuple[int, ...], P]]:
    return list(_REPORT)


def cache_shardings(cache_shapes: Any, mesh: Mesh, cfg: ArchConfig,
                    batch: int) -> Any:
    """Decode-cache shardings.

    k/v [L,B,C,KV,hd]: B on (pod,)data when divisible, C (seq) on model —
    and on the idle batch axes too when B doesn't shard (long_500k SP).
    rwkv/ssm states: head dim on model, B on data when divisible.
    """
    ms = mesh_size(mesh, "model")
    bax = batch_axes(mesh)
    bn = int(np.prod([mesh_size(mesh, a) for a in bax]))
    b_shardable = batch % bn == 0

    def f(path, leaf):
        name = _path_str(path).split("/")[-1]
        shp = leaf.shape
        if name in ("k", "v"):          # [L, B, C, KV, hd]
            C = shp[2]
            seq_axes: Tuple[str, ...] = ()
            if C % ms == 0:
                seq_axes = ("model",)
            if not b_shardable and C % (ms * bn) == 0:
                seq_axes = (*bax, "model")
            return NamedSharding(mesh, P(
                None, bax if b_shardable else None,
                seq_axes if seq_axes else None, None, None))
        if name == "pos":               # [B, C]
            C = shp[1]
            seq_axes = ()
            if C % ms == 0:
                seq_axes = ("model",)
            if not b_shardable and C % (ms * bn) == 0:
                seq_axes = (*bax, "model")
            return NamedSharding(mesh, P(
                bax if b_shardable else None,
                seq_axes if seq_axes else None))
        if name == "wkv":               # [L, B, H, N, N]
            Hn = shp[2]
            return NamedSharding(mesh, P(
                None, bax if b_shardable else None,
                "model" if Hn % ms == 0 else None, None, None))
        if name == "ssm":               # [L, B, H, P, N]
            Hn = shp[2]
            return NamedSharding(mesh, P(
                None, bax if b_shardable else None,
                "model" if Hn % ms == 0 else None, None, None))
        if name in ("tmix_prev", "cmix_prev"):  # [L, B, 1, D]
            return NamedSharding(mesh, P(
                None, bax if b_shardable else None, None,
                "model" if shp[3] % ms == 0 else None))
        if name == "conv":              # [L, B, K-1, d_in]
            return NamedSharding(mesh, P(
                None, bax if b_shardable else None, None,
                "model" if shp[3] % ms == 0 else None))
        if name in ("cross_k", "cross_v"):  # [L, B, S_src, KV, hd]
            S = shp[2]
            return NamedSharding(mesh, P(
                None, bax if b_shardable else None,
                "model" if S % ms == 0 else None, None, None))
        if name == "step":
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(*([None] * len(shp))))

    return jax.tree_util.tree_map_with_path(f, cache_shapes)
