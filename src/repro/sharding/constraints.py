"""Activation sharding constraints (Megatron-SP style), context-scoped.

XLA's sharding propagation sometimes replicates large intermediates (we
observed 4 GiB [B,S,d_ff] all-reduces in the rwkv trunk).  The fix is
standard: pin the key activations —

  residual stream   [B, S, D]  -> (batch, "model", None)   seq-sharded SP
  ffn hidden        [B, S, F]  -> (batch, None, "model")
  attention heads   [B, S, H*hd] -> (batch, None, "model")

Model code calls ``constrain(x, "residual")`` etc.; without an active mesh
(smoke tests, single device) it's a no-op.  The dry-run activates it with
``activation_mesh(mesh)``.  Every constraint validates divisibility and
silently degrades to fewer/no named axes (hymba's 25 heads etc.).
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: contextvars.ContextVar[Optional[Mesh]] = \
    contextvars.ContextVar("activation_mesh", default=None)


@contextlib.contextmanager
def activation_mesh(mesh: Optional[Mesh]):
    tok = _ACTIVE.set(mesh)
    try:
        yield
    finally:
        _ACTIVE.reset(tok)


def _bat(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape.get(a, 1)
    return dim % n == 0


def constrain(x: jax.Array, kind: str) -> jax.Array:
    mesh = _ACTIVE.get()
    if mesh is None or x.ndim < 2:
        return x
    B = x.shape[0]
    bat = _bat(mesh)
    b_ax = bat if _fits(B, mesh, bat) else \
        (("data",) if _fits(B, mesh, ("data",)) else None)
    if x.ndim == 2:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(b_ax, None)))
    S, D = x.shape[1], x.shape[-1]
    mid = [None] * (x.ndim - 3)
    if kind == "residual":
        s_ax = "model" if (S > 1 and _fits(S, mesh, "model")) else None
        spec = P(b_ax, s_ax, *mid, None)
    elif kind in ("ffn_hidden", "heads"):
        d_ax = "model" if _fits(D, mesh, "model") else None
        spec = P(b_ax, None, *mid, d_ax)
    else:
        raise ValueError(kind)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
