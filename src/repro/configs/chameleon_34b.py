"""Chameleon 34B — early-fusion VLM with VQ image tokens, qk-norm.

[arXiv:2405.09818; unverified]  48L, d_model=8192, 64H (GQA kv=8),
d_ff=22016, vocab=65536 (text + VQ image codes in one vocabulary),
head_dim=128, qk-norm for training stability.  The VQ-VAE image tokenizer
is a STUB: images arrive as token ids (early fusion means the backbone is
a plain LM).  Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig, DENSE, register

CONFIG = register(ArchConfig(
    name="chameleon-34b",
    family="vlm",
    source="arXiv:2405.09818; hf:facebook/chameleon-30b",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    head_dim=128,
    qk_norm=True,
    block_type=DENSE,
    frontend="vision",
))
