"""Granite 34B (code) — llama-arch with MQA (kv=1), 88 layers.

[arXiv:2405.04324; hf]  88L, d_model=6144, 48H (kv=1), d_ff=24576,
vocab=49152, head_dim=128.  Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig, DENSE, register

CONFIG = register(ArchConfig(
    name="granite-34b",
    family="dense",
    source="arXiv:2405.04324; hf:ibm-granite/granite-34b-code-base",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    block_type=DENSE,
    act="gelu",          # GPT-BigCode-style MLP (2 matmuls), not SwiGLU
))
