"""DBRX 132B — fine-grained MoE, 16 experts top-4.

[hf:databricks/dbrx-base; unverified]  40L, d_model=6144, 48H (GQA kv=8),
d_ff=10752 per expert, vocab=100352, head_dim=128.  MoE 16e/top-4: experts
shard 1:1 over the 16-way model axis (pure EP).  Full attention ->
long_500k skipped.  LMB additionally pages inactive expert weights.
"""
from repro.configs.base import ArchConfig, MOE, register

CONFIG = register(ArchConfig(
    name="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    block_type=MOE,
    num_experts=16,
    top_k=4,
))
