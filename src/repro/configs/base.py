"""Architecture / run configuration schema.

One ``ArchConfig`` fully describes a model; one ``ShapeConfig`` describes an
input-shape cell (the assigned shapes).  ``reduced()`` produces the
small-but-same-family config used by CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

# block types
DENSE = "dense"          # attention + MLP
MOE = "moe"              # attention + mixture-of-experts MLP
RWKV6 = "rwkv6"          # attention-free: RWKV-6 time-mix + channel-mix
HYBRID = "hybrid"        # parallel attention + SSM heads (hymba)

FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


#: the assigned LM shape set (identical for all 10 archs)
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str                    # one of FAMILIES (pool tag)
    source: str                    # provenance note

    # trunk
    num_layers: int = 12
    d_model: int = 1024
    num_heads: int = 8
    num_kv_heads: int = 8
    d_ff: int = 4096
    vocab_size: int = 32000
    head_dim: Optional[int] = None  # default d_model // num_heads

    # block selection
    block_type: str = DENSE
    encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # attention details
    sliding_window: Optional[int] = None   # SWA window (tokens), None = full
    qkv_bias: bool = False                 # qwen2
    qk_norm: bool = False                  # chameleon
    rope_theta: float = 10000.0
    max_position: int = 1 << 20

    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM / hybrid (hymba) & rwkv
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_heads: int = 0             # decay groups (mamba2-style)
    rwkv_head_dim: int = 64

    # frontend stubs
    frontend: Optional[str] = None  # "audio" | "vision" | None

    # norm / act
    norm_eps: float = 1e-5
    act: str = "swiglu"            # "swiglu" | "gelu"
    tie_embeddings: bool = False

    # training
    dtype: str = "bfloat16"        # compute/param dtype
    remat: bool = True

    # --- derived -----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        # production practice: pad vocab so the embedding shards cleanly
        return pad_to(self.vocab_size, 256)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def param_count(self) -> int:
        """Analytic parameter count (embedding + trunk), for 6ND."""
        D, F, V, L = self.d_model, self.d_ff, self.padded_vocab, self.num_layers
        H, KV, hd = self.num_heads, self.num_kv_heads, self.head_dim_
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.block_type == RWKV6:
            tmix = 5 * D * D + D * hd  # r,k,v,g,o + decay lora (approx)
            cmix = 2 * D * F
            per_layer = tmix + cmix
        else:
            attn = D * H * hd + 2 * D * KV * hd + H * hd * D
            if self.block_type == MOE:
                mlp = self.num_experts * 3 * D * F + D * self.num_experts
            elif self.act == "swiglu":
                mlp = 3 * D * F
            else:
                mlp = 2 * D * F
            per_layer = attn + mlp
            if self.block_type == HYBRID:
                d_in = self.ssm_expand * D
                per_layer += 2 * D * d_in + d_in * self.ssm_state * 2 + d_in * D
        layers = self.num_layers + self.num_encoder_layers
        if self.encoder_decoder:
            # decoder layers also carry cross-attention
            per_layer_dec = per_layer + D * H * hd + 2 * D * KV * hd + H * hd * D
            return emb + self.num_encoder_layers * per_layer + \
                self.num_layers * per_layer_dec
        return emb + layers * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts)."""
        if self.block_type != MOE:
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.num_layers
        inactive = (self.num_experts - self.top_k) * 3 * D * F
        return self.param_count() - L * inactive

    def supports_long_context(self) -> bool:
        """Sub-quadratic decode w/ bounded KV working set (DESIGN.md §5)."""
        return (self.block_type in (RWKV6, HYBRID)
                or self.sliding_window is not None)

    def shape_cells(self) -> Tuple[str, ...]:
        cells = ["train_4k", "prefill_32k", "decode_32k"]
        if self.supports_long_context():
            cells.append("long_500k")
        return tuple(cells)

    # --- smoke-test reduction ----------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        scale = dict(
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads,
                                    4 * self.num_kv_heads // self.num_heads
                                    or 1)),
            d_ff=128,
            vocab_size=128,
            head_dim=16,
            max_position=2048,
            num_encoder_layers=2 if self.encoder_decoder else 0,
            sliding_window=16 if self.sliding_window else None,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=8 if self.block_type == HYBRID else self.ssm_state,
            ssm_heads=2 if self.ssm_heads else 0,
            rwkv_head_dim=16,
            dtype="float32",
            remat=False,
        )
        return dataclasses.replace(self, **scale)


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    if not _REGISTRY:
        _load_all()
    return tuple(sorted(_REGISTRY))


def _load_all() -> None:
    # import for side effect of register(); one per line so each alias
    # carries its own noqa (ruff reports F401 at the alias's line)
    from repro.configs import chameleon_34b  # noqa: F401
    from repro.configs import command_r_plus_104b  # noqa: F401
    from repro.configs import dbrx_132b  # noqa: F401
    from repro.configs import granite_34b  # noqa: F401
    from repro.configs import h2o_danube_3_4b  # noqa: F401
    from repro.configs import hymba_1_5b  # noqa: F401
    from repro.configs import mixtral_8x22b  # noqa: F401
    from repro.configs import qwen2_1_5b  # noqa: F401
    from repro.configs import rwkv6_7b  # noqa: F401
    from repro.configs import seamless_m4t_large_v2  # noqa: F401
