"""H2O-Danube3 4B — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; unverified]  24L, d_model=3840, 32H (GQA kv=8),
d_ff=10240, vocab=32000, head_dim=120.  SWA window 4096 (mistral-style)
-> bounded KV working set -> long_500k runs.
"""
from repro.configs.base import ArchConfig, DENSE, register

CONFIG = register(ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    source="arXiv:2401.16818; hf:h2oai/h2o-danube3-4b-base",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    head_dim=120,
    sliding_window=4096,
    block_type=DENSE,
))
