"""Mixtral 8x22B — MoE 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf]  56L, d_model=6144, 48H (GQA kv=8), d_ff=16384 per
expert, vocab=32768, head_dim=128, SWA window 4096.  8 experts on a 16-way
model axis: expert FFN hidden dim is TP-sharded 16-way instead (experts
replicated across model shards in pairs is NOT used; see sharding rules).
SWA -> bounded KV -> long_500k runs.
"""
from repro.configs.base import ArchConfig, MOE, register

CONFIG = register(ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    source="arXiv:2401.04088; hf:mistralai/Mixtral-8x22B-v0.1",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    sliding_window=4096,
    block_type=MOE,
    num_experts=8,
    top_k=2,
))
