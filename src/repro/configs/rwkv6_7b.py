"""RWKV-6 "Finch" 7B — attention-free, data-dependent decay.

[arXiv:2404.05892; hf]  32L, d_model=4096, d_ff(channel-mix)=14336,
vocab=65536, head_dim=64 (64 wkv heads).  No KV cache: decode state is a
constant-size [H, hd, hd] matrix per layer — `long_500k` runs.
"""
from repro.configs.base import ArchConfig, RWKV6, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    source="arXiv:2404.05892 (Finch); hf:RWKV/rwkv-6-world-7b",
    num_layers=32,
    d_model=4096,
    num_heads=64,          # wkv heads = d_model / rwkv_head_dim
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    head_dim=64,
    rwkv_head_dim=64,
    block_type=RWKV6,
    act="swiglu",          # channel-mix uses squared-relu-ish; swiglu stand-in
))
