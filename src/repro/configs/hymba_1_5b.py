"""Hymba 1.5B — hybrid: parallel attention + mamba heads in each layer.

[arXiv:2411.13676; hf]  32L, d_model=1600, 25H (GQA kv=5), d_ff=5504,
vocab=32001 (padded 32256), head_dim=64, ssm_state=16.  Each block runs
attention and an SSM branch in parallel and fuses (mean of normed outputs).
25 heads don't divide the 16-way model axis: attention is REPLICATED over
model shards (tiny at 1.5B), FFN/SSM are TP-sharded.  Sliding window on
attention (Hymba uses SWA + few global layers; we use SWA 1024 throughout)
+ O(1) SSM state -> long_500k runs.  Meta-tokens are omitted (stub note).
"""
from repro.configs.base import ArchConfig, HYBRID, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676; hf:nvidia/Hymba-1.5B-Base",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    sliding_window=1024,
    block_type=HYBRID,
    ssm_state=16,
    ssm_expand=2,
    ssm_heads=25,
))
