"""SeamlessM4T large v2 — encoder-decoder, multimodal (audio backbone stub).

[arXiv:2308.11596; hf]  24 encoder + 24 decoder layers, d_model=1024,
16 heads (kv=16, i.e. MHA), d_ff=8192, vocab=256206 (padded to 256256).
The speech frontend (w2v-BERT conformer feature extractor) is a STUB:
`input_specs()` supplies precomputed frame embeddings [B, S, D].
Full attention -> long_500k skipped (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig, DENSE, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    source="arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large",
    num_layers=24,             # decoder
    num_encoder_layers=24,     # encoder
    encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    block_type=DENSE,
    act="gelu",
    frontend="audio",
))
