"""Qwen2 1.5B — dense GQA with QKV bias.

[arXiv:2407.10671; hf]  28L, d_model=1536, 12H (GQA kv=2), d_ff=8960,
vocab=151936, head_dim=128.  Full attention -> long_500k skipped.
"""
from repro.configs.base import ArchConfig, DENSE, register

CONFIG = register(ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    source="arXiv:2407.10671; hf:Qwen/Qwen2-1.5B",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    block_type=DENSE,
))
