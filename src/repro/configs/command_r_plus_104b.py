"""Command R+ 104B — dense GQA, no biases.

[hf:CohereForAI/c4ai-command-r-plus; unverified]  64L, d_model=12288,
96H (GQA kv=8), d_ff=33792, vocab=256000, head_dim=128.  Pure full
attention -> long_500k SKIPPED (DESIGN.md §5).  Largest assigned model:
primary beneficiary of LMB optimizer-state offload.
"""
from repro.configs.base import ArchConfig, DENSE, register

CONFIG = register(ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-plus",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    head_dim=128,
    block_type=DENSE,
))
