"""Perf hillclimb driver: hypothesis -> change -> re-lower -> record.

Runs the three chosen cells through a sequence of flag variants, measuring
the three roofline terms per variant; appends to perf_results.json.

    PYTHONPATH=src python -m benchmarks.hillclimb [--cell N]
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# variants: (cell_name, arch, shape, [(tag, hypothesis, flag_overrides)])
PLAN = [
    ("command-r-train", "command-r-plus-104b", "train_4k", [
        ("base", "paper-faithful baseline (naive chunked attention, "
         "remat=nothing, FSDP)", {}),
        ("causal-skip", "causal block skipping halves attention "
         "flops+score bytes -> compute -~40%, memory -~30%",
         {"causal_skip": True}),
        ("remat-dots", "saving matmul outputs cuts recompute reads "
         "-> memory down, compute -25%, temp up",
         {"causal_skip": True, "remat_policy": "dots"}),
        ("chunk-1024", "larger q-chunks cut loop/mask overhead bytes a "
         "few %, same flops",
         {"causal_skip": True, "attn_chunk": 1024}),
    ]),
    ("mixtral-prefill", "mixtral-8x22b", "prefill_32k", [
        ("base", "baseline: SWA arch paying full 32k attention", {}),
        ("swa-skip", "window+causal block skipping: k-range 32768 -> "
         "~4608 per q-chunk => ~7x attention flops/bytes cut",
         {"causal_skip": True}),
        ("moe-group-512", "halving dispatch group halves per-token "
         "dispatch flops (EC product), slight padding waste",
         {"causal_skip": True, "moe_group": 512}),
        ("chunk-256", "smaller q-chunk halves peak score buffer; total "
         "bytes ~const => memory term ~unchanged (test)",
         {"causal_skip": True, "attn_chunk": 256}),
        ("chunk-1024+group-512", "now collective-bound: fewer q-chunks "
         "=> fewer boundary collectives (command-r lesson) + cheap "
         "dispatch",
         {"causal_skip": True, "attn_chunk": 1024, "moe_group": 512}),
    ]),
    ("rwkv6-train", "rwkv6-7b", "train_4k", [
        ("base", "baseline: 5 separate token-shift projections", {}),
        ("fused-proj", "fold mu into fused weights: x/xs gathered once "
         "instead of 5x (fwd+bwd) => collective -30..50%",
         {"fuse_rwkv_proj": True}),
        ("chunk-128", "scan_chunk 64->128: intra-chunk flops ~S*T double,"
         " but half the chunk overhead => compute UP (expected refute "
         "for compute, test bytes)",
         {"fuse_rwkv_proj": True, "scan_chunk": 128}),
        ("remat-dots", "save matmul outputs -> fewer recompute reads",
         {"fuse_rwkv_proj": True, "remat_policy": "dots"}),
        ("chunk32-dots", "UNfused (fusion refuted: XLA already CSEs "
         "the x/xs gathers) + scan_chunk 32: intra-chunk bytes ~S*T "
         "halve + dots remat",
         {"scan_chunk": 32, "remat_policy": "dots"}),
        ("chunk16-dots", "scan_chunk 16: intra bytes halve again, but "
         "per-chunk overhead (state carries, cumsums) now ~40% of work "
         "=> expect diminishing or negative return",
         {"scan_chunk": 16, "remat_policy": "dots"}),
        ("chunk8-dots", "scan_chunk 8: state-carry outer products "
         "([N,N] per 8 tokens) start dominating; expect the knee",
         {"scan_chunk": 8, "remat_policy": "dots"}),
    ]),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", type=int, default=None)
    ap.add_argument("--out", default="perf_results.json")
    args = ap.parse_args()
    from repro.launch.dryrun import run_cell, load_table
    from repro.models.flags import Flags
    import dataclasses

    table = load_table(args.out)
    plan = PLAN if args.cell is None else [PLAN[args.cell]]
    for cell_name, arch, shape, variants in plan:
        for tag, hypothesis, overrides in variants:
            key = f"{cell_name}|{tag}"
            if key in table and table[key].get("status") == "ok":
                print(f"[{key}] cached")
                continue
            flags = dataclasses.replace(Flags(), **overrides)
            rec = run_cell(arch, shape, "single", flags)
            rec["hypothesis"] = hypothesis
            rec["tag"] = tag
            table[key] = rec
            with open(args.out, "w") as f:
                json.dump(table, f, indent=1)
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(f"[{key}] comp={r['compute_s']:.2f}s "
                      f"mem={r['memory_s']:.2f}s coll={r['collective_s']:.2f}s "
                      f"dom={r['dominant']} mfu={r['roofline_fraction']*100:.2f}%")
            else:
                print(f"[{key}] FAIL {rec.get('error')}")


if __name__ == "__main__":
    main()
