"""Benchmark harness — one scenario per paper table/figure or sweep.

Prints ``name,us_per_call,derived`` CSV rows.
Run: ``PYTHONPATH=src python -m benchmarks.run`` (or ``--only fig6``).
``--only`` takes a comma-separated list; ``--json PATH`` additionally
writes the rows as JSON (CI uploads ``BENCH_ci.json`` per PR so the perf
trajectory is tracked).

Scenarios self-register with the :func:`scenario` decorator.  A scenario
that wants CI to gate on its output declares :class:`Gate` rows inline —
``--json`` embeds them in the payload and
``tools/check_bench_regression.py`` enforces them, so adding a gated
sweep never means hand-wiring a new key into the checker.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import platform
import sys
import time
from typing import Callable, Dict, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

#: rows accumulated for --json output: (name, us_per_call, derived)
_ROWS: list = []


def _row(name: str, us: float, derived: str = "") -> None:
    _ROWS.append({"name": name, "us_per_call": round(us, 3),
                  "derived": derived})
    print(f"{name},{us:.3f},{derived}")


# --------------------------------------------------------------------------
# Scenario registry
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Gate:
    """One regression-gate bound a scenario declares on its own rows.

    ``row`` names an emitted row, ``field`` a ``key=value`` entry in its
    ``derived`` column; the checker fails CI when the value leaves
    ``[min, max]``.  Bounds should be machine-independent (modeled /
    virtual-time / count figures), since they gate every runner.
    """

    row: str
    field: str
    min: Optional[float] = None
    max: Optional[float] = None
    note: str = ""


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    fn: Callable[[], None]
    gates: Tuple[Gate, ...] = ()


#: name -> Scenario, in registration (= declaration) order
SCENARIOS: Dict[str, Scenario] = {}


def scenario(name: str, gate: Tuple[Gate, ...] = ()):
    """Register a benchmark scenario (optionally with its CI gate rows)."""
    def deco(fn: Callable[[], None]) -> Callable[[], None]:
        if name in SCENARIOS:
            raise ValueError(f"duplicate scenario {name!r}")
        SCENARIOS[name] = Scenario(name, fn, tuple(gate))
        return fn
    return deco


# ----------------------------------------------------------- Fig 2: tiers
@scenario("fig2")
def bench_fig2_latency() -> None:
    """Paper Fig 2: estimated access latencies per tier."""
    from repro.core.tiers import paper_tiers
    for kind, spec in paper_tiers().items():
        _row(f"fig2.latency.{kind.value}", spec.added_latency_s * 1e6,
             f"bw={spec.bandwidth_Bps/1e9:.0f}GBps")


# ------------------------------------------------------------- Fig 6: sim
@scenario("fig6")
def bench_fig6() -> None:
    """Paper Fig 6 (a)+(b): Ideal/DFTL/LMB-CXL/LMB-PCIe x 4 workloads."""
    from repro.sim import make_ssd_model, make_workload, simulate
    from repro.sim.ssd import make_schemes
    from repro.sim.workload import ALL_PAPER_WORKLOADS
    for gen in (4, 5):
        spec = make_ssd_model(gen)
        schemes = make_schemes(spec)
        for wl_name in ALL_PAPER_WORKLOADS:
            wl = make_workload(wl_name, n_ios=100_000)
            ideal = simulate(spec, schemes["ideal"], wl).iops
            for sname in ("ideal", "lmb-cxl", "lmb-pcie", "dftl"):
                t0 = time.perf_counter()
                r = simulate(spec, schemes[sname], wl)
                wall = (time.perf_counter() - t0) * 1e6
                _row(f"fig6.gen{gen}.{wl_name}.{sname}", wall,
                     f"kiops={r.iops/1e3:.0f};rel={r.iops/ideal:.3f};"
                     f"p99us={r.p99_lat_us:.1f}")


# --------------------------------------- shared-fabric sweep (repro.qos)
@scenario("fabric_sweep")
def bench_fabric_sweep() -> None:
    """1->16 devices on ONE expander: aggregate throughput saturates at
    link bandwidth, equal-weight devices split it fairly, and a 2:1-weight
    tenant gets ~2x an unweighted one (weighted max-min arbitration)."""
    from repro.sim import (make_ssd_model, make_workload,
                           simulate_shared_fabric)
    from repro.sim.ssd import make_schemes
    spec = make_ssd_model(5)
    scheme = make_schemes(spec)["lmb-cxl"]
    wl = make_workload("randread", n_ios=20_000)
    link = 30e9
    for n in (1, 2, 4, 8, 12, 16):
        t0 = time.perf_counter()
        r = simulate_shared_fabric(spec, scheme, wl, n,
                                   link_bandwidth_Bps=link)
        wall = (time.perf_counter() - t0) * 1e6
        goodputs = [d.iops * wl.io_bytes for d in r.per_device]
        spread = (max(goodputs) - min(goodputs)) / max(goodputs)
        _row(f"fabric_sweep.equal.n{n:02d}", wall,
             f"aggGBps={r.aggregate_goodput_Bps/1e9:.2f};"
             f"rho={r.offered_utilization:.2f};"
             f"jain={r.fairness_jain:.3f};spread={spread:.3f};"
             f"p99us={r.mean_p99_us:.1f}")
    # weighted tenants: dev0 weighs 2x, everyone saturated -> 2x goodput
    n = 16
    r = simulate_shared_fabric(spec, scheme, wl, n,
                               link_bandwidth_Bps=link,
                               weights=[2.0] + [1.0] * (n - 1))
    goodputs = [d.iops * wl.io_bytes for d in r.per_device]
    _row(f"fabric_sweep.weighted2x.n{n:02d}", 0.0,
         f"aggGBps={r.aggregate_goodput_Bps/1e9:.2f};"
         f"ratio={goodputs[0]/goodputs[1]:.2f};"
         f"p99us={r.mean_p99_us:.1f}")


# --------------------------------- multi-expander hot/cold migration sweep
@scenario("migration_sweep")
def bench_migration_sweep() -> None:
    """1 hot expander + 1 cold: every device starts on expander 0; hot-page
    migration rebalances the pool and the hot expander's p99 index latency
    recovers toward the uncontended baseline, at a reported migrated-bytes
    overhead."""
    from repro.sim import (make_ssd_model, make_workload,
                           simulate_multi_expander)
    from repro.sim.ssd import make_schemes
    spec = make_ssd_model(5)
    scheme = make_schemes(spec)["lmb-cxl"]
    wl = make_workload("randread", n_ios=20_000)
    link = 30e9
    for n in (4, 8, 12):
        t0 = time.perf_counter()
        r = simulate_multi_expander(spec, scheme, wl, n, n_expanders=2,
                                    link_bandwidth_Bps=link)
        wall = (time.perf_counter() - t0) * 1e6
        _row(f"migration_sweep.hotcold.n{n:02d}", wall,
             f"p99us_before={r.hot_p99_before_us:.1f};"
             f"p99us_after={r.hot_p99_after_us:.1f};"
             f"p99us_baseline={r.baseline_p99_us:.1f};"
             f"recovery={r.recovery_fraction:.2f};"
             f"migMiB={r.migrated_bytes/2**20:.0f};"
             f"migs={r.migration_wall_s*1e3:.1f}ms;"
             f"rho={r.utilization_before[0]:.2f}->"
             f"{max(r.utilization_after):.2f}")
    # live end-to-end: LinkedBuffer thrash saturates expander 0's link,
    # the MigrationEngine moves the hottest pages to expander 1
    import jax.numpy as jnp
    from repro.core import system_for
    from repro.core.metrics import Metrics
    from repro.qos import MigrationEngine, MigrationPolicy
    system = system_for("d0", host_id="h0", n_expanders=2, pool_gib=1,
                        page_bytes=1 << 16, metrics=Metrics())
    buf = system.buffer(name="mig", device_id="d0",
                        page_shape=(128, 128), dtype=jnp.float32,
                        onboard_pages=4, lmb_chunk_pages=8,
                        metrics=Metrics())
    pages = buf.append_pages(32)
    for p in pages:
        buf.write(p, jnp.ones((128, 128)))
    for _ in range(2):
        for p in pages:
            buf.read(p)                      # thrash: all traffic on exp 0
    eng = MigrationEngine(system, MigrationPolicy(max_pages_per_round=16))
    eng.register(buf)
    t0 = time.perf_counter()
    rep = eng.run_once()
    wall = (time.perf_counter() - t0) * 1e6
    place = buf.lmb_placement()
    _row("migration_sweep.live", wall,
         f"moved={rep.pages_moved};migMiB={rep.bytes_moved/2**20:.1f};"
         f"placement={place.get(0, 0)}:{place.get(1, 0)};"
         f"util0={rep.utilization.get(0, 0.0):.2f};"
         f"util1={rep.utilization.get(1, 0.0):.2f}")


# ------------------------------------------- batched data path (gather)
@scenario("gather_sweep", gate=(
    Gate("gather_sweep.meter_reduction.b064", "ratio", min=5,
         note="batched path must cut arbiter calls >=5x at batch 64"),
))
def bench_gather_sweep() -> None:
    """Batched vs scalar LMB data path, batch 1 -> 256: per-page gather
    latency (us_per_call column) and arbiter round-trips, onboard-hit vs
    LMB-resident working sets.  The LMB-resident cells run a steady-state
    thrash (two working-set halves, onboard holds one): every gather is
    all-miss, so scalar pays 2 arbiter calls per page (fault read +
    eviction write-back) while the batched path coalesces the whole burst
    into one charge per expander link — the >=5x metering reduction the
    batched engine exists for."""
    import jax.numpy as jnp
    from repro.core import system_for
    from repro.core.metrics import Metrics

    shape = (64, 64)                      # 16 KiB pages
    calls_at_64 = {}
    for resident in ("onboard", "lmb"):
        for batch in (1, 2, 8, 32, 64, 128, 256):
            system = system_for("d0", host_id="h0", pool_gib=2,
                                page_bytes=1 << 16, metrics=Metrics())
            onboard = batch if resident == "lmb" else 2 * batch
            buf = system.buffer(
                name=f"gs.{resident}.{batch}", device_id="d0",
                page_shape=shape, dtype=jnp.float32,
                onboard_pages=onboard, lmb_chunk_pages=64,
                metrics=Metrics())
            pages = buf.append_pages(2 * batch)
            for p in pages:
                buf.write(p, jnp.full(shape, float(p)))
            half_a, half_b = pages[:batch], pages[batch:]
            if resident == "onboard":
                buf.read_many(half_a)     # warm: every gather below hits
            iters = min(max(4, 64 // batch), 16)
            for mode in ("scalar", "batched"):
                for it in range(2):       # warmup: compile both halves
                    tgt = (half_a if resident == "onboard" or it % 2 == 0
                           else half_b)
                    (buf.read_many(tgt) if mode == "batched"
                     else [buf.read(p) for p in tgt])
                c0 = system.fm.meter_calls()
                best = float("inf")       # min-of-iters: robust to noise
                for it in range(iters):
                    # lmb case alternates halves -> permanent all-miss
                    tgt = (half_a if resident == "onboard" or it % 2 == 0
                           else half_b)
                    t0 = time.perf_counter()
                    if mode == "scalar":
                        for p in tgt:
                            buf.read(p)
                    else:
                        buf.read_many(tgt)
                    best = min(best, time.perf_counter() - t0)
                calls = system.fm.meter_calls() - c0
                if resident == "lmb" and batch == 64:
                    calls_at_64[mode] = calls
                _row(f"gather_sweep.{resident}.b{batch:03d}.{mode}",
                     best / batch * 1e6,
                     f"meter_calls={calls};pages={iters * batch}")
            system.close()
    ratio = calls_at_64["scalar"] / max(calls_at_64["batched"], 1)
    _row("gather_sweep.meter_reduction.b064", 0.0,
         f"ratio={ratio:.1f};scalar={calls_at_64['scalar']};"
         f"batched={calls_at_64['batched']}")


# ------------------------------------------- burst-aware prefetch sweep
@scenario("prefetch_sweep", gate=(
    Gate("prefetch_sweep.gate.hidden", "hidden", min=0.5,
         note="compute-rich sequential prefetch must hide >=50% of "
              "LMB read latency"),
    Gate("prefetch_sweep.gate.hidden", "speedup", min=1.5,
         note="prefetch must beat demand paging per-page"),
    Gate("prefetch_sweep.gate.hidden", "rand_ratio", max=1.25,
         note="random access must stay at parity (prefetch can't help "
              "but must not hurt)"),
))
def bench_prefetch_sweep() -> None:
    """Burst-aware prefetch + overlap scheduling vs demand-only paging:
    depth x access pattern x compute intensity.  Each cell streams a
    scan over an LMB-resident working set; between reads the device
    computes for a fixed window (virtual link time advances, and the
    overlap scheduler sizes its admission budget to the window).  The
    us_per_call column is the MODELED exposed (demand) link wait per
    page — prefetch traffic admitted behind the compute window accrues
    to the hidden counter instead.  Reported per cell: hidden fraction
    (hidden / (hidden + exposed) link wait), fault count, prefetch
    burst/page/used/wasted/deferred counters, arbiter calls.  The
    ``gate.hidden`` summary row is what CI gates on: in the compute-rich
    sequential configuration prefetch must hide >= 50% of the LMB read
    latency, beat demand-only per-page effective latency, and keep
    random access at parity (prefetch can't help there, so it must not
    hurt)."""
    import jax.numpy as jnp
    from repro.core import system_for
    from repro.core.metrics import Metrics

    shape = (64, 64)                      # 16 KiB fp32 pages
    n_scan, n_warm = 144, 48              # LMB scan set + onboard slots
    n_pages = n_scan + n_warm
    windows = {"rich": 2e-3, "poor": 5e-7}
    rng = np.random.default_rng(0)
    rand_order = [int(p) for p in rng.permutation(n_scan)]
    cells = {}
    for compute, window in windows.items():
        for access in ("stride1", "stride2", "sched", "rand"):
            if access == "rand" and compute == "poor":
                continue                  # parity only needs one regime
            order = {
                "stride1": list(range(n_scan)),
                "stride2": list(range(0, n_scan, 2)),
                "sched": rand_order,      # exact knowledge, no stride
                "rand": rand_order,       # no knowledge at all
            }[access]
            for depth in (0, 16):
                metrics = Metrics()
                system = system_for("d0", host_id="h0", pool_gib=2,
                                    page_bytes=1 << 16, metrics=metrics)
                # the system's own link model (spec bandwidth + CXL
                # added latency), not a hand-built TierSpec
                overlap = (system.overlap_scheduler(compute_window_s=window)
                           if depth else None)
                buf = system.buffer(
                    name="pf", device_id="d0", page_shape=shape,
                    dtype=jnp.float32, onboard_pages=n_warm,
                    lmb_chunk_pages=16, prefetch_depth=depth,
                    overlap=overlap, metrics=metrics)
                pages = buf.append_pages(n_pages)
                for p in pages:
                    buf.write(p, jnp.full(shape, float(p), jnp.float32))
                for p in pages[n_scan:]:
                    buf.release(p)        # scan streams through free slots
                c0 = system.fm.meter_calls()
                w0 = buf.link_wait_s
                miss0 = metrics.tier("pf", "onboard").misses
                t0 = time.perf_counter()
                for i, p in enumerate(order):
                    system.fm.advance_links(window)     # compute runs
                    buf.note_compute_window(window, observed=False)
                    if access == "sched" and depth:
                        buf.schedule_prefetch(order[i:i + depth])
                    buf.read(p)
                    buf.release(p)        # streaming consumer moves on
                wall_us = (time.perf_counter() - t0) / len(order) * 1e6
                exposed = buf.link_wait_s - w0
                hidden = buf.prefetch_hidden_s
                faults = metrics.tier("pf", "onboard").misses - miss0
                calls = system.fm.meter_calls() - c0
                pf = buf.prefetch_stats()
                hf = hidden / (hidden + exposed) if hidden + exposed else 0.0
                cell_us = exposed / len(order) * 1e6
                cells[(compute, access, depth)] = (cell_us, hf)
                _row(f"prefetch_sweep.{compute}.{access}.d{depth:02d}",
                     cell_us,
                     f"hidden={hf:.2f};faults={faults};"
                     f"pf_bursts={pf['bursts']};pf_pages={pf['pages']};"
                     f"used={pf['used']};wasted={pf['wasted']};"
                     f"deferred={pf['deferred']};meter_calls={calls};"
                     f"wall_us={wall_us:.1f}")
                system.close()
    # summary gate row (CI: tools/check_bench_regression.py)
    demand_us, _ = cells[("rich", "stride1", 0)]
    pf_us, hf = cells[("rich", "stride1", 16)]
    speedup = demand_us / max(pf_us, 1e-9)
    rand_ratio = (cells[("rich", "rand", 16)][0]
                  / max(cells[("rich", "rand", 0)][0], 1e-9))
    _row("prefetch_sweep.gate.hidden", 0.0,
         f"hidden={hf:.3f};speedup={speedup:.1f};"
         f"rand_ratio={rand_ratio:.3f}")


# --------------------------------------------------- §4.1.2 locality sweep
@scenario("locality")
def bench_locality_sweep() -> None:
    """Hot-index hit ratio -> throughput recovery (paper §4.1.2 claim)."""
    from repro.sim import make_ssd_model, make_workload, simulate
    from repro.sim.ssd import Scheme, make_schemes
    spec = make_ssd_model(5)
    base = make_schemes(spec)["lmb-pcie"]
    wl = make_workload("randread", n_ios=60_000)
    ideal = simulate(spec, make_schemes(spec)["ideal"], wl).iops
    for hit in (0.0, 0.5, 0.8, 0.9, 0.95, 0.99):
        s = Scheme(base.name, base.t_tier_s, base.write_through_index,
                   onboard_hit_ratio=hit)
        r = simulate(spec, s, wl)
        _row(f"locality.gen5.randread.hit{int(hit*100):02d}", 0.0,
             f"kiops={r.iops/1e3:.0f};rel={r.iops/ideal:.3f}")


# ------------------------------------------------------ allocator (§3.2)
@scenario("allocator")
def bench_allocator() -> None:
    """alloc/free/share microbench on the capability client API."""
    from repro.core import (DeviceSpec, HostSpec, LMBSystem, SystemSpec)
    spec = SystemSpec(expanders=1, pool_gib=8,
                      hosts=(HostSpec("h0", page_bytes=4096),),
                      devices=(DeviceSpec("d0"), DeviceSpec("d1")))
    system = LMBSystem(spec)
    N = 2000
    rng = np.random.default_rng(0)
    sizes = rng.integers(1, 1 << 20, N)
    t0 = time.perf_counter()
    handles = [system.alloc("d0", int(s)) for s in sizes]
    t_alloc = (time.perf_counter() - t0) / N * 1e6
    t0 = time.perf_counter()
    for h in handles[:500]:
        h.share("d1")
    t_share = (time.perf_counter() - t0) / 500 * 1e6
    t0 = time.perf_counter()
    for h in handles:
        h.free()
    t_free = (time.perf_counter() - t0) / N * 1e6
    _row("allocator.alloc", t_alloc, f"n={N}")
    _row("allocator.share", t_share, "n=500")
    _row("allocator.free", t_free,
         f"blocks_left={system.host().allocator.block_count}")


# --------------------------------------- offload overlap (TPU adaptation)
@scenario("offload")
def bench_offload_overlap() -> None:
    """Bytes the LMB tier can page per step hidden behind compute (tier
    model), plus measured LinkedBuffer fault cost on this host."""
    import jax.numpy as jnp
    from repro.core import system_for
    from repro.core.metrics import Metrics
    from repro.core.tiers import TierKind, hideable_page_bytes, tpu_tiers
    host_tier = tpu_tiers()[TierKind.HOST_DRAM]
    for step_ms in (5.0, 20.0, 100.0):
        b = hideable_page_bytes(step_ms / 1e3, host_tier, streams=2)
        _row(f"offload.hideable.step{int(step_ms)}ms", 0.0,
             f"MiB={b/2**20:.0f}")
    system = system_for("d0", host_id="h0", pool_gib=2,
                        page_bytes=1 << 16, metrics=Metrics())
    buf = system.buffer(name="bench", device_id="d0",
                        page_shape=(256, 256), dtype=jnp.float32,
                        onboard_pages=4, metrics=Metrics())
    pages = buf.append_pages(16)
    for p in pages:
        buf.write(p, jnp.ones((256, 256)))
    t0 = time.perf_counter()
    n = 64
    for i in range(n):
        buf.read(pages[i % 16])  # forced paging traffic
    dt = (time.perf_counter() - t0) / n * 1e6
    _row("offload.page_fault", dt, "page=256KiB")


# ---------------------------------------------------- roofline (dry-run)
@scenario("roofline")
def bench_roofline_report() -> None:
    """Summarize dryrun_results.json (run launch/dryrun.py first)."""
    path = os.environ.get("DRYRUN_JSON", "dryrun_results.json")
    if not os.path.exists(path):
        _row("roofline.missing", 0.0, f"run launch/dryrun.py ({path})")
        return
    with open(path) as f:
        table = json.load(f)
    for key, rec in sorted(table.items()):
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        _row(f"roofline.{key}", r["compute_s"] * 1e6,
             f"dom={r['dominant']};mem_s={r['memory_s']:.3f};"
             f"coll_s={r['collective_s']:.3f};"
             f"mfu@roof={r['roofline_fraction']*100:.1f}%")


# ------------------------------------------------------------ serve perf
@scenario("serve")
def bench_serving() -> None:
    """Engine throughput on the reduced model (CPU demo scale)."""
    import jax
    from repro.configs.base import get_config
    from repro.core import system_for
    from repro.models import build_model
    from repro.models.flags import Flags
    from repro.serve import EngineConfig, ServeEngine, SubmitSpec
    cfg = get_config("qwen2-1.5b").reduced()
    model = build_model(cfg, Flags(remat=False))
    params = model.init(jax.random.key(0))
    system = system_for("tpu0", host_id="h0", pool_gib=2, page_bytes=4096)
    eng = ServeEngine(model, params, system, EngineConfig(
        decode_slots=4, max_seq_len=64, page_tokens=8, onboard_pages=8,
        prefill_bucket=16))
    rng = np.random.default_rng(0)
    n_req, n_tok = 8, 8
    for _ in range(n_req):
        eng.submit(SubmitSpec(
            prompt=rng.integers(0, cfg.vocab_size, 12),
            max_new_tokens=n_tok))
    t0 = time.perf_counter()
    eng.run(500)
    wall = time.perf_counter() - t0
    st = eng.stats()
    _row("serve.engine", wall / (n_req * n_tok) * 1e6,
         f"tok_per_s={n_req*n_tok/wall:.1f};"
         f"kv_hit={st['kv']['hit_ratio']:.2f}")


# ---------------------------------------------- trace-driven serve sweep
@scenario("serve_sweep", gate=(
    Gate("serve_sweep.gate.pipeline", "tokens_equal", min=1,
         note="pipelined step must emit byte-identical tokens to the "
              "phased reference order"),
    Gate("serve_sweep.gate.pipeline", "wait_ratio", min=1.2,
         note="pipelining must strictly reduce modeled exposed link "
              "wait vs the phased order"),
    Gate("serve_sweep.tenant.steady", "ttft_p99_ms", max=40,
         note="virtual-time TTFT p99 bound, Poisson tenant"),
    Gate("serve_sweep.tenant.steady", "itl_p99_ms", max=6,
         note="virtual-time inter-token p99 bound, Poisson tenant"),
    Gate("serve_sweep.tenant.bursty", "ttft_p99_ms", max=80,
         note="virtual-time TTFT p99 bound, bursty tenant (queueing "
              "under bursts is expected, but bounded)"),
    Gate("serve_sweep.tenant.bursty", "itl_p99_ms", max=6,
         note="virtual-time inter-token p99 bound, bursty tenant"),
))
def bench_serve_sweep() -> None:
    """Trace-driven multi-tenant load sweep on the serve engine: a
    Poisson tenant and a bursty tenant share one engine whose KV pages
    against the LMB pool.  The engine runs on a VIRTUAL clock with a
    pinned round duration, so every latency row (TTFT / inter-token
    p50/p99, straight from ``ServeEngine.stats()['latency']``) is a
    modeled, machine-independent figure CI can gate on.  A second,
    phased-order twin replays the identical trace to check the
    pipelined step's contract: byte-identical tokens, strictly less
    modeled exposed link wait.  ``SERVE_SWEEP_SCALE=N`` multiplies
    per-tenant request counts for offline full-scale runs."""
    import jax
    from repro.configs.base import get_config
    from repro.core import system_for
    from repro.core.metrics import Metrics
    from repro.models import build_model
    from repro.models.flags import Flags
    from repro.serve import (EngineConfig, ServeEngine, TenantLoad,
                             VirtualClock, build_trace, run_sweep)

    cfg = get_config("qwen2-1.5b").reduced()
    model = build_model(cfg, Flags(remat=False))
    params = model.init(jax.random.key(0))
    round_s = 2e-3

    def make_engine(clock, *, pipeline):
        # per-engine Metrics: the A/B twin must not share histograms
        system = system_for("tpu0", host_id="h0", pool_gib=1,
                            page_bytes=4096, metrics=Metrics())
        return ServeEngine(model, params, system, EngineConfig(
            decode_slots=4, max_seq_len=64, page_tokens=8,
            onboard_pages=6, prefill_bucket=16, pipeline=pipeline,
            round_time_s=round_s), clock=clock)

    scale = int(os.environ.get("SERVE_SWEEP_SCALE", "1"))
    tenants = [
        TenantLoad("steady", rate_rps=150.0, n_requests=12 * scale,
                   prompt_tokens=(12, 28), max_new_tokens=(4, 8)),
        TenantLoad("bursty", rate_rps=150.0, n_requests=12 * scale,
                   process="bursty", burst_size=6,
                   prompt_tokens=(12, 28), max_new_tokens=(4, 8)),
    ]
    trace = build_trace(tenants, vocab_size=cfg.vocab_size, seed=0)
    clock = VirtualClock()
    eng = make_engine(clock, pipeline=True)
    t0 = time.perf_counter()
    report = run_sweep(eng, trace, clock)
    wall_us = (time.perf_counter() - t0) * 1e6
    tot = report.totals
    for name, row in sorted(report.per_tenant.items()):
        _row(f"serve_sweep.tenant.{name}", 0.0,
             f"done={row['done']};shed={row['shed']};"
             f"ttft_p50_ms={row['ttft_p50_s'] * 1e3:.3f};"
             f"ttft_p99_ms={row['ttft_p99_s'] * 1e3:.3f};"
             f"itl_p50_ms={row['itl_p50_s'] * 1e3:.3f};"
             f"itl_p99_ms={row['itl_p99_s'] * 1e3:.3f}")
    _row("serve_sweep.totals", wall_us / max(tot["rounds"], 1),
         f"rounds={tot['rounds']};virtual_s={tot['virtual_s']:.3f};"
         f"done={tot['done']};shed={tot['shed']};"
         f"peak_concurrent={tot['peak_concurrent']};"
         f"peak_lmb_pages={tot['peak_lmb_resident_pages']};"
         f"exposed_us={tot['exposed_link_wait_s'] * 1e6:.2f};"
         f"hidden_us={tot['hidden_link_wait_s'] * 1e6:.2f};"
         f"kv_hit={tot['kv_hit_ratio']:.3f};"
         f"meter_calls={tot['meter_calls']}")
    # phased-order twin on the IDENTICAL trace: the pipelined step's
    # contract is byte-identical tokens with strictly less exposed wait
    clock2 = VirtualClock()
    eng2 = make_engine(clock2, pipeline=False)
    run_sweep(eng2, trace, clock2)
    toks = {r.req_id: tuple(r.out_tokens) for r in eng.requests.values()}
    toks2 = {r.req_id: tuple(r.out_tokens) for r in eng2.requests.values()}
    exposed_pipe = eng.kv.buf.link_wait_s
    exposed_phased = eng2.kv.buf.link_wait_s
    _row("serve_sweep.gate.pipeline", 0.0,
         f"tokens_equal={int(toks == toks2)};"
         f"wait_ratio={exposed_phased / max(exposed_pipe, 1e-12):.2f};"
         f"exposed_pipelined_us={exposed_pipe * 1e6:.2f};"
         f"exposed_phased_us={exposed_phased * 1e6:.2f}")


# ------------------------------------------ paged-decode kernel sweep
@scenario("decode_sweep", gate=(
    Gate("decode_sweep.gate.identity", "tokens_equal", min=1,
         note="paged decode must emit byte-identical token streams to "
              "the dense slot-cache reference engine"),
    Gate("decode_sweep.gate.identity", "paged_rounds", min=1,
         note="the paged pool-direct rounds actually served the decode "
              "(not a silent fallback to the dense path)"),
    Gate("decode_sweep.gate.identity", "kernel_traced", min=1,
         note="the paged-attention decode dispatcher was staged into "
              "the compiled step (call-path proof)"),
    Gate("decode_sweep.gate.traffic", "bytes_reconciled", min=1,
         note="per-class link.xfer span bytes reconcile exactly with "
              "fm.op_bytes() — the DecodeView's page traffic rides the "
              "same metered accounting as every other access"),
    Gate("decode_sweep.cell.b4.s24", "tok_per_s", min=1000,
         note="modeled decode throughput (virtual-time) at batch 4"),
    Gate("decode_sweep.cell.b1.s8", "tok_per_s", min=300,
         note="modeled decode throughput (virtual-time) at batch 1"),
))
def bench_decode_sweep() -> None:
    """Batch x sequence-length sweep of the paged decode path: every
    round is ONE batched paged-attention step straight against the
    paged KV pool (DecodeView), timed on a VIRTUAL clock with a pinned
    round duration so tokens/s is a modeled, machine-independent
    figure.  Two gate rows ride along: an identity cell re-serving the
    largest configuration with ``paged_decode=False`` (byte-identical
    tokens, paged rounds > 0, kernel dispatcher on the call path) and a
    traffic cell reconciling the paged rounds' ``link.xfer`` spans
    against ``fm.op_bytes()`` per accounting class."""
    import jax
    from repro.configs.base import get_config
    from repro.core import system_for
    from repro.core.metrics import Metrics
    from repro.kernels import ops as kops
    from repro.models import build_model
    from repro.models.flags import Flags
    from repro.serve import (EngineConfig, ServeEngine, SubmitSpec,
                             VirtualClock)

    cfg = get_config("qwen2-1.5b").reduced()
    model = build_model(cfg, Flags(remat=False))
    params = model.init(jax.random.key(0))
    round_s = 2e-3
    max_new = 8

    def serve(batch, prompt_len, *, paged, trace=False):
        clock = VirtualClock()
        system = system_for("tpu0", host_id="h0", pool_gib=1,
                            page_bytes=4096, metrics=Metrics())
        eng = ServeEngine(model, params, system, EngineConfig(
            decode_slots=batch, max_seq_len=64, page_tokens=8,
            onboard_pages=6, prefill_bucket=16, round_time_s=round_s,
            paged_decode=paged, trace=trace), clock=clock)
        rng = np.random.default_rng(0)
        rids = [eng.submit(SubmitSpec(
            prompt=rng.integers(0, cfg.vocab_size, prompt_len),
            max_new_tokens=max_new)) for _ in range(batch * 2)]
        it = 0
        while (eng.waiting or eng.active) and it < 500:
            eng.step()
            clock.advance(round_s)
            it += 1
        toks = {r: tuple(eng.requests[r].out_tokens) for r in rids}
        return eng, toks, clock.now

    for batch in (1, 4):
        for plen in (8, 24):
            t0 = time.perf_counter()
            eng, toks, virtual_s = serve(batch, plen, paged=True)
            wall_us = (time.perf_counter() - t0) * 1e6
            n_tok = sum(len(t) for t in toks.values())
            st = eng.stats()
            _row(f"decode_sweep.cell.b{batch}.s{plen}",
                 wall_us / max(n_tok, 1),
                 f"tok_per_s={n_tok / virtual_s:.1f};"
                 f"rounds={st['paged_rounds']};"
                 f"kv_hit={st['kv']['hit_ratio']:.3f};"
                 f"meter_calls={st['fabric']['meter_calls']}")

    # identity + call-path gate: the largest cell, paged vs dense twin
    from repro.obs.trace import GLOBAL_TRACER
    before = kops.paged_attention_decode_traces()
    # under --trace the engine reuses the harness's enabled global
    # tracer, so remember where this run's spans start in the ring
    pre = len(GLOBAL_TRACER.spans()) if GLOBAL_TRACER.enabled else 0
    eng_p, toks_p, _ = serve(4, 24, paged=True, trace=True)
    traced = kops.paged_attention_decode_traces() - before
    # snapshot the paged run's span window BEFORE the dense twin runs
    # (it records into the same shared ring under --trace)
    spans = eng_p.trace.spans()
    if eng_p.trace is GLOBAL_TRACER:
        spans = spans[pre:]
    eng_d, toks_d, _ = serve(4, 24, paged=False)
    _row("decode_sweep.gate.identity", 0.0,
         f"tokens_equal={int(toks_p == toks_d)};"
         f"paged_rounds={eng_p.paged_rounds};"
         f"kernel_traced={traced};"
         f"dense_paged_rounds={eng_d.paged_rounds}")
    # traffic gate: the traced paged run's per-class link bytes
    by_op: Dict[str, int] = {}
    for sp in spans:
        if sp.name == "link.xfer":
            by_op[sp.op] = by_op.get(sp.op, 0) + sp.nbytes
    fm_bytes = eng_p.kv.buf.host.fm.op_bytes()
    reconciled = int(bool(by_op) and by_op == fm_bytes)
    _row("decode_sweep.gate.traffic", 0.0,
         f"bytes_reconciled={reconciled};"
         f"link_bytes={sum(by_op.values())};"
         f"classes={len(by_op)}")


# ------------------------------------------- chaos (repro.core.faults)
@scenario("chaos_sweep", gate=(
    Gate("chaos_sweep.gate.storm", "availability", min=0.99,
         note="with link-level retry enabled, a scripted transient-fault "
              "storm (CRC-error window + brownout + link flap) costs "
              "modeled time only: >=99% of requests still complete"),
    Gate("chaos_sweep.gate.storm", "noretry_lost", min=1,
         note="the identical storm with retries DISABLED escalates to "
              "failover and measurably loses work — proving the retry "
              "path, not storm mildness, earned the availability gate"),
    Gate("chaos_sweep.gate.storm", "retry_reconciled", min=1,
         note="injector retry_bytes reconcile exactly with the FM's "
              "op_bytes()['retry'] accounting class"),
    Gate("chaos_sweep.gate.repair", "recovery", min=0.9,
         note="after fail-stop + repair/re-admission, >=90% of requests "
              "arriving post-repair complete (degraded mode exits)"),
    Gate("chaos_sweep.gate.identity", "identical", min=1,
         note="a zero-fault FaultPlan run is byte-identical (tokens and "
              "per-class fm.op_bytes()) to a run with no injector"),
))
def bench_chaos_sweep() -> None:
    """Chaos drill on the serve engine: the same trace-driven sweep as
    ``serve_sweep``, but with a :class:`~repro.core.faults.FaultInjector`
    scripting fault storms against the (single) expander link.

    Four runs, three gates:

      1. **storm + retries** — transient CRC-error window, a brownout,
         and a link flap land mid-trace; bounded backoff + retransmission
         turns them into modeled time and availability stays >= 0.99.
      2. **storm, retries disabled** — the first CRC error escalates to
         the fail-stop path; the pool dies, KV paging degrades to
         onboard-only, and capacity cancellations lose real work.
      3. **fail-stop + repair** — the expander is killed, then readmitted
         blank; requests arriving after the repair complete (>= 90%),
         pinning the degraded-mode EXIT path.
      4. **zero-fault identity** — an attached-but-empty plan must be
         byte-identical to no injector at all (tokens, op_bytes).

    Everything runs on the virtual clock, so every figure is modeled and
    machine-independent."""
    import jax
    from repro.configs.base import get_config
    from repro.core import FaultEvent, FaultPlan, RetryPolicy, system_for
    from repro.core.metrics import Metrics
    from repro.models import build_model
    from repro.models.flags import Flags
    from repro.serve import (EngineConfig, ServeEngine, TenantLoad,
                             VirtualClock, build_trace, run_sweep)

    cfg = get_config("qwen2-1.5b").reduced()
    model = build_model(cfg, Flags(remat=False))
    params = model.init(jax.random.key(0))
    round_s = 2e-3

    def make_engine(clock, *, plan=None, retry=None):
        system = system_for("tpu0", host_id="h0", pool_gib=1,
                            page_bytes=4096, metrics=Metrics())
        injector = (system.attach_fault_injector(plan, retry=retry, seed=7)
                    if plan is not None else None)
        eng = ServeEngine(model, params, system, EngineConfig(
            decode_slots=4, max_seq_len=64, page_tokens=8,
            onboard_pages=6, prefill_bucket=16, pipeline=True,
            round_time_s=round_s), clock=clock)
        return eng, system, injector

    scale = int(os.environ.get("SERVE_SWEEP_SCALE", "1"))
    tenants = [
        TenantLoad("steady", rate_rps=150.0, n_requests=12 * scale,
                   prompt_tokens=(12, 28), max_new_tokens=(4, 8),
                   deadline_s=5.0),
        TenantLoad("bursty", rate_rps=150.0, n_requests=12 * scale,
                   process="bursty", burst_size=6,
                   prompt_tokens=(12, 28), max_new_tokens=(4, 8),
                   deadline_s=5.0),
    ]
    trace = build_trace(tenants, vocab_size=cfg.vocab_size, seed=0)
    t_end = max(s.arrival_time_s for s in trace)

    # ---- run 1+2: the storm, with and without link-level retry --------
    def storm_plan():
        return FaultPlan((
            FaultEvent(t_s=0.1 * t_end, kind="transient",
                       duration_s=0.8 * t_end, error_rate=0.35,
                       crc_retry_cost_s=2e-6),
            FaultEvent(t_s=0.3 * t_end, kind="brownout",
                       duration_s=0.3 * t_end, latency_factor=4.0),
            FaultEvent(t_s=0.6 * t_end, kind="link_flap",
                       retrain_s=2 * round_s),
        ))

    clock = VirtualClock()
    eng, system, inj = make_engine(
        clock, plan=storm_plan(),
        retry=RetryPolicy(link_retry_budget=100_000))
    t0 = time.perf_counter()
    report = run_sweep(eng, trace, clock, drain_idle_gaps=True)
    wall_us = (time.perf_counter() - t0) * 1e6
    tot = report.totals
    ctr = inj.counters()
    availability = tot["done"] / max(tot["requests"], 1)
    reconciled = int(ctr["retry_bytes"]
                     == system.fm.op_bytes().get("retry", 0))
    _row("chaos_sweep.storm.retry", wall_us / max(tot["rounds"], 1),
         f"done={tot['done']};cancelled={tot['cancelled']};"
         f"shed={tot['shed']};errors={ctr['transient_errors']};"
         f"retries={ctr['retries']};"
         f"retry_delay_us={ctr['retry_delay_s'] * 1e6:.2f};"
         f"brownout_delay_us={ctr['brownout_delay_s'] * 1e6:.2f};"
         f"flap_delay_us={ctr['flap_delay_s'] * 1e6:.2f};"
         f"escalations={ctr['escalations']}")

    clock2 = VirtualClock()
    eng2, system2, inj2 = make_engine(clock2, plan=storm_plan(),
                                      retry=RetryPolicy(max_retries=0))
    report2 = run_sweep(eng2, trace, clock2, drain_idle_gaps=True)
    tot2 = report2.totals
    lost = tot2["requests"] - tot2["done"]
    _row("chaos_sweep.storm.noretry", 0.0,
         f"done={tot2['done']};cancelled={tot2['cancelled']};"
         f"lost={lost};"
         f"escalations={inj2.counters()['escalations']};"
         f"healthy={int(system2.fm.healthy)}")
    _row("chaos_sweep.gate.storm", 0.0,
         f"availability={availability:.4f};noretry_lost={lost};"
         f"retry_reconciled={reconciled}")

    # ---- run 3: fail-stop then repair/re-admission --------------------
    clock3 = VirtualClock()
    # the plan targets the system's own expander id, so build the system
    # first, then the plan, then attach
    system3 = system_for("tpu0", host_id="h0", pool_gib=1,
                         page_bytes=4096, metrics=Metrics())
    eid = sorted(system3.fm.expander_ids)[0]
    t_fail, t_repair = 0.25 * t_end, 0.55 * t_end
    plan3 = FaultPlan((
        FaultEvent(t_s=t_fail, kind="fail_stop", expander_id=eid),
        FaultEvent(t_s=t_repair, kind="repair", expander_id=eid),
    ))
    inj3 = system3.attach_fault_injector(plan3, seed=7)
    eng3 = ServeEngine(model, params, system3, EngineConfig(
        decode_slots=4, max_seq_len=64, page_tokens=8,
        onboard_pages=6, prefill_bucket=16, pipeline=True,
        round_time_s=round_s), clock=clock3)
    report3 = run_sweep(eng3, trace, clock3, drain_idle_gaps=True)
    after = [r for r in eng3.requests.values()
             if r.submitted_at >= t_repair]
    done_after = sum(1 for r in after if r.state == "done")
    recovery = done_after / max(len(after), 1)
    tot3 = report3.totals
    _row("chaos_sweep.repair", 0.0,
         f"done={tot3['done']};cancelled={tot3['cancelled']};"
         f"arrived_after_repair={len(after)};done_after={done_after};"
         f"healthy={int(system3.fm.healthy)}")
    _row("chaos_sweep.gate.repair", 0.0,
         f"recovery={recovery:.4f};repaired={int(system3.fm.healthy)}")

    # ---- run 4: zero-fault plan is byte-identical to no injector ------
    clock4 = VirtualClock()
    eng4, system4, _ = make_engine(clock4, plan=FaultPlan())
    run_sweep(eng4, trace, clock4, drain_idle_gaps=True)
    clock5 = VirtualClock()
    eng5, system5, _ = make_engine(clock5)
    run_sweep(eng5, trace, clock5, drain_idle_gaps=True)
    toks4 = {r.req_id: tuple(r.out_tokens) for r in eng4.requests.values()}
    toks5 = {r.req_id: tuple(r.out_tokens) for r in eng5.requests.values()}
    ob4, ob5 = dict(system4.fm.op_bytes()), dict(system5.fm.op_bytes())
    identical = int(toks4 == toks5 and ob4 == ob5)
    _row("chaos_sweep.gate.identity", 0.0,
         f"identical={identical};tokens_equal={int(toks4 == toks5)};"
         f"op_bytes_equal={int(ob4 == ob5)}")


# ------------------------------------------------ rack-scale (repro.rack)
@scenario("rack_sweep", gate=(
    Gate("rack_sweep.hop.monotone", "monotone", min=1,
         note="p99 must grow (weakly) with fabric path latency: the "
              "topology hop cost feeds the index path end to end"),
    Gate("rack_sweep.placement.gate", "skew_over_pool", min=1.15,
         note="pool-aware placement (near-first, capacity-balanced via "
              "the real FM policy) beats piling every device on one "
              "cross-leaf link by >=15% p99"),
    Gate("rack_sweep.failover.gate", "recovery", min=0.9,
         note="after a domain-wide failure, plan_rebalance(alive=...) "
              "recovers >=90% of the pile-up p99 gap vs the balanced-"
              "survivor baseline"),
    Gate("rack_sweep.failover.gate", "lost", max=0,
         note="domain failover re-grants every block (survivors have "
              "room); losing any means the single-pass re-grant broke"),
    Gate("rack_sweep.failover.gate", "regranted", min=8,
         note="all 8 blocks homed on the dead pd0 domain re-granted"),
    Gate("rack_sweep.scale.d16", "requests", min=1_048_576,
         note="rack-scale reach: 256 devices x 4096 IOs in ONE "
              "vectorized call"),
    Gate("rack_sweep.scale.d16", "wall_s", max=60,
         note="CI wall-clock budget for the 1M-request run (locally "
              "~0.04 s; the bound only catches a vectorization "
              "regression back to per-IO Python)"),
    Gate("rack_sweep.speedup.gate", "speedup", min=20,
         note="vectorized core >=20x the scalar reference engine on the "
              "same 256-lane scenario (a wall-clock RATIO, so it is "
              "machine-independent to first order; measured 23-27x)"),
    Gate("rack_sweep.speedup.gate", "results_agree", min=1,
         note="scalar and vectorized engines produce identical per-lane "
              "p99s (rtol 1e-6) on the speedup scenario"),
))
def bench_rack_sweep() -> None:
    """Rack-scale CXL pool: hop costs, placement, correlated failover,
    and the vectorized event core's scale/speedup envelope."""
    from repro.rack import scenarios as rack

    hops = rack.hop_cost_sweep()
    for r in hops:
        _row(f"rack_sweep.hop.{r['case']}", r["p99_us"],
             f"hops={r['hops']};path_ns={r['path_ns']:.0f};"
             f"kiops={r['kiops']:.0f};mean_us={r['mean_us']:.2f}")
    p99s = [r["p99_us"] for r in hops]
    _row("rack_sweep.hop.monotone", 0.0,
         f"monotone={int(all(a <= b + 1e-9 for a, b in zip(p99s, p99s[1:])))}"
         f";span_us={p99s[-1] - p99s[0]:.2f}")

    face = rack.placement_face_off()
    for name in ("skewed", "spread", "pool_aware"):
        c = face[name]
        _row(f"rack_sweep.placement.{name}", c["p99_us"],
             f"kiops={c['kiops_total']:.0f};rho_max={c['rho_max']:.2f}")
    _row("rack_sweep.placement.gate", 0.0,
         f"skew_over_pool={face['p99_ratio_skew_over_pool']:.3f};"
         f"near_fraction={face['near_fraction_pool_aware']:.2f}")

    fo = rack.failover_recovery()
    _row("rack_sweep.failover.gate", fo["pileup_p99_us"],
         f"recovery={fo['recovery']:.3f};"
         f"baseline_us={fo['baseline_p99_us']:.2f};"
         f"rebalanced_us={fo['rebalanced_p99_us']:.2f};"
         f"regranted={fo['regranted']};lost={fo['lost']};"
         f"moved={fo['moved_devices']}")

    ss = rack.scale_sweep()
    for per, d in sorted(ss["density"].items()):
        _row(f"rack_sweep.scale.d{per}", d["p99_us"],
             f"devices={d['devices']};requests={d['requests']};"
             f"wall_s={d['wall_s']:.3f};rho_max={d['rho_max']:.2f};"
             f"agg_GBps={d['agg_GBps']:.0f}")

    vs = rack.vector_speedup()
    _row("rack_sweep.speedup.gate", vs["vector_s"] * 1e6,
         f"speedup={vs['speedup']:.1f};scalar_s={vs['scalar_s']:.3f};"
         f"vector_s={vs['vector_s']:.3f};"
         f"results_agree={int(vs['results_agree'])};"
         f"requests={vs['requests']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma-separated subset of {sorted(SCENARIOS)}")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (CI perf artifact)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record spans across all benches and write a "
                    "Chrome-trace JSON (open in ui.perfetto.dev; "
                    "inspect with tools/lmbtrace.py)")
    args, _ = ap.parse_known_args()
    names = (args.only.split(",") if args.only else list(SCENARIOS))
    unknown = [n for n in names if n not in SCENARIOS]
    if unknown:
        ap.error(f"unknown scenario(s) {unknown}; choose from "
                 f"{sorted(SCENARIOS)}")
    if args.trace:
        from repro.obs import enable_tracing
        enable_tracing()
    print("name,us_per_call,derived")
    for n in names:
        SCENARIOS[n].fn()
    if args.trace:
        from repro.obs import GLOBAL_TRACER
        from repro.obs.export import write_chrome_trace
        write_chrome_trace(GLOBAL_TRACER.spans(), args.trace,
                           extra={"benches": names,
                                  "dropped": GLOBAL_TRACER.dropped})
        print(f"# wrote {GLOBAL_TRACER.snapshot()['count']} spans to "
              f"{args.trace}", file=sys.stderr)
    if args.json:
        payload = {
            "benches": names,
            "python": platform.python_version(),
            "platform": platform.platform(),
            "rows": _ROWS,
            # every gate the scenarios that RAN declared — the checker
            # enforces these generically (no hand-wired keys)
            "gates": [dataclasses.asdict(g) for n in names
                      for g in SCENARIOS[n].gates],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {len(_ROWS)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
