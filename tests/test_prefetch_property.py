"""Property-based tests (hypothesis) for the burst-aware prefetcher.

Invariants under arbitrary schedules, access streams, and buffer
shapes (the deterministic versions live in tests/test_prefetch.py;
these drive the same contracts through randomized interleavings):

  * prefetch NEVER evicts a resident page and never exceeds the
    free-slot budget — it is strictly opportunistic;
  * the scheduled backlog stays bounded (deque cap) whatever is thrown
    at it, and scheduled pages always outrank stride guesses;
  * a prefetched-then-read page is byte-identical to a demand fault of
    the same page, including compressed and multi-expander placements.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import system_for
from repro.core.metrics import Metrics
from repro.core.policy import Prefetcher

PAGE = (4, 4)


def fresh_buffer(n_pages, onboard, chunk, depth, compress=False,
                 n_expanders=1):
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        n_expanders=n_expanders, metrics=Metrics())
    buf = system.buffer(name="pp", device_id="d0", page_shape=PAGE,
                        dtype=jnp.float32, onboard_pages=onboard,
                        lmb_chunk_pages=chunk, prefetch_depth=depth,
                        prefetch_min_burst=1, compress_lmb=compress,
                        metrics=Metrics())
    buf.append_pages(n_pages)
    return system, buf


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.data())
def test_prefetch_never_evicts_and_respects_free_slots(data):
    """Whatever gets scheduled, prefetch only ever fills FREE onboard
    slots: the pre-call resident set survives every round, issued pages
    never exceed the pre-call free-slot count, and the structural
    invariants hold after every operation."""
    n_pages = data.draw(st.integers(6, 24))
    onboard = data.draw(st.integers(2, 8))
    depth = data.draw(st.integers(1, 8))
    system, buf = fresh_buffer(n_pages, onboard,
                               chunk=data.draw(st.integers(2, 8)),
                               depth=depth)
    ops = data.draw(st.lists(
        st.tuples(
            st.sampled_from(["write", "read", "release", "schedule"]),
            st.integers(0, n_pages - 1)),
        min_size=1, max_size=40))
    released = set()
    for op, p in ops:
        if op == "write":
            buf.write(p, np.full(PAGE, float(p), np.float32))
            released.discard(p)
        elif op == "read":
            buf.read(p)
        elif op == "release":
            if p not in released and buf._pages[p].refcount == 1:
                buf.release(p)
                released.add(p)
        else:
            resident = {q for q in range(n_pages)
                        if buf._pages[q].tier == "onboard"}
            free_before = len(buf._onboard_free)
            issued_before = buf.prefetch_pages_total
            buf.schedule_prefetch(
                list(range(p, min(p + depth * 2, n_pages))))
            issued = buf.prefetch_pages_total - issued_before
            assert issued <= free_before, "prefetch exceeded free slots"
            still = {q for q in resident
                     if buf._pages[q].tier == "onboard"}
            assert still == resident, "prefetch evicted a resident page"
        buf.check_invariants()
        assert buf.prefetcher.pending() <= buf.prefetcher.backlog


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 200), min_size=0, max_size=40),
       st.integers(1, 12),
       st.integers(0, 30),
       st.integers(1, 16))
def test_scheduled_pages_outrank_stride_guesses(scheduled, depth, start,
                                                run_pages):
    """suggest_runs always emits every scheduled-source run before any
    stride-source run, never more than `depth` pages total, and stride
    guesses only fill the budget scheduled knowledge left over."""
    pf = Prefetcher(depth=depth)
    for p in (start, start + 2, start + 4):      # confident stride 2
        pf.observe(p)
    pf.schedule(scheduled)
    runs = pf.suggest_runs(500, run_pages=run_pages)
    sources = [r.source for r in runs]
    if "stride" in sources and "scheduled" in sources:
        assert sources.index("stride") > max(
            i for i, s in enumerate(sources) if s == "scheduled")
    pages = [p for r in runs for p in r.pages]
    assert len(pages) <= depth
    n_sched = sum(r.npages for r in runs if r.source == "scheduled")
    if n_sched >= depth:
        assert "stride" not in sources
    for r in runs:                               # chunk-aligned extents
        assert len({p // run_pages for p in r.pages}) == 1
    assert pf.pending() <= pf.backlog


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.data())
def test_prefetched_read_byte_identical_vs_demand(data):
    """Twin buffers, identical writes: one prefetches a drawn subset
    before reading, the other demand-faults everything.  Every page
    must read back byte-identical — across compression and
    multi-expander placement."""
    compress = data.draw(st.booleans())
    n_expanders = data.draw(st.sampled_from([1, 2]))
    n_pages = data.draw(st.integers(8, 20))
    onboard = data.draw(st.integers(3, 6))
    chunk = data.draw(st.integers(2, 6))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
    values = {p: rng.normal(size=PAGE).astype(np.float32)
              for p in range(n_pages)}
    bufs = []
    for _ in range(2):
        _, buf = fresh_buffer(n_pages, onboard, chunk, depth=8,
                              compress=compress, n_expanders=n_expanders)
        for p in range(n_pages):
            buf.write(p, values[p])
        bufs.append(buf)
    demand, pre = bufs
    # free a few slots on both twins so prefetch has room
    onboard_now = [p for p in range(n_pages)
                   if pre._pages[p].tier == "onboard"]
    n_free = data.draw(st.integers(0, len(onboard_now)))
    for p in onboard_now[:n_free]:
        pre.release(p)
        demand.release(p)
        values.pop(p)
    # compare the pages that are LMB-resident on BOTH twins: the ones a
    # prefetch-vs-demand-fault divergence could corrupt.  (Originally-
    # onboard dirty pages are excluded: whether they spill at all
    # legitimately differs once prefetch perturbs eviction order.)
    cold = [p for p in values if pre._pages[p].tier == "lmb"]
    subset = data.draw(st.permutations(cold)) if cold else []
    pre.schedule_prefetch(list(subset))
    order = data.draw(st.permutations(cold)) if cold else []
    for p in order:
        got = np.asarray(pre.read(p))
        want = np.asarray(demand.read(p))
        assert np.array_equal(got, want), p
    pre.check_invariants()
    demand.check_invariants()
