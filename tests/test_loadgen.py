"""Trace-driven serve-sweep harness: arrival processes, typed traces,
virtual-time replay, and the pipelined step's byte-identity contract."""

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import system_for
from repro.core.metrics import Metrics
from repro.models import build_model
from repro.models.flags import Flags
from repro.serve import (EngineConfig, ServeEngine, SubmitSpec, TenantLoad,
                         VirtualClock, build_trace, run_sweep)
from repro.sim.workload import arrival_times


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen2-1.5b").reduced()
    model = build_model(cfg, Flags(remat=False))
    params = model.init(jax.random.key(0))
    return cfg, model, params


def make_engine(served, clock, **kw):
    cfg, model, params = served
    defaults = dict(decode_slots=2, max_seq_len=64, page_tokens=8,
                    onboard_pages=6, prefill_bucket=16, round_time_s=2e-3)
    defaults.update(kw)
    # per-engine Metrics so twin engines never share histograms
    system = system_for("tpu0", host_id="h0", pool_gib=1,
                        page_bytes=4096, metrics=Metrics())
    return ServeEngine(model, params, system, EngineConfig(**defaults),
                       clock=clock)


# prompts sized so two active sequences overflow the 6-page onboard
# budget (page_tokens=8): the sweep actually exercises LMB spill traffic
SMALL = [TenantLoad("a", rate_rps=300.0, n_requests=5,
                    prompt_tokens=(12, 28), max_new_tokens=(4, 8)),
         TenantLoad("b", rate_rps=300.0, n_requests=5, process="bursty",
                    burst_size=3, prompt_tokens=(12, 28),
                    max_new_tokens=(4, 8))]


# ------------------------------------------------------ arrival processes
class TestArrivalTimes:
    def test_seeded_and_sorted(self):
        t1 = arrival_times(64, 100.0, seed=3)
        t2 = arrival_times(64, 100.0, seed=3)
        assert np.array_equal(t1, t2)
        assert np.all(np.diff(t1) >= 0)
        assert not np.array_equal(t1, arrival_times(64, 100.0, seed=4))

    def test_mean_rate_preserved(self):
        for process in ("poisson", "bursty"):
            t = arrival_times(4000, 50.0, process=process, seed=0)
            rate = len(t) / t[-1]
            assert rate == pytest.approx(50.0, rel=0.15), process

    def test_bursty_is_burstier(self):
        """Markov-modulated bursts must have a higher gap coefficient of
        variation than Poisson at the same mean rate."""
        def cv(t):
            gaps = np.diff(t)
            return gaps.std() / gaps.mean()
        po = arrival_times(2000, 100.0, seed=1)
        bu = arrival_times(2000, 100.0, process="bursty", seed=1)
        assert cv(bu) > 1.5 * cv(po)

    def test_unknown_process_rejected(self):
        with pytest.raises(ValueError):
            arrival_times(4, 1.0, process="constant")


# --------------------------------------------------------- virtual clock
class TestVirtualClock:
    def test_advance(self):
        c = VirtualClock(1.0)
        assert c() == 1.0
        c.advance(0.5)
        assert c.now == 1.5
        with pytest.raises(ValueError):
            c.advance(-0.1)

    def test_advance_to_never_rewinds(self):
        c = VirtualClock()
        c.advance_to(2.0)
        c.advance_to(1.0)
        assert c.now == 2.0


# ---------------------------------------------------------------- traces
class TestBuildTrace:
    def test_deterministic_and_time_ordered(self):
        cfg_vocab = 512
        tr1 = build_trace(SMALL, vocab_size=cfg_vocab, seed=7)
        tr2 = build_trace(SMALL, vocab_size=cfg_vocab, seed=7)
        assert len(tr1) == 10
        times = [s.arrival_time_s for s in tr1]
        assert times == sorted(times)
        for a, b in zip(tr1, tr2):
            assert a.tenant == b.tenant
            assert a.arrival_time_s == b.arrival_time_s
            assert a.max_new_tokens == b.max_new_tokens
            assert np.array_equal(a.prompt, b.prompt)

    def test_tenant_streams_independent(self):
        """Adding a tenant must not perturb an existing tenant's stream
        (per-tenant seeding from the trace seed + tenant name)."""
        solo = build_trace([SMALL[0]], vocab_size=512, seed=7)
        both = [s for s in build_trace(SMALL, vocab_size=512, seed=7)
                if s.tenant == "a"]
        assert len(solo) == len(both)
        for a, b in zip(solo, both):
            assert a.arrival_time_s == b.arrival_time_s
            assert np.array_equal(a.prompt, b.prompt)


# ------------------------------------------------------------ SubmitSpec
class TestSubmitSpec:
    def test_prompt_coerced_and_validated(self):
        spec = SubmitSpec(prompt=[1, 2, 3])
        assert spec.prompt.dtype == np.int32
        with pytest.raises(ValueError):
            SubmitSpec(prompt=[1], max_new_tokens=0)

    def test_arrival_time_charged_to_ttft(self, served):
        """A trace-stamped arrival time becomes submitted_at, so
        admission queueing counts toward TTFT."""
        clock = VirtualClock()
        clock.advance(5.0)
        eng = make_engine(served, clock)
        rid = eng.submit(SubmitSpec(prompt=np.arange(1, 9),
                                    max_new_tokens=2,
                                    arrival_time_s=4.0))
        assert eng.requests[rid].submitted_at == 4.0
        eng.run(50)
        ttft = (eng.requests[rid].first_token_at
                - eng.requests[rid].submitted_at)
        assert ttft >= 1.0          # the queued second is charged

    def test_legacy_submit_deprecated(self, served):
        eng = make_engine(served, VirtualClock())
        with pytest.warns(DeprecationWarning, match="SubmitSpec"):
            rid = eng.submit(np.arange(1, 9), max_new_tokens=2)
        eng.run(50)
        assert eng.requests[rid].state == "done"


# ------------------------------------------------------------- run_sweep
class TestRunSweep:
    def _run(self, served, *, pipeline=True, seed=0):
        trace = build_trace(SMALL, vocab_size=served[0].vocab_size,
                            seed=seed)
        clock = VirtualClock()
        eng = make_engine(served, clock, pipeline=pipeline)
        report = run_sweep(eng, trace, clock)
        return eng, report

    def test_seed_reproducible(self, served):
        _, r1 = self._run(served)
        _, r2 = self._run(served)
        assert r1.per_tenant == r2.per_tenant
        assert r1.totals == r2.totals
        assert r1.totals["done"] == 10

    def test_latency_from_engine_histograms(self, served):
        """Report rows must equal the engine's own histogram snapshot —
        the harness adds no timing of its own."""
        eng, report = self._run(served)
        lat = eng.stats()["latency"]
        for tenant, row in report.per_tenant.items():
            assert row["ttft_p99_s"] == lat[f"serve.ttft.{tenant}"]["p99"]
            assert row["itl_p50_s"] == lat[f"serve.itl.{tenant}"]["p50"]
        assert "exposed_link_wait_s" in report.totals
        assert report.table()       # formatter smoke

    def test_needs_round_duration_and_arrivals(self, served):
        eng = make_engine(served, VirtualClock(), round_time_s=None)
        trace = build_trace(SMALL[:1], vocab_size=64, seed=0)
        with pytest.raises(ValueError, match="round duration"):
            run_sweep(eng, trace, VirtualClock())
        eng2 = make_engine(served, VirtualClock())
        with pytest.raises(ValueError, match="arrival_time_s"):
            run_sweep(eng2, [SubmitSpec(prompt=np.arange(4))],
                      VirtualClock())

    def test_deadline_cancellations_in_totals(self, served):
        """TenantLoad.deadline_s flows trace -> SubmitSpec -> engine,
        and cancelled counts surface in totals and per-tenant rows."""
        tenants = [TenantLoad("tight", rate_rps=200.0, n_requests=6,
                              prompt_tokens=(8, 12),
                              max_new_tokens=(24, 32),
                              deadline_s=1e-3)]   # ~one round: must die
        trace = build_trace(tenants, vocab_size=served[0].vocab_size,
                            seed=0)
        assert all(s.deadline_s == 1e-3 for s in trace)
        clock = VirtualClock()
        eng = make_engine(served, clock, decode_slots=1)
        report = run_sweep(eng, trace, clock)
        tot = report.totals
        assert tot["cancelled"] > 0
        assert tot["done"] + tot["cancelled"] + tot["shed"] == 6
        cancelled_rows = sum(r.get("cancelled", 0)
                             for r in report.per_tenant.values())
        # per-tenant rows only exist for tenants with latency samples;
        # the engine-level count is authoritative
        assert cancelled_rows <= tot["cancelled"]

    def test_drain_idle_gaps_advances_fault_clock(self, served):
        """Chaos runs opt into draining links across idle jumps so an
        attached injector's event clock tracks virtual time."""
        from repro.core import FaultEvent, FaultPlan

        # two arrivals with a long quiet gap between them
        sparse = [SubmitSpec(prompt=np.arange(1, 9), max_new_tokens=2,
                             arrival_time_s=0.0),
                  SubmitSpec(prompt=np.arange(1, 9), max_new_tokens=2,
                             arrival_time_s=5.0)]
        clock = VirtualClock()
        eng = make_engine(served, clock)
        inj = eng._fm.fault_injector
        assert inj is None
        from repro.core.faults import FaultInjector
        inj = FaultInjector(FaultPlan((
            FaultEvent(t_s=2.0, kind="link_flap", retrain_s=0.1),)))
        eng._fm.attach_fault_injector(inj)
        run_sweep(eng, sparse, clock, drain_idle_gaps=True)
        # the t=2.0 event fired inside the idle gap, not at the end
        assert inj.snapshot()["events_fired"] == 1
        assert inj.now_s >= 5.0

    def test_pipelined_matches_phased_tokens_with_less_wait(self, served):
        """The tentpole contract: the pipelined step emits byte-identical
        token streams to the phased reference order while strictly
        reducing the modeled exposed link wait."""
        eng_p, _ = self._run(served, pipeline=True)
        eng_f, _ = self._run(served, pipeline=False)
        toks_p = {r.req_id: r.out_tokens for r in eng_p.requests.values()}
        toks_f = {r.req_id: r.out_tokens for r in eng_f.requests.values()}
        assert toks_p == toks_f
        assert eng_p.kv.buf.link_wait_s < eng_f.kv.buf.link_wait_s


# ------------------------------------- prefetch scheduling corner cases
class TestNextDecodePages:
    def test_boundaries(self, served):
        eng = make_engine(served, VirtualClock())
        kv = eng.kv
        sid = kv.new_seq()
        assert kv.next_decode_pages(sid) == []          # empty sequence
        pages = kv.buf.append_pages(2)
        kv.seq(sid).pages.extend(pages)
        kv.seq(sid).length = kv.page_tokens             # exactly full page
        assert kv.next_decode_pages(sid) == []          # next opens fresh
        kv.seq(sid).length = kv.page_tokens + 3         # mid second page
        assert kv.next_decode_pages(sid) == [pages[1]]  # RMW tail page

    def test_prefetch_identity_under_preemption(self, served):
        """Preempting mid-decode (KV parks in LMB, swap-in is scheduled
        as prefetch on resume) must not change any token stream."""
        def run(pipeline):
            clock = VirtualClock()
            eng = make_engine(served, clock, decode_slots=2,
                              pipeline=pipeline)
            rng = np.random.default_rng(5)
            for _ in range(3):
                eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 18),
                                      max_new_tokens=6))
            eng.step()
            eng.preempt(next(iter(eng.active)))   # forces LMB parking
            for _ in range(100):
                if not (eng.waiting or eng.active):
                    break
                eng.step()
                clock.advance(2e-3)
            return {r.req_id: r.out_tokens for r in eng.requests.values()}
        assert run(True) == run(False)

    def test_prefetch_identity_under_midstream_admission(self, served):
        """Requests arriving while decode is in flight (admitted by the
        pipelined round tail vs the phased round head) must still decode
        to identical tokens."""
        def run(pipeline):
            clock = VirtualClock()
            eng = make_engine(served, clock, decode_slots=2,
                              pipeline=pipeline)
            rng = np.random.default_rng(6)
            mk = lambda: SubmitSpec(prompt=rng.integers(0, 100, 12),
                                    max_new_tokens=4)
            eng.submit(mk())
            eng.step()
            eng.submit(mk())            # lands mid-stream
            eng.step()
            eng.submit(mk())
            for _ in range(100):
                if not (eng.waiting or eng.active):
                    break
                eng.step()
                clock.advance(2e-3)
            return {r.req_id: r.out_tokens for r in eng.requests.values()}
        assert run(True) == run(False)
