"""Sharding rules + roofline parsing (host-side; no 512-device mesh here —
the full mesh is exercised by launch/dryrun.py in a separate process)."""

import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.models import build_model
from repro.roofline.analysis import (collective_bytes_per_device,
                                     model_flops, parse_collectives,
                                     roofline_terms)
from repro.sharding.partition import batch_spec, param_shardings


def mesh1():
    return jax.make_mesh((1, 1), ("data", "model"))


class TestPartitionRules:
    def test_every_spec_divides(self):
        """On a (1,1) mesh every rule must produce valid shardings for
        every arch (divisibility fallback never crashes)."""
        mesh = mesh1()
        for arch in ("qwen2-1.5b", "hymba-1.5b", "rwkv6-7b",
                     "mixtral-8x22b", "seamless-m4t-large-v2"):
            cfg = get_config(arch)
            shapes = build_model(cfg).abstract_params()
            sh = param_shardings(shapes, mesh, cfg, fsdp=True)
            assert jax.tree_util.tree_structure(sh) == \
                jax.tree_util.tree_structure(shapes)

    def test_batch_spec_fallbacks(self):
        mesh = mesh1()
        assert batch_spec(mesh, 4) == P(("data",), None)
        # batch=1 on a (data=1) mesh still divides
        assert batch_spec(mesh, 1) == P(("data",), None)


class TestHloParsing:
    HLO = """
  %all-reduce.1 = f32[16,4096]{1,0} all-reduce(%x), replica_groups={}
  %all-gather.2 = bf16[8,1024,128]{2,1,0} all-gather(%y), dimensions={1}
  %rs = f32[4,256]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = (f32[2,2]{1,0}, f32[2,2]{1,0}) all-to-all(%p, %q)
  %notacoll = f32[2,2]{1,0} add(%a, %b)
"""

    def test_parse_kinds_and_bytes(self):
        got = dict()
        for kind, b in parse_collectives(self.HLO):
            got.setdefault(kind, 0)
            got[kind] += b
        assert got["all-reduce"] == 16 * 4096 * 4
        assert got["all-gather"] == 8 * 1024 * 128 * 2
        assert got["reduce-scatter"] == 4 * 256 * 4
        assert got["all-to-all"] == 2 * (2 * 2 * 4)

    def test_traffic_weighting(self):
        per = collective_bytes_per_device(self.HLO)
        assert per["all-reduce"] == 2.0 * 16 * 4096 * 4

    def test_roofline_terms_math(self):
        cost = {"flops": 197e12, "bytes accessed": 819e9}
        t = roofline_terms(cost, self.HLO, chips=256, model_flops=197e12)
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(1.0)
        assert t.dominant in ("compute", "memory")
        assert t.hlo_flops == pytest.approx(197e12 * 256)


class TestModelFlops:
    def test_moe_uses_active_params(self):
        from repro.configs.base import SHAPES
        dense = get_config("command-r-plus-104b")
        moe = get_config("dbrx-132b")
        shp = SHAPES["train_4k"]
        assert model_flops(moe, shp) < 0.5 * moe.param_count() * 6 * \
            shp.global_batch * shp.seq_len
        assert model_flops(dense, shp) == pytest.approx(
            6.0 * dense.param_count() * shp.global_batch * shp.seq_len)


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """End-to-end dry-run of one small cell on the 512-device mesh, in a
    subprocess (keeps this process on the 1-device backend)."""
    code = (
        "from repro.launch.dryrun import run_cell\n"
        "r = run_cell('qwen2-1.5b', 'decode_32k', 'single', verbose=False)\n"
        "assert r['status'] == 'ok', r.get('error')\n"
        "assert r['roofline']['hlo_flops'] > 0\n"
        "print('CELL-OK')\n")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1200,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "CELL-OK" in out.stdout, out.stderr[-2000:]
