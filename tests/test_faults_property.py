"""Property-based tests (hypothesis) on the chaos layer's determinism
contract (repro.core.faults):

  * **zero-fault identity** — attaching an injector with an empty plan
    changes NOTHING: per-transfer delays, per-class op_bytes, and link
    state are byte-identical to a run with no injector, for arbitrary
    transfer sequences.
  * **retry-time monotonicity** — for a fixed seed and transfer
    sequence, total modeled retry delay is monotone (non-decreasing) in
    the transient error rate.  This is a *coupling* property: the
    per-transfer seeded substreams guarantee transfer *i* sees the same
    uniforms at every rate, so a higher rate's error set is a superset.
  * **retry-byte conservation** — the injector's ``retry_bytes``
    counter reconciles exactly with the FM's ``op_bytes()["retry"]``
    accounting class, whatever the storm.
"""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import FaultPlan, RetryPolicy, system_for
from repro.core.metrics import Metrics


def fresh_system():
    return system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                      metrics=Metrics())


def run_storm(error_rate, sizes, *, seed, retry=None):
    """One deterministic storm run; returns (delays, counters, op_bytes).

    No ``advance`` during the measured transfers, so escalations (if
    any) stay pending and the transfer sequence is identical across
    error rates — the coupling the monotonicity property needs.
    """
    system = fresh_system()
    plan = (FaultPlan() if error_rate == 0.0 else
            FaultPlan.storm(t0_s=0.0, duration_s=1e9,
                            error_rate=error_rate))
    inj = system.attach_fault_injector(plan, retry=retry, seed=seed)
    host = system.host()
    a = host.alloc("d0", 1 << 20)
    system.fm.advance_links(0.0)          # fire the t=0 window
    delays = [host.meter_transfer("d0", nb, a.mmid) for nb in sizes]
    return delays, inj.counters(), dict(system.fm.op_bytes())


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.lists(st.integers(1 << 8, 1 << 18), min_size=1, max_size=24),
       st.integers(0, 2 ** 31))
def test_zero_fault_plan_is_byte_identical(sizes, seed):
    system0 = fresh_system()
    host0 = system0.host()
    a0 = host0.alloc("d0", 1 << 20)
    base = [host0.meter_transfer("d0", nb, a0.mmid) for nb in sizes]
    delays, ctr, ob = run_storm(0.0, sizes, seed=seed)
    assert delays == base
    assert ob == dict(system0.fm.op_bytes())
    assert "retry" not in ob
    assert ctr["transient_errors"] == 0 and ctr["retries"] == 0


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.lists(st.integers(1 << 8, 1 << 16), min_size=1, max_size=16),
       st.integers(0, 2 ** 31),
       st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_retry_delay_monotone_in_error_rate(sizes, seed, r_a, r_b):
    r_lo, r_hi = sorted((r_a, r_b))
    # unlimited budget isolates the monotone-cost property from
    # escalation side effects (which change the fabric mid-sequence)
    pol = RetryPolicy(link_retry_budget=None)
    _, ctr_lo, _ = run_storm(r_lo, sizes, seed=seed, retry=pol)
    _, ctr_hi, _ = run_storm(r_hi, sizes, seed=seed, retry=pol)
    assert ctr_hi["retry_delay_s"] >= ctr_lo["retry_delay_s"]
    assert ctr_hi["transient_errors"] >= ctr_lo["transient_errors"]
    assert ctr_hi["retries"] >= ctr_lo["retries"]


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.lists(st.integers(1 << 8, 1 << 16), min_size=1, max_size=16),
       st.integers(0, 2 ** 31), st.floats(0.05, 0.95))
def test_retry_bytes_reconcile_with_fm_accounting(sizes, seed, rate):
    pol = RetryPolicy(link_retry_budget=None)
    _, ctr, ob = run_storm(rate, sizes, seed=seed, retry=pol)
    assert ob.get("retry", 0) == ctr["retry_bytes"]
    # every retry retransmitted one of the submitted sizes
    if ctr["retries"] == 0:
        assert ctr["retry_bytes"] == 0
    else:
        assert ctr["retry_bytes"] >= ctr["retries"] * min(sizes)
        assert ctr["retry_bytes"] <= ctr["retries"] * max(sizes)
