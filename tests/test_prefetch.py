"""Burst-aware prefetcher + overlap scheduling.

Covers the prefetch rebuild end to end: the Prefetcher's pinned stride
semantics and new run/backlog machinery, LinkedBuffer prefetch bursts
(op-tagged metering, never-evict, free-slot budget, deferral instead of
truncation), the OverlapScheduler admission math, the serving engine's
exact-future scheduling, and the DES prefetch model.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import OverlapScheduler, system_for
from repro.core.metrics import Metrics
from repro.core.overlap import exposed_latency_s, hidden_fraction
from repro.core.policy import Prefetcher
from repro.core.tiers import (LMB_CXL_ADDED_S, TierKind, TierSpec,
                              hideable_page_bytes)

PAGE = (4, 4)
LINK_TIER = TierSpec(TierKind.LMB_CXL, LMB_CXL_ADDED_S, 30e9)


def make_buf(n_pages=24, onboard=8, chunk=8, depth=4, overlap=None,
             n_expanders=1, compress=False, min_burst=1, **kw):
    """System + buffer with every page written once (cold pages spilled
    to the LMB tier), stride detector untouched."""
    metrics = Metrics()
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        n_expanders=n_expanders, metrics=metrics)
    buf = system.buffer(name="pf", device_id="d0", page_shape=PAGE,
                        dtype=jnp.float32, onboard_pages=onboard,
                        lmb_chunk_pages=chunk, prefetch_depth=depth,
                        prefetch_min_burst=min_burst, overlap=overlap,
                        compress_lmb=compress, metrics=metrics, **kw)
    pages = buf.append_pages(n_pages)
    for p in pages:
        buf.write(p, jnp.full(PAGE, 1.0 + p, jnp.float32))
    return system, buf, pages


def lmb_pages(buf, pages):
    return [p for p in pages if buf._pages[p].tier == "lmb"]


def onboard_pages(buf, pages):
    return [p for p in pages if buf._pages[p].tier == "onboard"]


# ---------------------------------------------------------------- Prefetcher
def test_stride_confidence_pinned():
    """Regression pin of the pre-refactor stride semantics: confidence
    builds on repeated strides, fires at >= 2, resets to 1 on a stride
    change, saturates at 4; zero strides are ignored."""
    pf = Prefetcher(depth=4)
    pf.observe(10)
    assert pf.suggest(100) == []              # no stride yet
    pf.observe(12)
    assert pf._confidence == 1
    assert pf.suggest(100) == []              # one observation is a guess
    pf.observe(14)
    assert pf._confidence == 2
    assert pf.suggest(100) == [16, 18, 20, 22]
    pf.observe(14)                            # dup access: stride 0 ignored
    assert pf._confidence == 2
    pf.observe(15)                            # stride change resets
    assert pf._confidence == 1
    assert pf.suggest(100) == []
    for p in (16, 17, 18, 19, 20, 21):
        pf.observe(p)
    assert pf._confidence == 4                # saturates, never higher
    assert pf.suggest(23) == [22, 23]         # clipped to max_page
    pf2 = Prefetcher(depth=4)
    for p in (30, 28, 26):
        pf2.observe(p)
    assert pf2.suggest(100) == [24, 22, 20, 18]   # negative strides work


def test_suggest_runs_chunk_grouping_and_priority():
    """Scheduled pages come first, grouped per chunk extent; leftover
    budget is the stride detector promoted to a run extent."""
    pf = Prefetcher(depth=6)
    for p in (0, 1, 2):
        pf.observe(p)
    pf.schedule([9, 10, 17, 33])
    runs = pf.suggest_runs(100, run_pages=8)
    assert [(r.source, r.pages) for r in runs] == [
        ("scheduled", (9, 10)),               # chunk 1
        ("scheduled", (17,)),                 # chunk 2
        ("scheduled", (33,)),                 # chunk 4
        ("stride", (3, 4)),                   # budget 6 - 4 scheduled
    ]
    # scheduled knowledge consumed: next round is pure stride
    runs = pf.suggest_runs(100, run_pages=8)
    assert all(r.source == "stride" for r in runs)


def test_backlog_capped_deque_and_stale_drop():
    """The scheduled backlog is bounded (oldest shed first) and a page
    demand-faulted before its prefetch is dropped, not issued late."""
    pf = Prefetcher(depth=2, backlog_factor=2)   # cap = 4 pages
    pf.schedule([1, 2, 3, 4, 5, 6])
    assert pf.pending() == 4                     # 1, 2 shed (oldest)
    assert pf.dropped_overflow == 2
    pf.observe(3)                                # demand beat the prefetch
    runs = pf.suggest_runs(100, run_pages=64)
    issued = [p for r in runs for p in r.pages]
    assert 3 not in issued and issued == [4, 5]  # depth 2, stale skipped
    assert pf.dropped_stale == 1


def test_defer_preserves_front_priority():
    pf = Prefetcher(depth=4)
    pf.schedule([20, 21, 22, 23])
    taken = [p for r in pf.suggest_runs(100, run_pages=64)
             for p in r.pages]
    assert taken == [20, 21, 22, 23]
    pf.defer([22, 23])                           # overlap couldn't fit
    pf.schedule([24])
    taken = [p for r in pf.suggest_runs(100, run_pages=64)
             for p in r.pages]
    assert taken == [22, 23, 24]                 # deferred keep priority


# ------------------------------------------------------------- OverlapScheduler
def test_overlap_budget_and_admission_order():
    ov = OverlapScheduler(LINK_TIER, compute_window_s=1e-3)
    assert ov.budget_bytes() == hideable_page_bytes(1e-3, LINK_TIER)
    page = 64 * 1024
    budget_pages = ov.budget_bytes() // page
    # admit whole runs in order until the budget runs out
    n, charged = ov.admit([2, 2, int(budget_pages)], page)
    assert n == 2 and charged == [2, 2]
    assert ov.stats.deferred_runs == 1
    # a later small run must NOT jump a deferred big one next round:
    # admission is strictly prefix-order within one call
    ov.start_window()
    n, _ = ov.admit([int(budget_pages) + 1, 1], page)
    assert n == 0
    assert ov.stats.deferred_pages >= budget_pages + 2


def test_overlap_window_ewma_and_pinned():
    ov = OverlapScheduler(LINK_TIER, compute_window_s=0.0, ewma_alpha=0.5)
    assert ov.budget_bytes() == 0                # no window, no budget
    ov.observe_compute(1e-3)
    assert ov.window_s == pytest.approx(1e-3)    # first sample seeds
    ov.observe_compute(3e-3)
    assert ov.window_s == pytest.approx(2e-3)    # EWMA
    ov.start_window(5e-3)                        # pinned window wins
    assert ov.window_s == pytest.approx(5e-3)


def test_exposed_latency_and_hidden_fraction():
    assert exposed_latency_s(1e-6, 0.0) == 1e-6
    assert exposed_latency_s(1e-6, 4e-7) == pytest.approx(6e-7)
    assert exposed_latency_s(1e-6, 2e-6) == 0.0
    assert hidden_fraction(1e-6, 5e-7) == pytest.approx(0.5)
    assert hidden_fraction(0.0, 0.0) == 1.0


# ------------------------------------------------------------- buffer bursts
def test_prefetch_never_evicts_and_respects_free_slots():
    """Prefetch uses FREE onboard slots only: resident pages survive any
    schedule_prefetch, and an oversized schedule is deferred."""
    system, buf, pages = make_buf(n_pages=24, onboard=8)
    resident_before = set(onboard_pages(buf, pages))
    cold = lmb_pages(buf, pages)
    buf.schedule_prefetch(cold)                  # 16 cold pages, 0 free
    assert set(onboard_pages(buf, pages)) == resident_before
    assert buf.prefetch_pages_total == 0         # nothing issued
    assert buf.prefetcher.pending() > 0          # deferred, not dropped
    # free some slots: the backlog drains into exactly that budget
    for p in list(resident_before)[:4]:
        buf.release(p)
    buf.schedule_prefetch([])                    # kick a round
    assert buf.prefetch_pages_total == 4
    buf.check_invariants()


def test_schedule_prefetch_not_truncated_to_depth():
    """The seed issued only the first `depth` pages of an exact
    scheduled list; the rebuilt path keeps the remainder in the backlog
    and issues it on later rounds."""
    system, buf, pages = make_buf(n_pages=24, onboard=12, depth=2)
    for p in onboard_pages(buf, pages)[:8]:
        buf.release(p)                           # 8 free slots
    cold = lmb_pages(buf, pages)[:8]
    buf.schedule_prefetch(cold)                  # depth=2 per round
    assert all(buf._pages[p].tier == "onboard" for p in cold)
    assert buf.prefetch_pages_total == 8
    buf.check_invariants()


def test_prefetch_burst_metering_and_op_tag():
    """A multi-page prefetch is ONE arbiter call per expander, tagged
    op='prefetch' in the FM's per-class bytes and journal — never
    per-page meter calls."""
    system, buf, pages = make_buf(n_pages=24, onboard=8, chunk=32,
                                  depth=8)
    for p in onboard_pages(buf, pages):
        buf.release(p)
    cold = lmb_pages(buf, pages)[:6]
    calls0 = system.fm.meter_calls()
    journal0 = len(system.fm.journal)
    buf.schedule_prefetch(cold)
    assert buf.prefetch_pages_total == 6
    assert system.fm.meter_calls() - calls0 == 1         # one burst
    assert system.fm.op_bytes().get("prefetch", 0) == \
        6 * buf.lmb_page_bytes
    tagged = [e for e in system.fm.journal[journal0:]
              if e.op == "prefetch"]
    assert len(tagged) == 1                              # journaled burst
    buf.check_invariants()


@pytest.mark.parametrize("compress", [False, True])
@pytest.mark.parametrize("n_expanders", [1, 2])
def test_prefetched_read_identical_to_demand_fault(compress, n_expanders):
    """A prefetched-then-read page yields byte-identical contents vs a
    demand fault, including compressed and multi-expander placements."""
    mk = lambda: make_buf(n_pages=20, onboard=6, chunk=4,
                          compress=compress, n_expanders=n_expanders)
    _, buf_a, pages_a = mk()                     # demand twin
    _, buf_b, pages_b = mk()                     # prefetch twin
    assert pages_a == pages_b
    cold = lmb_pages(buf_b, pages_b)
    for p in onboard_pages(buf_b, pages_b)[:4]:
        buf_b.release(p)
        buf_a.release(p)
    buf_b.schedule_prefetch(cold)
    assert buf_b.prefetch_pages_total > 0
    for p in cold:
        got = np.asarray(buf_b.read(p))
        want = np.asarray(buf_a.read(p))         # pure demand fault
        assert np.array_equal(got, want), p
    buf_a.check_invariants()
    buf_b.check_invariants()


def test_prefetch_used_and_wasted_accounting():
    system, buf, pages = make_buf(n_pages=24, onboard=8)
    for p in onboard_pages(buf, pages)[:4]:
        buf.release(p)
    cold = lmb_pages(buf, pages)[:4]
    buf.schedule_prefetch(cold)
    assert buf.prefetch_pages_total == 4
    buf.read(cold[0])                            # used
    assert buf.prefetch_used == 1
    # hammer other pages until the remaining prefetched ones evict
    victims = lmb_pages(buf, pages)
    for p in victims:
        buf.read(p)
    assert buf.prefetch_used + buf.prefetch_wasted >= 3
    st = buf.prefetch_stats()
    assert st["pages"] == st["used"] + st["wasted"] + st["unread"]


def test_overlap_defers_prefetch_until_window_allows():
    """With a tiny compute window nothing is admitted (deferred, demand
    serves); growing the window lets the same backlog issue."""
    ov = OverlapScheduler(LINK_TIER, compute_window_s=0.0)
    system, buf, pages = make_buf(n_pages=24, onboard=8, overlap=ov)
    for p in onboard_pages(buf, pages)[:6]:
        buf.release(p)
    cold = lmb_pages(buf, pages)[:6]
    buf.note_compute_window(0.0, observed=False)
    buf.schedule_prefetch(cold)
    assert buf.prefetch_pages_total == 0         # no window, no traffic
    assert buf.prefetcher.pending() == 6
    buf.note_compute_window(1e-3, observed=False)
    buf.schedule_prefetch([])
    assert buf.prefetch_pages_total == 6
    assert buf.prefetch_hidden_s > 0             # wait accrued as hidden
    assert buf.link_wait_s == pytest.approx(buf.link_wait_s)
    buf.check_invariants()


def test_hidden_wait_separate_from_demand_wait():
    """Admitted prefetch wait lands in prefetch_hidden_s, demand wait in
    link_wait_s — the split the hidden-fraction metric is built on."""
    ov = OverlapScheduler(LINK_TIER, compute_window_s=1e-3)
    system, buf, pages = make_buf(n_pages=24, onboard=8, overlap=ov)
    for p in onboard_pages(buf, pages)[:4]:
        buf.release(p)
    demand0 = buf.link_wait_s
    buf.schedule_prefetch(lmb_pages(buf, pages)[:4])
    assert buf.prefetch_hidden_s > 0
    assert buf.link_wait_s == demand0            # no demand charge
    buf.read(lmb_pages(buf, pages)[0])           # a real demand fault
    assert buf.link_wait_s > demand0


def test_deferred_requeue_preserves_priority_order():
    """Pages cut by DIFFERENT budget passes (free-slot tail vs overlap
    deferral) must re-queue in original schedule order: a later run's
    tail never jumps ahead of an earlier deferred page."""
    page_bytes = int(np.prod(PAGE)) * 4
    window = LMB_CXL_ADDED_S + (2.5 * page_bytes) / 30e9   # 2-page budget
    ov = OverlapScheduler(LINK_TIER, compute_window_s=window)
    system, buf, pages = make_buf(n_pages=12, onboard=8, chunk=2,
                                  depth=4, overlap=ov)
    cold = lmb_pages(buf, pages)
    assert cold == [0, 1, 2, 3]                  # chunks (0,0), (1,1)
    for p in onboard_pages(buf, pages)[:3]:
        buf.release(p)                           # 3 free slots
    buf.schedule_prefetch(cold)
    # run (0,1) admitted; page 2 overlap-deferred (budget spent), page 3
    # free-slot-deferred — the backlog must hold them IN ORDER
    assert buf.prefetch_pages_total == 2
    assert list(buf.prefetcher._scheduled) == [2, 3]
    buf.check_invariants()


def test_stride_min_burst_hysteresis():
    """Steady-state stride lookahead accumulates into >= min_burst page
    bursts instead of one arbiter call per page."""
    system, buf, pages = make_buf(n_pages=40, onboard=16, chunk=8,
                                  depth=8, min_burst=4)
    for p in onboard_pages(buf, pages):
        buf.release(p)
    calls0 = system.fm.meter_calls()
    scan = lmb_pages(buf, pages)[:24]
    for p in scan:
        buf.read(p)
        buf.release(p)
    calls = system.fm.meter_calls() - calls0
    st = buf.prefetch_stats()
    assert st["pages"] > 0
    assert st["pages"] / max(st["bursts"], 1) >= 2   # real bursts
    assert calls < len(scan)                     # fewer calls than pages


# ------------------------------------------------------------------ serving
@pytest.fixture(scope="module")
def served():
    import jax
    from repro.configs.base import get_config
    from repro.models import build_model
    from repro.models.flags import Flags
    cfg = get_config("qwen2-1.5b").reduced()
    model = build_model(cfg, Flags(remat=False))
    params = model.init(jax.random.key(0))
    return cfg, model, params


def make_engine(served, **kw):
    from repro.serve import EngineConfig, ServeEngine
    cfg, model, params = served
    defaults = dict(decode_slots=4, max_seq_len=64, page_tokens=4,
                    onboard_pages=4, prefill_bucket=16)
    defaults.update(kw)
    return ServeEngine(model, params,
                       system_for("tpu0", host_id="h0", pool_gib=1,
                                  page_bytes=4096),
                       EngineConfig(**defaults))


def run_workload(eng, n_req=6, n_tok=6):
    from repro.serve import SubmitSpec
    rng = np.random.default_rng(7)
    rids = [eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 18),
                                  max_new_tokens=n_tok))
            for _ in range(n_req)]
    rounds = 0
    while (eng.waiting or eng.active) and rounds < 400:
        eng.step()
        rounds += 1
    return rids, rounds


def test_serve_prefetch_on_off_identical_tokens(served):
    """ServeEngine.step() with prefetch enabled produces identical
    tokens to prefetch-disabled runs — prefetch is a pure performance
    transform on the KV data path."""
    eng_on = make_engine(served, kv_prefetch=True)
    eng_off = make_engine(served, kv_prefetch=False)
    rids_on, _ = run_workload(eng_on)
    rids_off, _ = run_workload(eng_off)
    for a, b in zip(rids_on, rids_off):
        ra, rb = eng_on.requests[a], eng_off.requests[b]
        assert ra.state == rb.state == "done"
        assert ra.out_tokens == rb.out_tokens
    assert eng_on.kv.buf.prefetcher is not None
    assert eng_off.kv.buf.prefetcher is None


def test_serve_prefetch_meter_calls_do_not_regress(served):
    """meter_calls per decode round with engine-fed prefetch must not
    exceed the demand-only (PR-4 batched) baseline: scheduled pages move
    as bursts that REPLACE demand faults, they don't add traffic."""
    eng_on = make_engine(served, kv_prefetch=True)
    eng_off = make_engine(served, kv_prefetch=False)
    _, rounds_on = run_workload(eng_on)
    _, rounds_off = run_workload(eng_off)
    calls_on = eng_on.stats()["fabric"]["meter_calls"] / rounds_on
    calls_off = eng_off.stats()["fabric"]["meter_calls"] / rounds_off
    assert calls_on <= calls_off * 1.01
    # and the exact-future path actually engaged under KV spill pressure
    st = eng_on.kv.buf.prefetch_stats()
    assert st["enabled"]


def test_next_decode_pages():
    from repro.configs.base import get_config
    from repro.serve.kv_cache import PagedKVStore
    cfg = get_config("qwen2-1.5b").reduced()
    system = system_for("tpu0", host_id="h0", pool_gib=1, page_bytes=4096)
    kv = PagedKVStore(cfg=cfg, system=system, device_id="tpu0",
                      page_tokens=4, onboard_pages=8)
    sid = kv.new_seq()
    assert kv.next_decode_pages(sid) == []       # empty: fresh page next
    L, KV_, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim_
    kv.append_tokens(sid, jnp.ones((L, 2, 6, KV_, hd),
                                   jnp.dtype(cfg.dtype)))
    seq = kv.seq(sid)
    assert kv.next_decode_pages(sid) == [seq.pages[1]]   # tail partial
    kv.append_tokens(sid, jnp.ones((L, 2, 2, KV_, hd),
                                   jnp.dtype(cfg.dtype)))
    assert kv.next_decode_pages(sid) == []       # boundary: fresh page


# ---------------------------------------------------------------------- sim
def test_sim_prefetch_hides_sequential_latency_only():
    from repro.sim import make_ssd_model, make_workload, simulate
    from repro.sim.ssd import make_schemes
    spec = make_ssd_model(5)
    scheme = make_schemes(spec)["lmb-cxl"]
    seq = make_workload("seqread", n_ios=20_000)
    rand = make_workload("randread", n_ios=20_000)
    base_seq = simulate(spec, scheme, seq)
    pf_seq = simulate(spec, scheme, seq, prefetch_depth=8)
    assert pf_seq.mean_lat_us < base_seq.mean_lat_us
    assert pf_seq.iops >= base_seq.iops
    base_rand = simulate(spec, scheme, rand)
    pf_rand = simulate(spec, scheme, rand, prefetch_depth=8)
    assert pf_rand.mean_lat_us == base_rand.mean_lat_us   # parity
    assert pf_rand.iops == base_rand.iops


def test_sim_shared_fabric_prefetch_passthrough():
    from repro.sim import (make_ssd_model, make_workload,
                           simulate_shared_fabric)
    from repro.sim.ssd import make_schemes
    spec = make_ssd_model(5)
    scheme = make_schemes(spec)["lmb-cxl"]
    wl = make_workload("seqread", n_ios=10_000)
    base = simulate_shared_fabric(spec, scheme, wl, 4)
    pf = simulate_shared_fabric(spec, scheme, wl, 4, prefetch_depth=8)
    assert pf.mean_p99_us <= base.mean_p99_us


# ------------------------------------------------------------- client config
def test_system_spec_prefetch_knobs():
    import jax.numpy as jnp_
    from repro.core import (DeviceSpec, HostSpec, LMBSystem, PrefetchSpec,
                            SystemSpec)
    spec = SystemSpec(expanders=1, pool_gib=1,
                      hosts=(HostSpec("h0", page_bytes=4096),),
                      devices=(DeviceSpec("d0"),),
                      prefetch=PrefetchSpec(depth=6, overlap=True,
                                            compute_window_s=1e-3))
    with LMBSystem(spec) as system:
        buf = system.buffer(name="k", device_id="d0", page_shape=PAGE,
                            dtype=jnp_.float32, onboard_pages=4,
                            metrics=Metrics())
        assert buf.prefetcher is not None and buf.prefetcher.depth == 6
        assert buf.overlap is not None
        assert buf.overlap.window_s == pytest.approx(1e-3)
        # explicit knobs win over spec defaults
        buf2 = system.buffer(name="k2", device_id="d0", page_shape=PAGE,
                             dtype=jnp_.float32, onboard_pages=4,
                             prefetch_depth=0, metrics=Metrics())
        assert buf2.prefetcher is None and buf2.overlap is None
    with pytest.raises(ValueError):
        SystemSpec(hosts=("h0",),
                   prefetch=PrefetchSpec(depth=-1)).validate()
