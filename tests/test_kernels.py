"""Pallas kernel validation: shape/dtype sweeps vs. ref.py oracles
(interpret=True on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.models.rwkv6 import wkv_chunked
from repro.models.ssm import ssd_chunked

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16] if dtype == jnp.bfloat16 else TOL[jnp.float32]


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 192, 6, 1, 32),     # MQA, ragged S vs block
    (2, 64, 4, 2, 128),     # single k block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 48),
                                           (False, None)])
def test_flash_attention_sweep(B, S, H, KV, hd, dtype, causal, window):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=_tol(dtype), atol=_tol(dtype))


@pytest.mark.parametrize("B,S,H,N,chunk", [
    (1, 64, 2, 16, 16),
    (2, 128, 4, 32, 32),
    (1, 96, 1, 64, 32),     # uneven nc
    (2, 64, 3, 16, 64),     # single chunk
])
@pytest.mark.parametrize("strong_decay", [False, True])
def test_rwkv6_kernel_sweep(B, S, H, N, chunk, strong_decay):
    if S % chunk:
        pytest.skip("chunk must divide S")
    ks = jax.random.split(jax.random.key(2), 5)
    r = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N))
    v = jax.random.normal(ks[2], (B, S, H, N))
    if strong_decay:   # numerical stress: w down to ~0.01
        w = jnp.exp(-jnp.exp(jax.random.uniform(ks[3], (B, S, H, N),
                                                minval=-2.0, maxval=1.5)))
    else:
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, N))) * \
            0.3 + 0.69
    u = jax.random.normal(ks[4], (H, N)) * 0.2
    st = jax.random.normal(ks[4], (B, H, N, N)) * 0.1
    out_ref, st_ref = ref.rwkv6_ref(r, k, v, w, u, st)
    out_k, st_k = rwkv6_scan(r, k, v, w, u, st, chunk=chunk,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_ref),
                               rtol=2e-4, atol=2e-4)
    # the model's chunked XLA path must agree with both
    out_c, st_c = wkv_chunked(r, k, v, w, u, st, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,H,KV,hd,P,T,MP", [
    (2, 4, 2, 32, 8, 8, 4),
    (3, 8, 8, 64, 16, 16, 3),   # MHA pages
    (1, 6, 2, 32, 4, 4, 4),
])
def test_paged_attention_sweep(B, H, KV, hd, P, T, MP):
    ks = jax.random.split(jax.random.key(3), 4)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, T, KV, hd))
    vp = jax.random.normal(ks[2], (P, T, KV, hd))
    rng = np.random.default_rng(0)
    lengths = jnp.asarray(rng.integers(1, MP * T, B))
    pt = np.full((B, MP), -1, np.int32)
    perm = iter(rng.permutation(P))
    for b in range(B):
        for i in range(-(-int(lengths[b]) // T)):
            pt[b, i] = next(perm)
    pt = jnp.asarray(pt)
    out = paged_attention(q, kp, vp, pt, lengths, interpret=True)
    expect = ref.paged_attention_ref(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_ignores_unmapped_page_content():
    """Garbage in unmapped pool pages must not leak into output
    (IOMMU discipline: the clamped DMA reads page 0 but masks it)."""
    B, H, KV, hd, P, T, MP = 1, 2, 2, 16, 4, 4, 3
    ks = jax.random.split(jax.random.key(4), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, T, KV, hd))
    vp = jax.random.normal(ks[2], (P, T, KV, hd))
    pt = jnp.asarray([[2, -1, -1]], jnp.int32)
    lengths = jnp.asarray([3])
    out1 = paged_attention(q, kp, vp, pt, lengths, interpret=True)
    kp2 = kp.at[0].set(999.0)   # poison page 0 (the clamp target)
    vp2 = vp.at[0].set(999.0)
    out2 = paged_attention(q, kp2, vp2, pt, lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


def test_paged_attention_scale_override_zero():
    """Regression: ``scale_override=0.0`` is falsy and used to silently
    fall back to the default 1/sqrt(hd) scale; it must zero the scores
    (uniform attention over the valid positions), matching the ref."""
    B, H, KV, hd, P, T, MP = 2, 4, 2, 32, 8, 8, 4
    ks = jax.random.split(jax.random.key(6), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, T, KV, hd))
    vp = jax.random.normal(ks[2], (P, T, KV, hd))
    pt = jnp.asarray([[0, 1, -1, -1], [2, 3, 4, -1]], jnp.int32)
    lengths = jnp.asarray([13, 20])
    out = paged_attention(q, kp, vp, pt, lengths, scale_override=0.0,
                          interpret=True)
    # scale 0 -> uniform weights over the valid prefix
    expect = np.stack([
        np.asarray(vp)[np.asarray(pt[b])[:-(-int(lengths[b]) // T)]]
        .reshape(-1, KV, hd)[:int(lengths[b])].mean(0)
        for b in range(B)])                       # [B, KV, hd]
    expect = np.repeat(expect, H // KV, axis=1)   # group-broadcast
    np.testing.assert_allclose(np.asarray(out), expect,
                               rtol=2e-5, atol=2e-5)
    # and it must differ from the silent-default behavior it replaced
    dflt = paged_attention(q, kp, vp, pt, lengths, interpret=True)
    assert not np.allclose(np.asarray(out), np.asarray(dflt))


@pytest.mark.parametrize("lengths,table", [
    # a zero-length row batched with a live one
    ([0, 9], [[-1, -1, -1], [0, 1, 2]]),
    # length exactly on a page boundary (last page completely full)
    ([8, 12], [[3, 4, -1], [5, 6, 7]]),
    # all-unmapped table with zero length (fresh slot)
    ([0, 4], [[-1, -1, -1], [2, -1, -1]]),
])
def test_paged_attention_edge_lengths(lengths, table):
    """Edge geometry vs ref: zero-length rows, page-boundary lengths,
    unmapped tables — and the output must be NaN-free in every case."""
    B, H, KV, hd, P, T = 2, 4, 2, 16, 8, 4
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, T, KV, hd))
    vp = jax.random.normal(ks[2], (P, T, KV, hd))
    pt = jnp.asarray(table, jnp.int32)
    ln = jnp.asarray(lengths)
    out = np.asarray(paged_attention(q, kp, vp, pt, ln, interpret=True))
    expect = np.asarray(ref.paged_attention_ref(q, kp, vp, pt, ln))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)


def test_paged_attention_mixed_batch():
    """Batched multi-sequence tables with very different lengths — the
    per-row page walk must not leak state across grid rows."""
    B, H, KV, hd, P, T, MP = 4, 4, 2, 32, 16, 4, 4
    ks = jax.random.split(jax.random.key(8), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, T, KV, hd))
    vp = jax.random.normal(ks[2], (P, T, KV, hd))
    pt = jnp.asarray([[0, 1, 2, 3],
                      [4, -1, -1, -1],
                      [-1, -1, -1, -1],
                      [5, 6, -1, -1]], jnp.int32)
    ln = jnp.asarray([16, 1, 0, 7])
    out = np.asarray(paged_attention(q, kp, vp, pt, ln, interpret=True))
    expect = np.asarray(ref.paged_attention_ref(q, kp, vp, pt, ln))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)
    # the length-0 row contributes exactly zero, like the ref
    assert np.array_equal(out[2], np.zeros_like(out[2]))


def test_paged_attention_xla_decode_matches_ref():
    """The off-TPU decode fallback (the serve engine's CPU path) agrees
    with the oracle across the same edge geometry the kernel covers."""
    from repro.kernels.paged_attention import paged_attention_xla
    B, H, KV, hd, P, T, MP = 3, 4, 2, 16, 8, 4, 3
    ks = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kp = jax.random.normal(ks[1], (P, T, KV, hd))
    vp = jax.random.normal(ks[2], (P, T, KV, hd))
    pt = jnp.asarray([[0, 1, 2], [3, -1, -1], [-1, -1, -1]], jnp.int32)
    ln = jnp.asarray([12, 3, 0])
    out = np.asarray(paged_attention_xla(q, kp, vp, pt, ln))
    expect = np.asarray(ref.paged_attention_ref(q, kp, vp, pt, ln))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 64, 2, 8, 8, 16), (1, 128, 4, 16, 16, 64)])
def test_ssd_chunked_vs_ref(B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.key(5), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    st = jnp.zeros((B, H, P, N))
    y_ref, s_ref = ref.ssd_ref(xh, dt, A, Bm, Cm, st)
    y, s = ssd_chunked(xh, dt, A, Bm, Cm, st, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)
