"""Batched data path: scalar/batched equivalence + bulk machinery.

The batched engine (read_many/write_many, _evict_many, coalesced chunk
runs, meter_transfer_many) must be a pure performance transform: same
bytes over the same links, bit-identical page contents, and the same
LOGICAL page-table state as the scalar loop.  Physical LMB slot numbers
are not part of the logical state (a burst may recycle its own sources'
slots in a different order than the scalar interleave), so equivalence
here is: per-page tier, per-page onboard slot, LMB placement counts,
owned LMB bytes, metrics counters, metered link bytes — and strictly
FEWER arbiter round-trips.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import system_for
from repro.core.metrics import Metrics
from repro.core.policy import LRU, Clock, CostAwareLRU
from repro.core.pool import OutOfMemory

PAGE = (4, 4)


def make_pair(policy="lru", compress=False, n_pages=24, onboard=8,
              chunk=32, n_expanders=1):
    """Two identically-prepared (system, buffer) twins: every page
    written once, cold pages spilled to the LMB tier."""
    out = []
    for _ in range(2):
        metrics = Metrics()
        system = system_for("d0", host_id="h0", pool_gib=1,
                            page_bytes=4096, n_expanders=n_expanders,
                            metrics=metrics)
        buf = system.buffer(name="eq", device_id="d0", page_shape=PAGE,
                            dtype=jnp.float32, onboard_pages=onboard,
                            lmb_chunk_pages=chunk, policy=policy,
                            compress_lmb=compress, metrics=metrics)
        pages = buf.append_pages(n_pages)
        for p in pages:
            buf.write(p, jnp.full(PAGE, 1.0 + p, jnp.float32))
        out.append((system, buf, metrics))
    return out


def arbiter_bytes(system):
    snap = system.fm.arbiter.snapshot()["tenants"]
    return snap.get("d0", {}).get("bytes_total", 0)


def assert_logical_state_equal(sysA, bufA, mA, sysB, bufB, mB):
    for p, (ea, eb) in enumerate(zip(bufA._pages, bufB._pages)):
        assert ea.tier == eb.tier, f"page {p} tier {ea.tier}!={eb.tier}"
        if ea.tier == "onboard":
            assert ea.slot == eb.slot, f"page {p} onboard slot"
    assert bufA.lmb_placement() == bufB.lmb_placement()
    assert (sysA.host().owned_bytes("d0")
            == sysB.host().owned_bytes("d0"))
    ca, cb = mA.tier("eq", "onboard"), mB.tier("eq", "onboard")
    assert (ca.hits, ca.misses) == (cb.hits, cb.misses)
    la, lb = mA.tier("eq", "lmb"), mB.tier("eq", "lmb")
    assert (la.bytes_in, la.bytes_out) == (lb.bytes_in, lb.bytes_out)
    assert arbiter_bytes(sysA) == arbiter_bytes(sysB)
    bufA.check_invariants()
    bufB.check_invariants()


@pytest.mark.parametrize("policy", ["lru"])
@pytest.mark.parametrize("compress", [False, True])
def test_read_many_equivalence(policy, compress):
    """gather(batch) == [read(p) for p in batch]: contents bit-identical,
    metered bytes identical, logical page table identical, fewer arbiter
    calls — including eviction traffic and duplicate pages.  (LRU only:
    cost-aware's clean-page preference makes the SCALAR interleave evict
    pages faulted earlier in the same gather — see the anti-self-thrash
    test below for that deliberate batched improvement.)"""
    (sysA, bufA, mA), (sysB, bufB, mB) = make_pair(policy, compress)
    batch = list(range(8)) + [2, 0]          # LMB-resident + dups
    calls0 = (sysA.fm.meter_calls(), sysB.fm.meter_calls())
    scalar = jnp.stack([bufA.read(p) for p in batch])
    batched = bufB.read_many(batch)
    scalar_calls = sysA.fm.meter_calls() - calls0[0]
    batched_calls = sysB.fm.meter_calls() - calls0[1]
    assert np.array_equal(np.asarray(scalar), np.asarray(batched))
    assert_logical_state_equal(sysA, bufA, mA, sysB, bufB, mB)
    assert batched_calls < scalar_calls
    # follow-up reads see the same world
    assert np.array_equal(np.asarray(bufA.read(20)),
                          np.asarray(bufB.read(20)))


@pytest.mark.parametrize("compress", [False, True])
def test_write_many_equivalence(compress):
    """write_many == scalar write loop (mixed onboard/LMB/fresh targets,
    duplicate page: last write wins)."""
    (sysA, bufA, mA), (sysB, bufB, mB) = make_pair(compress=compress)
    fresh = bufA.append_pages(2), bufB.append_pages(2)
    targets = [0, 1, 20, fresh[0][0], 0]      # dup of page 0
    datas = [jnp.full(PAGE, 100.0 + i, jnp.float32)
             for i in range(len(targets))]
    for p, d in zip(targets, datas):
        bufA.write(p, d)
    bufB.write_many(targets, jnp.stack(datas))
    assert_logical_state_equal(sysA, bufA, mA, sysB, bufB, mB)
    for p in dict.fromkeys(targets):
        assert np.array_equal(np.asarray(bufA.read(p)),
                              np.asarray(bufB.read(p))), p
    # dup semantics: page 0 holds the LAST value
    assert float(np.asarray(bufB.read(0))[0, 0]) == 100.0 + 4


def test_batched_gather_does_not_self_thrash_cost_policy():
    """Seed misbehavior the batched path fixes: under CostAwareLRU the
    scalar gather interleave prefers CLEAN victims, i.e. the pages it
    just faulted in — a K-page gather could demote its own members
    mid-loop.  Batch victims come from the pre-batch resident set, so a
    gather that fits onboard ends with every member onboard."""
    (sysA, bufA, _), (sysB, bufB, _) = make_pair("cost")
    batch = list(range(8))                    # LMB-resident, == onboard cap
    scalar = jnp.stack([bufA.read(p) for p in batch])
    batched = bufB.read_many(batch)
    assert np.array_equal(np.asarray(scalar), np.asarray(batched))
    assert all(bufB._pages[p].tier == "onboard" for p in batch)
    # the scalar loop re-demoted at least one just-faulted batch member
    assert any(bufA._pages[p].tier == "lmb" for p in batch)
    bufA.check_invariants()
    bufB.check_invariants()


def test_read_many_wave_exceeding_onboard_capacity():
    """A batch larger than the onboard tier thrashes in waves but returns
    every page's correct contents."""
    (_, bufA, _), (sysB, bufB, _) = make_pair(n_pages=24, onboard=4)
    batch = list(range(24))
    scalar = jnp.stack([bufA.read(p) for p in batch])
    batched = bufB.read_many(batch)
    assert np.array_equal(np.asarray(scalar), np.asarray(batched))
    bufB.check_invariants()
    assert sum(1 for e in bufB._pages if e.tier == "onboard") <= 4


def test_write_many_wave_exceeding_onboard_keeps_scalar_dirty_state():
    """Multi-wave write_many: pages evicted by a later wave must end
    (tier='lmb', dirty=False) exactly like the scalar loop — dirty bits
    are applied per wave, before the next wave can evict."""
    (sysA, bufA, mA), (sysB, bufB, mB) = make_pair(
        "cost", n_pages=8, onboard=4, chunk=32)
    datas = [jnp.full(PAGE, 50.0 + p, jnp.float32) for p in range(8)]
    for p in range(8):
        bufA.write(p, datas[p])
    bufB.write_many(list(range(8)), jnp.stack(datas))
    for p in range(8):
        ea, eb = bufA._pages[p], bufB._pages[p]
        assert (ea.tier, ea.dirty) == (eb.tier, eb.dirty), p
        if hasattr(bufB.policy, "_dirty"):
            assert (p in bufA.policy._dirty) == (p in bufB.policy._dirty)
        assert np.array_equal(np.asarray(bufA.read(p)),
                              np.asarray(bufB.read(p))), p
    bufA.check_invariants()
    bufB.check_invariants()


def test_bulk_eviction_one_policy_call_coalesced_writeback():
    """_evict_many(k) demotes k pages with coalesced write-back: arbiter
    sees ONE call for the whole burst, contents survive."""
    metrics = Metrics()
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        metrics=metrics)
    buf = system.buffer(name="bulk", device_id="d0", page_shape=PAGE,
                        onboard_pages=8, lmb_chunk_pages=32,
                        metrics=metrics)
    pages = buf.append_pages(8)
    for p in pages:
        buf.write(p, jnp.full(PAGE, 7.0 + p, jnp.float32))
    calls0 = system.fm.meter_calls()
    freed = buf._evict_many(6)
    assert len(freed) == len(set(freed)) == 6
    assert system.fm.meter_calls() - calls0 == 1      # one burst charge
    assert sum(1 for e in buf._pages if e.tier == "lmb") == 6
    buf._onboard_free.extend(freed)   # what the batch-fault caller does
    buf.check_invariants()
    for p in pages:                                   # contents intact
        assert float(np.asarray(buf.read(p))[0, 0]) == 7.0 + p


@pytest.mark.parametrize("policy_cls", [LRU, Clock, CostAwareLRU])
def test_victims_matches_sequential_selection(policy_cls):
    """policy.victims(k) == k successive victim()+on_remove() picks."""
    a, b = policy_cls(), policy_cls()
    for pol in (a, b):
        for key in range(10):
            pol.on_insert(key)
        pol.on_access(3)
        pol.pin(0)
        if hasattr(pol, "mark_dirty"):
            pol.mark_dirty(1)
            pol.mark_dirty(4)
    bulk = a.victims(5)
    seq = []
    for _ in range(5):
        v = b.victim()
        seq.append(v)
        b.on_remove(v)
    assert bulk == seq
    if policy_cls is not Clock:
        # non-mutating for ordered policies: same picks again.  (Clock's
        # selection legitimately advances ref bits — exactly what the
        # equivalent sequential victim() calls would do.)
        assert a.victims(5) == bulk


def test_evict_many_raises_when_pinned_blocks_batch():
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        metrics=Metrics())
    buf = system.buffer(name="pin", device_id="d0", page_shape=PAGE,
                        onboard_pages=4, lmb_chunk_pages=8,
                        metrics=Metrics())
    pages = buf.append_pages(4)
    for p in pages:
        buf.write(p, jnp.ones(PAGE, jnp.float32))
    for p in pages[:3]:
        buf.pin(p)
    with pytest.raises(OutOfMemory):
        buf._evict_many(2)
    buf.check_invariants()                    # failed batch left no debris


def test_heat_epsilon_flushes_cold_pages():
    """Decayed-cold heat entries are zeroed during batch updates, so
    hottest_pages stops nominating pages that went quiet long ago."""
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        metrics=Metrics())
    buf = system.buffer(name="heat", device_id="d0", page_shape=PAGE,
                        onboard_pages=2, lmb_chunk_pages=8,
                        metrics=Metrics())
    pages = buf.append_pages(10)
    for p in pages:
        buf.write(p, jnp.ones(PAGE, jnp.float32))
    buf.read(0)
    assert buf.page_heat(0) > 0
    # hammer other pages: page 0's heat decays below epsilon and is
    # flushed to EXACTLY zero by the vectorized batch update
    for _ in range(40):
        buf.read_many([4, 5, 6, 7])
    assert buf.page_heat(0) == 0.0
    assert 0 not in buf.hottest_pages(10, min_heat=buf.heat_epsilon)
    hot = buf.hottest_pages(2, min_heat=buf.heat_epsilon)
    assert all(buf.page_heat(h) > 0 for h in hot)


def test_per_expander_free_lists():
    """Free slots are kept per expander: placement-restricted allocation
    pops O(1) from the right list and never crosses homes."""
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        n_expanders=2, metrics=Metrics())
    buf = system.buffer(name="fl", device_id="d0", page_shape=PAGE,
                        onboard_pages=2, lmb_chunk_pages=4,
                        metrics=Metrics())
    pages = buf.append_pages(10)
    for p in pages:
        buf.write(p, jnp.full(PAGE, float(p), jnp.float32))
    lmb_pages = [p for p in pages if buf._pages[p].tier == "lmb"]
    other = 1 if buf.page_expander(lmb_pages[0]) == 0 else 0
    moved = buf.migrate_pages(lmb_pages[:3], other)
    assert moved == 3
    for eid, lst in buf._lmb_free.items():
        for s in lst:
            assert buf._lmb_homes[s // buf._lmb_chunk_pages] == eid
    slot = buf._lmb_slot_alloc(expander_id=other)
    assert buf._lmb_homes[slot // buf._lmb_chunk_pages] == other
    buf._lmb_slot_free(slot)
    buf.check_invariants()
    for p in lmb_pages[:3]:                   # contents survived the move
        assert float(np.asarray(buf.read(p))[0, 0]) == p


def test_migrate_pages_batched_meters_both_links():
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        n_expanders=2, metrics=Metrics())
    buf = system.buffer(name="mig", device_id="d0", page_shape=PAGE,
                        onboard_pages=2, lmb_chunk_pages=4,
                        metrics=Metrics())
    pages = buf.append_pages(10)
    for p in pages:
        buf.write(p, jnp.ones(PAGE, jnp.float32))
    lmb_pages = [p for p in pages if buf._pages[p].tier == "lmb"][:4]
    src = buf.page_expander(lmb_pages[0])
    dst = 1 - src
    calls0 = system.fm.meter_calls()
    before = {e: system.fm._arbiters[e].snapshot()["tenants"]
              .get("d0", {}).get("bytes_total", 0) for e in (0, 1)}
    moved = buf.migrate_pages(lmb_pages, dst)
    after = {e: system.fm._arbiters[e].snapshot()["tenants"]
             .get("d0", {}).get("bytes_total", 0) for e in (0, 1)}
    assert moved == len(lmb_pages)
    assert after[src] - before[src] == moved * buf.lmb_page_bytes
    assert after[dst] - before[dst] == moved * buf.lmb_page_bytes
    # one arbiter round-trip per touched link, not per page
    assert system.fm.meter_calls() - calls0 <= 2


def test_degraded_mode_batched_paths():
    """After total expander loss: never-written pages still batch-read as
    zeros onboard; a batch that would need the LMB tier raises."""
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        metrics=Metrics())
    buf = system.buffer(name="deg", device_id="d0", page_shape=PAGE,
                        onboard_pages=4, lmb_chunk_pages=8,
                        metrics=Metrics())
    pages = buf.append_pages(8)
    for p in pages:
        buf.write(p, jnp.full(PAGE, float(p), jnp.float32))
    system.inject_failure()
    assert buf.degraded
    # pages 4..7 survived onboard; 0..3 were LMB-resident and are gone
    got = buf.read_many(pages[4:])            # pure onboard hits
    assert np.asarray(got)[:, 0, 0].tolist() == [4.0, 5.0, 6.0, 7.0]
    buf.check_invariants()
    with pytest.raises(OutOfMemory):
        buf.read_many(pages[:4])              # needs eviction to dead LMB
    buf.check_invariants()


def test_batch_hits_guarded_from_same_batch_eviction():
    """A batch's onboard hits must survive the batch's own evictions:
    under CostAwareLRU a clean hit page was the preferred victim, and
    read_many returned another page's contents through its stale slot."""
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        metrics=Metrics())
    buf = system.buffer(name="guard", device_id="d0", page_shape=PAGE,
                        onboard_pages=4, policy="cost",
                        lmb_chunk_pages=8, metrics=Metrics())
    pages = buf.append_pages(8)
    for p in pages:
        buf.write(p, jnp.full(PAGE, float(p), jnp.float32))
    # page 0: onboard + CLEAN (re-read), pages 5,6,7 onboard + dirty
    buf.read(0)
    onboard = [p for p in pages if buf._pages[p].tier == "onboard"]
    assert 0 in onboard
    lmb_page = next(p for p in pages if buf._pages[p].tier == "lmb")
    got = buf.read_many([0, lmb_page])
    assert float(np.asarray(got)[0, 0, 0]) == 0.0          # not corrupted
    assert float(np.asarray(got)[1, 0, 0]) == lmb_page
    assert buf._pages[0].tier == "onboard"                 # hit survived
    # the guard is transient: page 0 is evictable again afterwards
    assert 0 not in buf.policy._pinned()
    buf.check_invariants()


def test_migrate_pages_duplicate_ids():
    """Duplicate page ids in one migrate batch move once (the scalar
    loop skipped the repeat because its home had already changed)."""
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        n_expanders=2, metrics=Metrics())
    buf = system.buffer(name="dup", device_id="d0", page_shape=PAGE,
                        onboard_pages=2, lmb_chunk_pages=4,
                        metrics=Metrics())
    pages = buf.append_pages(8)
    for p in pages:
        buf.write(p, jnp.full(PAGE, float(p), jnp.float32))
    lmb_page = next(p for p in pages if buf._pages[p].tier == "lmb")
    dst = 1 - buf.page_expander(lmb_page)
    moved = buf.migrate_pages([lmb_page, lmb_page, lmb_page], dst)
    assert moved == 1
    assert buf.page_expander(lmb_page) == dst
    buf.check_invariants()
    assert float(np.asarray(buf.read(lmb_page))[0, 0]) == lmb_page


def test_pin_many_overflow_raises():
    """pin_many of more pages than the onboard tier raises (the scalar
    loop did too) instead of silently 'pinning' LMB-resident pages."""
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        metrics=Metrics())
    buf = system.buffer(name="pov", device_id="d0", page_shape=PAGE,
                        onboard_pages=2, lmb_chunk_pages=8,
                        metrics=Metrics())
    pages = buf.append_pages(4)
    for p in pages:
        buf.write(p, jnp.ones(PAGE, jnp.float32))
    with pytest.raises(OutOfMemory):
        buf.pin_many(pages)
    buf.check_invariants()
    buf.pin_many(pages[:2])                   # exactly capacity is fine
    assert all(buf._pages[p].tier == "onboard" for p in pages[:2])
    buf.unpin_many(pages[:2])


def test_read_many_under_pin_pressure_waves_through_remainder():
    """Pins shrink the batch-usable capacity, they must not make gather
    raise: the scalar loop thrashed a working set through the unpinned
    remainder one page at a time, so read_many waves at that size."""
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        metrics=Metrics())
    buf = system.buffer(name="pp", device_id="d0", page_shape=PAGE,
                        onboard_pages=4, lmb_chunk_pages=8,
                        metrics=Metrics())
    pages = buf.append_pages(8)
    for p in pages:
        buf.write(p, jnp.full(PAGE, float(p), jnp.float32))
    onboard = [p for p in pages if buf._pages[p].tier == "onboard"]
    lmb = [p for p in pages if buf._pages[p].tier == "lmb"]
    buf.pin_many(onboard[:3])                 # 1 unpinned slot remains
    got = buf.read_many(lmb[:2])              # scalar could; batch must
    assert np.asarray(got)[:, 0, 0].tolist() == [float(p) for p in lmb[:2]]
    buf.check_invariants()
    assert all(buf._pages[p].tier == "onboard" for p in onboard[:3])
    buf.unpin_many(onboard[:3])


def test_read_many_with_pinned_members_in_large_batch():
    """Pinned pages that are MEMBERS of an oversized batch: they hold
    their slots through every wave (the scalar loop read them as plain
    hits), so the gather must succeed and return correct contents."""
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        metrics=Metrics())
    buf = system.buffer(name="pm", device_id="d0", page_shape=PAGE,
                        onboard_pages=10, lmb_chunk_pages=16,
                        metrics=Metrics())
    pages = buf.append_pages(15)
    for p in pages:
        buf.write(p, jnp.full(PAGE, float(p), jnp.float32))
    onboard = [p for p in pages if buf._pages[p].tier == "onboard"]
    buf.pin_many(onboard[:5])
    got = buf.read_many(pages)                # scalar loop succeeded too
    assert np.asarray(got)[:, 0, 0].tolist() == [float(p) for p in pages]
    assert all(buf._pages[p].tier == "onboard" for p in onboard[:5])
    buf.check_invariants()
    buf.unpin_many(onboard[:5])


def test_duplicate_occurrence_recency_matches_scalar():
    """read_many([a, b, a]): the repeat of `a` must bump its recency
    AFTER insertion (scalar order insert-insert-access), so the next
    eviction victim is `b`, not `a`."""
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        metrics=Metrics())
    buf = system.buffer(name="rec", device_id="d0", page_shape=PAGE,
                        onboard_pages=2, lmb_chunk_pages=8,
                        metrics=Metrics())
    pages = buf.append_pages(5)
    for p in pages:
        buf.write(p, jnp.full(PAGE, float(p), jnp.float32))
    a, b = [p for p in pages if buf._pages[p].tier == "lmb"][:2]
    buf.read_many([a, b, a])                  # fills both onboard slots
    buf.read(next(p for p in pages
                  if buf._pages[p].tier == "lmb"))   # forces one eviction
    assert buf._pages[b].tier == "lmb"        # LRU victim was b
    assert buf._pages[a].tier == "onboard"    # the dup access kept a hot
    buf.check_invariants()


def test_kv_append_empty_slab_is_noop():
    from repro.configs.base import get_config
    from repro.serve.kv_cache import PagedKVStore
    cfg = get_config("qwen2-1.5b").reduced()
    system = system_for("tpu0", host_id="h0", pool_gib=1,
                        page_bytes=4096, metrics=Metrics())
    store = PagedKVStore(cfg=cfg, system=system, device_id="tpu0",
                         page_tokens=4, onboard_pages=4)
    sid = store.new_seq()
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim_
    empty = jnp.zeros((L, 2, 0, KV, hd), jnp.dtype(cfg.dtype))
    store.append_tokens(sid, empty)
    assert store.seq(sid).length == 0 and store.seq(sid).pages == []


def test_share_many_and_pin_many():
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        metrics=Metrics())
    buf = system.buffer(name="sp", device_id="d0", page_shape=PAGE,
                        onboard_pages=4, lmb_chunk_pages=8,
                        metrics=Metrics())
    pages = buf.append_pages(6)
    for p in pages:
        buf.write(p, jnp.full(PAGE, float(p), jnp.float32))
    shared = buf.share_many(pages[:3])
    assert shared == pages[:3]
    assert all(buf._pages[p].refcount == 2 for p in shared)
    buf.pin_many(pages[:4])
    assert all(buf._pages[p].tier == "onboard" for p in pages[:4])
    with pytest.raises(OutOfMemory):          # everything onboard pinned
        buf.read(pages[4])
    buf.unpin_many(pages[:4])
    buf.read(pages[4])                        # eviction possible again
    buf.check_invariants()


def test_meter_transfer_many_merges_per_link():
    """LMBHost.meter_transfer_many: one arbiter call per backing
    expander, byte totals unchanged."""
    system = system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                        metrics=Metrics())
    host = system.host()
    a = host.alloc("d0", 1 << 16)
    b = host.alloc("d0", 1 << 16)
    calls0 = system.fm.meter_calls()
    bytes0 = arbiter_bytes(system)
    host.meter_transfer_many("d0", [(4096, a.mmid), (8192, b.mmid)])
    assert system.fm.meter_calls() - calls0 == 1      # single expander
    assert arbiter_bytes(system) - bytes0 == 4096 + 8192
    # unattributed charges (mmid=None) ride the fallback link as their
    # own group; zero-byte charges are dropped
    calls0 = system.fm.meter_calls()
    host.meter_transfer_many("d0", [(4096, None), (0, a.mmid),
                                    (4096, a.mmid)])
    assert system.fm.meter_calls() - calls0 == 2


def test_kv_append_slab_equals_token_loop():
    """One multi-page prefill slab == the same tokens appended one by
    one (the batched planner must land every token in the same page
    cell)."""
    from repro.configs.base import get_config
    from repro.serve.kv_cache import PagedKVStore
    cfg = get_config("qwen2-1.5b").reduced()
    stores = []
    for _ in range(2):
        system = system_for("tpu0", host_id="h0", pool_gib=1,
                            page_bytes=4096, metrics=Metrics())
        stores.append(PagedKVStore(cfg=cfg, system=system,
                                   device_id="tpu0", page_tokens=4,
                                   onboard_pages=8))
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim_
    T = 11                                    # 3 pages, last partial
    rng = np.random.default_rng(0)
    kv = jnp.asarray(rng.standard_normal((L, 2, T, KV, hd)),
                     jnp.dtype(cfg.dtype))
    sa = stores[0].new_seq()
    stores[0].append_tokens(sa, kv)           # one slab
    sb = stores[1].new_seq()
    for t in range(T):                        # token loop
        stores[1].append_tokens(sb, kv[:, :, t:t + 1])
    assert stores[0].seq(sa).length == stores[1].seq(sb).length == T
    assert np.array_equal(np.asarray(stores[0].gather_seq(sa)),
                          np.asarray(stores[1].gather_seq(sb)))
    forked = stores[0].fork(sa)
    assert np.array_equal(np.asarray(stores[0].gather_seq(forked)),
                          np.asarray(stores[0].gather_seq(sa)))
