"""Unit tests: expander / allocator / FM / access control / API."""

import pytest

from repro.core import (BLOCK_BYTES, AccessDenied, DeviceClass, DeviceInfo,
                        Expander, InvalidHandle, LMBError, LMBHost,
                        MediaKind, OutOfMemory, make_default_fabric)


def make_host(pool_gib=1, page_bytes=4096, spare=False):
    fm, exp = make_default_fabric(pool_gib=pool_gib, spare=spare)
    fm.bind_host("h0")
    fm.register_device(DeviceInfo("ssd0", DeviceClass.PCIE))
    fm.register_device(DeviceInfo("gpu0", DeviceClass.PCIE))
    fm.register_device(DeviceInfo("acc0", DeviceClass.CXL, spid=5))
    return LMBHost(fm, "h0", page_bytes=page_bytes), fm, exp


class TestExpander:
    def test_block_grant_release(self):
        exp = Expander([(MediaKind.DRAM, BLOCK_BYTES * 4)])
        g1 = exp.grant_block("h0")
        g2 = exp.grant_block("h0")
        assert g1.block_id != g2.block_id
        assert exp.free_bytes() == BLOCK_BYTES * 2
        exp.release_block(g1.block_id)
        assert exp.free_bytes() == BLOCK_BYTES * 3
        with pytest.raises(InvalidHandle):
            exp.release_block(g1.block_id)

    def test_oom(self):
        exp = Expander([(MediaKind.DRAM, BLOCK_BYTES)])
        exp.grant_block("h0")
        with pytest.raises(OutOfMemory):
            exp.grant_block("h0")

    def test_translate(self):
        exp = Expander([(MediaKind.DRAM, BLOCK_BYTES * 2)])
        g = exp.grant_block("h0")
        dpa = exp.translate(g.block_id, 4096)
        assert dpa == g.dpa_base + 4096
        with pytest.raises(InvalidHandle):
            exp.translate(g.block_id, BLOCK_BYTES)


class TestAPI:
    def test_alloc_free_roundtrip(self):
        host, fm, _ = make_host()
        a = host.alloc("ssd0", 1 << 20)
        assert a.nbytes >= 1 << 20
        assert host.owned_bytes("ssd0") == a.nbytes
        host.free("ssd0", a.mmid)
        assert host.owned_bytes("ssd0") == 0
        # block returned to FM once empty
        assert fm.held_bytes("h0") == 0

    def test_wrong_owner_cannot_free(self):
        host, _, _ = make_host()
        a = host.alloc("ssd0", 4096)
        with pytest.raises((AccessDenied, LMBError)):
            host.free("gpu0", a.mmid)

    def test_share_grants_access(self):
        host, fm, _ = make_host()
        a = host.alloc("ssd0", 8192)
        with pytest.raises(AccessDenied):
            host.check_access("gpu0", a.mmid)
        s = host.share("ssd0", a.mmid, "gpu0")
        assert s.hpa == a.hpa        # zero-copy: same physical region
        host.check_access("gpu0", a.mmid)
        # CXL share path sets SAT + returns the expander DPID
        s2 = host.share("ssd0", a.mmid, "acc0")
        assert s2.dpid is not None
        host.check_access("acc0", a.mmid)

    def test_sharer_free_drops_mapping_only(self):
        host, _, _ = make_host()
        a = host.alloc("ssd0", 4096)
        host.share("ssd0", a.mmid, "gpu0")
        host.free("gpu0", a.mmid)   # sharer drop
        host.check_access("ssd0", a.mmid)    # owner still mapped
        with pytest.raises(AccessDenied):
            host.check_access("gpu0", a.mmid)

    def test_quota(self):
        host, fm, _ = make_host(pool_gib=1)
        fm.set_quota("h0", BLOCK_BYTES)
        host.alloc("ssd0", BLOCK_BYTES // 2)
        with pytest.raises(OutOfMemory):
            host.alloc("ssd0", BLOCK_BYTES)

    def test_pcie_and_cxl_bus_addressing_differ(self):
        """PCIe devices DMA through a distinct identity-mapped IOVA
        window; CXL devices address the region with its HPA."""
        from repro.core.api import HPA_WINDOW_BASE, PCIE_IOVA_BASE
        host, _, _ = make_host()
        a = host.alloc("ssd0", 4096)
        assert a.bus_addr != a.hpa
        assert a.bus_addr - PCIE_IOVA_BASE == a.hpa - HPA_WINDOW_BASE
        c = host.alloc("acc0", 4096)
        assert c.bus_addr == c.hpa
        # the deprecated lmb_pcie_/lmb_cxl_ shims still enforce class
        # membership — covered in tests/test_client.py::test_table2_shims


class TestFailover:
    def test_failure_without_spare_blocks_new_allocs(self):
        host, fm, exp = make_host()
        host.alloc("ssd0", 4096)
        fm.inject_failure()
        assert not fm.healthy
        with pytest.raises(LMBError):
            host.alloc("ssd0", BLOCK_BYTES * 2)

    def test_failover_with_spare_regrants(self):
        host, fm, exp = make_host(spare=True)
        host.alloc("ssd0", 4096)
        held_before = fm.held_bytes("h0")
        fm.inject_failure()
        assert fm.healthy
        assert fm.held_bytes("h0") == held_before
        # journal records the regrant for reconstruction
        ops = [e.op for e in fm.journal]
        assert "fail" in ops and "regrant" in ops

    def test_journal_tracks_lifecycle(self):
        host, fm, _ = make_host()
        a = host.alloc("ssd0", 4096)
        host.free("ssd0", a.mmid)
        ops = [e.op for e in fm.journal]
        assert ops.count("grant") == 1 and ops.count("release") == 1
