"""Transient-fault chaos layer: FaultPlan execution, retry/backoff,
escalation, brownout-aware placement, link flap, fail-stop idempotency,
and repair/re-admission (the degraded-mode EXIT path).

Covers the fault taxonomy end to end on real systems (system_for), plus
the two robustness satellites: ``FabricManager.inject_failure`` must be
idempotent/safe (double-inject and empty-pool are journaled no-ops or
typed errors, never grant corruption), and ``LinkedBuffer.degraded``
must be exit-able — repair restores paging and SAT/IOMMU mappings while
handles freed during the outage stay stale.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FaultEvent, FaultInjector, FaultPlan, InvalidHandle,
                        LMBError, OutOfMemory, RetryPolicy, StaleHandle,
                        system_for)
from repro.core.metrics import Metrics

PAGE = (4, 4)


def one_expander_system():
    return system_for("d0", host_id="h0", pool_gib=1, page_bytes=4096,
                      metrics=Metrics())


# ------------------------------------------------------------- validation
class TestPlanValidation:
    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            FaultEvent(t_s=0.0, kind="gamma_ray")

    def test_negative_time_rejected(self):
        with pytest.raises(ValueError):
            FaultEvent(t_s=-1.0, kind="transient")

    def test_expander_and_domain_exclusive(self):
        with pytest.raises(ValueError):
            FaultEvent(t_s=0.0, kind="transient", expander_id=0,
                       domain="pd0")

    def test_error_rate_bounds(self):
        with pytest.raises(ValueError):
            FaultEvent(t_s=0.0, kind="transient", error_rate=1.5)

    def test_brownout_needs_inflating_factor(self):
        with pytest.raises(ValueError):
            FaultEvent(t_s=0.0, kind="brownout", latency_factor=0.5)

    def test_plan_sorts_events_by_time(self):
        plan = FaultPlan((FaultEvent(t_s=2.0, kind="repair", expander_id=0),
                          FaultEvent(t_s=1.0, kind="fail_stop",
                                     expander_id=0)))
        assert [e.t_s for e in plan.events] == [1.0, 2.0]
        assert len(plan) == 2

    def test_storm_helper(self):
        plan = FaultPlan.storm(t0_s=0.5, duration_s=1.0, error_rate=0.3)
        assert len(plan) == 1
        assert plan.events[0].kind == "transient"

    def test_retry_policy_validation(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_retries=-1)
        with pytest.raises(ValueError):
            RetryPolicy(jitter=1.5)

    def test_backoff_bounded_and_jittered(self):
        pol = RetryPolicy(backoff_base_s=1e-6, backoff_multiplier=2.0,
                          backoff_max_s=1e-4, jitter=0.1)
        # attempt 0 at u=0.5 is exactly the base; cap binds eventually
        assert pol.backoff_s(0, 0.5) == pytest.approx(1e-6)
        assert pol.backoff_s(50, 0.5) == pytest.approx(1e-4)
        lo, hi = pol.backoff_s(3, 0.0), pol.backoff_s(3, 1.0)
        assert lo == pytest.approx(8e-6 * 0.9)
        assert hi == pytest.approx(8e-6 * 1.1)


# ---------------------------------------------------------- zero-fault id
class TestZeroFaultIdentity:
    def test_attached_empty_plan_is_inert(self):
        def run(plan):
            system = one_expander_system()
            if plan is not None:
                system.attach_fault_injector(plan)
            host = system.host()
            a = host.alloc("d0", 1 << 20)
            delays = [host.meter_transfer("d0", 1 << 16, a.mmid)
                      for _ in range(16)]
            system.fm.advance_links(1e-3)
            return delays, dict(system.fm.op_bytes())

        d0, ob0 = run(None)
        d1, ob1 = run(FaultPlan())
        assert d0 == d1
        assert ob0 == ob1
        assert "retry" not in ob1

    def test_bind_refuses_second_fabric(self):
        inj = FaultInjector(FaultPlan())
        s1, s2 = one_expander_system(), one_expander_system()
        s1.fm.attach_fault_injector(inj)
        with pytest.raises(LMBError):
            s2.fm.attach_fault_injector(inj)


# ------------------------------------------------------------- transient
class TestTransientRetry:
    def test_storm_costs_time_and_reconciles_bytes(self):
        system = one_expander_system()
        inj = system.attach_fault_injector(
            FaultPlan.storm(t0_s=0.0, duration_s=10.0, error_rate=0.5),
            seed=3)
        host = system.host()
        a = host.alloc("d0", 1 << 20)
        system.fm.advance_links(1e-9)          # open the error window
        base = None
        for _ in range(32):
            host.meter_transfer("d0", 1 << 16, a.mmid)
        ctr = inj.counters()
        assert ctr["transient_errors"] > 0
        assert ctr["retries"] >= ctr["transient_errors"] * 0  # sane
        assert ctr["retry_delay_s"] > 0.0
        # retransmitted bytes land in the FM's "retry" op class, exactly
        assert system.fm.op_bytes()["retry"] == ctr["retry_bytes"]
        assert system.fm.healthy               # no escalation at rate 0.5

    def test_deterministic_given_seed(self):
        def counters(seed):
            system = one_expander_system()
            inj = system.attach_fault_injector(
                FaultPlan.storm(t0_s=0.0, duration_s=10.0, error_rate=0.5),
                seed=seed)
            host = system.host()
            a = host.alloc("d0", 1 << 20)
            system.fm.advance_links(1e-9)
            for _ in range(32):
                host.meter_transfer("d0", 1 << 16, a.mmid)
            return inj.counters()

        assert counters(11) == counters(11)
        assert counters(11) != counters(12)

    def test_retries_disabled_escalates_to_failover(self):
        system = one_expander_system()
        system.attach_fault_injector(
            FaultPlan.storm(t0_s=0.0, duration_s=10.0, error_rate=1.0),
            retry=RetryPolicy(max_retries=0))
        host = system.host()
        a = host.alloc("d0", 1 << 20)
        system.fm.advance_links(1e-9)
        host.meter_transfer("d0", 1 << 16, a.mmid)   # first error
        assert system.fm.healthy               # deferred to the heartbeat
        system.fm.advance_links(1e-3)          # heartbeat applies it
        assert not system.fm.healthy

    def test_budget_exhaustion_escalates(self):
        system = one_expander_system()
        inj = system.attach_fault_injector(
            FaultPlan.storm(t0_s=0.0, duration_s=10.0, error_rate=1.0),
            retry=RetryPolicy(max_retries=4, link_retry_budget=4))
        host = system.host()
        a = host.alloc("d0", 1 << 20)
        system.fm.advance_links(1e-9)
        host.meter_transfer("d0", 1 << 16, a.mmid)   # burns all 4 budget
        assert inj.counters()["escalations"] == 1
        system.fm.advance_links(1e-3)
        assert not system.fm.healthy

    def test_budget_survives_while_it_lasts(self):
        system = one_expander_system()
        inj = system.attach_fault_injector(
            FaultPlan.storm(t0_s=0.0, duration_s=10.0, error_rate=0.4),
            retry=RetryPolicy(link_retry_budget=10_000), seed=5)
        host = system.host()
        a = host.alloc("d0", 1 << 20)
        system.fm.advance_links(1e-9)
        for _ in range(64):
            host.meter_transfer("d0", 1 << 16, a.mmid)
        system.fm.advance_links(1e-3)
        assert system.fm.healthy
        assert inj.counters()["escalations"] == 0


# ------------------------------------------------------ brownout and flap
class TestBrownoutAndFlap:
    def test_brownout_inflates_delay_for_the_window(self):
        system = one_expander_system()
        inj = system.attach_fault_injector(FaultPlan((
            FaultEvent(t_s=0.0, kind="brownout", duration_s=1.0,
                       latency_factor=5.0),)))
        host = system.host()
        a = host.alloc("d0", 1 << 20)
        system.fm.advance_links(1e-9)
        d_in = host.meter_transfer("d0", 1 << 20, a.mmid)
        system.fm.advance_links(5.0)           # window over
        d_out = host.meter_transfer("d0", 1 << 20, a.mmid)
        assert d_in > d_out
        assert inj.counters()["brownout_delay_s"] > 0.0

    def test_brownout_saturates_placement_view(self):
        system = system_for("d0", host_id="h0", pool_gib=1,
                            page_bytes=4096, n_expanders=2,
                            metrics=Metrics())
        eids = sorted(system.fm.expander_ids)
        inj = system.attach_fault_injector(FaultPlan((
            FaultEvent(t_s=0.0, kind="brownout", duration_s=10.0,
                       latency_factor=4.0, expander_id=eids[0]),)))
        system.fm.advance_links(1e-9)
        assert inj.brownout_active(eids[0])
        # least-loaded (the migration-target query) steers off the brown
        # expander even though its real utilization is identical
        assert system.fm.least_loaded_expander() == eids[1]

    def test_flap_queues_transfers_until_retrained(self):
        system = one_expander_system()
        inj = system.attach_fault_injector(FaultPlan((
            FaultEvent(t_s=0.0, kind="link_flap", retrain_s=0.25),)))
        host = system.host()
        a = host.alloc("d0", 1 << 20)
        system.fm.advance_links(1e-9)
        d = host.meter_transfer("d0", 1 << 10, a.mmid)
        assert d >= 0.25 - 1e-9                # waited out the retrain
        assert inj.counters()["flap_delay_s"] == pytest.approx(
            0.25 - 1e-9, abs=1e-6)
        system.fm.advance_links(1.0)
        assert host.meter_transfer("d0", 1 << 10, a.mmid) < 0.25


# ----------------------------------------- satellite: inject_failure safety
class TestInjectFailureSafety:
    def test_double_inject_is_journaled_noop(self):
        system = system_for("d0", pool_gib=1, n_expanders=2,
                            metrics=Metrics())
        h0 = system.alloc("d0", 4096, expander_id=0)
        system.inject_failure(0)
        state_before = system.fm.placement()
        gen_before = system.host().generation_of(0)
        system.inject_failure(0)               # again: must not corrupt
        assert system.fm.placement() == state_before
        assert system.host().generation_of(0) == gen_before
        noops = [e for e in system.fm.journal if e.op == "fail.noop"]
        assert len(noops) == 1
        assert "expander=0" in noops[0].detail
        assert h0.stale

    def test_default_inject_on_empty_pool_raises(self):
        system = one_expander_system()
        system.inject_failure()
        with pytest.raises(LMBError) as ei:
            system.inject_failure()            # nothing healthy left
        assert "no healthy expander" in str(ei.value)

    def test_explicit_inject_on_empty_pool_noops(self):
        system = one_expander_system()
        eid = system.fm.expander_ids[0]
        system.inject_failure(eid)
        system.inject_failure(eid)             # journaled no-op, no raise
        assert any(e.op == "fail.noop" for e in system.fm.journal)

    def test_unknown_expander_rejected(self):
        system = one_expander_system()
        with pytest.raises(InvalidHandle):
            system.inject_failure(999)


# -------------------------------------------- repair and degraded-mode exit
class TestRepairReadmission:
    def test_readmit_unknown_rejected(self):
        system = one_expander_system()
        with pytest.raises(InvalidHandle):
            system.readmit_expander(999)

    def test_readmit_healthy_rejected(self):
        system = one_expander_system()
        with pytest.raises(LMBError):
            system.readmit_expander(system.fm.expander_ids[0])

    def test_repair_restores_alloc_and_access(self):
        system = one_expander_system()
        eid = system.fm.expander_ids[0]
        system.inject_failure(eid)
        assert not system.fm.healthy
        system.readmit_expander(eid)
        assert system.fm.healthy
        assert any(e.op == "repair" for e in system.fm.journal)
        # the readmitted expander serves fresh grants with live mappings
        h = system.alloc("d0", 4096)
        system.host().check_access("d0", h.mmid)
        h.free()

    def test_stale_handles_stay_stale_after_repair(self):
        """Generations do NOT roll back: a pre-failure capability must
        not resurrect when the (blank) expander rejoins."""
        system = one_expander_system()
        h = system.alloc("d0", 4096)
        eid = system.fm.expander_ids[0]
        system.inject_failure(eid)
        assert h.stale
        system.readmit_expander(eid)
        assert h.stale
        with pytest.raises(StaleHandle):
            h.expander()
        with pytest.raises(StaleHandle):
            h.free()

    def test_buffer_exits_degraded_and_pages_again(self):
        system = one_expander_system()
        buf = system.buffer(name="b", device_id="d0", page_shape=PAGE,
                            onboard_pages=2, lmb_chunk_pages=4,
                            metrics=Metrics())
        pages = buf.append_pages(4)            # spills into the LMB tier
        for p in pages:
            buf.write(p, jnp.full(PAGE, float(p), jnp.float32))
        eid = system.fm.expander_ids[0]
        system.inject_failure(eid)
        assert buf.degraded
        with pytest.raises(OutOfMemory):
            for p in buf.append_pages(4):      # LMB growth refused
                buf.write(p, jnp.ones(PAGE, jnp.float32))
        system.readmit_expander(eid)
        assert not buf.degraded                # the ladder's last rung
        fresh = buf.append_pages(4)            # paging works again
        for p in fresh:
            buf.write(p, jnp.full(PAGE, float(p), jnp.float32))
        got = buf.read_many(fresh)
        assert np.asarray(got)[:, 0, 0].tolist() == [float(p)
                                                     for p in fresh]
        buf.check_invariants()

    def test_closed_buffer_stays_degraded_after_repair(self):
        system = one_expander_system()
        buf = system.buffer(name="c", device_id="d0", page_shape=PAGE,
                            onboard_pages=2, lmb_chunk_pages=4,
                            metrics=Metrics())
        eid = system.fm.expander_ids[0]
        system.inject_failure(eid)
        buf.close()
        system.readmit_expander(eid)
        assert buf.degraded                    # close() is terminal

    def test_scripted_fail_stop_then_repair(self):
        """The same ladder driven entirely by a FaultPlan."""
        system = one_expander_system()
        eid = system.fm.expander_ids[0]
        inj = system.attach_fault_injector(FaultPlan((
            FaultEvent(t_s=1.0, kind="fail_stop", expander_id=eid),
            FaultEvent(t_s=2.0, kind="repair", expander_id=eid))))
        system.fm.advance_links(1.5)
        assert not system.fm.healthy
        system.fm.advance_links(1.0)
        assert system.fm.healthy
        snap = inj.snapshot()
        assert snap["events_fired"] == 2
        # repair refilled the link's fault state
        assert not snap["links"][eid]["escalated"]

    def test_fm_snapshot_carries_fault_state(self):
        system = one_expander_system()
        assert system.fm.snapshot()["faults"] is None
        system.attach_fault_injector(FaultPlan())
        snap = system.fm.snapshot()["faults"]
        assert snap["events_total"] == 0
        assert "counters" in snap
