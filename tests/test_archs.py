"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs; decode == prefill consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs
from repro.models import build_model
from repro.models.flags import Flags

ARCHS = list_configs()


def tiny_batch(cfg, rng, B=2, S=32, with_labels=True):
    tok = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if with_labels:
        batch["labels"] = tok
    if cfg.encoder_decoder:
        batch["src_emb"] = jnp.full((B, S, cfg.d_model), 0.1,
                                    jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    batch = tiny_batch(cfg, rng)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    gnorms = [float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
              for g in jax.tree_util.tree_leaves(grads)]
    assert all(np.isfinite(g) for g in gnorms)
    assert any(g > 0 for g in gnorms)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_shapes(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 32
    batch = tiny_batch(cfg, rng, B, S, with_labels=False)
    cache = model.init_cache(B, S)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = jax.jit(model.decode_step)(params, cache, tok)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    assert int(cache["step"]) == S + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch, rng):
    """prefill(S) + decode(1) == prefill(S+1) at the last position."""
    cfg = get_config(arch).reduced()
    if cfg.num_experts:   # lossless dispatch for the consistency check
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 17
    batch = tiny_batch(cfg, rng, B, S, with_labels=False)
    ref_logits, _ = jax.jit(model.prefill)(
        params, batch, model.init_cache(B, S))
    pre = {k: (v[:, :S - 1] if k == "tokens" else v)
           for k, v in batch.items()}
    _, cache = jax.jit(model.prefill)(params, pre, model.init_cache(B, S))
    dec_logits, _ = jax.jit(model.decode_step)(
        params, cache, batch["tokens"][:, S - 1:S])
    np.testing.assert_allclose(np.asarray(ref_logits),
                               np.asarray(dec_logits),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_cells(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    for cell in cfg.shape_cells():
        specs = model.input_specs(cell)
        assert specs
        for v in specs.values():
            assert all(d > 0 for d in v.shape)
    # long_500k policy matches DESIGN.md §5
    expect_long = cfg.supports_long_context()
    assert ("long_500k" in cfg.shape_cells()) == expect_long


def test_long_context_assignment_is_exactly_documented():
    runs_long = {a for a in ARCHS
                 if "long_500k" in get_config(a).shape_cells()}
    assert runs_long == {"rwkv6-7b", "h2o-danube-3-4b", "mixtral-8x22b",
                         "hymba-1.5b"}


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "rwkv6-7b", "hymba-1.5b"])
def test_unroll_layers_bit_equal(arch, rng):
    cfg = get_config(arch).reduced()
    m0 = build_model(cfg, Flags(remat=False))
    m1 = build_model(cfg, Flags(remat=False, unroll_layers=True,
                                unroll_scans=True))
    params = m0.init(rng)
    batch = tiny_batch(cfg, rng)
    l0 = jax.jit(m0.loss)(params, batch)
    l1 = jax.jit(m1.loss)(params, batch)
    assert float(l0) == pytest.approx(float(l1), abs=1e-6)


def test_param_counts_sane():
    """Analytic param counts within 20% of the nameplate sizes."""
    expect = {"qwen2-1.5b": 1.5e9, "command-r-plus-104b": 104e9,
              "granite-34b": 34e9, "dbrx-132b": 132e9,
              "mixtral-8x22b": 141e9, "rwkv6-7b": 7e9,
              "hymba-1.5b": 1.5e9, "chameleon-34b": 34e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.75 * n <= got <= 1.35 * n, (arch, got / 1e9)
