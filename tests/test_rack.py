"""Rack-scale pool tests: topology paths and failure domains, the
vectorized event core's scalar-regression contract, FM topology wiring
(correlated domain failure), the ``alive=`` failover planner, the
pool-aware placement policy, and the rack observability plumbing."""

import numpy as np
import pytest

from repro.core.placement import (ExpanderView, PlacementRequest,
                                  PoolAwarePolicy)
from repro.core.tiers import TierKind, TierSpec, tier_over_path
from repro.qos.migration import plan_rebalance
from repro.rack.des import simulate_lanes
from repro.rack.topology import PathCost, RackTopology, TopologyError
from repro.sim import (make_ssd_model, make_workload, simulate,
                       simulate_multi_expander, simulate_shared_fabric)
from repro.sim.engine import recovery_fraction
from repro.sim.ssd import make_schemes
from repro.sim.workload import (arrival_times, batch_arrival_times,
                                batch_locality_hits, locality_hits)

N_IOS = 5_000


@pytest.fixture(scope="module")
def gen5():
    spec = make_ssd_model(5)
    return spec, make_schemes(spec)


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------

class TestTopology:
    def test_direct_is_one_hop_zero_latency(self):
        topo = RackTopology.direct((0, 1), ("h0",))
        p = topo.path("h0", 0)
        assert p.hops == 1
        assert p.latency_s == 0.0
        assert p.bandwidth_Bps > 0

    def test_two_tier_same_vs_cross_leaf(self):
        topo = RackTopology.two_tier(2, 2, hosts_per_leaf=1)
        near = topo.path("h0", 0)       # h0 and e0 share leaf 0
        far = topo.path("h0", 2)        # e2 lives under leaf 1
        assert near.hops == 1 and far.hops == 3
        assert far.latency_s > near.latency_s > 0.0

    def test_path_is_symmetric_in_cost_and_cached(self):
        topo = RackTopology.two_tier(2, 2, hosts_per_leaf=1)
        assert topo.path("h0", 3) == topo.path("h0", 3)

    def test_failure_domains_follow_leaves(self):
        topo = RackTopology.two_tier(2, 2, hosts_per_leaf=1)
        assert topo.domain_of(0) == topo.domain_of(1) == "pd0"
        assert topo.domain_of(2) == topo.domain_of(3) == "pd1"
        assert sorted(topo.expanders_in_domain("pd0")) == [0, 1]

    def test_unknown_endpoints_raise(self):
        topo = RackTopology.two_tier(1, 1)
        with pytest.raises(TopologyError):
            topo.path("nope", 0)
        with pytest.raises(TopologyError):
            topo.path("h0", 99)

    def test_tier_over_path_folds_latency_and_bottleneck_bw(self):
        tier = TierSpec(TierKind.LMB_CXL, 190e-9, 30e9)
        path = PathCost(hops=3, latency_s=140e-9, bandwidth_Bps=16e9)
        t = tier_over_path(tier, path)
        assert t.added_latency_s == pytest.approx(330e-9)
        assert t.bandwidth_Bps == 16e9
        # direct attach is the degenerate identity (same bw, 0 ns)
        ident = tier_over_path(tier, PathCost(1, 0.0, 30e9))
        assert ident == tier


# ---------------------------------------------------------------------------
# vectorized event core vs the scalar reference engine
# ---------------------------------------------------------------------------

class TestVectorizedCore:
    @pytest.mark.parametrize("scheme_name",
                             ["ideal", "dftl", "lmb-cxl", "lmb-pcie"])
    @pytest.mark.parametrize("wl_name", ["randread", "seqwrite"])
    def test_simulate_matches_scalar(self, gen5, scheme_name, wl_name):
        """Same seed -> same p50/p99/iops from both engines."""
        spec, schemes = gen5
        wl = make_workload(wl_name, n_ios=N_IOS)
        v = simulate(spec, schemes[scheme_name], wl)
        s = simulate(spec, schemes[scheme_name], wl, engine="scalar")
        assert v.iops == pytest.approx(s.iops, rel=1e-6)
        assert v.mean_lat_us == pytest.approx(s.mean_lat_us, rel=1e-6)
        assert v.p99_lat_us == pytest.approx(s.p99_lat_us, rel=1e-6)
        assert v.index_hit_ratio == s.index_hit_ratio

    def test_simulate_kwargs_match_scalar(self, gen5):
        spec, schemes = gen5
        wl = make_workload("randread", n_ios=N_IOS)
        kw = dict(data_rate_cap_iops=4e5, link_utilization=0.5,
                  extra_index_latency_s=140e-9)
        v = simulate(spec, schemes["lmb-cxl"], wl, **kw)
        s = simulate(spec, schemes["lmb-cxl"], wl, engine="scalar", **kw)
        assert v.p99_lat_us == pytest.approx(s.p99_lat_us, rel=1e-6)
        assert v.iops == pytest.approx(s.iops, rel=1e-6)

    def test_unknown_engine_rejected(self, gen5):
        spec, schemes = gen5
        wl = make_workload("randread", n_ios=100)
        with pytest.raises(ValueError, match="engine"):
            simulate(spec, schemes["ideal"], wl, engine="gpu")

    def test_shared_fabric_matches_scalar(self, gen5):
        spec, schemes = gen5
        wl = make_workload("randread", n_ios=N_IOS)
        v = simulate_shared_fabric(spec, schemes["lmb-cxl"], wl, 6)
        s = simulate_shared_fabric(spec, schemes["lmb-cxl"], wl, 6,
                                   engine="scalar")
        assert v.mean_p99_us == pytest.approx(s.mean_p99_us, rel=1e-6)
        assert v.aggregate_goodput_Bps == pytest.approx(
            s.aggregate_goodput_Bps, rel=1e-6)
        assert v.fairness_jain == pytest.approx(s.fairness_jain, rel=1e-6)

    def test_multi_expander_matches_scalar(self, gen5):
        spec, schemes = gen5
        wl = make_workload("randread", n_ios=N_IOS)
        v = simulate_multi_expander(spec, schemes["lmb-cxl"], wl, 8)
        s = simulate_multi_expander(spec, schemes["lmb-cxl"], wl, 8,
                                    engine="scalar")
        assert v.placement_after == s.placement_after
        assert v.hot_p99_before_us == pytest.approx(s.hot_p99_before_us,
                                                    rel=1e-6)
        assert v.hot_p99_after_us == pytest.approx(s.hot_p99_after_us,
                                                   rel=1e-6)
        assert v.recovery_fraction == pytest.approx(s.recovery_fraction,
                                                    rel=1e-5)

    def test_lanes_match_independent_single_runs(self, gen5):
        """The SoA engine is N independent lanes, not an approximation:
        each lane reproduces its own single-device run exactly."""
        spec, schemes = gen5
        wl = make_workload("zipfread", n_ios=N_IOS)
        seeds = [11, 22, 33]
        lanes = simulate_lanes(spec, schemes["lmb-cxl"], wl, seeds=seeds)
        for i, seed in enumerate(seeds):
            solo = simulate(spec, schemes["lmb-cxl"], wl, seed=seed)
            assert lanes.p99_lat_s[i] * 1e6 == pytest.approx(
                solo.p99_lat_us, rel=1e-6)
            assert lanes.iops[i] == pytest.approx(solo.iops, rel=1e-6)

    def test_heterogeneous_per_lane_conditions(self, gen5):
        """Per-lane caps/utilization/path latencies differ -> each lane
        still matches its scalar twin (the rack pool case)."""
        spec, schemes = gen5
        wl = make_workload("randread", n_ios=2_000)
        caps = [3e5, 6e5, 1e12]   # the huge cap never binds (uncapped)
        utils = [0.0, 0.4, 0.8]
        extras = [0.0, 50e-9, 330e-9]
        lanes = simulate_lanes(
            spec, schemes["lmb-cxl"], wl, seeds=[1, 2, 3],
            data_rate_cap_iops=caps,
            link_utilization=utils, extra_index_latency_s=extras)
        for i in range(3):
            solo = simulate(
                spec, schemes["lmb-cxl"], wl, seed=i + 1,
                engine="scalar",
                data_rate_cap_iops=caps[i],
                link_utilization=utils[i],
                extra_index_latency_s=extras[i])
            assert lanes.p99_lat_s[i] * 1e6 == pytest.approx(
                solo.p99_lat_us, rel=1e-6)


# ---------------------------------------------------------------------------
# FM topology wiring + correlated domain failure
# ---------------------------------------------------------------------------

class TestFabricTopology:
    def _fabric(self, placement=None):
        from repro.core.fabric import make_multi_fabric
        topo = RackTopology.two_tier(2, 2, hosts_per_leaf=1)
        fm, _ = make_multi_fabric(4, pool_gib=4, topology=topo,
                                  placement=placement)
        fm.bind_host("h0")
        return fm, topo

    def test_topology_must_cover_pool(self):
        from repro.core.fabric import make_multi_fabric
        with pytest.raises(Exception):
            # only 2 expanders racked for a 4-expander pool
            make_multi_fabric(4, topology=RackTopology.two_tier(1, 2))

    def test_path_cost_and_domain_queries(self):
        fm, topo = self._fabric()
        assert fm.path_cost("h0", 0).hops == 1
        assert fm.path_cost("h0", 2).hops == 3
        assert fm.domain_of(0) == "pd0" and fm.domain_of(3) == "pd1"
        snap = fm.snapshot()
        assert snap["topology"] is not None
        assert {e["domain"]
                for e in snap["expanders"].values()} == {"pd0", "pd1"}

    def test_path_cost_without_topology_is_direct(self):
        from repro.core.fabric import make_multi_fabric
        fm, _ = make_multi_fabric(2)
        p = fm.path_cost("anyhost", 0)
        assert p.hops == 1 and p.latency_s == 0.0
        assert fm.domain_of(0) is None

    def test_domain_failure_regrants_outside_dead_domain(self):
        fm, topo = self._fabric()
        grants = [fm.request_block("h0", expander_id=e)
                  for e in (0, 0, 1, 2, 3)]
        failed = fm.inject_domain_failure("pd0")
        assert sorted(failed) == [0, 1]
        homes = {fm.expander_of(g.block_id) for g in fm.held_grants("h0")}
        assert homes and homes.isdisjoint({0, 1})
        by_op = fm.journal_stats()["by_op"]
        assert by_op.get("regrant", 0) == 3      # blocks on e0/e0/e1
        assert by_op.get("lost", 0) == 0
        assert by_op.get("fail", 0) == 2         # both leaf expanders
        assert len(fm.held_grants("h0")) == 5

    def test_domain_failure_requires_topology(self):
        from repro.core.fabric import LMBError, make_multi_fabric
        fm, _ = make_multi_fabric(2)
        with pytest.raises(LMBError):
            fm.inject_domain_failure("pd0")

    def test_unknown_domain_rejected(self):
        fm, _ = self._fabric()
        with pytest.raises(TopologyError):
            fm.inject_domain_failure("pd-nope")

    def test_domain_without_pooled_expander_rejected(self):
        from repro.core.fabric import InvalidHandle, make_multi_fabric
        # rack the 2-expander pool on leaf 0 of a 2-leaf topology: pd1
        # exists in the topology but holds no pooled expander
        topo = RackTopology.two_tier(2, 2, hosts_per_leaf=1)
        fm, _ = make_multi_fabric(2, pool_gib=1, topology=topo)
        with pytest.raises(InvalidHandle):
            fm.inject_domain_failure("pd1")

    def test_domain_failure_notifies_listeners_per_expander(self):
        fm, _ = self._fabric()
        fm.request_block("h0", expander_id=0)
        seen = []
        fm.on_failover(seen.append)
        fm.inject_domain_failure("pd0")
        assert sorted(seen) == [0, 1]

    def test_pool_aware_placement_through_fm(self):
        """The policy sees real path costs: every grant from h0 lands
        on h0's own leaf, capacity-balanced across its two expanders."""
        fm, _ = self._fabric(placement="pool-aware")
        homes = [fm.expander_of(fm.request_block("h0").block_id)
                 for _ in range(6)]
        assert set(homes) == {0, 1}
        assert homes.count(0) == homes.count(1)


# ---------------------------------------------------------------------------
# failover planning (plan_rebalance alive=)
# ---------------------------------------------------------------------------

class TestAliveRebalance:
    def test_forced_evacuation_balances_survivors(self):
        place = [d % 4 for d in range(16)]
        out = plan_rebalance([1e9] * 16, place, 4, 30e9, alive=[2, 3])
        assert all(e in (2, 3) for e in out)
        assert out.count(2) == out.count(3) == 8
        # devices already on survivors were not gratuitously moved
        assert all(out[d] == place[d] for d in range(16)
                   if place[d] in (2, 3))

    def test_evacuation_is_heaviest_first_to_least_loaded(self):
        demands = [4e9, 1e9, 1e9]
        out = plan_rebalance(demands, [0, 1, 2], 3, 30e9, alive=[1, 2])
        # the 4 GB/s evacuee goes to the emptier survivor at its turn
        assert out[0] in (1, 2) and out[1] == 1 and out[2] == 2

    def test_no_survivors_raises(self):
        with pytest.raises(ValueError):
            plan_rebalance([1e9], [0], 2, 30e9, alive=[])

    def test_unknown_survivor_raises(self):
        with pytest.raises(ValueError):
            plan_rebalance([1e9], [0], 2, 30e9, alive=[5])

    def test_alive_none_is_previous_behaviour(self):
        place = [0, 0, 1]
        assert plan_rebalance([1e8] * 3, place, 2, 30e9) == place


# ---------------------------------------------------------------------------
# pool-aware placement policy (unit)
# ---------------------------------------------------------------------------

class TestPoolAwarePolicy:
    REQ = PlacementRequest()

    def _view(self, eid, util=0.0, lat=0.0, free=2**30):
        return ExpanderView(eid, free, util, path_latency_s=lat)

    def test_nearest_cool_wins(self):
        pol = PoolAwarePolicy()
        views = [self._view(0, lat=190e-9), self._view(1, lat=50e-9),
                 self._view(2, lat=330e-9)]
        assert pol.choose(self.REQ, views) == 1

    def test_all_hot_degrades_to_least_loaded(self):
        pol = PoolAwarePolicy(hot_threshold=0.5)
        views = [self._view(0, util=0.9, lat=50e-9),
                 self._view(1, util=0.6, lat=330e-9)]
        assert pol.choose(self.REQ, views) == 1

    def test_without_topology_matches_least_loaded(self):
        pol = PoolAwarePolicy()
        views = [self._view(0, util=0.3), self._view(1, util=0.1)]
        assert pol.choose(self.REQ, views) == 1
        assert pol.choose(self.REQ, []) is None


# ---------------------------------------------------------------------------
# satellite: recovery_fraction zero-denominator guard
# ---------------------------------------------------------------------------

class TestRecoveryFraction:
    def test_zero_gap_is_full_recovery(self):
        assert recovery_fraction(50.0, 50.0, 50.0) == 1.0

    def test_negative_gap_is_full_recovery(self):
        # contended p99 landed BELOW baseline (noise): still 1.0, not
        # a negative-denominator blowup
        assert recovery_fraction(40.0, 39.0, 50.0) == 1.0

    def test_clamped_to_unit_interval(self):
        assert recovery_fraction(100.0, 120.0, 50.0) == 0.0
        assert recovery_fraction(100.0, 40.0, 50.0) == 1.0

    def test_partial_recovery(self):
        assert recovery_fraction(100.0, 75.0, 50.0) == pytest.approx(0.5)

    def test_multi_expander_result_uses_guard(self, gen5):
        spec, schemes = gen5
        wl = make_workload("randread", n_ios=1_000)
        # balanced placement: nothing to migrate, gap ~ 0 -> exactly 1.0
        r = simulate_multi_expander(spec, schemes["lmb-cxl"], wl, 2,
                                    placement=[0, 1])
        assert 0.0 <= r.recovery_fraction <= 1.0


# ---------------------------------------------------------------------------
# satellite: workload stream determinism (scalar vs batch)
# ---------------------------------------------------------------------------

class TestWorkloadDeterminism:
    def test_locality_hits_scalar_matches_batch_rows(self):
        seeds = [7, 8, 9]
        batch = batch_locality_hits(512, 0.6, seeds)
        for i, s in enumerate(seeds):
            np.testing.assert_array_equal(batch[i],
                                          locality_hits(512, 0.6, s))

    def test_locality_hits_same_seed_reproduces(self):
        a = locality_hits(256, 0.4, 42)
        np.testing.assert_array_equal(a, locality_hits(256, 0.4, 42))
        assert not np.array_equal(a, locality_hits(256, 0.4, 43))

    def test_all_miss_identical_regardless_of_seed(self):
        np.testing.assert_array_equal(locality_hits(64, 0.0, 1),
                                      locality_hits(64, 0.0, 2))
        assert not batch_locality_hits(64, 0.0, [1, 2]).any()

    def test_arrival_times_scalar_matches_batch_rows(self):
        seeds = [3, 4]
        batch = batch_arrival_times(256, 1e6, seeds)
        for i, s in enumerate(seeds):
            np.testing.assert_array_equal(
                batch[i], arrival_times(256, 1e6, seed=s))

    def test_vector_engine_hit_streams_match_scalar(self, gen5):
        """End to end: a scheme WITH onboard hits produces the same hit
        ratio and latencies through both engines (the hit stream is the
        only stochastic input)."""
        from repro.sim.ssd import Scheme
        spec, schemes = gen5
        base = schemes["lmb-cxl"]
        s = Scheme(base.name, base.t_tier_s, base.write_through_index,
                   onboard_hit_ratio=0.35)
        wl = make_workload("zipfread", n_ios=N_IOS)
        v = simulate(spec, s, wl)
        r = simulate(spec, s, wl, engine="scalar")
        assert v.index_hit_ratio == pytest.approx(r.index_hit_ratio,
                                                  rel=1e-12)
        assert v.p99_lat_us == pytest.approx(r.p99_lat_us, rel=1e-6)


# ---------------------------------------------------------------------------
# satellite: benchmark harness fails fast on unknown scenarios
# ---------------------------------------------------------------------------

class TestBenchmarkCLI:
    def test_unknown_only_lists_available(self, monkeypatch, capsys):
        from benchmarks import run as bench
        monkeypatch.setattr(
            "sys.argv", ["benchmarks.run", "--only", "rack_sweep,nope"])
        with pytest.raises(SystemExit) as exc:
            bench.main()
        assert exc.value.code == 2
        err = capsys.readouterr().err
        assert "unknown scenario(s) ['nope']" in err
        assert "rack_sweep" in err and "fig6" in err

    def test_rack_sweep_registered_with_gates(self):
        from benchmarks import run as bench
        sc = bench.SCENARIOS["rack_sweep"]
        fields = {(g.row, g.field) for g in sc.gates}
        assert ("rack_sweep.failover.gate", "recovery") in fields
        assert ("rack_sweep.speedup.gate", "speedup") in fields
        assert ("rack_sweep.scale.d16", "requests") in fields


# ---------------------------------------------------------------------------
# rack scenarios (smoke at reduced size) + observability plumbing
# ---------------------------------------------------------------------------

class TestRackScenarios:
    def test_hop_cost_monotone(self):
        from repro.rack.scenarios import hop_cost_sweep
        rows = hop_cost_sweep(n_ios=2_000)
        p99s = [r["p99_us"] for r in rows]
        assert p99s == sorted(p99s)
        assert rows[0]["case"] == "direct" and rows[0]["path_ns"] == 0.0

    def test_failover_recovery_gate(self):
        from repro.rack.scenarios import failover_recovery
        fo = failover_recovery(n_ios=2_000)
        assert fo["recovery"] >= 0.9
        assert fo["lost"] == 0 and fo["regranted"] == 8
        assert sorted(fo["failed_expanders"]) == [0, 1]

    def test_placement_face_off_pool_beats_skew(self):
        from repro.rack.scenarios import placement_face_off
        face = placement_face_off(n_ios=2_000)
        assert face["p99_ratio_skew_over_pool"] > 1.1
        assert face["near_fraction_pool_aware"] == 1.0

    def test_domain_spans_flow_to_trace_and_summary(self, tmp_path):
        from repro.obs.export import load_trace, write_chrome_trace
        from repro.obs.trace import SpanTracer
        tr = SpanTracer(enabled=True)
        tr.add("link.xfer", 0.0, 1e-6, op="demand", expander=0,
               nbytes=4096, domain="pd0")
        tr.add("link.xfer", 1e-6, 1e-6, op="demand", expander=2,
               nbytes=8192, domain="pd1")
        tr.add("link.xfer", 2e-6, 1e-6, op="demand", expander=1,
               nbytes=1024)                       # domainless: untagged
        path = str(tmp_path / "t.json")
        write_chrome_trace(tr.spans(), path)
        import json
        doc = json.load(open(path))
        dom_events = [e for e in doc["traceEvents"]
                      if e["pid"] == 3 and e.get("ph") == "X"]
        assert len(dom_events) == 2
        names = {e["args"]["name"] for e in doc["traceEvents"]
                 if e["pid"] == 3 and e["name"] == "thread_name"}
        assert names == {"domain pd0", "domain pd1"}
        # and the CLI summary reports per-domain bytes from either format
        import importlib
        import os
        import sys
        tools = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "tools")
        if tools not in sys.path:
            sys.path.insert(0, tools)
        lmbtrace = importlib.import_module("lmbtrace")
        summ = lmbtrace.summarize(load_trace(path))
        assert summ["domain_bytes"] == {"pd0": 4096, "pd1": 8192}

    def test_fm_meter_transfer_tags_domain(self):
        from repro.core.fabric import make_multi_fabric
        from repro.obs.trace import SpanTracer
        topo = RackTopology.two_tier(2, 1, hosts_per_leaf=1)
        tr = SpanTracer(enabled=True)
        fm, _ = make_multi_fabric(2, pool_gib=1, topology=topo)
        fm.tracer = tr
        fm.bind_host("h0")
        from repro.core.fabric import DeviceClass, DeviceInfo
        fm.register_device(DeviceInfo("devX", DeviceClass.CXL, spid=1))
        g = fm.request_block("h0", expander_id=1)
        fm.meter_transfer("devX", 4096, block_id=g.block_id)
        xfers = [s for s in tr.spans() if s.name == "link.xfer"]
        assert xfers and xfers[-1].args.get("domain") == "pd1"
