"""Activation-constraint helper + metrics accounting."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.metrics import Metrics
from repro.sharding.constraints import activation_mesh, constrain


class TestConstraints:
    def test_noop_without_mesh(self):
        x = jnp.ones((4, 8, 16))
        y = constrain(x, "residual")
        assert y is x

    def test_applies_inside_context(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        x = jnp.ones((4, 8, 16))
        with activation_mesh(mesh):
            y = constrain(x, "residual")
            z = constrain(x, "ffn_hidden")
        # on a 1x1 mesh the constraint is trivially satisfiable
        assert y.shape == x.shape and z.shape == x.shape

    def test_divisibility_degrades_not_crashes(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with activation_mesh(mesh):
            # odd dims that divide nothing still pass through
            out = constrain(jnp.ones((3, 5, 7)), "residual")
        assert out.shape == (3, 5, 7)

    def test_decode_single_token_residual(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with activation_mesh(mesh):
            out = constrain(jnp.ones((2, 1, 16)), "residual")
        assert out.shape == (2, 1, 16)


class TestMetrics:
    def test_hit_ratio_and_moves(self):
        m = Metrics()
        m.record_hit("kv", "onboard")
        m.record_hit("kv", "onboard")
        m.record_miss("kv", "onboard")
        m.record_move("kv", "onboard", "lmb", 4096)
        c = m.tier("kv", "onboard")
        assert c.hit_ratio == pytest.approx(2 / 3)
        assert c.bytes_out == 4096
        assert m.tier("kv", "lmb").bytes_in == 4096
        snap = m.snapshot()
        assert snap["kv"]["onboard"]["hits"] == 2
        m.reset()
        assert m.tier("kv", "onboard").accesses == 0
