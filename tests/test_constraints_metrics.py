"""Activation-constraint helper + metrics accounting."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.metrics import Metrics
from repro.sharding.constraints import activation_mesh, constrain


class TestConstraints:
    def test_noop_without_mesh(self):
        x = jnp.ones((4, 8, 16))
        y = constrain(x, "residual")
        assert y is x

    def test_applies_inside_context(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        x = jnp.ones((4, 8, 16))
        with activation_mesh(mesh):
            y = constrain(x, "residual")
            z = constrain(x, "ffn_hidden")
        # on a 1x1 mesh the constraint is trivially satisfiable
        assert y.shape == x.shape and z.shape == x.shape

    def test_divisibility_degrades_not_crashes(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with activation_mesh(mesh):
            # odd dims that divide nothing still pass through
            out = constrain(jnp.ones((3, 5, 7)), "residual")
        assert out.shape == (3, 5, 7)

    def test_decode_single_token_residual(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with activation_mesh(mesh):
            out = constrain(jnp.ones((2, 1, 16)), "residual")
        assert out.shape == (2, 1, 16)


class TestMetrics:
    def test_hit_ratio_and_moves(self):
        m = Metrics()
        m.record_hit("kv", "onboard")
        m.record_hit("kv", "onboard")
        m.record_miss("kv", "onboard")
        m.record_move("kv", "onboard", "lmb", 4096)
        c = m.tier("kv", "onboard")
        assert c.hit_ratio == pytest.approx(2 / 3)
        assert c.bytes_out == 4096
        assert m.tier("kv", "lmb").bytes_in == 4096
        snap = m.snapshot()
        assert snap["tiers"]["kv"]["onboard"]["hits"] == 2
        m.reset()
        assert m.tier("kv", "onboard").accesses == 0

    def test_hit_miss_record_bytes(self):
        """record_hit/record_miss must credit nbytes (regression: the
        arguments used to be accepted and dropped)."""
        m = Metrics()
        m.record_hit("kv", "onboard", nbytes=4096)
        m.record_miss("kv", "onboard", nbytes=512)
        c = m.tier("kv", "onboard")
        assert c.bytes_hit == 4096
        assert c.bytes_missed == 512
        snap = m.snapshot()["tiers"]["kv"]["onboard"]
        assert snap["bytes_hit"] == 4096
        assert snap["bytes_missed"] == 512

    def test_event_ring_is_bounded(self):
        """Regression: the event log used to be an unbounded list."""
        m = Metrics(max_events=8)
        for i in range(100):
            m.event("dev0", f"alloc mmid={i}")
        assert m.snapshot()["events"] == {
            "count": 8, "capacity": 8, "total": 100}
        # the ring keeps the most recent events
        assert m._events[-1][2] == "alloc mmid=99"

    def test_counters_gauges_histograms(self):
        m = Metrics()
        m.inc("faults")
        m.inc("faults", 2)
        m.gauge("depth", 7.0)
        m.observe("wait_s", 1e-3)
        m.observe("wait_s", 2e-3)
        snap = m.snapshot()
        assert snap["counters"]["faults"] == 3
        assert snap["gauges"]["depth"] == 7.0
        h = snap["histograms"]["wait_s"]
        assert h["count"] == 2
        assert h["min"] == pytest.approx(1e-3)

    def test_merge(self):
        a, b = Metrics(), Metrics()
        a.record_hit("kv", "onboard", nbytes=10)
        b.record_hit("kv", "onboard", nbytes=20)
        b.record_miss("kv", "lmb")
        a.inc("n", 1)
        b.inc("n", 2)
        a.observe("w", 1.0)
        b.observe("w", 3.0)
        b.event("d0", "free mmid=1")
        a.merge(b)
        assert a.tier("kv", "onboard").hits == 2
        assert a.tier("kv", "onboard").bytes_hit == 30
        assert a.tier("kv", "lmb").misses == 1
        snap = a.snapshot()
        assert snap["counters"]["n"] == 3
        assert snap["histograms"]["w"]["count"] == 2
        assert snap["events"]["count"] == 1
