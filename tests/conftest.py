# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# the real (1-device) CPU backend; only launch/dryrun.py forces 512.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def rng():
    return jax.random.key(0)


@pytest.fixture(autouse=True)
def _reset_global_metrics():
    """GLOBAL_METRICS is a process-global counter; without a reset,
    per-test byte/transfer assertions leak across tests."""
    from repro.core.metrics import GLOBAL_METRICS
    GLOBAL_METRICS.reset()
    yield
