"""Training integration: loss decreases, checkpoint/restart, failure
recovery, elastic re-shard, grad compression, optimizer-state offload."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import run as train_run
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import ef_compress_tree, ef_state_init
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.fault import StragglerDetector


def test_loss_decreases():
    out = train_run("qwen2-1.5b", steps=30, global_batch=4, seq_len=64,
                    verbose=False)
    assert out["final_loss"] < out["first_loss"] - 0.1


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([4.0, -3.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=200)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(cfg, g, state, params)
    assert float(loss(params)) < 1e-2


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 7, {"params": tree})
    assert latest_step(str(tmp_path)) == 7
    out, step = restore_checkpoint(str(tmp_path), {"params": tree})
    assert step == 7
    np.testing.assert_array_equal(np.asarray(out["params"]["a"]),
                                  np.asarray(tree["a"]))
    assert out["params"]["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomicity(tmp_path):
    """A .tmp dir (torn write) must be invisible to latest_step."""
    tree = {"a": jnp.zeros((2,))}
    save_checkpoint(str(tmp_path), 1, {"params": tree})
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert latest_step(str(tmp_path)) == 1


def test_async_checkpoint(tmp_path):
    tree = {"a": jnp.ones((128, 128))}
    t = save_checkpoint(str(tmp_path), 3, {"params": tree},
                        async_save=True)
    t.join()
    assert latest_step(str(tmp_path)) == 3


def test_restart_after_failure_resumes_and_matches(tmp_path):
    """Crash at step 12, restart from ckpt 10: final params must equal an
    uninterrupted run (deterministic data + checkpointed state)."""
    kw = dict(steps=20, global_batch=4, seq_len=32, ckpt_every=10,
              verbose=False)
    ref = train_run("qwen2-1.5b", **kw)

    ckpt = str(tmp_path / "ck")
    with pytest.raises(RuntimeError):
        train_run("qwen2-1.5b", ckpt_dir=ckpt, fail_at={12}, **kw)
    assert latest_step(ckpt) == 10
    out = train_run("qwen2-1.5b", ckpt_dir=ckpt, **kw)  # resumes at 10
    for a, b in zip(jax.tree_util.tree_leaves(ref["params"]),
                    jax.tree_util.tree_leaves(out["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)


def test_elastic_restore_onto_new_sharding(tmp_path):
    """Checkpoint restores onto a different device layout (elastic
    re-mesh): values identical regardless of sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 5, {"params": tree})
    sh = {"params": {"w": NamedSharding(mesh, P("data", None))}}
    out, _ = restore_checkpoint(str(tmp_path), {"params": tree},
                                shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(tree["w"]))
    assert out["params"]["w"].sharding == sh["params"]["w"]


def test_grad_compression_error_feedback():
    """int8 EF compression: biased once, unbiased over repetition."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(256,)).astype(np.float32))}
    err = ef_state_init(g)
    total = jnp.zeros_like(g["w"])
    for _ in range(50):
        d, err = ef_compress_tree(g, err)
        total = total + d["w"]
    mean = total / 50
    np.testing.assert_allclose(np.asarray(mean), np.asarray(g["w"]),
                               atol=2e-2)


def test_training_with_compression_still_learns():
    out = train_run("qwen2-1.5b", steps=30, global_batch=4, seq_len=64,
                    compress_grads=True, verbose=False)
    assert out["final_loss"] < out["first_loss"] - 0.1


def test_offloaded_opt_state_matches_onboard():
    a = train_run("qwen2-1.5b", steps=10, global_batch=4, seq_len=32,
                  verbose=False)
    b = train_run("qwen2-1.5b", steps=10, global_batch=4, seq_len=32,
                  offload_opt=True, verbose=False)
    assert a["final_loss"] == pytest.approx(b["final_loss"], abs=1e-5)


def test_straggler_detector_flags_outlier():
    det = StragglerDetector(window=32)
    flagged = [det.observe(0.1) for _ in range(20)]
    assert not any(flagged)
    assert det.observe(1.0)
    assert not det.observe(0.1)


def test_grad_accum_equivalent():
    """grad_accum=2 over the same tokens == one big batch (linear loss)."""
    a = train_run("qwen2-1.5b", steps=5, global_batch=8, seq_len=32,
                  grad_accum=1, verbose=False)
    b = train_run("qwen2-1.5b", steps=5, global_batch=8, seq_len=32,
                  grad_accum=2, verbose=False)
    assert a["final_loss"] == pytest.approx(b["final_loss"], abs=5e-3)
