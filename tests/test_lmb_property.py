"""Property-based tests (hypothesis) on the LMB invariants.

Invariants under arbitrary alloc/free/share interleavings:
  * no double allocation (regions never overlap within a block)
  * owner accounting exact; free returns every byte
  * blocks return to the FM exactly when empty
  * LinkedBuffer: page table consistent, slots never alias, data survives
    arbitrary eviction traffic (read-what-you-wrote)
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (DeviceClass, DeviceInfo, LMBHost, LinkedBuffer,
                        OutOfMemory, make_default_fabric)
from repro.core.metrics import Metrics
from repro.core.policy import LRU, Clock, CostAwareLRU


def fresh_host(page_bytes=4096):
    fm, _ = make_default_fabric(pool_gib=1)
    fm.bind_host("h0")
    fm.register_device(DeviceInfo("dev0", DeviceClass.PCIE))
    fm.register_device(DeviceInfo("dev1", DeviceClass.PCIE))
    return LMBHost(fm, "h0", page_bytes=page_bytes, metrics=Metrics())


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.lists(st.tuples(st.sampled_from(["alloc", "free", "share"]),
                          st.integers(0, 1),          # device
                          st.integers(1, 96 * 1024)),  # size / index seed
                min_size=1, max_size=40))
def test_allocator_invariants(ops):
    host = fresh_host()
    live = {}      # mmid -> (owner, nbytes)
    for op, dev, size in ops:
        device = f"dev{dev}"
        if op == "alloc":
            a = host.alloc(device, size)
            assert a.mmid not in live
            live[a.mmid] = (device, a.nbytes)
        elif op == "free" and live:
            mmid = sorted(live)[size % len(live)]
            owner, _ = live.pop(mmid)
            host.free(owner, mmid)
        elif op == "share" and live:
            mmid = sorted(live)[size % len(live)]
            owner, _ = live[mmid]
            other = "dev1" if owner == "dev0" else "dev0"
            s = host.share(owner, mmid, other)
            assert s.mmid == mmid
        # invariant: owned bytes match live set exactly
        for d in ("dev0", "dev1"):
            expect = sum(n for o, n in live.values() if o == d)
            assert host.owned_bytes(d) == expect
        # regions never overlap: per block, page sets disjoint
        seen = {}
        for r in host.allocator.iter_regions():
            pages = set(range(r.page_start, r.page_start + r.npages))
            prev = seen.setdefault(r.block_id, set())
            assert not (prev & pages), "overlapping regions"
            prev |= pages
    # drain: everything freed -> all blocks returned
    for mmid, (owner, _) in list(live.items()):
        host.free(owner, mmid)
    assert host.allocator.block_count == 0


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.data())
def test_linked_buffer_read_what_you_wrote(data):
    host = fresh_host(page_bytes=256)
    n_onboard = data.draw(st.integers(2, 6))
    policy = data.draw(st.sampled_from(["lru", "clock", "cost"]))
    buf = LinkedBuffer(name="t", device_id="dev0", host=host,
                       page_shape=(4, 4), dtype=jnp.float32,
                       onboard_pages=n_onboard, policy=policy,
                       lmb_chunk_pages=4, metrics=Metrics())
    n_pages = data.draw(st.integers(1, 20))
    buf.append_pages(n_pages)
    shadow = {}
    ops = data.draw(st.lists(
        st.tuples(st.sampled_from(["write", "read", "share_release"]),
                  st.integers(0, n_pages - 1), st.integers(0, 1000)),
        min_size=1, max_size=60))
    for op, p, val in ops:
        if op == "write":
            arr = np.full((4, 4), float(val), np.float32)
            buf.write(p, arr)
            shadow[p] = float(val)
        elif op == "read":
            got = np.asarray(buf.read(p))
            expect = shadow.get(p, 0.0)
            assert np.all(got == expect), (p, expect, got[0, 0])
        else:
            buf.share(p)
            buf.release(p)
        buf.check_invariants()
    for p, val in shadow.items():
        assert float(np.asarray(buf.read(p))[0, 0]) == val


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["insert", "access", "remove"]),
                          st.integers(0, 15)), min_size=1, max_size=80),
       st.sampled_from([LRU, Clock, CostAwareLRU]))
def test_eviction_policy_victim_validity(ops, policy_cls):
    pol = policy_cls()
    present = set()
    for op, key in ops:
        if op == "insert":
            pol.on_insert(key)
            present.add(key)
        elif op == "access":
            pol.on_access(key)
        else:
            if key in present:
                pol.on_remove(key)
                present.discard(key)
        v = pol.victim()
        if present:
            assert v in present, f"{policy_cls.__name__} victim {v}"
        else:
            assert v is None


def test_pinned_pages_never_evicted():
    host = fresh_host(page_bytes=256)
    buf = LinkedBuffer(name="p", device_id="dev0", host=host,
                       page_shape=(2, 2), dtype=jnp.float32,
                       onboard_pages=2, lmb_chunk_pages=4,
                       metrics=Metrics())
    pages = buf.append_pages(4)
    buf.write(0, np.ones((2, 2), np.float32))
    buf.pin(0)
    for p in pages[1:]:
        buf.write(p, np.ones((2, 2), np.float32) * p)
    assert buf._pages[0].tier == "onboard"   # survived the traffic
    buf.unpin(0)
    buf.check_invariants()


def test_onboard_exhaustion_all_pinned():
    host = fresh_host(page_bytes=256)
    buf = LinkedBuffer(name="x", device_id="dev0", host=host,
                       page_shape=(2, 2), dtype=jnp.float32,
                       onboard_pages=2, lmb_chunk_pages=4,
                       metrics=Metrics())
    pages = buf.append_pages(3)
    buf.pin(pages[0])
    buf.pin(pages[1])
    with pytest.raises(OutOfMemory):
        buf.pin(pages[2])


def test_compressed_lmb_tier_roundtrip():
    """int8 page compression on demotion: 4x fewer pool bytes, values
    within quantization tolerance after a spill/fault round trip."""
    import jax.numpy as jnp
    host = fresh_host(page_bytes=256)
    buf = LinkedBuffer(name="c", device_id="dev0", host=host,
                       page_shape=(8, 8), dtype=jnp.float32,
                       onboard_pages=2, lmb_chunk_pages=4,
                       compress_lmb=True, metrics=Metrics())
    pages = buf.append_pages(8)
    rng = np.random.default_rng(0)
    data = {p: rng.normal(size=(8, 8)).astype(np.float32) for p in pages}
    for p in pages:
        buf.write(p, data[p])          # forces spills of earlier pages
    for p in pages:
        got = np.asarray(buf.read(p))
        err = np.abs(got - data[p]).max() / (np.abs(data[p]).max() + 1e-9)
        assert err < 2e-2, (p, err)
    buf.check_invariants()
    # pool footprint: int8 pages -> 1/4 of the fp32 bytes
    assert buf.lmb_page_bytes * 4 == buf.page_bytes
    assert host.owned_bytes("dev0") <= 4 * 256  # one int8 chunk
