"""Multi-expander pooling + hot-page migration (ISSUE 2).

Pins the migration invariants: migrated pages keep their contents
(read-back equality), access-control entries move with the pages
(IOMMU/SAT revoked on the source block, granted on the destination),
and link metering is conserved (a migration charges exactly one page
read on the source link and one page write on the destination link).
Plus: the failover re-grant path replays bandwidth shares onto the
standby's arbiter, and the pooled-fabric simulator shows p99 recovery.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LMBHost, LinkedBuffer, make_default_fabric,
                        system_for)
from repro.core.fabric import DeviceClass, DeviceInfo
from repro.core.pool import BLOCK_ID_STRIDE
from repro.qos import MigrationEngine, MigrationPolicy, plan_rebalance


def make_pooled(n_expanders=2, pool_gib=1, page_bytes=1 << 16):
    """Pooled stack constructed through the client API (LMBSystem)."""
    system = system_for("d0", host_id="h0", n_expanders=n_expanders,
                        pool_gib=pool_gib, page_bytes=page_bytes)
    return system.fm, system.host()


def make_buffer(host, n_pages=12, onboard=2, chunk=4, **kw):
    buf = LinkedBuffer(name="mig", device_id="d0", host=host,
                       page_shape=(8, 8), dtype=jnp.float32,
                       onboard_pages=onboard, lmb_chunk_pages=chunk, **kw)
    pages = buf.append_pages(n_pages)
    for i, p in enumerate(pages):
        buf.write(p, jnp.full((8, 8), float(i + 1)))
    return buf, pages


# ------------------------------------------------------------- placement
def test_pooled_block_ids_never_collide():
    fm, host = make_pooled(n_expanders=3)
    blocks = []
    for eid in range(3):
        a = host.alloc("d0", 4096, expander_id=eid)
        assert host.expander_of(a.mmid) == eid
        blocks.append(host.allocator.region(a.mmid).block_id)
    assert len(set(blocks)) == 3
    for eid, bid in enumerate(blocks):
        assert bid // BLOCK_ID_STRIDE == eid
        assert fm.expander_of(bid) == eid
    assert sum(fm.placement().values()) == 3


def test_placement_prefers_least_loaded_link():
    fm, host = make_pooled(n_expanders=2)
    # heat up expander 0's link, then let an unhinted block grant pick
    # (sub-block allocs reuse granted blocks; placement is per block)
    a0 = host.alloc("d0", 4096, expander_id=0)
    for _ in range(50):
        host.meter_transfer("d0", 1 << 20, mmid=a0.mmid)
    grant = fm.request_block("h0")
    assert grant.expander_id == 1
    assert fm.expander_of(grant.block_id) == 1


# ------------------------------------------------- migration invariants
def test_migration_preserves_contents():
    fm, host = make_pooled()
    buf, pages = make_buffer(host)
    lmb_pages = [p for p in pages if buf.page_expander(p) is not None]
    assert lmb_pages, "working set never spilled"
    src = buf.page_expander(lmb_pages[0])
    dst = 1 - src
    expected = {p: float(p + 1) for p in lmb_pages}
    moved = buf.migrate_pages(lmb_pages, dst)
    assert moved == len(lmb_pages)
    buf.check_invariants()
    for p in lmb_pages:
        assert buf.page_expander(p) == dst
        np.testing.assert_array_equal(
            np.asarray(buf.read(p)), np.full((8, 8), expected[p]))


def test_migration_regrants_iommu_entries():
    fm, host = make_pooled()
    buf, pages = make_buffer(host, n_pages=6, onboard=2, chunk=4)
    lmb_pages = [p for p in pages if buf.page_expander(p) is not None]
    src_blocks = [b for b in fm.snapshot()["held_blocks"]["h0"]
                  if fm.expander_of(b) == 0]
    assert src_blocks and all(
        fm.iommu.check("d0", b, 0) for b in src_blocks)
    moved = buf.migrate_pages(lmb_pages, 1)
    assert moved == len(lmb_pages)
    # source chunks emptied -> allocation freed -> IOMMU revoked on the
    # source block (and, fully drained, the block returned to the FM)
    for b in src_blocks:
        assert not fm.iommu.check("d0", b, 0)
    dst_blocks = [b for b in fm.snapshot()["held_blocks"]["h0"]
                  if fm.expander_of(b) == 1]
    assert dst_blocks and all(
        fm.iommu.check("d0", b, 0) for b in dst_blocks)
    assert fm.placement()[0] == 0 and fm.placement()[1] >= 1


def test_migration_conserves_metered_bytes():
    fm, host = make_pooled()
    buf, pages = make_buffer(host)
    lmb_pages = [p for p in pages if buf.page_expander(p) is not None]

    def metered(eid):
        link = fm.snapshot()["expanders"][eid]["link"]
        return link["tenants"]["d0"]["bytes_total"]

    before = {eid: metered(eid) for eid in (0, 1)}
    moved = buf.migrate_pages(lmb_pages, 1)
    after = {eid: metered(eid) for eid in (0, 1)}
    page_b = buf.lmb_page_bytes
    # one page-read charged to the source link per move ...
    assert after[0] - before[0] == moved * page_b
    # ... one page-write charged to the destination link per move
    assert after[1] - before[1] == moved * page_b
    # and nothing else: total metered delta is exactly 2x payload
    total = sum(after.values()) - sum(before.values())
    assert total == 2 * moved * page_b


def test_migration_stops_cleanly_when_target_full():
    """Destination quota exhaustion mid-batch must not corrupt pages:
    the batch stops early and every unmoved page keeps its contents
    (regression: compressed pages lost their scale on a failed move)."""
    from repro.core.pool import BLOCK_BYTES
    fm, host = make_pooled()
    buf = LinkedBuffer(name="mig", device_id="d0", host=host,
                       page_shape=(8, 8), dtype=jnp.float32,
                       onboard_pages=2, lmb_chunk_pages=4,
                       compress_lmb=True)
    pages = buf.append_pages(12)
    for i, p in enumerate(pages):
        buf.write(p, jnp.full((8, 8), float(i + 1)))
    # quota now exactly covers what's held: any new block is refused
    fm.set_quota("h0", fm.held_bytes("h0"))
    assert fm.held_bytes("h0") < 2 * BLOCK_BYTES
    lmb_pages = [p for p in pages if buf.page_expander(p) is not None]
    moved = buf.migrate_pages(lmb_pages, 1)
    assert moved == 0                      # nothing could move...
    buf.check_invariants()
    for i, p in enumerate(pages):          # ...and nothing was corrupted
        np.testing.assert_allclose(
            np.asarray(buf.read(p)), np.full((8, 8), float(i + 1)),
            rtol=2e-2)
    eng = MigrationEngine(fm)              # engine survives the same case
    eng.register(buf)
    rep = eng.run_once()
    assert rep.pages_moved == 0


def test_last_expander_failure_degrades_and_invalidates():
    """Losing the final healthy expander must still notify consumers:
    the buffer enters degraded mode and sheds the dead pages
    (regression: the no-target early-return skipped the callbacks)."""
    fm, host = make_pooled()
    buf, pages = make_buffer(host)
    lmb_pages = [p for p in pages if buf.page_expander(p) is not None]
    half = lmb_pages[: len(lmb_pages) // 2]
    buf.migrate_pages(half, 1)
    fm.inject_failure(expander_id=0)
    assert fm.healthy and not buf.degraded
    fm.inject_failure(expander_id=1)
    assert not fm.healthy
    assert buf.degraded
    for p in lmb_pages:                    # every LMB page was shed
        assert buf.page_expander(p) is None
    buf.check_invariants()
    # dead capacity is not allocatable: raw Table-2 allocs refuse too
    with pytest.raises(Exception):
        host.alloc("d0", 4096)


def test_failover_purges_stale_access_entries():
    """Re-granting a dead expander's blocks must revoke the old block
    ids' SAT/IOMMU authorizations — access control may not keep vouching
    for blocks that no longer exist (regression)."""
    fm, host = make_pooled()
    buf, _ = make_buffer(host)
    dead_blocks = [b for b in fm.snapshot()["held_blocks"]["h0"]
                   if fm.expander_of(b) == 0]
    assert dead_blocks and all(
        fm.iommu.check("d0", b, 0) for b in dead_blocks)
    fm.inject_failure(expander_id=0)
    for b in dead_blocks:
        assert not fm.iommu.check("d0", b, 0)


def test_parameterless_failure_targets_a_healthy_expander():
    """Cascading inject_failure() calls must fail a LIVE expander each
    time, not re-fail the first (dead) one (regression)."""
    fm, _ = make_pooled()
    fm.inject_failure()
    assert fm.healthy
    fm.inject_failure()                    # must pick the survivor
    assert not fm.healthy
    fails = [j.detail for j in fm.journal if j.op == "fail"]
    assert fails == ["expander=0", "expander=1"]


def test_engine_rejects_foreign_buffer():
    fm_a, _ = make_pooled()
    fm_b, host_b = make_pooled()
    buf_b, _ = make_buffer(host_b)
    eng = MigrationEngine(fm_a)
    with pytest.raises(ValueError):
        eng.register(buf_b)


def test_migration_is_idempotent_toward_target():
    fm, host = make_pooled()
    buf, pages = make_buffer(host)
    lmb_pages = [p for p in pages if buf.page_expander(p) is not None]
    assert buf.migrate_pages(lmb_pages, 1) == len(lmb_pages)
    # already home: second call is a no-op, nothing metered twice
    assert buf.migrate_pages(lmb_pages, 1) == 0
    buf.check_invariants()


def test_heat_ranks_hotter_pages_higher():
    fm, host = make_pooled()
    buf, pages = make_buffer(host, n_pages=8, onboard=2)
    lmb_pages = [p for p in pages if buf.page_expander(p) is not None]
    hot, cold = lmb_pages[0], lmb_pages[-1]
    for _ in range(5):
        buf.read(hot)          # faults in + demotes others: link touches
    assert buf.page_heat(hot) > buf.page_heat(cold)
    ranked = buf.hottest_pages(4, expander_id=0)
    assert cold not in ranked[:1]


# ------------------------------------------------------ MigrationEngine
def test_engine_noop_below_threshold():
    fm, host = make_pooled()
    buf, _ = make_buffer(host, n_pages=4, onboard=4)  # all onboard: idle
    eng = MigrationEngine(fm)
    eng.register(buf)
    rep = eng.run_once()
    assert not rep.triggered and rep.pages_moved == 0
    assert "threshold" in rep.reason


def test_engine_migrates_hot_pages_off_saturated_link():
    fm, host = make_pooled()
    buf, pages = make_buffer(host)
    for _ in range(3):
        for p in pages:
            buf.read(p)                      # thrash expander 0's link
    assert fm.link_utilizations()[0] > 0.7
    eng = MigrationEngine(fm, MigrationPolicy(max_pages_per_round=4))
    eng.register(buf)
    rep = eng.run_once()
    assert rep.triggered
    assert rep.src_expander == 0 and rep.dst_expander == 1
    assert rep.pages_moved == 4
    assert rep.bytes_moved == 4 * buf.lmb_page_bytes
    assert buf.lmb_placement().get(1, 0) == 4
    assert any(j.op == "migrate" for j in fm.journal)
    assert eng.stats()["pages_moved"] == 4
    buf.check_invariants()


# ------------------------------------------------ failover (satellite)
def test_failover_replays_bw_shares_onto_standby():
    fm, _ = make_default_fabric(pool_gib=1, spare=True)
    fm.bind_host("h0")
    fm.register_device(DeviceInfo("d0", DeviceClass.PCIE))
    fm.register_device(DeviceInfo("d1", DeviceClass.PCIE))
    fm.set_bw_share("d0", 3.0, burst_bytes=1 << 20)
    host = LMBHost(fm, "h0", page_bytes=4096)
    host.alloc("d0", 4096)
    fm.inject_failure()
    assert fm.healthy
    spare = fm.snapshot()["expanders"][1]["link"]["tenants"]
    assert spare["d0"]["weight"] == 3.0       # share survived failover
    assert spare["d1"]["weight"] == 1.0
    replays = [j for j in fm.journal
               if j.op == "bw_share" and "replay" in j.detail]
    assert len(replays) == 2
    # post-failover traffic lands on the standby's arbiter
    fm.meter_transfer("d0", 4096,
                      block_id=fm.snapshot()["held_blocks"]["h0"][0])
    spare = fm.snapshot()["expanders"][1]["link"]["tenants"]
    assert spare["d0"]["bytes_total"] == 4096


def test_new_allocations_avoid_failed_expander():
    """After a partial failure, fresh LinkedBuffer growth must land on a
    healthy expander (regression: the host allocator kept free runs in
    the dead expander's blocks and placed new regions there)."""
    fm, host = make_pooled()
    buf, pages = make_buffer(host)          # all chunks homed on 0
    assert set(buf.lmb_placement()) == {0}
    fm.inject_failure(expander_id=0)
    new = buf.append_pages(8)
    for p in new:
        buf.write(p, jnp.full((8, 8), 7.0))
    assert set(buf.lmb_placement()) == {1}  # only the survivor
    buf.check_invariants()
    # and the survivor's arbiter saw the traffic
    link1 = fm.snapshot()["expanders"][1]["link"]["tenants"]["d0"]
    assert link1["bytes_total"] > 0


def test_meter_fallback_prefers_healthy_expander():
    """Unattributed transfers must not vanish into a dead expander's
    frozen arbiter after failover (regression)."""
    fm, _ = make_default_fabric(pool_gib=1, spare=True)
    fm.register_device(DeviceInfo("d0", DeviceClass.PCIE))
    fm.inject_failure()
    fm.meter_transfer("d0", 4096)           # no block attribution
    snap = fm.snapshot()
    assert snap["expanders"][1]["link"]["tenants"]["d0"][
        "bytes_total"] == 4096
    assert snap["link"]["tenants"]["d0"]["bytes_total"] == 4096


def test_failover_regrants_stay_usable_within_quota():
    """The blank replacement blocks the FM re-grants on failover must be
    adoptable by the host allocator: held capacity stays allocatable and
    the quota charge doesn't turn into a permanent leak (regression)."""
    fm, host = make_pooled()
    buf, pages = make_buffer(host)
    held = fm.held_bytes("h0")
    fm.set_quota("h0", held)               # no headroom for NEW blocks
    fm.inject_failure(expander_id=0)
    assert fm.held_bytes("h0") == held     # replacements, not leaks
    new = buf.append_pages(8)
    for p in new:                          # regrow INSIDE the re-grant
        buf.write(p, jnp.full((8, 8), 9.0))
    assert set(buf.lmb_placement()) == {1}
    assert fm.held_bytes("h0") == held
    buf.check_invariants()


def test_partial_failure_only_invalidates_dead_expander_pages():
    fm, host = make_pooled()
    buf, pages = make_buffer(host)
    lmb_pages = [p for p in pages if buf.page_expander(p) is not None]
    half = lmb_pages[: len(lmb_pages) // 2]
    buf.migrate_pages(half, 1)
    fm.inject_failure(expander_id=0)
    assert fm.healthy                          # pool survives
    buf.check_invariants()
    for p in half:                             # survivors keep contents
        assert buf.page_expander(p) == 1
        np.testing.assert_array_equal(
            np.asarray(buf.read(p)), np.full((8, 8), float(p + 1)))
    for p in lmb_pages[len(half):]:            # victims zero-filled
        assert buf.page_expander(p) in (None, 1)


# ------------------------------------------------------- planning + sim
def test_plan_rebalance_never_raises_max_load():
    rng = np.random.default_rng(0)
    for _ in range(25):
        n_dev = int(rng.integers(1, 12))
        n_exp = int(rng.integers(1, 4))
        demands = rng.uniform(1e9, 12e9, n_dev).tolist()
        placement = rng.integers(0, n_exp, n_dev).tolist()
        cap = 30e9

        def max_load(place):
            loads = [0.0] * n_exp
            for d, e in enumerate(place):
                loads[e] += demands[d]
            return max(loads)

        out = plan_rebalance(demands, placement, n_exp, cap)
        assert len(out) == len(placement)
        assert max_load(out) <= max_load(placement) + 1e-6


def test_plan_rebalance_splits_hot_expander():
    out = plan_rebalance([10e9] * 8, [0] * 8, 2, 30e9,
                         saturation_threshold=0.7)
    assert sorted((out.count(0), out.count(1))) == [4, 4]


def test_simulate_multi_expander_p99_recovers():
    from repro.sim import (make_ssd_model, make_workload,
                           simulate_multi_expander)
    from repro.sim.ssd import make_schemes
    spec = make_ssd_model(5)
    scheme = make_schemes(spec)["lmb-cxl"]
    wl = make_workload("randread", n_ios=6_000)
    r = simulate_multi_expander(spec, scheme, wl, 8, n_expanders=2)
    assert r.utilization_before[0] == pytest.approx(1.0)
    assert r.utilization_before[1] == 0.0
    assert max(r.utilization_after) < 1.0      # load actually split
    assert r.migrated_devices > 0 and r.migrated_bytes > 0
    assert r.hot_p99_after_us < r.hot_p99_before_us
    # recovery toward the uncontended baseline (acceptance criterion)
    assert r.recovery_fraction > 0.5
    gap_after = r.hot_p99_after_us - r.baseline_p99_us
    gap_before = r.hot_p99_before_us - r.baseline_p99_us
    assert gap_after < 0.5 * gap_before


def test_simulate_multi_expander_finds_hot_link_anywhere():
    """The hot expander is measured, not assumed to be expander 0
    (regression: placement=[1]*N reported recovery for an idle link)."""
    from repro.sim import (make_ssd_model, make_workload,
                           simulate_multi_expander)
    from repro.sim.ssd import make_schemes
    spec = make_ssd_model(5)
    scheme = make_schemes(spec)["lmb-cxl"]
    wl = make_workload("randread", n_ios=6_000)
    r = simulate_multi_expander(spec, scheme, wl, 8, n_expanders=2,
                                placement=[1] * 8)
    assert r.utilization_before[1] == pytest.approx(1.0)
    assert r.hot_p99_before_us > r.baseline_p99_us
    assert r.hot_p99_after_us < r.hot_p99_before_us
    assert r.recovery_fraction > 0.5
