"""Serving engine: continuous batching, KV paging, preemption, prefix
sharing, capacity exceeding HBM (the LMB thesis applied to serving)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import system_for
from repro.models import build_model
from repro.models.flags import Flags
from repro.serve import EngineConfig, ServeEngine, SubmitSpec
from repro.serve.kv_cache import PagedKVStore


def fresh_system(pool_gib=1):
    """The serve stack is constructed through the client API."""
    return system_for("tpu0", host_id="h0", pool_gib=pool_gib,
                      page_bytes=4096)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen2-1.5b").reduced()
    model = build_model(cfg, Flags(remat=False))
    params = model.init(jax.random.key(0))
    return cfg, model, params


def make_engine(served, **kw):
    cfg, model, params = served
    qos = kw.pop("qos", None)
    clock = kw.pop("clock", None)
    defaults = dict(decode_slots=2, max_seq_len=64, page_tokens=8,
                    onboard_pages=8, prefill_bucket=16)
    defaults.update(kw)
    return ServeEngine(model, params, fresh_system(), EngineConfig(
        **defaults), qos=qos, clock=clock)


def test_requests_complete(served):
    eng = make_engine(served)
    rng = np.random.default_rng(0)
    rids = [eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 12),
                               max_new_tokens=4))
            for _ in range(5)]
    eng.run(200)
    assert all(eng.requests[r].state == "done" for r in rids)
    assert all(len(eng.requests[r].out_tokens) == 4 for r in rids)
    # pooled-fabric placement surfaces in the engine snapshot
    fab = eng.stats()["fabric"]
    assert set(fab) == {"block_placement", "kv_page_placement",
                        "link_utilization", "meter_calls"}
    assert 0 in fab["block_placement"]         # every pool expander listed
    assert all(0.0 <= u <= 1.0 for u in fab["link_utilization"].values())
    assert fab["meter_calls"] >= 0             # arbitration round-trips


def test_deterministic_outputs_vs_direct_decode(served):
    """Engine output == direct prefill+argmax-decode of the same model."""
    cfg, model, params = served
    prompt = np.arange(1, 11, dtype=np.int32)
    eng = make_engine(served)
    rid = eng.submit(SubmitSpec(prompt=prompt, max_new_tokens=4))
    eng.run(100)
    got = eng.requests[rid].out_tokens

    cache = model.init_cache(1, 64)
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(prompt[None])}, cache)
    expect = [int(jnp.argmax(logits[0]))]
    for _ in range(3):
        tok = jnp.asarray([[expect[-1]]], jnp.int32)
        logits, cache = jax.jit(model.decode_step)(params, cache, tok)
        expect.append(int(jnp.argmax(logits[0])))
    assert got == expect


def test_kv_capacity_exceeds_onboard(served):
    """More concurrent KV state than onboard pages: pages spill to the
    LMB tier and requests still complete (paper's capacity thesis)."""
    eng = make_engine(served, decode_slots=4, onboard_pages=4)
    rng = np.random.default_rng(1)
    rids = [eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 20),
                               max_new_tokens=6))
            for _ in range(6)]
    eng.run(400)
    assert all(eng.requests[r].state == "done" for r in rids)
    c = eng.kv.buf.metrics.tier(eng.kv.buf.name, "onboard")
    assert c.misses > 0          # spill traffic actually happened


def test_preemption_and_resume(served):
    eng = make_engine(served, decode_slots=2)
    rng = np.random.default_rng(2)
    r1 = eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 10),
                               max_new_tokens=8))
    r2 = eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 10),
                               max_new_tokens=8))
    eng.step()
    assert eng.requests[r1].state == "active"
    slot = next(s for s, r in eng.active.items() if r.req_id == r1)
    eng.preempt(slot)
    assert eng.requests[r1].state == "preempted"
    eng.run(300)
    assert eng.requests[r1].state == "done"
    assert eng.requests[r2].state == "done"


def test_prefix_fork_zero_copy(served):
    cfg, model, params = served
    system = fresh_system()
    kv = PagedKVStore(cfg=cfg, system=system, device_id="tpu0",
                      page_tokens=4, onboard_pages=4)
    sid = kv.new_seq()
    L, KV_, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim_
    kvdata = jnp.ones((L, 2, 8, KV_, hd), jnp.dtype(cfg.dtype))
    kv.append_tokens(sid, kvdata)
    held = system.host().owned_bytes("tpu0")
    fork = kv.fork(sid)
    assert system.host().owned_bytes("tpu0") == held   # no new LMB bytes
    assert kv.seq(fork).length == kv.seq(sid).length
    # writing to the fork triggers COW, original unchanged
    kv.append_tokens(fork, kvdata * 2)
    a = np.asarray(kv.gather_seq(sid), np.float32)
    assert a.max() == 1.0
    kv.free_seq(fork)
    kv.free_seq(sid)
    kv.buf.check_invariants()


def test_page_table_export(served):
    cfg, *_ = served
    kv = PagedKVStore(cfg=cfg, system=fresh_system(), device_id="tpu0",
                      page_tokens=4, onboard_pages=4)
    sid = kv.new_seq()
    L, KV_, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim_
    kv.append_tokens(sid, jnp.ones((L, 2, 10, KV_, hd),
                                   jnp.dtype(cfg.dtype)))
    pt = kv.page_table(sid, 8)
    assert (pt >= 0).sum() == 3          # ceil(10/4)
    assert (pt[3:] == -1).all()


def test_qos_admission_shed_and_slo_feedback(served):
    """A tenant whose demand blows its own SLO on the shared link is shed;
    a well-provisioned tenant completes and feeds its latency tracker."""
    from repro.qos import AdmissionController, SLOTarget

    ctrl = AdmissionController(link_bandwidth_Bps=10e9)
    ctrl.register("gold", target=SLOTarget(p99_latency_s=10.0),
                  demand_Bps=1e9, base_latency_s=0.01)
    ctrl.register("abuser",
                  target=SLOTarget(p99_latency_s=0.005, shed_factor=1.5),
                  demand_Bps=9.5e9, base_latency_s=0.01)
    eng = make_engine(served, qos=ctrl)
    rng = np.random.default_rng(0)
    gold = eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 8),
                                 max_new_tokens=3, tenant="gold"))
    abuser = eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 8),
                                   max_new_tokens=3, tenant="abuser"))
    eng.run(100)
    assert eng.requests[gold].state == "done"
    assert eng.requests[abuser].state == "shed"
    st = eng.stats()
    assert st["shed"] == 1
    t = st["qos"]["tenants"]
    assert t["abuser"]["shed_count"] == 1
    assert t["gold"]["observed_p99_s"] is not None   # latency fed back
    assert not t["gold"]["admitted"]                 # released on drain


def test_per_tenant_latency_attribution(served):
    """Engine-level tracing: every tenant gets its own TTFT and
    inter-token histograms, and ttft/token spans carry the tenant tag."""
    eng = make_engine(served, trace=True)
    rng = np.random.default_rng(0)
    rids = {}
    for i in range(4):
        tenant = f"t{i % 2}"
        rids.setdefault(tenant, []).append(
            eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 12),
                                  max_new_tokens=4, tenant=tenant)))
    eng.run(200)
    st = eng.stats()
    for tenant, ids in rids.items():
        assert all(eng.requests[r].state == "done" for r in ids)
        ttft = st["latency"][f"serve.ttft.{tenant}"]
        itl = st["latency"][f"serve.itl.{tenant}"]
        assert ttft["count"] == len(ids)          # one TTFT per request
        # 4 new tokens -> first is TTFT, the other 3 are gaps
        assert itl["count"] == 3 * len(ids)
        assert 0 < ttft["p50"] <= ttft["p99"]
        assert 0 < itl["p50"] <= itl["p99"]
    # the span stream attributes the same events per tenant
    spans = eng.trace.spans()
    assert any(s.name == "serve.round" for s in spans)
    for tenant, ids in rids.items():
        ttft_spans = [s for s in spans
                      if s.name == "ttft" and s.tenant == tenant]
        tok_spans = [s for s in spans
                     if s.name == "token" and s.tenant == tenant]
        assert len(ttft_spans) == len(ids)
        assert len(tok_spans) == 3 * len(ids)
        assert {s.args["req"] for s in ttft_spans} == set(ids)
    assert st["trace"]["enabled"] and st["trace"]["count"] == len(spans)


def test_deadline_expires_waiting_request(served):
    """A queued request whose deadline passes is cancelled in place —
    never seated, never prefilled, counted in engine stats."""
    from repro.serve import VirtualClock

    clock = VirtualClock()
    eng = make_engine(served, decode_slots=1, clock=clock)
    rng = np.random.default_rng(0)
    r1 = eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 10),
                               max_new_tokens=8))
    r2 = eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 10),
                               max_new_tokens=4, deadline_s=0.5))
    eng.step()                       # r1 takes the only slot
    assert eng.requests[r2].state == "waiting"
    clock.advance(1.0)               # past r2's deadline
    eng.step()
    req = eng.requests[r2]
    assert req.state == "cancelled" and req.cancel_reason == "deadline"
    assert req.seq_id is None        # nothing was ever allocated for it
    eng.run(200)
    assert eng.requests[r1].state == "done"
    st = eng.stats()
    assert st["cancelled"] == 1 and st["done"] == 1


def test_deadline_cancels_active_mid_flight(served):
    """An ACTIVE request past its deadline is pulled out of its decode
    slot and its KV sequence freed mid-flight."""
    from repro.serve import VirtualClock

    clock = VirtualClock()
    eng = make_engine(served, decode_slots=1, clock=clock)
    rng = np.random.default_rng(1)
    rid = eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 10),
                                max_new_tokens=64, deadline_s=0.5))
    eng.step()
    req = eng.requests[rid]
    assert req.state == "active" and req.seq_id is not None
    clock.advance(1.0)
    eng.step()
    assert req.state == "cancelled" and req.cancel_reason == "deadline"
    assert req.seq_id is None        # KV freed mid-flight
    assert not eng.active            # slot returned
    assert len(eng._slot_free) == 1
    eng.kv.buf.check_invariants()


def test_cancellation_counted_per_tenant_slo(served):
    """Deadline cancellations land in the tenant's SLO record."""
    from repro.qos import AdmissionController, SLOTarget
    from repro.serve import VirtualClock

    ctrl = AdmissionController(link_bandwidth_Bps=10e9)
    ctrl.register("gold", target=SLOTarget(p99_latency_s=100.0),
                  demand_Bps=1e6, base_latency_s=0.01)
    clock = VirtualClock()
    eng = make_engine(served, decode_slots=1, qos=ctrl, clock=clock)
    rng = np.random.default_rng(2)
    blocker = eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 10),
                                    max_new_tokens=8, tenant="gold"))
    doomed = eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 10),
                                   max_new_tokens=4, tenant="gold",
                                   deadline_s=0.25))
    eng.step()
    clock.advance(1.0)
    eng.run(200)
    assert eng.requests[blocker].state == "done"
    assert eng.requests[doomed].state == "cancelled"
    snap = eng.stats()["qos"]["tenants"]["gold"]
    assert snap["cancelled_count"] == 1
    assert not snap["admitted"]      # demand released after the cancel


def test_throttle_preserves_fifo_and_cannot_starve(served):
    """Satellite regression: a throttled request returns to the FRONT of
    the queue in arrival order (no tail-requeue reordering), and a
    permanently-throttled tenant cannot starve later arrivals — its
    deadline bounds the retries."""
    from repro.qos.slo import Decision
    from repro.serve import VirtualClock

    class AlwaysThrottle:
        """Throttles one tenant forever, admits everyone else."""

        def __init__(self, victim):
            self.victim = victim

        def decide(self, tenant):
            return (Decision.THROTTLE if tenant == self.victim
                    else Decision.ADMIT)

        def observe(self, tenant, latency_s):
            pass

        def release(self, tenant):
            pass

        def record_cancel(self, tenant):
            pass

        def snapshot(self):
            return {}

    clock = VirtualClock()
    eng = make_engine(served, decode_slots=1,
                      qos=AlwaysThrottle("starved"), clock=clock)
    rng = np.random.default_rng(3)
    bad = eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 10),
                                max_new_tokens=4, tenant="starved",
                                deadline_s=2.0))
    g1 = eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 10),
                               max_new_tokens=4, tenant="good"))
    g2 = eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 10),
                               max_new_tokens=4, tenant="good"))
    eng.step()
    # bad was throttled, g1 took the slot; FIFO arrival order holds in
    # the queue: the throttled request is still AHEAD of g2
    assert [r.req_id for r in eng.waiting] == [bad, g2]
    for _ in range(30):
        if not (eng.waiting or eng.active):
            break
        eng.step()
        clock.advance(0.1)
    # both good requests completed despite the ever-throttled head-of-line
    assert eng.requests[g1].state == "done"
    assert eng.requests[g2].state == "done"
    # and the starved tenant's request died at its deadline, not forever
    assert eng.requests[bad].state == "cancelled"
    assert eng.requests[bad].cancel_reason == "deadline"


def test_capacity_cancel_when_pool_degrades_mid_run(served):
    """Expander failure mid-run: the engine cancels what no longer fits
    (reason='capacity') instead of crashing, and still drains."""
    cfg, model, params = served
    system = fresh_system()
    eng = ServeEngine(model, params, system, EngineConfig(
        decode_slots=4, max_seq_len=64, page_tokens=8,
        onboard_pages=4, prefill_bucket=16))
    rng = np.random.default_rng(4)
    rids = [eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 20),
                                  max_new_tokens=8))
            for _ in range(6)]
    eng.step()
    system.inject_failure()          # the only expander dies, no spare
    eng.run(400)                     # must not raise
    states = {eng.requests[r].state for r in rids}
    assert states <= {"done", "cancelled"}
    cancelled = [r for r in rids
                 if eng.requests[r].state == "cancelled"]
    assert cancelled                 # the degraded pool lost real work
    assert all(eng.requests[r].cancel_reason == "capacity"
               for r in cancelled)
    assert eng.stats()["cancelled"] == len(cancelled)


def test_tracing_off_by_default(served):
    """EngineConfig.trace=False must leave the engine on the disabled
    global tracer and record nothing."""
    eng = make_engine(served)
    rng = np.random.default_rng(0)
    eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 8),
                          max_new_tokens=2))
    eng.run(50)
    assert not eng.trace.enabled
    assert len(eng.trace.spans()) == 0
    # per-tenant histograms still collect (cheap, always on)
    assert eng.stats()["latency"]["serve.ttft.default"]["count"] == 1


def test_page_table_overflow_raises(served):
    """Regression: a sequence outgrowing its page table used to be
    silently truncated (numpy slice clamping dropped the tail pages) —
    attention would read garbage for every token past the table edge.
    Both the scalar and the batched export must raise instead."""
    cfg, *_ = served
    kv = PagedKVStore(cfg=cfg, system=fresh_system(), device_id="tpu0",
                      page_tokens=4, onboard_pages=4)
    sid = kv.new_seq()
    L, KV_, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim_
    kv.append_tokens(sid, jnp.ones((L, 2, 10, KV_, hd),
                                   jnp.dtype(cfg.dtype)))   # 3 pages
    with pytest.raises(ValueError, match="exceed"):
        kv.page_table(sid, 2)
    with pytest.raises(ValueError, match="exceed"):
        kv.page_tables([sid], 2)
    # exact fit and slack are both fine
    assert (kv.page_table(sid, 3) >= 0).all()
    tables, lengths = kv.page_tables([sid], 5)
    assert tables.shape == (1, 5)
    assert (tables[0, :3] >= 0).all() and (tables[0, 3:] == -1).all()
    assert lengths[0] == 10


def test_gather_seq_trims_to_length(served):
    """Regression: gather_seq used to return n_pages*page_tokens tokens
    with an uninitialized tail and no valid-length signal; it must trim
    to the sequence's true length."""
    cfg, *_ = served
    kv = PagedKVStore(cfg=cfg, system=fresh_system(), device_id="tpu0",
                      page_tokens=4, onboard_pages=4)
    sid = kv.new_seq()
    L, KV_, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim_
    data = jnp.arange(L * 2 * 10 * KV_ * hd, dtype=jnp.dtype(cfg.dtype)) \
        .reshape(L, 2, 10, KV_, hd)
    kv.append_tokens(sid, data)
    got = kv.gather_seq(sid)
    assert got.shape == (L, 2, 10, KV_, hd)      # not padded to 12
    np.testing.assert_array_equal(np.asarray(got), np.asarray(data))


def test_paged_decode_serves_identical_tokens(served):
    """The tentpole contract: with paged_decode on (the default), every
    decode round runs ONE batched paged-attention step against the
    paged pool, and the emitted token streams are byte-identical to the
    dense slot-cache path."""
    from repro.kernels import ops as kops

    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, 100, n).astype(np.int32)
               for n in (5, 13, 20, 9, 17)]

    def serve(paged):
        eng = make_engine(served, paged_decode=paged, trace=paged)
        rids = [eng.submit(SubmitSpec(prompt=p, max_new_tokens=6))
                for p in prompts]
        eng.run(300)
        toks = [eng.requests[r].out_tokens for r in rids]
        assert all(eng.requests[r].state == "done" for r in rids)
        return toks, eng

    dense_toks, dense_eng = serve(False)
    before = kops.paged_attention_decode_traces()
    paged_toks, paged_eng = serve(True)
    assert paged_toks == dense_toks              # byte-identical streams
    # the paged kernel path actually served the rounds
    assert dense_eng.paged_rounds == 0
    assert paged_eng.paged_rounds > 0
    assert kops.paged_attention_decode_traces() > before
    assert paged_eng.stats()["decode_path"] == "paged"
    assert dense_eng.stats()["decode_path"] == "dense"
    # ...and left its span in the trace
    names = [s.name for s in paged_eng.trace.spans()]
    assert "decode.paged" in names
    # the dense handoff cache is retired on the paged path
    assert all(r._cache is None for r in paged_eng.requests.values())


def test_paged_decode_spills_past_onboard(served):
    """Paged decode with a working set far beyond the onboard tier: the
    DecodeView's coalesced read bursts wave through onboard capacity and
    requests still complete (the capacity thesis on the new data path)."""
    eng = make_engine(served, decode_slots=4, onboard_pages=4)
    assert eng._use_paged
    rng = np.random.default_rng(8)
    rids = [eng.submit(SubmitSpec(prompt=rng.integers(0, 100, 20),
                                  max_new_tokens=6))
            for _ in range(6)]
    eng.run(400)
    assert all(eng.requests[r].state == "done" for r in rids)
    c = eng.kv.buf.metrics.tier(eng.kv.buf.name, "onboard")
    assert c.misses > 0              # spill traffic actually happened
